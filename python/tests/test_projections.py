"""Projection kernels vs explicit phi_q/phi_k matrices, and Alg. 2 vs Alg. 1.

These are the core correctness signals for the paper's mechanism:
* the fast per-token projections equal the explicit (slow) matrices,
* Algorithm 2 == Algorithm 1 exactly for the factorizable methods,
* Algorithm 2 ~= Algorithm 1 to Fourier tolerance for SE(2) Fourier,
* the Pallas projection kernels match the jnp fallbacks.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, rope as rope_mod, se2_fourier as se2f

SCALES = (1.0, 0.5)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _poses(rng, n, rmax=2.0):
    return jnp.asarray(
        np.column_stack([
            rng.uniform(-rmax, rmax, n),
            rng.uniform(-rmax, rmax, n),
            rng.uniform(-np.pi, np.pi, n),
        ]),
        jnp.float32,
    )


@pytest.fixture
def rng():
    return np.random.default_rng(42)


# --------------------------------------------------------------------------
# fast projections == explicit matrices
# --------------------------------------------------------------------------

def test_rope2d_projection_matches_matrix(rng):
    n, d = 8, 8
    x = _rand(rng, n, d)
    pose = _poses(rng, n)
    scales = rope_mod.block_scales(d, 4, SCALES)
    fast_q = rope_mod.rope2d_project(x, pose, scales)
    mat = ref.phi_q_mat_rope2d(pose, d, SCALES)
    slow_q = jnp.einsum("ndc,nd->nc", mat, x)
    np.testing.assert_allclose(fast_q, slow_q, atol=1e-5)
    mat_k = ref.phi_k_mat_rope2d(pose, d, SCALES)
    slow_k = jnp.einsum("ncd,nd->nc", mat_k, x)
    # For RoPE, phi_q^T x == phi_k x (both rotate by +a p)
    np.testing.assert_allclose(fast_q, slow_k, atol=1e-5)


def test_se2rep_projection_matches_matrix(rng):
    n, d = 8, 9
    x = _rand(rng, n, d)
    pose = _poses(rng, n)
    scales = rope_mod.block_scales(d, 3, SCALES)
    fast_q = rope_mod.se2rep_project_q(x, pose, scales)
    mat_q = ref.phi_q_mat_se2rep(pose, d, SCALES)
    np.testing.assert_allclose(
        fast_q, jnp.einsum("ndc,nd->nc", mat_q, x), atol=1e-5
    )
    fast_k = rope_mod.se2rep_project_k(x, pose, scales)
    mat_k = ref.phi_k_mat_se2rep(pose, d, SCALES)
    np.testing.assert_allclose(
        fast_k, jnp.einsum("ncd,nd->nc", mat_k, x), atol=1e-5
    )
    fast_o = rope_mod.se2rep_unproject_o(x, pose, scales)
    np.testing.assert_allclose(
        fast_o, jnp.einsum("ndc,nc->nd", mat_q, x), atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(f=st.integers(4, 20), seed=st.integers(0, 10_000))
def test_se2fourier_projection_matches_matrix(f, seed):
    rng = np.random.default_rng(seed)
    n, d = 6, 12
    q = _rand(rng, n, d)
    pose = _poses(rng, n)
    scales = se2f.scales_for(d, SCALES)
    c = (4 * f + 2) * (d // 6)
    pref = (c / d) ** 0.25
    mat_q = ref.phi_q_mat_se2fourier(pose, d, SCALES, f)
    np.testing.assert_allclose(
        se2f.project_q_jnp(q, pose, scales, f, pref),
        pref * jnp.einsum("ndc,nd->nc", mat_q, q),
        atol=1e-4,
    )
    mat_k = ref.phi_k_mat_se2fourier(pose, d, SCALES, f)
    np.testing.assert_allclose(
        se2f.project_k_jnp(q, pose, scales, f, pref),
        pref * jnp.einsum("ncd,nd->nc", mat_k, q),
        atol=1e-4,
    )
    ot = _rand(rng, n, c)
    np.testing.assert_allclose(
        se2f.unproject_o_jnp(ot, pose, scales, f),
        jnp.einsum("ndc,nc->nd", mat_q, ot),
        atol=1e-4,
    )


# --------------------------------------------------------------------------
# Pallas kernels == jnp fallbacks
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(
    f=st.sampled_from([6, 12, 18]),
    n=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 1000),
)
def test_pallas_projections_match_jnp(f, n, seed):
    rng = np.random.default_rng(seed)
    d = 12
    q = _rand(rng, n, d)
    pose = _poses(rng, n)
    scales = se2f.scales_for(d, SCALES)
    np.testing.assert_allclose(
        se2f.project_q_pallas(q, pose, scales, f, 1.3),
        se2f.project_q_jnp(q, pose, scales, f, 1.3),
        atol=1e-5,
    )
    np.testing.assert_allclose(
        se2f.project_k_pallas(q, pose, scales, f, 1.3),
        se2f.project_k_jnp(q, pose, scales, f, 1.3),
        atol=1e-5,
    )
    ot = _rand(rng, n, (4 * f + 2) * (d // 6))
    np.testing.assert_allclose(
        se2f.unproject_o_pallas(ot, pose, scales, f),
        se2f.unproject_o_jnp(ot, pose, scales, f),
        atol=1e-5,
    )


# --------------------------------------------------------------------------
# Algorithm 2 == Algorithm 1
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["rope2d", "se2rep"])
def test_alg2_equals_alg1_exact_methods(rng, method):
    n, m, d = 10, 14, 12
    q, k, v = _rand(rng, n, d), _rand(rng, m, d), _rand(rng, m, d)
    pq, pk = _poses(rng, n), _poses(rng, m)
    o1 = ref.algorithm1(q, k, v, pq, pk, method, SCALES)
    o2 = ref.algorithm2_explicit(q, k, v, pq, pk, method, SCALES)
    np.testing.assert_allclose(o1, o2, atol=1e-4)


@pytest.mark.parametrize("f,tol", [(8, 1.5e-1), (14, 8e-3), (20, 1e-3)])
def test_alg2_converges_to_alg1_fourier(rng, f, tol):
    """The linear-memory SE(2) Fourier attention converges to the quadratic
    oracle as F grows (paper Sec. IV-A)."""
    n, m, d = 10, 14, 12
    q, k, v = _rand(rng, n, d), _rand(rng, m, d), _rand(rng, m, d)
    pq, pk = _poses(rng, n), _poses(rng, m)
    o1 = ref.algorithm1(q, k, v, pq, pk, "se2fourier", SCALES)
    o2 = ref.algorithm2_explicit(q, k, v, pq, pk, "se2fourier", SCALES, f=f)
    assert float(jnp.max(jnp.abs(o1 - o2))) < tol


def test_alg1_with_mask(rng):
    """Masked Alg. 1 == masked Alg. 2 for exact methods."""
    n, m, d = 8, 8, 8
    q, k, v = _rand(rng, n, d), _rand(rng, m, d), _rand(rng, m, d)
    pq, pk = _poses(rng, n), _poses(rng, m)
    tq = jnp.asarray(np.random.default_rng(0).integers(0, 3, n), jnp.int32)
    tk = jnp.asarray(np.random.default_rng(1).integers(0, 3, m), jnp.int32)
    mask = tq[:, None] >= tk[None, :]
    o1 = ref.algorithm1(q, k, v, pq, pk, "rope2d", SCALES, mask=mask)
    o2 = ref.algorithm2_explicit(
        q, k, v, pq, pk, "rope2d", SCALES, mask=mask
    )
    np.testing.assert_allclose(o1, o2, atol=1e-4)
