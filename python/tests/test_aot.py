"""AOT pipeline smoke tests: HLO text emission + manifest correctness.

Uses the tiny TEST_CONFIG so lowering stays fast; the full DEFAULT_CONFIG
artifacts are produced by ``make artifacts`` and exercised by the Rust
integration tests.
"""

import json
import os

import pytest

from compile import aot
from compile.config import TEST_CONFIG


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_all(out, TEST_CONFIG, methods=("se2fourier",))
    return out


def test_artifacts_exist(artifact_dir):
    for name in ("init", "flash_sdpa", "fwd_se2fourier",
                 "train_step_se2fourier", "decode_se2fourier",
                 "attn_se2fourier"):
        assert os.path.exists(os.path.join(artifact_dir, f"{name}.hlo.txt"))
        assert os.path.exists(
            os.path.join(artifact_dir, f"{name}.manifest.json"))
    assert os.path.exists(os.path.join(artifact_dir, "index.json"))


def test_hlo_text_is_parseable_module(artifact_dir):
    text = open(os.path.join(artifact_dir, "fwd_se2fourier.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_shapes(artifact_dir):
    cfg = TEST_CONFIG
    man = json.load(
        open(os.path.join(artifact_dir, "fwd_se2fourier.manifest.json")))
    by_name = {e["name"]: e for e in man["inputs"]}
    assert by_name["feat"]["shape"] == [cfg.batch_size, cfg.n_tokens,
                                        cfg.feat_dim]
    assert by_name["pose"]["shape"] == [cfg.batch_size, cfg.n_tokens, 3]
    assert by_name["tq"]["dtype"] == "int32"
    (out,) = man["outputs"]
    assert out["shape"] == [cfg.batch_size, cfg.n_tokens, cfg.n_actions]


def test_train_manifest_roundtrip(artifact_dir):
    man = json.load(open(
        os.path.join(artifact_dir, "train_step_se2fourier.manifest.json")))
    in_params = [e for e in man["inputs"] if e["name"].startswith("param:")]
    out_params = [e for e in man["outputs"]
                  if e["name"].startswith("param:")]
    assert [e["name"] for e in in_params] == [e["name"] for e in out_params]
    assert [e["shape"] for e in in_params] == [e["shape"] for e in
                                               out_params]
    assert man["outputs"][-1]["name"] == "loss"
    assert man["outputs"][-1]["shape"] == []


def test_index_config(artifact_dir):
    idx = json.load(open(os.path.join(artifact_dir, "index.json")))
    assert idx["config"]["n_actions"] == TEST_CONFIG.n_actions
    assert idx["config"]["fourier_f"] == TEST_CONFIG.fourier_f
    assert "param_names" in idx and len(idx["param_names"]) > 10
