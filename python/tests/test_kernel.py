"""Top-level kernel-vs-reference gate (the CORE correctness signal).

Runs the complete linear-memory SE(2) Fourier attention path — Pallas
projections + Pallas flash SDPA + Pallas unprojection, exactly the
composition baked into the ``attn_se2fourier`` AOT artifact — against the
quadratic-memory Algorithm 1 oracle.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, se2_fourier as se2f
from compile.kernels.flash_sdpa import flash_sdpa

SCALES = (1.0, 0.5, 0.25, 0.125)


def full_linear_attention(q, k, v, pose, tq, f, spatial_scales=SCALES):
    """The production composition (mirrors aot.py attn_se2fourier)."""
    d = q.shape[-1]
    scales = se2f.scales_for(d, spatial_scales)
    c = (4 * f + 2) * (d // 6)
    pref = (c / d) ** 0.25
    qp = se2f.project_q_pallas(q, pose, scales, f, pref)
    kp = se2f.project_k_pallas(k, pose, scales, f, pref)
    vp = se2f.project_k_pallas(v, pose, scales, f, 1.0)
    ot = flash_sdpa(qp, kp, vp, tq, tq, 1.0 / math.sqrt(c))
    return se2f.unproject_o_pallas(ot, pose, scales, f)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.sampled_from([16, 64]),
    d=st.sampled_from([6, 12, 24]),
    f=st.sampled_from([14, 20]),
)
def test_full_pallas_path_vs_quadratic_oracle(seed, n, d, f):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    pose = jnp.asarray(np.column_stack([
        rng.uniform(-2, 2, n), rng.uniform(-2, 2, n),
        rng.uniform(-np.pi, np.pi, n)]), jnp.float32)
    tq = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
    mask = tq[:, None] >= tq[None, :]
    got = full_linear_attention(q, k, v, pose, tq, f)
    expect = ref.algorithm1(q, k, v, pose, pose, "se2fourier", SCALES,
                            mask=mask)
    tol = 5e-2 if f == 14 else 8e-3
    np.testing.assert_allclose(got, expect, atol=tol)


def test_paper_headline_error_band():
    """Paper abstract: approximation error < 1e-3 with practical settings
    (radius <= 2 with F = 18 per Fig. 3 calibration)."""
    rng = np.random.default_rng(0)
    n, d, f = 64, 12, 18
    q = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    pose = jnp.asarray(np.column_stack([
        rng.uniform(-1.4, 1.4, n), rng.uniform(-1.4, 1.4, n),
        rng.uniform(-np.pi, np.pi, n)]), jnp.float32)
    tq = jnp.zeros((n,), jnp.int32)
    got = full_linear_attention(q, k, v, pose, tq, f,
                                spatial_scales=(1.0,))
    expect = ref.algorithm1(q, k, v, pose, pose, "se2fourier", (1.0,),
                            mask=jnp.ones((n, n), bool))
    assert float(jnp.max(jnp.abs(got - expect))) < 1e-3
