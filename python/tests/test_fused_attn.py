"""Fused single-kernel SE(2) Fourier attention vs the quadratic oracle and
vs the unfused Pallas composition."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_attn import fused_se2f_attention
from tests.test_kernel import full_linear_attention

SCALES = (1.0, 0.5, 0.25, 0.125)


def _scene(seed, n, d):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    pose = jnp.asarray(np.column_stack([
        rng.uniform(-1.5, 1.5, n), rng.uniform(-1.5, 1.5, n),
        rng.uniform(-np.pi, np.pi, n)]), jnp.float32)
    tq = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
    return q, k, v, pose, tq


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.sampled_from([16, 64]),
    d=st.sampled_from([12, 48]),
    f=st.sampled_from([12, 18]),
)
def test_fused_matches_quadratic_oracle(seed, n, d, f):
    q, k, v, pose, tq = _scene(seed, n, d)
    got = fused_se2f_attention(q, k, v, pose, tq, f, SCALES)
    mask = tq[:, None] >= tq[None, :]
    expect = ref.algorithm1(q, k, v, pose, pose, "se2fourier", SCALES,
                            mask=mask)
    tol = 5e-2 if f == 12 else 8e-3
    np.testing.assert_allclose(got, expect, atol=tol)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fused_matches_unfused_composition(seed):
    """Fusion is an implementation detail: identical math to the three-
    kernel composition, so agreement is to float rounding, not Fourier
    tolerance."""
    n, d, f = 64, 12, 12
    q, k, v, pose, tq = _scene(seed, n, d)
    fused = fused_se2f_attention(q, k, v, pose, tq, f, SCALES)
    unfused = full_linear_attention(q, k, v, pose, tq, f, SCALES)
    np.testing.assert_allclose(fused, unfused, atol=2e-5)
