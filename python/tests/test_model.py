"""Model-level tests: shapes, masking semantics, and per-method invariance."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import geometry, model
from compile.config import ALL_METHODS, TEST_CONFIG as CFG


def _batch(seed, cfg=CFG):
    rng = np.random.default_rng(seed)
    b, n = cfg.batch_size, cfg.n_tokens
    feat = jnp.asarray(rng.normal(size=(b, n, cfg.feat_dim)), jnp.float32)
    pose = jnp.asarray(np.concatenate([
        rng.uniform(-2, 2, (b, n, 2)),
        rng.uniform(-np.pi, np.pi, (b, n, 1))], -1), jnp.float32)
    tq = jnp.asarray(rng.integers(0, 6, (b, n)), jnp.int32)
    target = jnp.asarray(rng.integers(-1, cfg.n_actions, (b, n)), jnp.int32)
    return feat, pose, tq, target


@pytest.fixture(scope="module")
def params():
    return model.init_params(0, CFG)


@pytest.mark.parametrize("method", ALL_METHODS)
def test_forward_shape_and_finite(params, method):
    feat, pose, tq, _ = _batch(0)
    logits = model.forward(params, feat, pose, tq, CFG, method)
    assert logits.shape == (CFG.batch_size, CFG.n_tokens, CFG.n_actions)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("method", ALL_METHODS)
def test_loss_positive_and_near_uniform_at_init(params, method):
    feat, pose, tq, target = _batch(1)
    loss = float(model.nll_loss(params, feat, pose, tq, target, CFG, method))
    assert 0.0 < loss < 2.0 * np.log(CFG.n_actions)


def test_future_tokens_do_not_affect_past():
    """Causality: changing features of a later-timestep token must not
    change logits at earlier-timestep tokens."""
    params = model.init_params(0, CFG)
    feat, pose, tq, _ = _batch(2)
    b, n = feat.shape[:2]
    tq = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    logits = model.forward(params, feat, pose, tq, CFG, "se2fourier")
    feat2 = feat.at[:, n - 1].set(feat[:, n - 1] + 100.0)
    logits2 = model.forward(params, feat2, pose, tq, CFG, "se2fourier")
    np.testing.assert_allclose(
        logits[:, : n - 1], logits2[:, : n - 1], atol=1e-4
    )
    assert float(jnp.max(jnp.abs(logits[:, -1] - logits2[:, -1]))) > 1e-3


@pytest.mark.parametrize(
    "method,should_be_invariant,tol",
    [
        ("abs", False, None),
        ("rope2d", False, None),
        ("se2rep", True, 1e-3),
        ("se2fourier", True, 1e-1),
    ],
)
def test_model_se2_invariance(method, should_be_invariant, tol):
    """End-to-end Fig. 1 claim: full-model logits under a global frame
    rotation+translation."""
    params = model.init_params(0, CFG)
    feat, pose, tq, _ = _batch(3)
    z = jnp.asarray([0.5, -0.4, 0.9], jnp.float32)
    zinv = geometry.inverse(z)
    pose2 = geometry.compose(
        jnp.broadcast_to(zinv, pose.shape[:-1] + (3,)), pose
    )
    l1 = model.forward(params, feat, pose, tq, CFG, method)
    l2 = model.forward(params, feat, pose2, tq, CFG, method)
    diff = float(jnp.max(jnp.abs(l1 - l2)))
    if should_be_invariant:
        assert diff < tol, f"{method} should be invariant, diff={diff}"
    else:
        assert diff > 1e-3, f"{method} should NOT be invariant, diff={diff}"


def test_decode_samples_valid_actions():
    params = model.init_params(0, CFG)
    feat, pose, tq, _ = _batch(4)
    actions, logp, logits = model.decode(
        params, feat, pose, tq, 123, 1.0, CFG, "se2fourier"
    )
    assert actions.shape == (CFG.batch_size, CFG.n_tokens)
    assert int(actions.min()) >= 0 and int(actions.max()) < CFG.n_actions
    assert bool(jnp.all(logp <= 0.0))
    # temperature -> 0 approaches greedy
    greedy, _, _ = model.decode(params, feat, pose, tq, 123, 1e-3, CFG,
                                "se2fourier")
    np.testing.assert_array_equal(
        np.asarray(greedy), np.asarray(jnp.argmax(logits, -1))
    )


def test_param_shapes_consistent():
    shapes = model.param_shapes(CFG)
    params = model.init_params(1, CFG)
    assert sorted(shapes) == sorted(params)
    for k, s in shapes.items():
        assert params[k].shape == s, k
