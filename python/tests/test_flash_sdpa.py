"""Flash SDPA Pallas kernel vs the naive reference — forward and backward.

Hypothesis sweeps shapes, block sizes and timestep patterns; this is the
correctness gate for the linear-memory attention subroutine of Alg. 2.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_sdpa import PAD_T, flash_sdpa, flash_sdpa_batched


def _case(seed, n, m, c, cv, tmax):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(m, cv)), jnp.float32)
    tq = jnp.asarray(rng.integers(-1, tmax, n), jnp.int32)
    tk = jnp.asarray(rng.integers(-1, tmax, m), jnp.int32)
    return q, k, v, tq, tk


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.sampled_from([4, 16, 32, 64]),
    m=st.sampled_from([4, 16, 48]),
    c=st.sampled_from([8, 32]),
    cv=st.sampled_from([8, 24]),
    bq=st.sampled_from([4, 16, 32]),
    bk=st.sampled_from([4, 16]),
)
def test_flash_matches_naive(seed, n, m, c, cv, bq, bk):
    q, k, v, tq, tk = _case(seed, n, m, c, cv, tmax=5)
    scale = 1.0 / np.sqrt(c)
    mask = tq[:, None] >= tk[None, :]
    expect = ref.naive_sdpa(q, k, v, scale=scale, mask=mask)
    got = flash_sdpa(q, k, v, tq, tk, scale, bq, bk)
    # conventions differ on rows with NO visible key: flash outputs zeros
    # (tested separately), the naive reference degenerates to uniform —
    # compare only rows that see at least one key.
    visible = np.asarray(mask).any(axis=1)
    np.testing.assert_allclose(
        np.asarray(got)[visible], np.asarray(expect)[visible],
        atol=2e-5, rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(got)[~visible], 0.0, atol=0.0,
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_flash_gradients_match_naive(seed):
    n, m, c = 16, 24, 16
    q, k, v, tq, tk = _case(seed, n, m, c, c, tmax=4)
    scale = 1.0 / np.sqrt(c)
    mask = tq[:, None] >= tk[None, :]
    co = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(n, c)),
                     jnp.float32)

    def loss_naive(q, k, v):
        return jnp.sum(co * ref.naive_sdpa(q, k, v, scale=scale, mask=mask))

    def loss_flash(q, k, v):
        return jnp.sum(co * flash_sdpa(q, k, v, tq, tk, scale, 8, 8))

    g1 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-3)


def test_fully_masked_rows_produce_zeros():
    n, m, c = 8, 8, 8
    q, k, v, _, _ = _case(0, n, m, c, c, tmax=3)
    tq = jnp.full((n,), -10, jnp.int32)  # sees nothing
    tk = jnp.zeros((m,), jnp.int32)
    out = flash_sdpa(q, k, v, tq, tk, 1.0, 4, 4)
    np.testing.assert_allclose(out, np.zeros((n, c)), atol=0)


def test_padding_keys_are_invisible():
    n, m, c = 8, 16, 8
    q, k, v, tq, _ = _case(1, n, m, c, c, tmax=3)
    tq = jnp.abs(tq)
    # keys 8.. are padding
    tk = jnp.concatenate([
        jnp.zeros((8,), jnp.int32), jnp.full((8,), PAD_T, jnp.int32)
    ])
    out_full = flash_sdpa(q, k, v, tq, tk, 1.0, 4, 4)
    out_trunc = flash_sdpa(q, k[:8], v[:8], tq, tk[:8], 1.0, 4, 4)
    np.testing.assert_allclose(out_full, out_trunc, atol=1e-6)


def test_map_tokens_visible_to_all():
    """Timestep -1 (map) keys are visible to every non-pad query."""
    n, m, c = 4, 6, 8
    q, k, v, _, _ = _case(2, n, m, c, c, tmax=3)
    tq = jnp.asarray([0, 1, 2, 3], jnp.int32)
    tk = jnp.full((m,), -1, jnp.int32)
    out = flash_sdpa(q, k, v, tq, tk, 1.0, 4, 3)
    mask = jnp.ones((n, m), bool)
    expect = ref.naive_sdpa(q, k, v, scale=1.0, mask=mask)
    np.testing.assert_allclose(out, expect, atol=1e-5)


def test_batched_matches_loop():
    b, h, n, c = 2, 3, 16, 8
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, h, n, c)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, n, c)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, n, c)), jnp.float32)
    tq = jnp.asarray(rng.integers(0, 4, (b, n)), jnp.int32)
    scale = 1.0 / np.sqrt(c)
    out = flash_sdpa_batched(q, k, v, tq, tq, scale, 8, 8)
    for bi in range(b):
        for hi in range(h):
            expect = flash_sdpa(
                q[bi, hi], k[bi, hi], v[bi, hi], tq[bi], tq[bi], scale, 8, 8
            )
            np.testing.assert_allclose(out[bi, hi], expect, atol=1e-6)


def test_softmax_numerics_large_logits():
    """Online softmax must be stable for large score magnitudes."""
    n, c = 8, 8
    rng = np.random.default_rng(4)
    q = jnp.asarray(50.0 * rng.normal(size=(n, c)), jnp.float32)
    k = jnp.asarray(50.0 * rng.normal(size=(n, c)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
    tq = jnp.zeros((n,), jnp.int32)
    out = flash_sdpa(q, k, v, tq, tq, 1.0, 4, 4)
    assert bool(jnp.all(jnp.isfinite(out)))
    expect = ref.naive_sdpa(q, k, v, scale=1.0,
                            mask=jnp.ones((n, n), bool))
    np.testing.assert_allclose(out, expect, atol=1e-5)
