"""Unit tests for the Fourier basis machinery (paper Sec. III-B)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import basis


def test_basis_frequencies():
    assert list(basis.basis_frequencies(7)) == [0, 1, 1, 2, 2, 3, 3]


def test_eval_basis_matches_definition():
    f = 9
    theta = jnp.linspace(-np.pi, np.pi, 17)
    b = np.asarray(basis.eval_basis(theta, f))
    for i in range(f):
        if i % 2 == 0:
            expect = np.cos((i / 2) * np.asarray(theta))
        else:
            expect = np.sin(((i + 1) / 2) * np.asarray(theta))
        np.testing.assert_allclose(b[:, i], expect, atol=1e-6)


def test_quadrature_matrix_orthogonality():
    """Quadrature of g_i against g_j recovers the identity (i, j < F):
    the 2F-point rule integrates products of basis elements exactly."""
    f = 8
    z = basis.quadrature_grid(f)
    w = basis.quadrature_matrix(f)
    for i in range(f):
        gi = (np.cos((i // 2) * z) if i % 2 == 0
              else np.sin(((i + 1) // 2) * z))
        coeffs = gi @ w
        expect = np.zeros(f)
        expect[i] = 1.0
        np.testing.assert_allclose(coeffs, expect, atol=1e-6)


def test_quadrature_jnp_matches_numpy():
    for f in (4, 9, 18):
        np.testing.assert_allclose(
            np.asarray(basis.quadrature_matrix_jnp(f)),
            basis.quadrature_matrix(f),
            atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(basis.quadrature_grid_jnp(f)),
            basis.quadrature_grid(f),
            atol=1e-6,
        )


@settings(max_examples=25, deadline=None)
@given(
    x=st.floats(-2.0, 2.0),
    y=st.floats(-2.0, 2.0),
    theta=st.floats(-np.pi, np.pi),
)
def test_fourier_approximation_error_small_radius(x, y, theta):
    """With F=18 and radius <= ~2.8 the pointwise approximation of
    cos(u(theta)) is accurate to ~1e-4 (paper Fig. 3 band)."""
    f = 18
    xx = jnp.asarray([x], jnp.float32)
    yy = jnp.asarray([y], jnp.float32)
    approx = basis.approx_cos_u(xx, yy, jnp.asarray([theta]), f, "x")
    exact = np.cos(x * np.cos(theta) + y * np.sin(theta))
    assert abs(float(approx[0, 0]) - exact) < 5e-4


@settings(max_examples=20, deadline=None)
@given(
    r=st.floats(0.1, 4.0),
    psi=st.floats(-np.pi, np.pi),
    theta=st.floats(-np.pi, np.pi),
)
def test_coefficients_jacobi_anger(r, psi, theta):
    """Cross-check the quadrature coefficients against the Jacobi-Anger
    reconstruction: sum_i Gamma(i) g_i(theta) ~= cos(u(theta))."""
    f = 28
    x, y = r * np.cos(psi), r * np.sin(psi)
    gamma, lam = basis.fourier_coefficients(
        jnp.asarray([x], jnp.float32), jnp.asarray([y], jnp.float32), f, "x"
    )
    b = basis.eval_basis(jnp.asarray([theta], jnp.float32), f)
    recon_cos = float(jnp.sum(gamma[0] * b[0]))
    recon_sin = float(jnp.sum(lam[0] * b[0]))
    u = x * np.cos(theta) + y * np.sin(theta)
    assert abs(recon_cos - np.cos(u)) < 1e-3
    assert abs(recon_sin - np.sin(u)) < 1e-3


def test_error_grows_with_radius():
    """Fig. 3 shape: for fixed F, error increases with key radius."""
    f = 12
    thetas = jnp.linspace(-np.pi, np.pi, 64)
    errs = []
    for r in (1.0, 4.0, 8.0):
        x, y = r / np.sqrt(2), r / np.sqrt(2)
        approx = basis.approx_cos_u(
            jnp.asarray([x], jnp.float32), jnp.asarray([y], jnp.float32),
            thetas, f, "x",
        )
        exact = np.cos(x * np.cos(np.asarray(thetas))
                       + y * np.sin(np.asarray(thetas)))
        errs.append(float(np.max(np.abs(np.asarray(approx) - exact))))
    assert errs[0] < errs[1] < errs[2]


def test_error_shrinks_with_basis_size():
    """Fig. 3 shape: for fixed radius, error decreases with F."""
    x, y = 3.0, -2.0
    thetas = jnp.linspace(-np.pi, np.pi, 64)
    errs = []
    for f in (6, 12, 18, 28):
        approx = basis.approx_cos_u(
            jnp.asarray([x], jnp.float32), jnp.asarray([y], jnp.float32),
            thetas, f, "x",
        )
        exact = np.cos(x * np.cos(np.asarray(thetas))
                       + y * np.sin(np.asarray(thetas)))
        errs.append(float(np.max(np.abs(np.asarray(approx) - exact))))
    assert errs[0] > errs[1] > errs[2] > errs[3]
    assert errs[3] < 1e-4
