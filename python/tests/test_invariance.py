"""SE(2) invariance properties (paper Eq. 2 / Fig. 1).

Applying a global frame change z^{-1} to every pose must leave the attention
output unchanged — exactly for se2rep and the quadratic oracle, to Fourier
tolerance for se2fourier.  rope2d must be invariant to translations but NOT
to rotations; abs is invariant to neither.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import geometry
from compile.kernels import ref

SCALES = (1.0, 0.5)


def _scene(seed, n=8, m=10, d=12, rmax=1.5):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    pq = jnp.asarray(np.column_stack([
        rng.uniform(-rmax, rmax, n), rng.uniform(-rmax, rmax, n),
        rng.uniform(-np.pi, np.pi, n)]), jnp.float32)
    pk = jnp.asarray(np.column_stack([
        rng.uniform(-rmax, rmax, m), rng.uniform(-rmax, rmax, m),
        rng.uniform(-np.pi, np.pi, m)]), jnp.float32)
    return q, k, v, pq, pk


def _shift(poses, z):
    zinv = geometry.inverse(jnp.asarray(z, jnp.float32))
    return geometry.compose(zinv[None, :], poses)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    zx=st.floats(-1.0, 1.0), zy=st.floats(-1.0, 1.0),
    zt=st.floats(-np.pi, np.pi),
)
def test_alg1_se2_invariant(seed, zx, zy, zt):
    q, k, v, pq, pk = _scene(seed)
    z = (zx, zy, zt)
    for method in ("se2rep", "se2fourier"):
        o = ref.algorithm1(q, k, v, pq, pk, method, SCALES)
        o2 = ref.algorithm1(q, k, v, _shift(pq, z), _shift(pk, z),
                            method, SCALES)
        np.testing.assert_allclose(o, o2, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    zx=st.floats(-1.0, 1.0), zy=st.floats(-1.0, 1.0),
    zt=st.floats(-np.pi, np.pi),
)
def test_alg2_se2fourier_invariant(seed, zx, zy, zt):
    """The linear-memory version inherits invariance up to approximation
    error.  Note the frame shift moves positions off-center, so the Fourier
    radius grows — tolerance reflects F=20 at radius <= ~3.5."""
    q, k, v, pq, pk = _scene(seed)
    z = (zx, zy, zt)
    o = ref.algorithm2_explicit(q, k, v, pq, pk, "se2fourier", SCALES, f=20)
    o2 = ref.algorithm2_explicit(
        q, k, v, _shift(pq, z), _shift(pk, z), "se2fourier", SCALES, f=20
    )
    np.testing.assert_allclose(o, o2, atol=5e-3)


def test_rope2d_translation_invariant_only():
    q, k, v, pq, pk = _scene(7)
    # translation: invariant
    o = ref.algorithm1(q, k, v, pq, pk, "rope2d", SCALES)
    zt = (0.7, -0.3, 0.0)
    o_trans = ref.algorithm1(q, k, v, _shift(pq, zt), _shift(pk, zt),
                             "rope2d", SCALES)
    np.testing.assert_allclose(o, o_trans, atol=1e-4)
    # rotation: NOT invariant (Fig. 1b)
    zr = (0.0, 0.0, 1.1)
    o_rot = ref.algorithm1(q, k, v, _shift(pq, zr), _shift(pk, zr),
                           "rope2d", SCALES)
    assert float(jnp.max(jnp.abs(o - o_rot))) > 1e-3


def test_se2_group_axioms():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(-2, 2, (5, 3)), jnp.float32)
    b = jnp.asarray(rng.uniform(-2, 2, (5, 3)), jnp.float32)
    c = jnp.asarray(rng.uniform(-2, 2, (5, 3)), jnp.float32)
    ident = jnp.zeros((5, 3), jnp.float32)
    # identity
    np.testing.assert_allclose(
        geometry.compose(a, ident), a, atol=1e-5)
    # inverse
    inv = geometry.compose(geometry.inverse(a), a)
    np.testing.assert_allclose(inv[:, :2], np.zeros((5, 2)), atol=1e-5)
    np.testing.assert_allclose(np.sin(inv[:, 2]), np.zeros(5), atol=1e-5)
    # associativity
    lhs = geometry.compose(geometry.compose(a, b), c)
    rhs = geometry.compose(a, geometry.compose(b, c))
    np.testing.assert_allclose(lhs[:, :2], rhs[:, :2], atol=1e-4)
    np.testing.assert_allclose(
        np.sin(lhs[:, 2] - rhs[:, 2]), np.zeros(5), atol=1e-5)


def test_matrix_representation_homomorphism():
    """psi(a * b) == psi(a) psi(b) — Eq. 8 is a group representation."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(-2, 2, (4, 3)), jnp.float32)
    b = jnp.asarray(rng.uniform(-2, 2, (4, 3)), jnp.float32)
    lhs = geometry.se2_matrix(geometry.compose(a, b))
    rhs = jnp.matmul(geometry.se2_matrix(a), geometry.se2_matrix(b))
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)
