"""Training-step tests: loss decreases, Adam bookkeeping, determinism."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model, train
from compile.config import ALL_METHODS, TEST_CONFIG as CFG


def _batch(seed, learnable=True):
    """A learnable toy task: target action is a deterministic function of
    the token features, so a few steps must reduce loss."""
    rng = np.random.default_rng(seed)
    b, n = CFG.batch_size, CFG.n_tokens
    feat = jnp.asarray(rng.normal(size=(b, n, CFG.feat_dim)), jnp.float32)
    pose = jnp.asarray(np.concatenate([
        rng.uniform(-2, 2, (b, n, 2)),
        rng.uniform(-np.pi, np.pi, (b, n, 1))], -1), jnp.float32)
    tq = jnp.asarray(rng.integers(0, 4, (b, n)), jnp.int32)
    if learnable:
        target = jnp.asarray(
            (np.asarray(feat[..., 0]) > 0).astype(np.int32), jnp.int32
        )
    else:
        target = jnp.asarray(rng.integers(0, CFG.n_actions, (b, n)),
                             jnp.int32)
    return feat, pose, tq, target


@pytest.mark.parametrize("method", ALL_METHODS)
def test_loss_decreases(method):
    params = model.init_params(0, CFG)
    m, v = train.init_opt_state(params)
    feat, pose, tq, target = _batch(0)
    loss0 = float(model.nll_loss(params, feat, pose, tq, target, CFG, method))
    for step in range(1, 9):
        params, m, v, loss = train.train_step(
            params, m, v, float(step), feat, pose, tq, target, CFG, method
        )
    assert float(loss) < loss0, (method, loss0, float(loss))


def test_train_step_deterministic():
    params = model.init_params(0, CFG)
    m, v = train.init_opt_state(params)
    feat, pose, tq, target = _batch(1)
    out1 = train.train_step(params, m, v, 1.0, feat, pose, tq, target,
                            CFG, "se2fourier")
    out2 = train.train_step(params, m, v, 1.0, feat, pose, tq, target,
                            CFG, "se2fourier")
    np.testing.assert_array_equal(
        np.asarray(out1[3]), np.asarray(out2[3])
    )
    for k in params:
        np.testing.assert_array_equal(
            np.asarray(out1[0][k]), np.asarray(out2[0][k])
        )


def test_adam_moments_update():
    params = model.init_params(0, CFG)
    m, v = train.init_opt_state(params)
    feat, pose, tq, target = _batch(2)
    _, m2, v2, _ = train.train_step(params, m, v, 1.0, feat, pose, tq,
                                    target, CFG, "rope2d")
    # second moments are nonnegative and some moments moved
    moved = 0
    for k in params:
        assert bool(jnp.all(v2[k] >= 0.0))
        if float(jnp.max(jnp.abs(m2[k]))) > 0:
            moved += 1
    assert moved > len(params) // 2


def test_masked_tokens_get_no_loss():
    params = model.init_params(0, CFG)
    feat, pose, tq, target = _batch(3)
    all_masked = jnp.full_like(target, -1)
    loss = model.nll_loss(params, feat, pose, tq, all_masked, CFG, "abs")
    assert float(loss) == 0.0
