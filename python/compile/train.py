"""Training step (Adam) for the agent-simulation model.

Hand-written Adam so the whole optimizer lowers into a single AOT artifact:
the Rust trainer holds params / m / v as device-resident PJRT buffers and
feeds them back each step (no optimizer state ever lives host-side).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import model
from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


def init_opt_state(params: Params) -> Tuple[Params, Params]:
    """Adam first/second-moment accumulators, zero-initialized."""
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in params.items()}


def train_step(params: Params, m: Params, v: Params, step, feat, pose, tq,
               target, cfg: ModelConfig, method: str):
    """One Adam step.  ``step`` is a float32 scalar (1-based).

    Returns (new_params, new_m, new_v, loss).
    """
    loss, grads = jax.value_and_grad(model.nll_loss)(
        params, feat, pose, tq, target, cfg, method
    )
    b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.learning_rate
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_k = b1 * m[k] + (1.0 - b1) * g
        v_k = b2 * v[k] + (1.0 - b2) * g * g
        update = lr * (m_k / bc1) / (jnp.sqrt(v_k / bc2) + eps)
        new_params[k] = params[k] - update
        new_m[k] = m_k
        new_v[k] = v_k
    return new_params, new_m, new_v, loss
