"""SE(2) geometry helpers (pure jnp, build-time only).

Poses are arrays with trailing dimension 3: ``(x, y, theta)``.  The group
operation is the usual rigid-transform composition; ``relative(a, b)``
computes ``a^{-1} b``, the pose of ``b`` expressed in the frame of ``a``
(paper Sec. II-A: ``p_{n->m} = p_n^{-1} p_m``).
"""

from __future__ import annotations

import jax.numpy as jnp


def wrap_angle(theta):
    """Wrap angles to (-pi, pi]."""
    return jnp.arctan2(jnp.sin(theta), jnp.cos(theta))


def compose(a, b):
    """Group product a * b for SE(2) poses (..., 3)."""
    ax, ay, at = a[..., 0], a[..., 1], a[..., 2]
    bx, by, bt = b[..., 0], b[..., 1], b[..., 2]
    c, s = jnp.cos(at), jnp.sin(at)
    return jnp.stack(
        [ax + c * bx - s * by, ay + s * bx + c * by, wrap_angle(at + bt)],
        axis=-1,
    )


def inverse(a):
    """Group inverse a^{-1} for SE(2) poses (..., 3)."""
    ax, ay, at = a[..., 0], a[..., 1], a[..., 2]
    c, s = jnp.cos(at), jnp.sin(at)
    return jnp.stack(
        [-c * ax - s * ay, s * ax - c * ay, wrap_angle(-at)], axis=-1
    )


def relative(a, b):
    """Relative pose a^{-1} b; broadcasting over leading dims."""
    return compose(inverse(a), b)


def rot2(theta):
    """2D rotation matrix rho(theta) (paper Eq. 5), shape (..., 2, 2)."""
    c, s = jnp.cos(theta), jnp.sin(theta)
    row0 = jnp.stack([c, -s], axis=-1)
    row1 = jnp.stack([s, c], axis=-1)
    return jnp.stack([row0, row1], axis=-2)


def se2_matrix(pose):
    """Homogeneous representation psi(x, y, theta) (paper Eq. 8), (..., 3, 3)."""
    x, y, t = pose[..., 0], pose[..., 1], pose[..., 2]
    c, s = jnp.cos(t), jnp.sin(t)
    zero, one = jnp.zeros_like(x), jnp.ones_like(x)
    row0 = jnp.stack([c, -s, x], axis=-1)
    row1 = jnp.stack([s, c, y], axis=-1)
    row2 = jnp.stack([zero, zero, one], axis=-1)
    return jnp.stack([row0, row1, row2], axis=-2)
