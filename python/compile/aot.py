"""AOT pipeline: lower every L2 entrypoint to HLO text + JSON manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts written to ``artifacts/`` (relative to the repo root):

    {name}.hlo.txt        HLO text, lowered with return_tuple=True
    {name}.manifest.json  input/output names, shapes, dtypes
    index.json            artifact list + the full model configuration

Entrypoints per attention method m in {abs, rope2d, se2rep, se2fourier}:

    fwd_{m}         (params..., feat, pose, tq)                  -> (logits,)
    train_step_{m}  (params..., m..., v..., step, batch...)      -> (params'..., m'..., v'..., loss)
    decode_{m}      (params..., feat, pose, tq, seed, temp)      -> (actions, logp, logits)
    attn_{m}        (q, k, v, pose, tq)                          -> (out,)    [single head]

plus method-independent:

    init            (seed,)                                      -> (params...,)
    flash_sdpa      (q, k, v, tq, tk)                            -> (out,)

Run ``python -m compile.aot --out-dir ../artifacts`` from ``python/``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train
from .config import ALL_METHODS, DEFAULT_CONFIG, ModelConfig
from .kernels import se2_fourier as se2f
from .kernels.flash_sdpa import flash_sdpa

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser).

    CRITICAL: default HLO printing elides large array constants as a
    literal ``{...}`` placeholder, which the xla_extension 0.5.1 text
    parser silently accepts as garbage data (observed as wrong numerics in
    any artifact with a constant table, e.g. the spatial-scale ladder).
    ``print_large_constants=True`` keeps the payload; metadata is dropped
    to keep files small and the old parser happy.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, s):
    return {
        "name": name,
        "shape": list(s.shape),
        "dtype": str(s.dtype),
    }


def emit(out_dir, name, fn, in_specs, in_names, out_names=None):
    """Lower ``fn`` at ``in_specs`` and write artifact + manifest.

    keep_unused=True: parameters that a variant doesn't read (e.g. `pose`
    in the abs attention) must stay in the signature so the manifest and
    the compiled program agree on buffer count.
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
    text = to_hlo_text(lowered)
    out_shape = jax.eval_shape(fn, *in_specs)
    outs = jax.tree_util.tree_leaves(out_shape)
    if out_names is None:
        out_names = [f"out{i}" for i in range(len(outs))]
    assert len(out_names) == len(outs), (name, len(out_names), len(outs))
    manifest = {
        "name": name,
        "inputs": [_io_entry(n, s) for n, s in zip(in_names, in_specs)],
        "outputs": [_io_entry(n, s) for n, s in zip(out_names, outs)],
    }
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: {len(text) / 1e6:.2f} MB hlo, "
          f"{len(in_specs)} in / {len(outs)} out")
    return manifest


def build_all(out_dir: str, cfg: ModelConfig, methods=ALL_METHODS):
    os.makedirs(out_dir, exist_ok=True)
    pnames = sorted(model.param_shapes(cfg))
    pshapes = model.param_shapes(cfg)
    nparams = len(pnames)
    b, n = cfg.batch_size, cfg.n_tokens

    param_specs = [spec(pshapes[k]) for k in pnames]
    batch_specs = [
        spec((b, n, cfg.feat_dim)),          # feat
        spec((b, n, 3)),                     # pose
        spec((b, n), I32),                   # tq
    ]
    batch_names = ["feat", "pose", "tq"]

    artifacts = []

    # ---- init --------------------------------------------------------
    def init_flat(seed):
        params = model.init_params(seed, cfg)
        return tuple(params[k] for k in pnames)

    artifacts.append(emit(
        out_dir, "init", init_flat, [spec((), I32)], ["seed"],
        out_names=[f"param:{k}" for k in pnames],
    ))

    # ---- flash sdpa standalone ----------------------------------------
    nn, c = 256, 64

    def flash_flat(q, k, v, tq, tk):
        return (flash_sdpa(q, k, v, tq, tk, 1.0 / math.sqrt(c)),)

    artifacts.append(emit(
        out_dir, "flash_sdpa", flash_flat,
        [spec((nn, c)), spec((nn, c)), spec((nn, c)),
         spec((nn,), I32), spec((nn,), I32)],
        ["q", "k", "v", "tq", "tk"], ["out"],
    ))

    # ---- per-method entrypoints ----------------------------------------
    for method in methods:
        def fwd_flat(*args, _m=method):
            params = dict(zip(pnames, args[:nparams]))
            feat, pose, tq = args[nparams:]
            return (model.forward(params, feat, pose, tq, cfg, _m),)

        artifacts.append(emit(
            out_dir, f"fwd_{method}", fwd_flat,
            param_specs + batch_specs,
            [f"param:{k}" for k in pnames] + batch_names,
            ["logits"],
        ))

        def train_flat(*args, _m=method):
            params = dict(zip(pnames, args[:nparams]))
            mm = dict(zip(pnames, args[nparams : 2 * nparams]))
            vv = dict(zip(pnames, args[2 * nparams : 3 * nparams]))
            step, feat, pose, tq, target = args[3 * nparams :]
            np_, nm, nv, loss = train.train_step(
                params, mm, vv, step, feat, pose, tq, target, cfg, _m
            )
            return (
                tuple(np_[k] for k in pnames)
                + tuple(nm[k] for k in pnames)
                + tuple(nv[k] for k in pnames)
                + (loss,)
            )

        artifacts.append(emit(
            out_dir, f"train_step_{method}", train_flat,
            param_specs * 3
            + [spec(())]
            + batch_specs
            + [spec((b, n), I32)],
            [f"param:{k}" for k in pnames]
            + [f"m:{k}" for k in pnames]
            + [f"v:{k}" for k in pnames]
            + ["step"] + batch_names + ["target"],
            [f"param:{k}" for k in pnames]
            + [f"m:{k}" for k in pnames]
            + [f"v:{k}" for k in pnames]
            + ["loss"],
        ))

        def decode_flat(*args, _m=method):
            params = dict(zip(pnames, args[:nparams]))
            feat, pose, tq, seed, temp = args[nparams:]
            return model.decode(
                params, feat, pose, tq, seed, temp, cfg, _m
            )

        artifacts.append(emit(
            out_dir, f"decode_{method}", decode_flat,
            param_specs + batch_specs + [spec((), I32), spec(())],
            [f"param:{k}" for k in pnames] + batch_names
            + ["seed", "temperature"],
            ["actions", "logp", "logits"],
        ))

    # ---- standalone single-head attention (pallas projections) ---------
    for method in methods:
        def attn_flat(q, k, v, pose, tq, _m=method):
            qh = q[None, :, None, :]  # (1, N, 1, dh)
            kh = k[None, :, None, :]
            vh = v[None, :, None, :]
            if _m == "se2fourier":
                f = cfg.fourier_f
                scales = se2f.scales_for(cfg.head_dim, cfg.spatial_scales)
                c = cfg.se2f_proj_dim
                pref = (float(c) / float(cfg.head_dim)) ** 0.25
                qp = se2f.project_q_pallas(q, pose, scales, f, pref)
                kp = se2f.project_k_pallas(k, pose, scales, f, pref)
                vp = se2f.project_k_pallas(v, pose, scales, f, 1.0)
                ot = flash_sdpa(qp, kp, vp, tq, tq, 1.0 / math.sqrt(c))
                return (se2f.unproject_o_pallas(ot, pose, scales, f),)
            params_stub = {}  # unused
            del params_stub
            from . import model as _model
            qp, kp, vp, scale = _model._project_qkv(
                qh, kh, vh, pose[None], cfg, _m
            )
            out = flash_sdpa(
                qp[0, :, 0, :], kp[0, :, 0, :], vp[0, :, 0, :],
                tq, tq, scale,
            )
            out = _model._unproject_o(
                out[None, :, None, :], pose[None], cfg, _m
            )
            return (out[0, :, 0, :],)

        nt, dh = cfg.n_tokens, cfg.head_dim
        artifacts.append(emit(
            out_dir, f"attn_{method}", attn_flat,
            [spec((nt, dh)), spec((nt, dh)), spec((nt, dh)),
             spec((nt, 3)), spec((nt,), I32)],
            ["q", "k", "v", "pose", "tq"], ["out"],
        ))

    # ---- fused SE(2) Fourier attention (single Pallas kernel) -----------
    if "se2fourier" in methods:
        from .kernels.fused_attn import fused_se2f_attention

        def fused_flat(q, k, v, pose, tq):
            return (fused_se2f_attention(
                q, k, v, pose, tq, cfg.fourier_f, cfg.spatial_scales
            ),)

        nt, dh = cfg.n_tokens, cfg.head_dim
        artifacts.append(emit(
            out_dir, "attn_se2fourier_fused", fused_flat,
            [spec((nt, dh)), spec((nt, dh)), spec((nt, dh)),
             spec((nt, 3)), spec((nt,), I32)],
            ["q", "k", "v", "pose", "tq"], ["out"],
        ))

    # ---- index ----------------------------------------------------------
    index = {
        "artifacts": [a["name"] for a in artifacts],
        "config": dataclasses.asdict(cfg),
        "param_names": pnames,
        "methods": list(methods),
    }
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote {len(artifacts)} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--methods", default=",".join(ALL_METHODS))
    args = ap.parse_args()
    methods = tuple(m for m in args.methods.split(",") if m)
    build_all(args.out_dir, DEFAULT_CONFIG, methods)


if __name__ == "__main__":
    main()
