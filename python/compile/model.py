"""L2: the agent-simulation transformer (JAX, build-time only).

A next-token-prediction model over tokenized driving scenes (paper Sec.
IV-B): each token is an agent-timestep or a map element with an associated
SE(2) pose; a transformer with one of four relative-attention mechanisms
predicts a categorical distribution over a discrete action codebook.

The four attention methods (paper Table I):

* ``abs``        — absolute position embeddings added to features, plain SDPA
* ``rope2d``     — 2D RoPE (Eq. 7), translation invariant only
* ``se2rep``     — SE(2) homogeneous representation (Eq. 9)
* ``se2fourier`` — the paper's SE(2) Fourier mechanism (Eq. 19)

All methods share an identical parameter structure so the Rust coordinator
can treat checkpoints uniformly.  The SDPA inner loop is the Pallas flash
kernel from ``kernels/flash_sdpa.py`` (linear memory, custom VJP).
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .config import (
    METHOD_ABS,
    METHOD_ROPE2D,
    METHOD_SE2FOURIER,
    METHOD_SE2REP,
    ModelConfig,
)
from .kernels import rope as rope_mod
from .kernels import se2_fourier as se2f
from .kernels.flash_sdpa import flash_sdpa_batched

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    """Deterministic name -> shape map; the manifest order is sorted(name)."""
    h = cfg.n_heads * cfg.head_dim
    shapes = {
        "embed_w": (cfg.feat_dim, cfg.d_model),
        "embed_b": (cfg.d_model,),
        # absolute-position pathway (used by method 'abs' only, but always
        # present so every method has an identical checkpoint layout)
        "posemb_w": (24, cfg.d_model),
        "posemb_b": (cfg.d_model,),
        "final_ln_g": (cfg.d_model,),
        "final_ln_b": (cfg.d_model,),
        "head_w": (cfg.d_model, cfg.n_actions),
        "head_b": (cfg.n_actions,),
    }
    for i in range(cfg.n_layers):
        p = f"layer{i}_"
        shapes.update(
            {
                p + "ln1_g": (cfg.d_model,),
                p + "ln1_b": (cfg.d_model,),
                p + "wqkv": (cfg.d_model, 3 * h),
                p + "bqkv": (3 * h,),
                p + "wo": (h, cfg.d_model),
                p + "bo": (cfg.d_model,),
                p + "ln2_g": (cfg.d_model,),
                p + "ln2_b": (cfg.d_model,),
                p + "wff1": (cfg.d_model, cfg.d_ff),
                p + "bff1": (cfg.d_ff,),
                p + "wff2": (cfg.d_ff, cfg.d_model),
                p + "bff2": (cfg.d_model,),
            }
        )
    return shapes


def init_params(seed, cfg: ModelConfig) -> Params:
    """Initialize parameters from an int32 seed (traceable, AOT-friendly)."""
    key = jax.random.PRNGKey(seed)
    shapes = param_shapes(cfg)
    params = {}
    for name in sorted(shapes):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("_g"):  # layernorm gains
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            std = 1.0 / math.sqrt(max(1, fan_in))
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def pose_sincos(pose):
    """Sin-cos embedding of an SE(2) pose (for the 'abs' baseline): four
    frequencies over x and y plus heading harmonics, width 24."""
    x, y, t = pose[..., 0], pose[..., 1], pose[..., 2]
    feats = []
    for freq in (0.5, 1.0, 2.0, 4.0):
        feats += [jnp.sin(freq * x), jnp.cos(freq * x),
                  jnp.sin(freq * y), jnp.cos(freq * y)]
    feats += [jnp.sin(t), jnp.cos(t), jnp.sin(2 * t), jnp.cos(2 * t),
              jnp.sin(3 * t), jnp.cos(3 * t), jnp.sin(4 * t), jnp.cos(4 * t)]
    return jnp.stack(feats, axis=-1)  # (..., 24)


def _project_qkv(q, k, v, pose, cfg: ModelConfig, method: str):
    """Apply the method's phi_q^T / phi_k maps per head.

    q, k, v: (B, N, H, dh); pose: (B, N, 3) broadcast over heads as
    (B, N, 1, 3).  Returns projected (B, N, H, c) tensors plus the SDPA
    scale 1/sqrt(c) (Alg. 2 line 3).
    """
    pb = pose[:, :, None, :]  # (B, N, 1, 3)
    dh = cfg.head_dim
    if method == METHOD_ABS:
        return q, k, v, 1.0 / math.sqrt(dh)
    if method == METHOD_ROPE2D:
        scales = rope_mod.block_scales(dh, 4, cfg.spatial_scales)
        qp = rope_mod.rope2d_project(q, pb, scales)
        kp = rope_mod.rope2d_project(k, pb, scales)
        # Alg. 2 transforms values too (v~ = phi_k v); combined with the
        # phi_q post-rotation this equals Alg. 1's phi(p_rel) v.
        vp = rope_mod.rope2d_project(v, pb, scales)
        return qp, kp, vp, 1.0 / math.sqrt(dh)
    if method == METHOD_SE2REP:
        scales = rope_mod.block_scales(dh, 3, cfg.spatial_scales)
        qp = rope_mod.se2rep_project_q(q, pb, scales)
        kp = rope_mod.se2rep_project_k(k, pb, scales)
        vp = rope_mod.se2rep_project_k(v, pb, scales)
        return qp, kp, vp, 1.0 / math.sqrt(dh)
    if method == METHOD_SE2FOURIER:
        f = cfg.fourier_f
        scales = se2f.scales_for(dh, cfg.spatial_scales)
        c = cfg.se2f_proj_dim
        pref = (float(c) / float(dh)) ** 0.25  # Alg. 2 prefactor (c/d)^(1/4)
        qp = se2f.project_q_jnp(q, pb, scales, f, pref)
        kp = se2f.project_k_jnp(k, pb, scales, f, pref)
        vp = se2f.project_k_jnp(v, pb, scales, f, 1.0)
        return qp, kp, vp, 1.0 / math.sqrt(c)
    raise ValueError(f"unknown method {method}")


def _unproject_o(o, pose, cfg: ModelConfig, method: str):
    """Alg. 2 line 4: o = phi_q(p) o_tilde (identity for abs)."""
    pb = pose[:, :, None, :]
    dh = cfg.head_dim
    if method == METHOD_ROPE2D:
        scales = rope_mod.block_scales(dh, 4, cfg.spatial_scales)
        # phi_q(p) = rho(-a x) blocks: rotate by negated coordinates
        return rope_mod.rope2d_project(o, -pb, scales)
    if method == METHOD_SE2REP:
        scales = rope_mod.block_scales(dh, 3, cfg.spatial_scales)
        return rope_mod.se2rep_unproject_o(o, pb, scales)
    if method == METHOD_SE2FOURIER:
        scales = se2f.scales_for(dh, cfg.spatial_scales)
        return se2f.unproject_o_jnp(o, pb, scales, cfg.fourier_f)
    return o


def attention(x, pose, tq, params: Params, prefix: str,
              cfg: ModelConfig, method: str):
    """One multi-head relative-attention layer (paper Alg. 2 end-to-end)."""
    bsz, n, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    qkv = x @ params[prefix + "wqkv"] + params[prefix + "bqkv"]
    qkv = qkv.reshape(bsz, n, 3, h, dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (B, N, H, dh)
    qp, kp, vp, scale = _project_qkv(q, k, v, pose, cfg, method)
    # (B, N, H, c) -> (B, H, N, c)
    qp = qp.transpose(0, 2, 1, 3)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    ot = flash_sdpa_batched(qp, kp, vp, tq, tq, scale)
    ot = ot.transpose(0, 2, 1, 3)  # (B, N, H, c)
    o = _unproject_o(ot, pose, cfg, method)  # (B, N, H, dh)
    o = o.reshape(bsz, n, h * dh)
    return o @ params[prefix + "wo"] + params[prefix + "bo"]


def forward(params: Params, feat, pose, tq, cfg: ModelConfig, method: str):
    """Logits over the action codebook.

    feat: (B, N, feat_dim) raw token features
    pose: (B, N, 3) SE(2) pose per token
    tq:   (B, N) int32 visibility timestep (see flash_sdpa docstring)
    returns logits (B, N, n_actions)
    """
    x = feat @ params["embed_w"] + params["embed_b"]
    if method == METHOD_ABS:
        x = x + pose_sincos(pose) @ params["posemb_w"] + params["posemb_b"]
    for i in range(cfg.n_layers):
        p = f"layer{i}_"
        a = attention(
            layer_norm(x, params[p + "ln1_g"], params[p + "ln1_b"]),
            pose, tq, params, p, cfg, method,
        )
        x = x + a
        mlp_in = layer_norm(x, params[p + "ln2_g"], params[p + "ln2_b"])
        hdn = jax.nn.gelu(mlp_in @ params[p + "wff1"] + params[p + "bff1"])
        x = x + hdn @ params[p + "wff2"] + params[p + "bff2"]
    x = layer_norm(x, params["final_ln_g"], params["final_ln_b"])
    return x @ params["head_w"] + params["head_b"]


def nll_loss(params: Params, feat, pose, tq, target, cfg: ModelConfig,
             method: str):
    """Masked mean cross-entropy; target < 0 means no loss at that token."""
    logits = forward(params, feat, pose, tq, cfg, method)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.clip(target, 0, cfg.n_actions - 1)
    chosen = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = logz - chosen
    mask = (target >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def decode(params: Params, feat, pose, tq, seed, temperature,
           cfg: ModelConfig, method: str):
    """Sample actions at every token: returns (actions, logp, logits)."""
    logits = forward(params, feat, pose, tq, cfg, method)
    key = jax.random.PRNGKey(seed)
    scaled = logits / jnp.maximum(temperature, 1e-3)
    actions = jax.random.categorical(key, scaled, axis=-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    chosen = jnp.take_along_axis(logp, actions[..., None], axis=-1)[..., 0]
    return actions.astype(jnp.int32), chosen, logits
