"""Shared model / kernel configuration.

This module is the single source of truth for the static shapes baked into
the AOT artifacts.  The Rust runtime reads the same values from the manifest
files emitted by ``aot.py`` — change them here and re-run ``make artifacts``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# Attention method identifiers. These strings appear in artifact filenames
# and in the Rust `AttentionMethod` enum — keep them in sync.
METHOD_ABS = "abs"
METHOD_ROPE2D = "rope2d"
METHOD_SE2REP = "se2rep"
METHOD_SE2FOURIER = "se2fourier"
ALL_METHODS = (METHOD_ABS, METHOD_ROPE2D, METHOD_SE2REP, METHOD_SE2FOURIER)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer configuration for the agent-simulation model.

    The head dimension must be divisible by 6 (SE(2) Fourier blocks), 4
    (2D RoPE blocks) and 3 (SE(2) representation blocks); 48 and 96 are the
    natural choices.
    """

    # -- transformer -----------------------------------------------------
    n_layers: int = 2
    n_heads: int = 2
    head_dim: int = 48
    d_model: int = 96
    d_ff: int = 192
    # -- tokens ----------------------------------------------------------
    n_tokens: int = 64          # tokens per scene (map + agent-step tokens)
    feat_dim: int = 16          # raw token feature width
    n_actions: int = 64         # discrete action codebook size
    # -- SE(2) Fourier ---------------------------------------------------
    fourier_f: int = 12         # basis size F (paper Fig 3: F=12 ~ radius 2)
    # Per-block spatial scales applied to (x, y) before the rotary /
    # Fourier machinery, cycled across blocks (paper Sec III-C, [17]).
    # All <= 1: scaling *down* keeps the effective key radius inside the
    # Fourier-accurate band of Fig. 3 (radius <= 4 at F ~ 18).
    spatial_scales: Tuple[float, ...] = (1.0, 0.5, 0.25, 0.125)
    # -- training --------------------------------------------------------
    batch_size: int = 8
    learning_rate: float = 3e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    # -- masking sentinels -----------------------------------------------
    map_timestep: int = -1      # timestep id for map tokens (visible to all)
    pad_timestep: int = -1000   # timestep id for padding tokens (masked out)
    no_loss_target: int = -1    # target id meaning "no loss at this token"

    @property
    def se2f_blocks(self) -> int:
        """Number of 6-wide SE(2) Fourier blocks per head."""
        assert self.head_dim % 6 == 0
        return self.head_dim // 6

    @property
    def se2f_proj_dim(self) -> int:
        """Projected per-head width c = (4F + 2) * blocks (paper Sec III-C)."""
        return (4 * self.fourier_f + 2) * self.se2f_blocks

    def proj_dim(self, method: str) -> int:
        """Per-head width after the method's phi_q/phi_k projection."""
        if method == METHOD_SE2FOURIER:
            return self.se2f_proj_dim
        return self.head_dim


DEFAULT_CONFIG = ModelConfig()

# A tiny configuration used by fast unit tests.
TEST_CONFIG = ModelConfig(
    n_layers=1,
    n_heads=1,
    head_dim=12,
    d_model=12,
    d_ff=24,
    n_tokens=16,
    feat_dim=8,
    n_actions=16,
    fourier_f=12,
    batch_size=2,
)
