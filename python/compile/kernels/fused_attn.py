"""Fused SE(2) Fourier attention: one Pallas kernel for the whole of
Algorithm 2 (phi_q/phi_k projections + flash SDPA + phi_q unprojection).

Rationale (DESIGN.md §8 / EXPERIMENTS.md §Perf): the projected
q~/k~/v~/o~ tensors are (4F+2)/6 ~ 8.3x wider than the raw heads.  In the
unfused path they round-trip HBM between the projection kernels and the
SDPA kernel; fusing keeps them in VMEM for the lifetime of a q-tile.  VMEM
budget at (block_q=64, full K=64, c=400): q~ + k~ + v~ + acc ~= 4 * 64 *
400 * 4 B = 410 KiB — comfortably inside a TPU core's ~16 MiB.

Trade-off: with more than one q-tile the key-side projection is recomputed
per tile (k~/v~ are tile-invariant).  At the model's N=64 there is exactly
one tile, so fusion is a pure win; for long sequences the unfused path
amortizes better — both are provided and benchmarked.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import se2_fourier as se2f

NEG_INF = -1e30


def _fused_kernel(f, scale_pref, sm_scale,
                  pose_ref, q_ref, k_ref, v_ref, tq_ref, tk_ref,
                  scales_ref, o_ref):
    """Single-tile fused Algorithm 2 (q-tile x full keys)."""
    scales = scales_ref[...]
    pose_q = pose_ref[...]  # (bq, 3) — q-tile poses
    pose_k = pose_ref[...]  # self-attention: same pose table
    # ---- projections (Eq. 19), all in VMEM -----------------------------
    qt = se2f.project_q_jnp(q_ref[...], pose_q, scales, f, scale_pref)
    kt = se2f.project_k_jnp(k_ref[...], pose_k, scales, f, scale_pref)
    vt = se2f.project_k_jnp(v_ref[...], pose_k, scales, f, 1.0)
    # ---- SDPA with the visibility rule ---------------------------------
    s = jnp.dot(qt, kt.T) * sm_scale
    mask = tq_ref[...][:, None] >= tk_ref[...][None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m) * mask
    l = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    ot = jnp.dot(p / l, vt)
    # ---- unprojection (Alg. 2 line 4) -----------------------------------
    o_ref[...] = se2f.unproject_o_jnp(ot, pose_q, scales, f)


def fused_se2f_attention(q, k, v, pose, tq, f, spatial_scales):
    """Fused single-head SE(2) Fourier attention.  q/k/v: (N, d) with
    d % 6 == 0; pose: (N, 3); tq: (N,) visibility timesteps.

    Self-attention only (key poses == query poses), matching the
    `attn_se2fourier` artifact's contract.
    """
    n, d = q.shape
    c = (4 * f + 2) * (d // 6)
    pref = (c / d) ** 0.25
    sm_scale = 1.0 / math.sqrt(c)
    nb = d // 6
    scales_arr = jnp.asarray(
        [float(spatial_scales[j % len(spatial_scales)]) for j in range(nb)],
        jnp.float32,
    )
    kern = functools.partial(_fused_kernel, f, pref, sm_scale)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, 3), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(pose, q, k, v, tq, tq, scales_arr)
