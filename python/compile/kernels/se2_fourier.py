"""SE(2) Fourier projections — the paper's contribution (Sec. III).

Implements the linear-memory factorization

    phi_q(p_n) phi_k(p_m)  ~=  diag[rho(x_rel), rho(y_rel), rho(theta_rel)]

per 6-wide feature block (Eq. 19/20).  Three entry points:

* ``project_q(q, pose)``          -> q_tilde  (..., (4F+2) * B)
* ``project_k(k, pose)``          -> k_tilde / v_tilde
* ``unproject_o(o_tilde, pose)``  -> o        (..., 6 * B)

Each has a pure-jnp implementation (``*_jnp``) and a Pallas kernel
(``*_pallas``, interpret=True for CPU-PJRT per the image constraint).  The
Pallas kernels tile over tokens: per tile the key-side kernel evaluates
``u = x cos z + y sin z`` on the constant 2F-point quadrature grid and
contracts against the constant quadrature matrix — a (T*B, 2F) x (2F, F)
matmul that maps directly onto the MXU on real hardware.

Layout per 6-wide input block j (scale a_j): input features
``[qx0 qx1 qy0 qy1 qt0 qt1]`` map to projected features
``[x-cos part (F) | x-sin part (F) | y-cos (F) | y-sin (F) | theta pair (2)]``
of width 4F+2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import basis as basis_mod
from .rope import block_scales

# Token tile for the Pallas projection kernels. 64 tokens x (4F+2)B floats
# comfortably fits VMEM (see DESIGN.md §8).
TILE = 64


def _prep(pose, scales):
    """Scaled coordinates per block: x, y (..., B); theta terms (..., 1)."""
    x = pose[..., 0:1] * scales
    y = pose[..., 1:2] * scales
    t = pose[..., 2:3]  # keep the trailing axis for broadcasting vs (..., B)
    return x, y, t


# --------------------------------------------------------------------------
# pure-jnp reference/fallback implementations
# --------------------------------------------------------------------------

def project_q_jnp(q, pose, scales, f: int, scale_pref: float = 1.0):
    """q_tilde = scale_pref * phi_q(p)^T q (Alg. 2 line 1).

    q: (..., 6B), pose: (..., 3) -> (..., (4F+2) B).
    """
    nb = q.shape[-1] // 6
    blocks = q.reshape(q.shape[:-1] + (nb, 6))
    x, y, t = _prep(pose, scales)
    ct, st = jnp.cos(t), jnp.sin(t)  # (..., 1)
    b = basis_mod.eval_basis(t[..., 0], f)[..., None, :]  # (..., 1, F)
    vx = -x * ct - y * st  # (..., B)
    vy = x * st - y * ct
    cx, sx = jnp.cos(vx)[..., None], jnp.sin(vx)[..., None]  # (..., B, 1)
    cy, sy = jnp.cos(vy)[..., None], jnp.sin(vy)[..., None]
    q0, q1 = blocks[..., 0:1], blocks[..., 1:2]
    q2, q3 = blocks[..., 2:3], blocks[..., 3:4]
    q4, q5 = blocks[..., 4], blocks[..., 5]  # (..., B)
    out = jnp.concatenate(
        [
            b * (cx * q0 + sx * q1),      # (..., B, F)
            b * (-sx * q0 + cx * q1),
            b * (cy * q2 + sy * q3),
            b * (-sy * q2 + cy * q3),
            # theta pair: phi_q^(theta)^T = rho(-t)^T = rho(t)
            jnp.stack([ct * q4 - st * q5, st * q4 + ct * q5], axis=-1),
        ],
        axis=-1,
    )
    return (scale_pref * out).reshape(q.shape[:-1] + (-1,))


def project_k_jnp(k, pose, scales, f: int, scale_pref: float = 1.0):
    """k_tilde = scale_pref * phi_k(p) k (Alg. 2 line 2).

    Use scale_pref=1 for the value path."""
    nb = k.shape[-1] // 6
    blocks = k.reshape(k.shape[:-1] + (nb, 6))
    x, y, t = _prep(pose, scales)
    ct, st = jnp.cos(t), jnp.sin(t)  # (..., 1)
    gx, lx = basis_mod.fourier_coefficients(x, y, f, "x")  # (..., B, F)
    gy, ly = basis_mod.fourier_coefficients(x, y, f, "y")
    k0, k1 = blocks[..., 0:1], blocks[..., 1:2]
    k2, k3 = blocks[..., 2:3], blocks[..., 3:4]
    k4, k5 = blocks[..., 4], blocks[..., 5]  # (..., B)
    out = jnp.concatenate(
        [
            gx * k0 - lx * k1,
            lx * k0 + gx * k1,
            gy * k2 - ly * k3,
            ly * k2 + gy * k3,
            # theta pair: phi_k^(theta) = rho(t)
            jnp.stack([ct * k4 - st * k5, st * k4 + ct * k5], axis=-1),
        ],
        axis=-1,
    )
    return (scale_pref * out).reshape(k.shape[:-1] + (-1,))


def unproject_o_jnp(ot, pose, scales, f: int):
    """o = phi_q(p) o_tilde (Alg. 2 line 4): (..., (4F+2)B) -> (..., 6B)."""
    w = 4 * f + 2
    nb = ot.shape[-1] // w
    blocks = ot.reshape(ot.shape[:-1] + (nb, w))
    x, y, t = _prep(pose, scales)
    ct, st = jnp.cos(t), jnp.sin(t)  # (..., 1)
    b = basis_mod.eval_basis(t[..., 0], f)[..., None, :]  # (..., 1, F)
    vx = -x * ct - y * st  # (..., B)
    vy = x * st - y * ct
    cx, sx = jnp.cos(vx), jnp.sin(vx)  # (..., B)
    cy, sy = jnp.cos(vy), jnp.sin(vy)
    # b-contractions: per block, s = b . ot_slice
    sxa = jnp.sum(b * blocks[..., 0:f], axis=-1)  # (..., B)
    sxb = jnp.sum(b * blocks[..., f : 2 * f], axis=-1)
    sya = jnp.sum(b * blocks[..., 2 * f : 3 * f], axis=-1)
    syb = jnp.sum(b * blocks[..., 3 * f : 4 * f], axis=-1)
    o4, o5 = blocks[..., 4 * f], blocks[..., 4 * f + 1]  # (..., B)
    out = jnp.stack(
        [
            cx * sxa - sx * sxb,
            sx * sxa + cx * sxb,
            cy * sya - sy * syb,
            sy * sya + cy * syb,
            # theta pair: phi_q^(theta) = rho(-t)
            ct * o4 + st * o5,
            -st * o4 + ct * o5,
        ],
        axis=-1,
    )
    return out.reshape(ot.shape[:-1] + (-1,))


# --------------------------------------------------------------------------
# Pallas kernels (token-tiled)
# --------------------------------------------------------------------------

def _q_kernel(f, scale_pref, pose_ref, q_ref, scales_ref, o_ref):
    o_ref[...] = project_q_jnp(
        q_ref[...], pose_ref[...], scales_ref[...], f, scale_pref
    )


def _k_kernel(f, scale_pref, pose_ref, k_ref, scales_ref, o_ref):
    o_ref[...] = project_k_jnp(
        k_ref[...], pose_ref[...], scales_ref[...], f, scale_pref
    )


def _o_kernel(f, pose_ref, ot_ref, scales_ref, o_ref):
    o_ref[...] = unproject_o_jnp(ot_ref[...], pose_ref[...], scales_ref[...], f)


def _tile_for(n: int) -> int:
    return TILE if n % TILE == 0 else n


def _projection_call(kernel, pose, x, scales, out_w):
    n, d = x.shape
    tile = _tile_for(n)
    nb = scales.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, 3), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, out_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, out_w), jnp.float32),
        interpret=True,
    )(pose, x, scales)


def project_q_pallas(q, pose, scales, f: int, scale_pref: float = 1.0):
    """Pallas-tiled q projection.  q: (N, 6B), pose: (N, 3)."""
    nb = q.shape[-1] // 6
    sc = jnp.broadcast_to(jnp.asarray(scales, jnp.float32), (nb,))
    out_w = (4 * f + 2) * nb
    return _projection_call(
        functools.partial(_q_kernel, f, scale_pref), pose, q, sc, out_w
    )


def project_k_pallas(k, pose, scales, f: int, scale_pref: float = 1.0):
    """Pallas-tiled k (or v with scale_pref=1) projection."""
    nb = k.shape[-1] // 6
    sc = jnp.broadcast_to(jnp.asarray(scales, jnp.float32), (nb,))
    out_w = (4 * f + 2) * nb
    return _projection_call(
        functools.partial(_k_kernel, f, scale_pref), pose, k, sc, out_w
    )


def unproject_o_pallas(ot, pose, scales, f: int):
    """Pallas-tiled output unprojection.  ot: (N, (4F+2)B)."""
    nb = ot.shape[-1] // (4 * f + 2)
    sc = jnp.broadcast_to(jnp.asarray(scales, jnp.float32), (nb,))
    return _projection_call(
        functools.partial(_o_kernel, f), pose, ot, sc, 6 * nb
    )


def scales_for(head_dim: int, spatial_scales) -> jnp.ndarray:
    return block_scales(head_dim, 6, spatial_scales)
