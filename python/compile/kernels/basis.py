"""Fourier basis machinery for SE(2) Fourier attention (paper Sec. III-B).

The basis functions are (paper Eq. 12)::

    g_0(z) = 1
    g_1(z) = sin(z)     g_2(z) = cos(z)
    g_3(z) = sin(2z)    g_4(z) = cos(2z)   ...

i.e. ``g_i(z) = cos((i/2) z)`` for even i and ``sin(((i+1)/2) z)`` for odd i.

The key-side coefficients ``Gamma_m(i)`` / ``Lambda_m(i)`` (Eq. 14/15) are
the Fourier coefficients of ``cos(u_m(z))`` / ``sin(u_m(z))`` where
``u_m^{(x)}(z) = x_m cos z + y_m sin z``.  They are computed by the paper's
recipe: numerical integration over a uniform 2F-point grid, which for a
2π-periodic integrand is the (exact-up-to-aliasing) trapezoid rule and
reduces to a single small matmul against a constant quadrature matrix —
MXU-friendly by construction.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def basis_frequencies(f: int) -> np.ndarray:
    """Integer frequency of each basis element: [0, 1, 1, 2, 2, 3, 3, ...]."""
    i = np.arange(f)
    return np.where(i % 2 == 0, i // 2, (i + 1) // 2)


def eval_basis(theta, f: int):
    """Evaluate b = [g_0(theta), ..., g_{F-1}(theta)] (paper Sec. III-B).

    theta: (...,) -> returns (..., F).

    Built entirely from ``jnp.arange`` (lowered as iota) so it can be used
    inside Pallas kernel bodies without captured host constants.
    """
    i = jnp.arange(f)
    freqs = ((i + 1) // 2).astype(theta.dtype)  # 0, 1, 1, 2, 2, ...
    ang = theta[..., None] * freqs  # (..., F)
    even = i % 2 == 0
    return jnp.where(even, jnp.cos(ang), jnp.sin(ang))


def quadrature_grid(f: int) -> np.ndarray:
    """The 2F-point uniform grid z_j on [-pi, pi) used for Eq. 14/15."""
    return -np.pi + np.pi * np.arange(2 * f) / f


def quadrature_matrix(f: int) -> np.ndarray:
    """Constant matrix W of shape (2F, F) such that for samples
    ``s_j = h(z_j)`` of a periodic function h, ``s @ W`` gives the basis
    coefficients ``(a_i / 2F) * sum_j h(z_j) g_i(z_j)`` (Eq. 14).
    """
    z = quadrature_grid(f)  # (2F,)
    freqs = basis_frequencies(f)
    ang = np.outer(z, freqs)  # (2F, F)
    even = np.arange(f) % 2 == 0
    g = np.where(even, np.cos(ang), np.sin(ang))
    a = np.where(np.arange(f) == 0, 1.0, 2.0)
    return (g * a) / (2.0 * f)


def quadrature_grid_jnp(f: int, dtype=jnp.float32):
    """jnp/iota version of ``quadrature_grid`` (Pallas-kernel safe)."""
    return (-jnp.pi + jnp.pi * jnp.arange(2 * f) / f).astype(dtype)


def quadrature_matrix_jnp(f: int, dtype=jnp.float32):
    """jnp/iota version of ``quadrature_matrix`` (Pallas-kernel safe)."""
    z = quadrature_grid_jnp(f, dtype)
    i = jnp.arange(f)
    freqs = ((i + 1) // 2).astype(dtype)
    ang = z[:, None] * freqs  # (2F, F)
    even = i % 2 == 0
    g = jnp.where(even, jnp.cos(ang), jnp.sin(ang))
    a = jnp.where(i == 0, 1.0, 2.0).astype(dtype)
    return (g * a) / (2.0 * f)


def u_x(x, y, z):
    """u_m^{(x)}(z) = x cos z + y sin z (paper Eq. 11)."""
    return x[..., None] * jnp.cos(z) + y[..., None] * jnp.sin(z)


def u_y(x, y, z):
    """u_m^{(y)}(z) = -x sin z + y cos z (paper Eq. 18)."""
    return -x[..., None] * jnp.sin(z) + y[..., None] * jnp.cos(z)


def fourier_coefficients(x, y, f: int, axis: str = "x"):
    """Gamma_m, Lambda_m of shape (..., F) for key position (x, y).

    axis='x' approximates cos/sin of u^{(x)}; axis='y' of u^{(y)}.
    Implements Eq. 14/15 with 2F-point quadrature.
    """
    z = quadrature_grid_jnp(f, x.dtype)
    w = quadrature_matrix_jnp(f, x.dtype)
    u = u_x(x, y, z) if axis == "x" else u_y(x, y, z)  # (..., 2F)
    gamma = jnp.matmul(jnp.cos(u), w)  # (..., F)
    lam = jnp.matmul(jnp.sin(u), w)  # (..., F)
    return gamma, lam


def approx_cos_u(x, y, theta, f: int, axis: str = "x"):
    """Reconstruct the Fourier approximation of cos(u(theta)) — used by the
    Fig. 4 reproduction and by unit tests.

    x, y: (...,) key position; theta: (T,) -> returns (..., T).
    """
    gamma, _ = fourier_coefficients(x, y, f, axis)  # (..., F)
    b = eval_basis(theta, f)  # (T, F)
    return jnp.matmul(gamma, b.T)
