"""Flash-attention-style SDPA Pallas kernel (linear memory).

This is the ``SDPA`` subroutine of the paper's Algorithm 2: a standard
scaled-dot-product attention that never materializes the N x M score matrix.
Forward and backward are both Pallas kernels using the FlashAttention-2
recomputation scheme, wired together with ``jax.custom_vjp`` so the model
can train through it.

Masking: instead of an (N, M) boolean mask (which would itself be quadratic
memory), visibility is derived inside the kernel from two *linear* integer
vectors ``tq`` (N,) and ``tk`` (M,): token n sees token m iff
``tq[n] >= tk[m]``.  The agent-simulation model encodes

    map tokens      -> timestep -1   (visible to everyone)
    agent tokens    -> timestep  t   (causal by scene time)
    padding tokens  -> timestep  PAD_T = 2^30  (see nothing / seen by nobody)

Rows with no visible key produce zeros (guarded divide).

On real TPU hardware the k-loop would move into the grid with BlockSpec
streaming HBM->VMEM; under interpret=True we keep the loop inside the kernel
body, which is numerically identical (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
PAD_T = 1 << 30

# 64 x 64 blocks: at the model's N=64 the k-loop runs exactly once, which
# matters twice over — on TPU it is the MXU-native tile, and under
# interpret=True it minimizes the per-iteration interpreter overhead that
# dominates CPU wall-clock (see EXPERIMENTS.md §Perf, L1 iteration 1).
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _pick_block(n: int, pref: int) -> int:
    if n % pref == 0:
        return pref
    for b in (64, 32, 16, 8, 4, 2, 1):
        if b <= pref and n % b == 0:
            return b
    return n


# --------------------------------------------------------------------------
# forward kernel
# --------------------------------------------------------------------------

def _fwd_kernel(block_k, scale, q_ref, k_ref, v_ref, tq_ref, tk_ref,
                o_ref, lse_ref):
    bq, c = q_ref.shape
    m_tot, cv = v_ref.shape
    q = q_ref[...]
    tq = tq_ref[...]

    def body(j, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None)))
        tk_blk = pl.load(tk_ref, (pl.ds(j * block_k, block_k),))
        s = jnp.dot(q, k_blk.T) * scale  # (bq, bk)
        mask = tq[:, None] >= tk_blk[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask  # re-mask: exp(0) rows
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, cv), jnp.float32)
    m_f, l_f, acc_f = jax.lax.fori_loop(0, m_tot // block_k, body,
                                        (m0, l0, acc0))
    safe_l = jnp.maximum(l_f, 1e-30)
    o_ref[...] = acc_f / safe_l[:, None]
    # log-sum-exp per row, saved for the backward pass
    lse_ref[...] = m_f + jnp.log(safe_l)


def _flash_fwd(q, k, v, tq, tk, scale, block_q, block_k):
    n, c = q.shape
    m, cv = k.shape[0], v.shape[1]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    kern = functools.partial(_fwd_kernel, bk, scale)
    o, lse = pl.pallas_call(
        kern,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, cv), lambda i: (0, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, cv), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, cv), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, tq, tk)
    return o, lse


# --------------------------------------------------------------------------
# backward kernels (FlashAttention-2 style recomputation)
# --------------------------------------------------------------------------

def _bwd_dq_kernel(block_k, scale, q_ref, k_ref, v_ref, tq_ref, tk_ref,
                   lse_ref, do_ref, delta_ref, dq_ref):
    m_tot = k_ref.shape[0]
    q = q_ref[...]
    tq = tq_ref[...]
    lse = lse_ref[...]
    do = do_ref[...]
    delta = delta_ref[...]

    def body(j, dq):
        k_blk = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None)))
        tk_blk = pl.load(tk_ref, (pl.ds(j * block_k, block_k),))
        s = jnp.dot(q, k_blk.T) * scale
        mask = tq[:, None] >= tk_blk[None, :]
        p = jnp.exp(jnp.where(mask, s, NEG_INF) - lse[:, None]) * mask
        dp = jnp.dot(do, v_blk.T)  # (bq, bk)
        ds = p * (dp - delta[:, None])
        return dq + jnp.dot(ds, k_blk) * scale

    dq0 = jnp.zeros_like(q)
    dq_ref[...] = jax.lax.fori_loop(0, m_tot // block_k, body, dq0)


def _bwd_dkv_kernel(block_q, scale, q_ref, k_ref, v_ref, tq_ref, tk_ref,
                    lse_ref, do_ref, delta_ref, dk_ref, dv_ref):
    n_tot = q_ref.shape[0]
    k_blk = k_ref[...]
    v_blk = v_ref[...]
    tk = tk_ref[...]

    def body(i, carry):
        dk, dv = carry
        q_blk = pl.load(q_ref, (pl.ds(i * block_q, block_q), slice(None)))
        tq_blk = pl.load(tq_ref, (pl.ds(i * block_q, block_q),))
        lse_blk = pl.load(lse_ref, (pl.ds(i * block_q, block_q),))
        do_blk = pl.load(do_ref, (pl.ds(i * block_q, block_q), slice(None)))
        delta_blk = pl.load(delta_ref, (pl.ds(i * block_q, block_q),))
        s = jnp.dot(q_blk, k_blk.T) * scale  # (bq, bk)
        mask = tq_blk[:, None] >= tk[None, :]
        p = jnp.exp(jnp.where(mask, s, NEG_INF) - lse_blk[:, None]) * mask
        dv_new = dv + jnp.dot(p.T, do_blk)
        dp = jnp.dot(do_blk, v_blk.T)
        ds = p * (dp - delta_blk[:, None])
        dk_new = dk + jnp.dot(ds.T, q_blk) * scale
        return dk_new, dv_new

    dk0 = jnp.zeros_like(k_blk)
    dv0 = jnp.zeros_like(v_blk)
    dk_f, dv_f = jax.lax.fori_loop(0, n_tot // block_q, body, (dk0, dv0))
    dk_ref[...] = dk_f
    dv_ref[...] = dv_f


def _flash_bwd(q, k, v, tq, tk, o, lse, do, scale, block_q, block_k):
    n, c = q.shape
    m, cv = k.shape[0], v.shape[1]
    bq = _pick_block(n, block_q)
    bk = _pick_block(m, block_k)
    # delta_n = sum_c do_nc * o_nc  (FlashAttention-2 Eq. for D)
    delta = jnp.sum(do * o, axis=-1)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bk, scale),
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
            pl.BlockSpec((m, cv), lambda i: (0, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq, cv), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=True,
    )(q, k, v, tq, tk, lse, do, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq, scale),
        grid=(m // bk,),
        in_specs=[
            pl.BlockSpec((n, c), lambda j: (0, 0)),
            pl.BlockSpec((bk, c), lambda j: (j, 0)),
            pl.BlockSpec((bk, cv), lambda j: (j, 0)),
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((bk,), lambda j: (j,)),
            pl.BlockSpec((n,), lambda j: (0,)),
            pl.BlockSpec((n, cv), lambda j: (0, 0)),
            pl.BlockSpec((n,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bk, c), lambda j: (j, 0)),
            pl.BlockSpec((bk, cv), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, c), jnp.float32),
            jax.ShapeDtypeStruct((m, cv), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, tq, tk, lse, do, delta)
    return dq, dk, dv


# --------------------------------------------------------------------------
# custom-vjp wrapper
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_sdpa(q, k, v, tq, tk, scale,
               block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Linear-memory SDPA.  q: (N, c), k: (M, c), v: (M, cv);
    tq: (N,) int32, tk: (M,) int32 visibility timesteps."""
    o, _ = _flash_fwd(q, k, v, tq, tk, scale, block_q, block_k)
    return o


def _vjp_fwd(q, k, v, tq, tk, scale, block_q, block_k):
    o, lse = _flash_fwd(q, k, v, tq, tk, scale, block_q, block_k)
    return o, (q, k, v, tq, tk, o, lse)


def _vjp_bwd(scale, block_q, block_k, res, do):
    q, k, v, tq, tk, o, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, tq, tk, o, lse, do,
                            scale, block_q, block_k)
    return dq, dk, dv, None, None


flash_sdpa.defvjp(_vjp_fwd, _vjp_bwd)


def flash_sdpa_batched(q, k, v, tq, tk, scale,
                       block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """vmapped flash_sdpa over (B, H): q (B, H, N, c), tq (B, N)."""
    inner = lambda qq, kk, vv, tqq, tkk: flash_sdpa(
        qq, kk, vv, tqq, tkk, scale, block_q, block_k
    )
    over_heads = jax.vmap(inner, in_axes=(0, 0, 0, None, None))
    over_batch = jax.vmap(over_heads, in_axes=(0, 0, 0, 0, 0))
    return over_batch(q, k, v, tq, tk)
