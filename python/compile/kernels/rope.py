"""Vectorized projections for the baseline relative-attention methods.

These are the *fast* (linear-memory, per-token) forms of phi_q^T / phi_k for
2D RoPE (paper Eq. 7) and the SE(2) representation (paper Eq. 9).  Both are
cheap elementwise/3x3 operations that XLA fuses into the attention prologue,
so they do not need a dedicated Pallas kernel; the SE(2) Fourier projection
(the paper's contribution, with its quadrature matmul) lives in
``se2_fourier.py`` as a Pallas kernel.

All functions take

    x     : (..., d)  per-head features
    pose  : (..., 3)  SE(2) pose per token
    scales: (B,)      per-block spatial scale (B = d // block_width)

and return the projected features with the same leading shape.
"""

from __future__ import annotations

import jax.numpy as jnp


def _pair_rotate(x_pairs, angle):
    """Rotate feature pairs by ``angle``: x_pairs (..., B, 2), angle (..., B)."""
    c, s = jnp.cos(angle), jnp.sin(angle)
    x0, x1 = x_pairs[..., 0], x_pairs[..., 1]
    return jnp.stack([c * x0 - s * x1, s * x0 + c * x1], axis=-1)


def rope1d_project(x, positions, scales):
    """Classic RoPE (paper Eq. 6): blocks of 2, angle = scale * position.

    Applied identically to queries and keys (phi_q(p)^T = phi_k(p) = rho(ap)).
    positions: (...,) scalar location per token.
    """
    d = x.shape[-1]
    nb = d // 2
    pairs = x.reshape(x.shape[:-1] + (nb, 2))
    angle = positions[..., None] * scales
    return _pair_rotate(pairs, angle).reshape(x.shape)


def rope2d_project(x, pose, scales):
    """2D RoPE (paper Eq. 7): blocks of 4 = [x-pair, y-pair].

    Identical for queries and keys.  Ignores pose[..., 2] (not rotation
    invariant — that is the paper's Fig. 1(b) point).
    """
    d = x.shape[-1]
    nb = d // 4
    pairs = x.reshape(x.shape[:-1] + (2 * nb, 2))
    ax = pose[..., 0:1] * scales  # (..., B)
    ay = pose[..., 1:2] * scales
    angle = jnp.stack([ax, ay], axis=-1).reshape(pose.shape[:-1] + (2 * nb,))
    return _pair_rotate(pairs, angle).reshape(x.shape)


def _se2_apply(x_triples, pose, scales, inverse, transpose):
    """Apply psi(pose) (optionally of the inverse pose, optionally
    transposed) to feature triples: x_triples (..., B, 3)."""
    px = pose[..., 0:1] * scales
    py = pose[..., 1:2] * scales
    t = jnp.broadcast_to(pose[..., 2:3], px.shape)
    if inverse:
        c, s = jnp.cos(t), jnp.sin(t)
        px, py, t = -c * px - s * py, s * px - c * py, -t
    c, s = jnp.cos(t), jnp.sin(t)
    x0, x1, x2 = x_triples[..., 0], x_triples[..., 1], x_triples[..., 2]
    if not transpose:
        # [c -s px; s c py; 0 0 1] @ [x0 x1 x2]
        return jnp.stack(
            [c * x0 - s * x1 + px * x2, s * x0 + c * x1 + py * x2, x2],
            axis=-1,
        )
    # transpose: [c s 0; -s c 0; px py 1] @ [x0 x1 x2]
    return jnp.stack(
        [c * x0 + s * x1, -s * x0 + c * x1, px * x0 + py * x1 + x2],
        axis=-1,
    )


def se2rep_project_q(x, pose, scales):
    """phi_q(p)^T q with phi_q = psi(p^{-1}) (paper Eq. 9)."""
    d = x.shape[-1]
    triples = x.reshape(x.shape[:-1] + (d // 3, 3))
    out = _se2_apply(triples, pose, scales, inverse=True, transpose=True)
    return out.reshape(x.shape)


def se2rep_project_k(x, pose, scales):
    """phi_k(p) k with phi_k = psi(p) (paper Eq. 9).  Also used for values."""
    d = x.shape[-1]
    triples = x.reshape(x.shape[:-1] + (d // 3, 3))
    out = _se2_apply(triples, pose, scales, inverse=False, transpose=False)
    return out.reshape(x.shape)


def se2rep_unproject_o(x, pose, scales):
    """phi_q(p) o_tilde — the post-attention output map (Alg. 2 line 4)."""
    d = x.shape[-1]
    triples = x.reshape(x.shape[:-1] + (d // 3, 3))
    out = _se2_apply(triples, pose, scales, inverse=True, transpose=False)
    return out.reshape(x.shape)


def block_scales(head_dim: int, block: int, spatial_scales) -> jnp.ndarray:
    """The per-block scale ladder, cycled (paper Sec. III-C / [17])."""
    nb = head_dim // block
    vals = [spatial_scales[j % len(spatial_scales)] for j in range(nb)]
    return jnp.asarray(vals, dtype=jnp.float32)
