"""Pure-jnp correctness oracles.

Two layers of reference:

1. ``algorithm1`` — the paper's Algorithm 1 (quadratic-memory relative
   SDPA): explicitly materializes ``phi(p_{n->m})`` for every query/key pair.
   This is the ground truth every linear-memory implementation is checked
   against.
2. Explicit *matrix* builders for ``phi_q`` / ``phi_k`` of each method
   (Eq. 6/7/9/19).  The fast vectorized projections in ``se2_fourier.py`` /
   ``rope.py`` must match these matrices applied naively.

Everything here is deliberately simple and quadratic; nothing from this file
is ever lowered into an artifact.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import geometry
from . import basis as basis_mod

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Reference scaled dot-product attention
# --------------------------------------------------------------------------

def naive_sdpa(q, k, v, scale=None, mask=None):
    """Reference SDPA.  q: (N, c), k/v: (M, c) / (M, cv), mask: (N, M) bool."""
    c = q.shape[-1]
    scale = (1.0 / jnp.sqrt(c)) if scale is None else scale
    logits = jnp.matmul(q, k.T) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    a = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    return jnp.matmul(a, v)


def visibility_mask(tq, tk, valid_q, valid_k):
    """The model's attention rule: token n sees token m iff t_n >= t_m and
    both are valid.  Map tokens carry timestep -1 so they are visible to
    everyone (and see only other map tokens)."""
    see = tq[:, None] >= tk[None, :]
    return see & valid_q[:, None] & valid_k[None, :]


# --------------------------------------------------------------------------
# phi(p_rel) builders — the *target* matrices of each method
# --------------------------------------------------------------------------

def _block_diag(mats):
    """Stack a list of (..., a, b) matrices block-diagonally -> (..., A, B)."""
    rows = sum(m.shape[-2] for m in mats)
    cols = sum(m.shape[-1] for m in mats)
    batch = jnp.broadcast_shapes(*[m.shape[:-2] for m in mats])
    out = jnp.zeros(batch + (rows, cols), dtype=mats[0].dtype)
    r = c = 0
    for m in mats:
        m = jnp.broadcast_to(m, batch + m.shape[-2:])
        out = out.at[..., r : r + m.shape[-2], c : c + m.shape[-1]].set(m)
        r += m.shape[-2]
        c += m.shape[-1]
    return out


def _scales_for(head_dim: int, block: int, spatial_scales):
    n_blocks = head_dim // block
    return [spatial_scales[j % len(spatial_scales)] for j in range(n_blocks)]


def phi_rel_rope2d(pose_n, pose_m, head_dim, spatial_scales):
    """Eq. 7 stacked: diag over blocks of [rho(a*dx), rho(a*dy)].

    2D RoPE uses the *abelian* relative position (plain subtraction)."""
    dx = pose_m[..., 0] - pose_n[..., 0]
    dy = pose_m[..., 1] - pose_n[..., 1]
    blocks = []
    for a in _scales_for(head_dim, 4, spatial_scales):
        blocks.append(geometry.rot2(a * dx))
        blocks.append(geometry.rot2(a * dy))
    return _block_diag(blocks)


def phi_rel_se2rep(pose_n, pose_m, head_dim, spatial_scales):
    """Eq. 9 stacked: psi(p_n^{-1} p_m) per 3-wide block, positions scaled."""
    rel = geometry.relative(pose_n, pose_m)
    blocks = []
    for a in _scales_for(head_dim, 3, spatial_scales):
        scaled = jnp.stack(
            [a * rel[..., 0], a * rel[..., 1], rel[..., 2]], axis=-1
        )
        blocks.append(geometry.se2_matrix(scaled))
    return _block_diag(blocks)


def phi_rel_se2fourier(pose_n, pose_m, head_dim, spatial_scales):
    """Eq. 10 stacked: diag[rho(x_rel), rho(y_rel), rho(theta_rel)] per
    6-wide block — the *exact* target that SE(2) Fourier approximates."""
    rel = geometry.relative(pose_n, pose_m)
    blocks = []
    for a in _scales_for(head_dim, 6, spatial_scales):
        blocks.append(geometry.rot2(a * rel[..., 0]))
        blocks.append(geometry.rot2(a * rel[..., 1]))
        blocks.append(geometry.rot2(rel[..., 2]))
    return _block_diag(blocks)


PHI_REL = {
    "rope2d": phi_rel_rope2d,
    "se2rep": phi_rel_se2rep,
    "se2fourier": phi_rel_se2fourier,
}


# --------------------------------------------------------------------------
# Algorithm 1 — quadratic-memory relative SDPA (the oracle)
# --------------------------------------------------------------------------

def algorithm1(q, k, v, pose_q, pose_k, method, spatial_scales, mask=None):
    """Paper Algorithm 1.  q: (N, d); k, v: (M, d); poses (N/M, 3)."""
    d = q.shape[-1]
    phi = PHI_REL[method](
        pose_q[:, None, :], pose_k[None, :, :], d, spatial_scales
    )  # (N, M, d, d)
    logits = jnp.einsum("nd,nmde,me->nm", q, phi, k) / jnp.sqrt(d)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    a = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    return jnp.einsum("nm,nmde,me->nd", a, phi, v)


# --------------------------------------------------------------------------
# Explicit phi_q / phi_k matrices (slow; for verifying the fast projections)
# --------------------------------------------------------------------------

def phi_q_mat_rope2d(pose, head_dim, spatial_scales):
    blocks = []
    for a in _scales_for(head_dim, 4, spatial_scales):
        blocks.append(geometry.rot2(-a * pose[..., 0]))
        blocks.append(geometry.rot2(-a * pose[..., 1]))
    return _block_diag(blocks)


def phi_k_mat_rope2d(pose, head_dim, spatial_scales):
    blocks = []
    for a in _scales_for(head_dim, 4, spatial_scales):
        blocks.append(geometry.rot2(a * pose[..., 0]))
        blocks.append(geometry.rot2(a * pose[..., 1]))
    return _block_diag(blocks)


def phi_q_mat_se2rep(pose, head_dim, spatial_scales):
    blocks = []
    for a in _scales_for(head_dim, 3, spatial_scales):
        scaled = jnp.stack(
            [a * pose[..., 0], a * pose[..., 1], pose[..., 2]], axis=-1
        )
        blocks.append(geometry.se2_matrix(geometry.inverse(scaled)))
    return _block_diag(blocks)


def phi_k_mat_se2rep(pose, head_dim, spatial_scales):
    blocks = []
    for a in _scales_for(head_dim, 3, spatial_scales):
        scaled = jnp.stack(
            [a * pose[..., 0], a * pose[..., 1], pose[..., 2]], axis=-1
        )
        blocks.append(geometry.se2_matrix(scaled))
    return _block_diag(blocks)


def _phi_q_fourier_block(pose, a, f):
    """One 6 x (4F+2) query block (paper Eq. 19)."""
    x, y, t = a * pose[..., 0], a * pose[..., 1], pose[..., 2]
    b = basis_mod.eval_basis(t, f)  # (..., F)
    vx = -x * jnp.cos(t) - y * jnp.sin(t)
    vy = x * jnp.sin(t) - y * jnp.cos(t)

    def rot_outer(vv):
        c, s = jnp.cos(vv)[..., None], jnp.sin(vv)[..., None]
        top = jnp.concatenate([c * b, -s * b], axis=-1)  # (..., 2F)
        bot = jnp.concatenate([s * b, c * b], axis=-1)
        return jnp.stack([top, bot], axis=-2)  # (..., 2, 2F)

    theta_blk = geometry.rot2(-t)  # (..., 2, 2)
    return _block_diag([rot_outer(vx), rot_outer(vy), theta_blk])


def _phi_k_fourier_block(pose, a, f):
    """One (4F+2) x 6 key block (paper Eq. 19)."""
    x, y, t = a * pose[..., 0], a * pose[..., 1], pose[..., 2]

    def coeff_mat(axis):
        gamma, lam = basis_mod.fourier_coefficients(x, y, f, axis)
        top = jnp.stack([gamma, -lam], axis=-1)  # (..., F, 2)
        bot = jnp.stack([lam, gamma], axis=-1)
        return jnp.concatenate([top, bot], axis=-2)  # (..., 2F, 2)

    theta_blk = geometry.rot2(t)
    return _block_diag([coeff_mat("x"), coeff_mat("y"), theta_blk])


def phi_q_mat_se2fourier(pose, head_dim, spatial_scales, f):
    blocks = [
        _phi_q_fourier_block(pose, a, f)
        for a in _scales_for(head_dim, 6, spatial_scales)
    ]
    return _block_diag(blocks)


def phi_k_mat_se2fourier(pose, head_dim, spatial_scales, f):
    blocks = [
        _phi_k_fourier_block(pose, a, f)
        for a in _scales_for(head_dim, 6, spatial_scales)
    ]
    return _block_diag(blocks)


def algorithm2_explicit(
    q, k, v, pose_q, pose_k, method, spatial_scales, f=None, mask=None
):
    """Paper Algorithm 2 using the explicit phi_q/phi_k matrices above.

    Used by tests to show Alg2 == Alg1 (exactly for rope2d/se2rep, to
    Fourier tolerance for se2fourier).
    """
    d = q.shape[-1]
    if method == "rope2d":
        pq = phi_q_mat_rope2d(pose_q, d, spatial_scales)
        pk = phi_k_mat_rope2d(pose_k, d, spatial_scales)
    elif method == "se2rep":
        pq = phi_q_mat_se2rep(pose_q, d, spatial_scales)
        pk = phi_k_mat_se2rep(pose_k, d, spatial_scales)
    elif method == "se2fourier":
        pq = phi_q_mat_se2fourier(pose_q, d, spatial_scales, f)
        pk = phi_k_mat_se2fourier(pose_k, d, spatial_scales, f)
    else:
        raise ValueError(method)
    c = pq.shape[-1]
    scale = (float(c) / float(d)) ** 0.25
    qt = scale * jnp.einsum("ndc,nd->nc", pq, q)
    kt = scale * jnp.einsum("mcd,md->mc", pk, k)
    vt = jnp.einsum("mcd,md->mc", pk, v)
    ot = naive_sdpa(qt, kt, vt, scale=1.0 / jnp.sqrt(c), mask=mask)
    return jnp.einsum("ndc,nc->nd", pq, ot)
