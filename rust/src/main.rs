//! `se2-attention` — leader binary: CLI over the coordinator.
//!
//! Subcommands:
//!   info       platform + artifact inventory + scenario-family registry
//!   gen-data   generate family-tagged dataset shards (--family / --mix)
//!   train      train one attention variant, log the loss curve
//!   render     ASCII-render any scenario family (debug)
//!   simulate   batched rollout serving with per-family stats report
//!              (--trace-out / --metrics-out / --profile / --synthetic)
//!   stats      render a metrics snapshot as Prometheus text; validate
//!              trace/metrics exports (CI observability smoke)
//!   approx     SE(2) Fourier approximation error probe (Fig. 3 pointwise)
//!   bench-report  render the README Benchmarks section from BENCH_*.json;
//!              `--compare OLD NEW` diffs two runs and exits nonzero on a
//!              >10% regression in any gated metric (CI bench-regression)

use std::sync::Arc;

use anyhow::{Context, Result};

use se2attn::cli::{App, Command, Matches, ParseOutcome};
use se2attn::config::{Method, SystemConfig};
use se2attn::coordinator::{ModelHandle, RolloutRequest, ServeConfig, Server, Trainer};
use se2attn::fourier;
use se2attn::geometry::Pose;
use se2attn::prng::Rng;
use se2attn::runtime::Engine;

fn app() -> App {
    App::new("se2-attention", "Linear Memory SE(2) Invariant Attention — coordinator")
        .command(Command::new("info", "show platform, config and artifacts")
            .opt("artifacts", "artifacts", "artifact directory"))
        .command(Command::new("gen-data", "generate dataset shards")
            .opt("artifacts", "artifacts", "artifact directory")
            .opt("examples", "512", "number of examples")
            .opt("seed", "0", "generation seed")
            .opt("family", "corridor", "scenario family (see `info`), or 'mixed'")
            .opt("mix", "", "weighted family mix, e.g. 'highway-merge:2,roundabout:1'")
            .opt("out", "data/train.shard", "output shard path"))
        .command(Command::new("train", "train one attention variant")
            .opt("artifacts", "artifacts", "artifact directory")
            .opt("method", "se2fourier", "abs|rope2d|se2rep|se2fourier")
            .opt("steps", "200", "optimizer steps")
            .opt("examples", "256", "dataset size (ignored with --data)")
            .opt("seed", "0", "init + data seed")
            .opt("data", "", "dataset shard to train from (see gen-data)")
            .opt("save", "", "write a checkpoint here when done")
            .opt("resume", "", "restore params/opt-state from a checkpoint")
            .opt("augment", "0", "SE(2) frame-jitter augmentation magnitude (model units; 0 = off)"))
        .command(Command::new("render", "ASCII-render a scenario (debug)")
            .opt("artifacts", "artifacts", "artifact directory")
            .opt("family", "corridor", "scenario family to render (see `info`)")
            .opt("seed", "42", "scenario seed")
            .opt("step", "7", "timestep to draw")
            .flag("futures", "overlay ground-truth futures"))
        .command(Command::new("simulate", "serve batched rollout requests")
            .opt("artifacts", "artifacts", "artifact directory")
            .opt("method", "se2fourier", "attention method")
            .opt("scenes", "16", "number of scenario requests")
            .opt("samples", "4", "rollout samples per scene")
            .opt("family", "corridor", "scenario family (see `info`), or 'mixed'")
            .opt("mix", "", "weighted family mix, e.g. 'urban-crossing:1,roundabout:3'")
            .opt("seed", "0", "scenario seed base")
            .opt("workers", "0", "serving worker shards (0 = one per core, max 8)")
            .opt("worker-procs", "0",
                 "run shards as separate worker *processes* over the local \
                  socket protocol instead of in-process threads (requires \
                  --synthetic; sessions migrate on drain, envelopes replay \
                  on worker death — DESIGN.md §19)")
            .opt("admit-queue", "256",
                 "per-shard admission-queue capacity (a full queue answers \
                  with a typed busy rejection instead of queueing unboundedly)")
            .opt("deadline-ms", "0",
                 "admission deadline: shed queued requests that wait longer \
                  than this before joining a step batch (0 = never shed)")
            .opt("tenant-rate", "0",
                 "per-tenant admission pacing in requests/s via token \
                  buckets (0 = unpaced FIFO admission)")
            .opt("tenant-burst", "8", "per-tenant token-bucket burst size")
            .opt("max-live-sessions", "32",
                 "decode sessions concurrently resident in one shard's \
                  continuous step batch")
            .opt("kernel-threads", "0",
                 "threads per native CPU flash-attention call, for engines \
                  derived from this server's model config (0 = one per core; \
                  bit-identical at any setting; PJRT artifact decode is \
                  threaded by XLA and unaffected)")
            .flag("kernel-autotune",
                  "pick {block_m, lanes, threads} for the native flash \
                   kernel via a one-shot startup microbenchmark instead of \
                   the defaults (SE2ATTN_KERNEL_* env pins still win; \
                   results are bit-identical to an explicit config with \
                   the same shape)")
            .opt("cache-precision", "f32",
                 "storage precision of cached session feature rows \
                  (f32|f16|bf16): f16/bf16 roughly halve resident cache \
                  bytes per session — about twice the sessions per byte \
                  budget — at a bounded feature rounding; poses and \
                  re-anchoring stay exact")
            .opt("trace-out", "",
                 "write a Chrome trace_event JSON timeline of every \
                  request's route/enqueue/batch/tokenize/decode/attend/\
                  respond spans here (enables span tracing; open in \
                  chrome://tracing or Perfetto)")
            .opt("metrics-out", "",
                 "write a JSON metrics snapshot here (render/validate it \
                  with `stats`)")
            .opt("trace-spans", "16384",
                 "span-ring slots per shard when tracing (32 B each; the \
                  ring overwrites oldest spans when full)")
            .opt("obs-addr", "",
                 "serve live introspection HTTP on this address while the \
                  run is in flight (e.g. 127.0.0.1:9464): GET /metrics \
                  (Prometheus), /metrics.json, /memory (allocator \
                  attribution), /trace (Chrome trace), /healthz, \
                  /vars?watch=N")
            .opt("obs-hold-ms", "0",
                 "keep the server and the --obs-addr endpoints alive this \
                  many ms after the request loop drains, so external \
                  scrapers can land a mid-run read (used by the CI \
                  observability smoke)")
            .flag("profile",
                  "enable kernel/cache profiling counters (block skips, \
                   dequantized rows, scratch bytes, evictions) — \
                   reported at exit and in the metrics snapshot")
            .flag("synthetic",
                  "serve the native-kernel synthetic decoder instead of \
                   PJRT artifacts (no artifact directory needed; used by \
                   the CI observability smoke)"))
        .command(Command::new("stats",
                              "render a metrics snapshot as Prometheus text")
            .opt("in", "metrics.json",
                 "metrics snapshot JSON written by `simulate --metrics-out`")
            .opt("prev", "",
                 "earlier snapshot: report the interval delta (counters \
                  and histograms subtract; gauges keep current values)")
            .opt("trace", "",
                 "also validate this Chrome trace JSON: it must parse and \
                  contain spans for every pipeline stage")
            .flag("check",
                  "validate the Prometheus exposition format and report \
                   the sample count on stderr"))
        .command(Command::new("approx", "Fourier approximation error probe")
            .opt("radius", "2.0", "key position radius")
            .opt("basis", "12", "basis size F")
            .opt("trials", "256", "random (key, query) pairs"))
        .command(Command::new("bench-report",
                              "render the README Benchmarks section from BENCH_*.json")
            .opt("attention", "BENCH_attention.json",
                 "attention_throughput JSON document (written by `cargo bench`)")
            .opt("decode", "BENCH_decode.json",
                 "decode_throughput JSON document (written by `cargo bench`)")
            .opt("serving", "BENCH_serving.json",
                 "serving_load JSON document (written by `cargo bench`)")
            .flag("compare",
                  "diff two BENCH_*.json documents instead of rendering: \
                   prints a markdown delta table and exits 1 when any gated \
                   metric regressed by more than 10% (the CI \
                   bench-regression job)")
            .free_args("OLD NEW — with --compare, baseline and candidate \
                        BENCH_*.json files"))
        .command(Command::new("worker",
                              "internal: one worker process for `simulate --worker-procs`")
            .hidden()
            .opt("connect", "", "coordinator address to connect to")
            .opt("worker-id", "0", "slot index assigned by the coordinator")
            .opt("token", "0", "handshake token from the coordinator")
            .opt("heartbeat-ms", "250", "heartbeat period in milliseconds")
            .opt("methods", "se2fourier", "comma-separated methods to deploy")
            .opt("cache-precision", "f32", "session cache storage precision (f32|f16|bf16)")
            .opt("synthetic-work", "0",
                 "per-token synthetic decoder spin work (0 = native flash kernel)"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&argv) {
        ParseOutcome::Help(h) => {
            println!("{h}");
            Ok(())
        }
        ParseOutcome::Error(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
        ParseOutcome::Run(m) => dispatch(&m),
    }
}

fn dispatch(m: &Matches) -> Result<()> {
    match m.command.as_str() {
        "info" => cmd_info(m),
        "gen-data" => cmd_gen_data(m),
        "train" => cmd_train(m),
        "render" => cmd_render(m),
        "simulate" => cmd_simulate(m),
        "stats" => cmd_stats(m),
        "approx" => cmd_approx(m),
        "bench-report" => cmd_bench_report(m),
        "worker" => cmd_worker(m),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn cmd_info(m: &Matches) -> Result<()> {
    let cfg = SystemConfig::load(m.get("artifacts"))?;
    let engine = Engine::cpu(&cfg.artifact_dir)?;
    println!("platform      : {}", engine.platform());
    println!("artifact dir  : {}", cfg.artifact_dir.display());
    println!(
        "model         : {} layers, {} heads x {}d, {} tokens, {} actions, F={}",
        cfg.model.n_layers,
        cfg.model.n_heads,
        cfg.model.head_dim,
        cfg.model.n_tokens,
        cfg.model.n_actions,
        cfg.model.fourier_f
    );
    println!(
        "se2fourier c  : {} per head (vs d={})",
        cfg.model.se2f_proj_dim(),
        cfg.model.head_dim
    );
    println!(
        "sim           : dt={}s, {} history + {} future steps, {} agents",
        cfg.sim.dt, cfg.sim.history_steps, cfg.sim.future_steps, cfg.sim.n_agents
    );
    println!("scenario suite:");
    for f in se2attn::sim::suite::registry() {
        println!(
            "  {:<22} {} (standalone agents {}, extent {:.0} m, {:.0}-{:.0} m/s; \
             serving uses sim n_agents)",
            f.id.name(),
            f.about,
            f.knobs.n_agents,
            f.knobs.map_extent,
            f.knobs.speed_range.0,
            f.knobs.speed_range.1
        );
    }
    Ok(())
}

fn cmd_gen_data(m: &Matches) -> Result<()> {
    let cfg = SystemConfig::load(m.get("artifacts"))?;
    let tok = se2attn::tokenizer::Tokenizer::new(&cfg.model, &cfg.sim);
    let n = m.get_usize("examples");
    let mix = se2attn::config::scenario_mix(m.get("family"), m.get("mix"))?;
    let t0 = std::time::Instant::now();
    let examples =
        se2attn::dataset::generate_examples_mix(&cfg.sim, &tok, &mix, m.get_u64("seed"), n);
    let out = m.get("out");
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    se2attn::dataset::write_shard(out, &examples)?;
    // per-family shard composition
    let mut counts: std::collections::BTreeMap<&'static str, usize> =
        std::collections::BTreeMap::new();
    for e in &examples {
        *counts.entry(e.family_id().name()).or_insert(0) += 1;
    }
    let breakdown: Vec<String> =
        counts.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!(
        "wrote {} examples to {out} in {:.1}s [{}]",
        examples.len(),
        t0.elapsed().as_secs_f64(),
        breakdown.join(" ")
    );
    Ok(())
}

fn cmd_train(m: &Matches) -> Result<()> {
    let cfg = SystemConfig::load(m.get("artifacts"))?;
    let method = Method::parse(m.get("method"))?;
    let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
    let mut model = ModelHandle::init(Arc::clone(&engine), method, m.get_u64("seed") as i32)?;
    if let Some(resume) = m.get_opt("resume") {
        let ck = se2attn::checkpoint::Checkpoint::load(resume)?;
        model.restore(&ck, &cfg.model.param_names)?;
        println!("resumed from {resume} (step {})", model.step);
    }
    println!(
        "training {} ({} tensors, {} weights)",
        method.display(),
        model.n_params(),
        model.n_weights()
    );
    let mut trainer = if let Some(data) = m.get_opt("data") {
        let examples = se2attn::dataset::read_shard(data)?;
        println!("loaded {} examples from {data}", examples.len());
        Trainer::from_examples(
            cfg.model.clone(),
            cfg.sim.clone(),
            examples,
            m.get_u64("seed"),
        )
    } else {
        Trainer::new(
            cfg.model.clone(),
            cfg.sim.clone(),
            m.get_usize("examples"),
            m.get_u64("seed"),
        )
    };
    let aug = m.get_f64("augment");
    if aug > 0.0 {
        trainer.augment = Some(aug);
        println!("augmentation: SE(2) frame jitter up to {aug} model units");
    }
    let report = trainer.run(&mut model, m.get_u64("steps"))?;
    if let Some(save) = m.get_opt("save") {
        model
            .to_checkpoint(&cfg.model.param_names)?
            .save(save)?;
        println!("checkpoint written to {save}");
    }
    for (step, loss) in &report.loss_curve {
        println!("step {step:>5}  loss {loss:.4}");
    }
    println!(
        "done: {} steps in {:.1}s ({:.1} ex/s), val NLL {:.4}",
        report.steps,
        report.wall_secs,
        report.examples_seen as f64 / report.wall_secs,
        report.final_val_loss
    );
    Ok(())
}

fn cmd_render(m: &Matches) -> Result<()> {
    let cfg = SystemConfig::load(m.get("artifacts"))?;
    let family = se2attn::sim::FamilyId::parse(m.get("family"))?;
    let s = se2attn::sim::Family::new(family).generate(&cfg.sim, m.get_u64("seed"));
    let step = m.get_usize("step").min(s.n_steps() - 1);
    println!("family: {} (seed {})", family.name(), s.seed);
    let futures = m.get_flag("futures");
    if futures {
        println!(
            "{}",
            se2attn::sim::render::render_futures(&s, step, 100, 30)
        );
    } else {
        println!(
            "{}",
            se2attn::sim::render::render_scenario(&s, step, None, 100, 30)
        );
    }
    // per-agent legend: kind + heading always (the canvas draws kinds,
    // not directions); trajectory class when the futures overlay is shown
    for (a, st) in s.states[step].iter().enumerate() {
        let class = if futures {
            format!("  class {}", s.classify_future(a, step).name())
        } else {
            String::new()
        };
        println!(
            "agent {a}: {} {} v={:.1} m/s heading {}{class}",
            if a == 0 { "R" } else { " " },
            se2attn::sim::render::kind_glyph(st.kind),
            st.speed,
            se2attn::sim::render::heading_glyph(st.pose.theta),
        );
    }
    Ok(())
}

fn cmd_simulate(m: &Matches) -> Result<()> {
    let worker_procs = m.get_usize("worker-procs");
    if worker_procs > 0 {
        return cmd_simulate_procs(m, worker_procs);
    }
    let synthetic = m.get_flag("synthetic");
    let cfg = if synthetic {
        // artifact-free: the native-kernel decoder needs no PJRT programs
        SystemConfig {
            artifact_dir: std::path::PathBuf::from("artifacts-not-needed"),
            model: se2attn::config::ModelConfig::synthetic(),
            sim: se2attn::config::SimConfig::default(),
            threads: m.get_usize("workers").max(1),
        }
    } else {
        SystemConfig::load(m.get("artifacts"))?
    };
    let method = Method::parse(m.get("method"))?;
    let scenes = m.get_usize("scenes");
    let samples = m.get_usize("samples");
    let seed = m.get_u64("seed");

    let mix = se2attn::config::scenario_mix(m.get("family"), m.get("mix"))?;

    let mut serve = ServeConfig::with_workers(m.get_usize("workers"));
    serve.kernel =
        se2attn::attention::kernel::KernelConfig::with_threads(m.get_usize("kernel-threads"));
    if m.get_flag("kernel-autotune") {
        // resolve eagerly (not just via ServeConfig.autotune_kernel) so
        // the synthetic factory below captures the tuned shape too; the
        // pick is process-cached, so both resolutions agree
        serve.autotune_kernel = true;
        serve.kernel = se2attn::attention::kernel::KernelConfig::autotune();
        println!(
            "kernel autotune: block_m={} lanes={} threads={}",
            serve.kernel.block_m, serve.kernel.lanes, serve.kernel.threads
        );
    }
    serve.cache.precision =
        se2attn::config::CachePrecision::parse(m.get("cache-precision"))?;
    serve.admission.max_queue = m.get_usize("admit-queue").max(1);
    serve.admission.deadline = std::time::Duration::from_millis(m.get_u64("deadline-ms"));
    serve.admission.tenant_rate = m.get_f64("tenant-rate");
    serve.admission.tenant_burst = m.get_f64("tenant-burst");
    serve.admission.max_live_sessions = m.get_usize("max-live-sessions").max(1);
    serve.trace.enabled = m.get_opt("trace-out").is_some();
    serve.trace.ring_spans = m.get_usize("trace-spans").max(1);
    serve.profile.enabled = m.get_flag("profile");
    let profile_before = serve
        .profile
        .enabled
        .then(se2attn::trace::KernelProfile::snapshot);
    let server = if synthetic {
        let n_actions = cfg.model.n_actions;
        let kernel = serve.kernel;
        let factory: se2attn::coordinator::BackendFactory =
            Arc::new(move |_shard: usize| -> anyhow::Result<se2attn::coordinator::Backend> {
                let mut backend: se2attn::coordinator::Backend =
                    se2attn::coordinator::Router::new();
                backend.deploy(
                    method,
                    Box::new(se2attn::coordinator::NativeSdpaDecoder::new(n_actions, kernel)),
                );
                Ok(backend)
            });
        Server::start_with_backend(cfg.clone(), vec![method], serve, factory)?
    } else {
        Server::start(cfg.clone(), vec![method], seed as i32, serve)?
    };
    println!(
        "serving on {} worker shard(s), session-affinity routing by scene id, \
         cache precision {}",
        server.n_shards(),
        m.get("cache-precision"),
    );
    let obs = if let Some(addr) = m.get_opt("obs-addr") {
        let obs_cfg = se2attn::config::ObsConfig::at(addr);
        let obs = se2attn::obs::http::ObsServer::start(&obs_cfg, server.obs_sources())
            .with_context(|| format!("starting introspection server on {addr}"))?;
        println!(
            "introspection server on http://{} \
             (/metrics /metrics.json /memory /trace /healthz /vars)",
            obs.addr()
        );
        Some(obs)
    } else {
        None
    };
    let gen = se2attn::sim::MixGenerator::new(cfg.sim.clone(), mix);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..scenes {
        let scenario = gen.generate(seed + i as u64);
        let family = scenario.family;
        let req = RolloutRequest {
            scenario,
            t0: cfg.sim.history_steps - 1,
            n_samples: samples,
            temperature: 1.0,
            seed: i as i32,
        };
        pending.push((family, server.submit(method, req)));
    }
    let mut ades = Vec::new();
    let mut breakdown = se2attn::metrics::FamilyBreakdown::default();
    for (family, rx) in pending {
        let res = rx.recv().context("response channel closed")??;
        breakdown.add_rollout(family, &res.min_ade, res.collisions, res.trajectories.len());
        ades.extend(res.min_ade);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mean_ade, _) = se2attn::metrics::mean_std(&ades);
    println!("method={} scenes={scenes} samples={samples}", method.name());
    println!(
        "wall {:.2}s  throughput {:.2} scenes/s  minADE(mean over agents) {:.2} m",
        wall,
        scenes as f64 / wall,
        mean_ade
    );
    for line in breakdown.summary_lines() {
        println!("  {line}");
    }
    println!("server stats: {}", server.stats.summary());

    // give external scrapers a window where the server (and the obs
    // endpoints) are still fully live — the CI smoke curls /metrics and
    // /healthz inside this hold
    let hold_ms = m.get_u64("obs-hold-ms");
    if obs.is_some() && hold_ms > 0 {
        println!("holding {hold_ms} ms for live scrapes (--obs-hold-ms)");
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    }

    // exports: join the workers first so every in-flight span and counter
    // update lands before we snapshot the rings
    let tracer = server.tracer().cloned();
    let stats = Arc::clone(&server.stats);
    drop(server);
    if let Some(obs) = obs {
        obs.stop();
    }
    if let Some(before) = profile_before {
        let prof = se2attn::trace::KernelProfile::snapshot().delta(&before);
        println!("kernel profile (this run):");
        for (name, value) in prof.rows() {
            println!("  {name:<28} {value}");
        }
    }
    if let Some(path) = m.get_opt("trace-out") {
        let t = tracer.as_ref().expect("tracing was enabled by --trace-out");
        t.write_chrome_trace(std::path::Path::new(path))
            .with_context(|| format!("writing trace to {path}"))?;
        let (recorded, dropped) = t.totals();
        println!("trace written to {path} ({recorded} spans, {dropped} dropped)");
    }
    if let Some(path) = m.get_opt("metrics-out") {
        let snap = se2attn::metrics_export::MetricsSnapshot::collect(&stats, tracer.as_deref());
        std::fs::write(path, snap.to_json().to_string())
            .with_context(|| format!("writing metrics to {path}"))?;
        println!(
            "metrics snapshot written to {path} ({} scalars, {} histograms)",
            snap.scalars.len(),
            snap.histograms.len()
        );
    }
    Ok(())
}

/// `simulate --worker-procs N`: the multi-process serving path.  Worker
/// shards are child processes of this coordinator, spawned from the
/// same binary's hidden `worker` entry point and speaking the local
/// socket protocol (DESIGN.md §19).
fn cmd_simulate_procs(m: &Matches, workers: usize) -> Result<()> {
    use se2attn::coordinator::proc::ProcServer;
    if !m.get_flag("synthetic") {
        anyhow::bail!(
            "--worker-procs requires --synthetic: worker processes serve the \
             artifact-free native decoder"
        );
    }
    let method = Method::parse(m.get("method"))?;
    let scenes = m.get_usize("scenes");
    let samples = m.get_usize("samples");
    let seed = m.get_u64("seed");
    let mix = se2attn::config::scenario_mix(m.get("family"), m.get("mix"))?;
    let sim = se2attn::config::SimConfig::default();
    // precision is validated here, applied inside each worker process
    se2attn::config::CachePrecision::parse(m.get("cache-precision"))?;

    let admission = se2attn::coordinator::AdmissionConfig {
        max_queue: m.get_usize("admit-queue").max(1),
        ..Default::default()
    };
    let exe = std::env::current_exe().context("locating the se2-attention binary")?;
    let worker_cmd = vec![
        exe.to_string_lossy().into_owned(),
        "worker".to_string(),
        "--methods".to_string(),
        m.get("method").to_string(),
        "--cache-precision".to_string(),
        m.get("cache-precision").to_string(),
    ];
    let server = ProcServer::start(
        workers,
        se2attn::config::ProcConfig::default(),
        admission,
        worker_cmd,
    )?;
    println!(
        "serving on {} worker process(es), session-affinity routing by scene id, \
         cache precision {}",
        server.n_workers(),
        m.get("cache-precision"),
    );
    let obs = if let Some(addr) = m.get_opt("obs-addr") {
        let obs_cfg = se2attn::config::ObsConfig::at(addr);
        let obs = se2attn::obs::http::ObsServer::start(&obs_cfg, server.obs_sources())
            .with_context(|| format!("starting introspection server on {addr}"))?;
        println!(
            "introspection server on http://{} \
             (/metrics /metrics.json /memory /healthz /vars)",
            obs.addr()
        );
        Some(obs)
    } else {
        None
    };
    let gen = se2attn::sim::MixGenerator::new(sim.clone(), mix);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..scenes {
        let scenario = gen.generate(seed + i as u64);
        let family = scenario.family;
        let req = RolloutRequest {
            scenario,
            t0: sim.history_steps - 1,
            n_samples: samples,
            temperature: 1.0,
            seed: i as i32,
        };
        pending.push((family, server.submit(method, req)));
    }
    let mut ades = Vec::new();
    let mut breakdown = se2attn::metrics::FamilyBreakdown::default();
    for (family, rx) in pending {
        let res = rx.recv().context("response channel closed")??;
        breakdown.add_rollout(family, &res.min_ade, res.collisions, res.trajectories.len());
        ades.extend(res.min_ade);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mean_ade, _) = se2attn::metrics::mean_std(&ades);
    println!("method={} scenes={scenes} samples={samples}", method.name());
    println!(
        "wall {:.2}s  throughput {:.2} scenes/s  minADE(mean over agents) {:.2} m",
        wall,
        scenes as f64 / wall,
        mean_ade
    );
    for line in breakdown.summary_lines() {
        println!("  {line}");
    }
    let stats = server.stats();
    println!("server stats: {}", stats.summary());
    let hold_ms = m.get_u64("obs-hold-ms");
    if obs.is_some() && hold_ms > 0 {
        println!("holding {hold_ms} ms for live scrapes (--obs-hold-ms)");
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    }
    server.shutdown();
    drop(server);
    if let Some(obs) = obs {
        obs.stop();
    }
    if let Some(path) = m.get_opt("metrics-out") {
        let snap = se2attn::metrics_export::MetricsSnapshot::collect(&stats, None);
        std::fs::write(path, snap.to_json().to_string())
            .with_context(|| format!("writing metrics to {path}"))?;
        println!(
            "metrics snapshot written to {path} ({} scalars, {} histograms)",
            snap.scalars.len(),
            snap.histograms.len()
        );
    }
    Ok(())
}

/// Hidden `worker` entry point: one worker process of a
/// `simulate --worker-procs` fleet.  Connects back to the coordinator
/// that spawned it and serves until drained or disconnected.
fn cmd_worker(m: &Matches) -> Result<()> {
    use se2attn::coordinator::proc::{worker_serve, WorkerOptions};
    let Some(connect) = m.get_opt("connect") else {
        anyhow::bail!(
            "worker is an internal entry point for `simulate --worker-procs`; \
             it needs --connect from a coordinator"
        );
    };
    let model_cfg = se2attn::config::ModelConfig::synthetic();
    let sim = se2attn::config::SimConfig::default();
    let engine = se2attn::coordinator::RolloutEngine::new(model_cfg.clone(), sim);
    let mut backend: se2attn::coordinator::Backend = se2attn::coordinator::Router::new();
    let work = m.get_usize("synthetic-work");
    for name in m.get("methods").split(',') {
        let method = Method::parse(name.trim())?;
        if work > 0 {
            backend.deploy(
                method,
                Box::new(se2attn::coordinator::SyntheticDecoder::with_work(
                    model_cfg.n_actions,
                    work,
                )),
            );
        } else {
            let kernel = se2attn::attention::kernel::KernelConfig::with_threads(0);
            backend.deploy(
                method,
                Box::new(se2attn::coordinator::NativeSdpaDecoder::new(
                    model_cfg.n_actions,
                    kernel,
                )),
            );
        }
    }
    let cache = se2attn::coordinator::CacheConfig {
        precision: se2attn::config::CachePrecision::parse(m.get("cache-precision"))?,
        ..Default::default()
    };
    let opts = WorkerOptions {
        connect: connect.to_string(),
        worker_id: m.get_usize("worker-id") as u32,
        token: m.get_u64("token"),
        heartbeat: std::time::Duration::from_millis(m.get_u64("heartbeat-ms").max(10)),
    };
    worker_serve(&engine, &mut backend, cache, &opts)
}

fn cmd_stats(m: &Matches) -> Result<()> {
    use se2attn::metrics_export::{validate_prometheus, MetricsSnapshot};
    let path = m.get("in");
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = se2attn::jsonio::Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let mut snap = MetricsSnapshot::from_json(&doc)?;
    if let Some(prev) = m.get_opt("prev") {
        let ptext = std::fs::read_to_string(prev).with_context(|| format!("reading {prev}"))?;
        let pdoc =
            se2attn::jsonio::Json::parse(&ptext).with_context(|| format!("parsing {prev}"))?;
        snap = snap.delta(&MetricsSnapshot::from_json(&pdoc)?);
    }
    let exposition = snap.to_prometheus();
    if m.get_flag("check") {
        let samples = validate_prometheus(&exposition)?;
        eprintln!("prometheus OK: {samples} samples");
    }
    print!("{exposition}");
    if let Some(trace_path) = m.get_opt("trace") {
        validate_trace_file(trace_path)?;
    }
    Ok(())
}

/// CI smoke check: the Chrome trace must parse and contain at least one
/// span for every pipeline stage (Route..Respond).
fn validate_trace_file(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = se2attn::jsonio::Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("trace document has no traceEvents array")?;
    let mut counts: std::collections::BTreeMap<&str, usize> = std::collections::BTreeMap::new();
    for ev in events {
        if let Some(name) = ev.get("name").and_then(|n| n.as_str()) {
            *counts.entry(name).or_insert(0) += 1;
        }
    }
    for stage in se2attn::trace::Stage::PIPELINE {
        let n = counts.get(stage.name()).copied().unwrap_or(0);
        if n == 0 {
            anyhow::bail!("trace {path} has no {} spans", stage.name());
        }
        eprintln!("trace: {:<9} {n} spans", stage.name());
    }
    eprintln!("trace OK: {path} covers all pipeline stages");
    Ok(())
}

fn cmd_bench_report(m: &Matches) -> Result<()> {
    if m.get_flag("compare") {
        // comparison mode is the CI gate: unreadable inputs are hard
        // errors, and a regression exits nonzero
        let [old_path, new_path] = m.free() else {
            anyhow::bail!("--compare needs exactly two files: bench-report --compare OLD NEW");
        };
        let read = |path: &str| -> Result<se2attn::jsonio::Json> {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            se2attn::jsonio::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
        };
        let (md, regressed) =
            se2attn::benchlib::compare_bench_reports(&read(old_path)?, &read(new_path)?);
        print!("{md}");
        if regressed {
            eprintln!("bench-report: gated metric regressed >10% vs {old_path}");
            std::process::exit(1);
        }
        return Ok(());
    }
    // missing inputs are reported inside the rendered markdown (the
    // benches may not have run yet), not as a hard error
    let load = |path: &str| -> Option<se2attn::jsonio::Json> {
        let text = std::fs::read_to_string(path).ok()?;
        se2attn::jsonio::Json::parse(&text).ok()
    };
    let attention = load(m.get("attention"));
    let decode = load(m.get("decode"));
    let serving = load(m.get("serving"));
    print!(
        "{}",
        se2attn::benchlib::render_bench_report(
            attention.as_ref(),
            decode.as_ref(),
            serving.as_ref()
        )
    );
    Ok(())
}

fn cmd_approx(m: &Matches) -> Result<()> {
    let radius = m.get_f64("radius");
    let f = m.get_usize("basis");
    let trials = m.get_usize("trials");
    let mut rng = Rng::new(42);
    let mut errs: Vec<f64> = (0..trials)
        .map(|_| {
            let psi = rng.range(-std::f64::consts::PI, std::f64::consts::PI);
            let pm = Pose::new(radius * psi.cos(), radius * psi.sin(), rng.range(-3.14, 3.14));
            let pn = Pose::new(0.0, 0.0, rng.range(-std::f64::consts::PI, std::f64::consts::PI));
            fourier::approximation_error(&pn, &pm, f)
        })
        .collect();
    errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    println!(
        "radius={radius} F={f}: mean {:.2e}  p2.5 {:.2e}  p97.5 {:.2e}  (fp16 eps {:.2e}, bf16 eps {:.2e})",
        mean,
        errs[(errs.len() as f64 * 0.025) as usize],
        errs[(errs.len() as f64 * 0.975) as usize],
        fourier::FP16_EPS,
        fourier::BF16_EPS
    );
    Ok(())
}
