//! Thread-pool executor + channels (substrate for the absent `tokio`).
//!
//! The coordinator's event loop is synchronous-with-workers: a fixed pool of
//! OS threads drains a job queue; completion is signalled over std mpsc
//! channels.  This matches the deployment shape of the serving path (one
//! PJRT executable is internally threaded by XLA; the pool handles
//! pre/post-processing and batching concurrency).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> ThreadPool {
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = (0..n_threads.max(1))
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("se2attn-worker-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Submit a job for execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        jobs.push_back(Box::new(f));
        self.queue.cv.notify_one();
    }

    /// Run a batch of jobs and wait for all of them (parallel map that
    /// preserves input order).
    pub fn map_wait<T, R, F>(&self, inputs: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = inputs.len();
        let (tx, rx) = std::sync::mpsc::channel();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.submit(move || {
                let _ = tx.send((i, f(input)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rx.recv().expect("worker died");
            out[i] = Some(v);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    break Some(j);
                }
                if *q.shutdown.lock().unwrap() {
                    break None;
                }
                jobs = q.cv.wait(jobs).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.queue.shutdown.lock().unwrap() = true;
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Simple parallel-for over an index range using scoped threads (no pool).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    return;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_wait_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map_wait((0..32usize).collect(), |x| x * x);
        assert_eq!(out, (0..32usize).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_for_covers_range() {
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        par_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }
}
