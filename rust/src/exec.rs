//! Thread-pool executor + channels (substrate for the absent `tokio`).
//!
//! The coordinator's event loop is synchronous-with-workers: a fixed pool of
//! OS threads drains a job queue; completion is signalled over std mpsc
//! channels.  This matches the deployment shape of the serving path (one
//! PJRT executable is internally threaded by XLA; the pool handles
//! pre/post-processing and batching concurrency).

use std::collections::VecDeque;
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> ThreadPool {
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = (0..n_threads.max(1))
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("se2attn-worker-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Submit a job for execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        jobs.push_back(Box::new(f));
        self.queue.cv.notify_one();
    }

    /// Run a batch of jobs and wait for all of them (parallel map that
    /// preserves input order).
    pub fn map_wait<T, R, F>(&self, inputs: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = inputs.len();
        let (tx, rx) = std::sync::mpsc::channel();
        for (i, input) in inputs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.submit(move || {
                let _ = tx.send((i, f(input)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rx.recv().expect("worker died");
            out[i] = Some(v);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    break Some(j);
                }
                if *q.shutdown.lock().unwrap() {
                    break None;
                }
                jobs = q.cv.wait(jobs).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.queue.shutdown.lock().unwrap() = true;
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Reusable *scoped* worker pool
// ---------------------------------------------------------------------------

/// Hard ceiling on pool workers — a runaway `threads` request must not
/// fork-bomb the host.  The kernel layer additionally derives its default
/// from [`crate::config::default_workers`].
const MAX_SCOPED_WORKERS: usize = 32;

/// One parallel-for job: a work-stealing index counter plus a retirement
/// barrier.  Participants (pool workers holding a ticket, and the calling
/// thread itself) repeatedly claim the next index until the range is
/// exhausted; `pending` counts unretired tickets so the caller knows when
/// every borrowed reference has been dropped.
struct ScopedJob {
    next: AtomicUsize,
    n: usize,
    pending: Mutex<usize>,
    done_cv: Condvar,
    panicked: std::sync::atomic::AtomicBool,
}

/// A participation ticket for one pool worker.  The raw closure pointer is
/// sound because [`ScopedPool::run`] does not return until every ticket is
/// retired (executed or reclaimed) — the borrow can never outlive the
/// caller's stack frame, even on panic.
struct Ticket {
    f: *const (dyn Fn(usize) + Sync),
    job: Arc<ScopedJob>,
    /// The submitter's memory-attribution scope: pool workers allocate on
    /// behalf of the caller (e.g. kernel scratch), so their allocations
    /// are charged to the caller's scope, not the worker's default.
    scope: crate::obs::alloc::Scope,
}

unsafe impl Send for Ticket {}

struct ScopedShared {
    queue: Mutex<VecDeque<Ticket>>,
    cv: Condvar,
    shutdown: std::sync::atomic::AtomicBool,
}

/// A **reusable scoped** thread pool: long-lived parked workers that
/// execute closures borrowing the caller's stack.  Unlike
/// [`ThreadPool`] (whose jobs must be `'static`) or [`par_for`] (which
/// spawns fresh OS threads per call), `ScopedPool::run` hands borrowed
/// work to already-running workers and blocks until all of it retires —
/// the per-call cost is a queue push + condvar wake, not a `clone(2)`.
///
/// This is the substrate for the blocked flash-attention kernel
/// ([`crate::attention::kernel`]), which partitions query rows across the
/// pool on every attention call and therefore cannot afford per-call
/// thread spawns.
///
/// ```
/// use std::sync::atomic::{AtomicUsize, Ordering};
/// use se2attn::exec::ScopedPool;
///
/// let pool = ScopedPool::new(2);
/// let sum = AtomicUsize::new(0); // stack state, borrowed by the workers
/// pool.run(10, 2, &|i| {
///     sum.fetch_add(i, Ordering::Relaxed);
/// });
/// // run() blocks until every index is processed, so the borrow is done
/// assert_eq!(sum.load(Ordering::Relaxed), 45);
/// ```
pub struct ScopedPool {
    shared: Arc<ScopedShared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    max_workers: usize,
}

impl ScopedPool {
    pub fn new(max_workers: usize) -> ScopedPool {
        ScopedPool {
            shared: Arc::new(ScopedShared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                shutdown: std::sync::atomic::AtomicBool::new(false),
            }),
            workers: Mutex::new(Vec::new()),
            max_workers: max_workers.clamp(1, MAX_SCOPED_WORKERS),
        }
    }

    /// Workers currently spawned (grows lazily up to the cap).
    pub fn n_workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Execute `f(0..n)` with up to `threads` participants (the calling
    /// thread plus `threads - 1` pool workers), blocking until every index
    /// has been processed.  Indices are claimed through an atomic counter,
    /// so WHICH thread runs an index is nondeterministic — callers must
    /// make per-index work independent of the executing thread (the kernel
    /// does: each index owns a disjoint slice of the output).
    ///
    /// Panics in `f` propagate to the caller — but only after the
    /// retirement barrier, so no worker can still hold a borrow of `f`
    /// when `run` unwinds.
    pub fn run(&self, n: usize, threads: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let helpers = threads.min(n).min(self.max_workers + 1).saturating_sub(1);
        if helpers == 0 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.ensure_workers(helpers);
        let job = Arc::new(ScopedJob {
            next: AtomicUsize::new(0),
            n,
            pending: Mutex::new(helpers),
            done_cv: Condvar::new(),
            panicked: std::sync::atomic::AtomicBool::new(false),
        });
        let scope = crate::obs::alloc::current_scope();
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..helpers {
                q.push_back(Ticket {
                    f: f as *const (dyn Fn(usize) + Sync),
                    job: Arc::clone(&job),
                    scope,
                });
            }
        }
        self.shared.cv.notify_all();

        // The caller is a full participant: progress is guaranteed even if
        // every pool worker is busy with another caller's job.
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scoped_drain(f, &job);
        }));

        // Reclaim tickets no worker picked up (they would find the counter
        // exhausted anyway).  This also makes nested `run` calls from
        // inside a pool worker deadlock-free: the nested caller never
        // waits on a ticket that only it could have served.
        {
            let mut q = self.shared.queue.lock().unwrap();
            let before = q.len();
            q.retain(|t| !Arc::ptr_eq(&t.job, &job));
            let reclaimed = before - q.len();
            if reclaimed > 0 {
                let mut pending = job.pending.lock().unwrap();
                *pending -= reclaimed;
            }
        }

        // Retirement barrier: after this, no thread holds a borrow of `f`.
        let mut pending = job.pending.lock().unwrap();
        while *pending > 0 {
            pending = job.done_cv.wait(pending).unwrap();
        }
        drop(pending);

        if let Err(p) = mine {
            std::panic::resume_unwind(p);
        }
        if job.panicked.load(std::sync::atomic::Ordering::SeqCst) {
            panic!("ScopedPool worker panicked while executing a scoped job");
        }
    }

    fn ensure_workers(&self, want: usize) {
        let mut ws = self.workers.lock().unwrap();
        while ws.len() < want.min(self.max_workers) {
            let shared = Arc::clone(&self.shared);
            let handle = std::thread::Builder::new()
                .name(format!("se2attn-kernel-{}", ws.len()))
                .spawn(move || scoped_worker(shared))
                .expect("spawn scoped-pool worker");
            ws.push(handle);
        }
    }
}

fn scoped_drain(f: &(dyn Fn(usize) + Sync), job: &ScopedJob) {
    loop {
        let i = job.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if i >= job.n {
            return;
        }
        f(i);
    }
}

fn scoped_worker(shared: Arc<ScopedShared>) {
    loop {
        let ticket = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.shutdown.load(std::sync::atomic::Ordering::SeqCst) {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let Some(t) = ticket else { return };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // run the borrowed closure under the submitter's scope so
            // worker-side allocations land in the caller's ledger row
            let _mem = crate::obs::alloc::MemScope::enter_scope(t.scope);
            scoped_drain(unsafe { &*t.f }, &t.job);
        }));
        if r.is_err() {
            t.job
                .panicked
                .store(true, std::sync::atomic::Ordering::SeqCst);
        }
        let mut pending = t.job.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            t.job.done_cv.notify_all();
        }
    }
}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        self.shared
            .shutdown
            .store(true, std::sync::atomic::Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

/// The process-wide scoped pool shared by every CPU kernel call (all
/// shard workers included — each attention call borrows participants and
/// returns them, so one pool serves any number of concurrent callers).
pub fn shared_pool() -> &'static ScopedPool {
    static POOL: std::sync::OnceLock<ScopedPool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| ScopedPool::new(MAX_SCOPED_WORKERS))
}

/// Raw mutable pointer that may cross task boundaries — THE shared
/// wrapper for disjoint-row-partition kernels (one audited `unsafe`
/// surface instead of one per kernel).  The caller contract: every task
/// must touch a range no other concurrent task touches, and the pointee
/// must outlive the `run`/`run_chunked` call (both block until all tasks
/// retire, so buffers owned by the calling frame qualify).
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    /// Reborrow `[offset, offset + len)` as a mutable slice.
    ///
    /// # Safety
    /// The range must be in-bounds of the original allocation and
    /// disjoint from every range any other thread accesses while the
    /// returned borrow lives.
    // &mut-from-&self is the entire point of this wrapper: the shared
    // reference is what crosses threads, and the safety contract above
    // (disjoint ranges) is what makes the derived &mut sound.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

/// Hint the CPU to pull `data[idx..]` toward L1 ahead of use (`T0`
/// locality).  A no-op off x86_64 and for out-of-range indices — purely a
/// performance hint, never an observable effect, so callers (e.g. the
/// fused kernel's next-key-block prefetch) need no cfg guards.
#[inline]
pub fn prefetch_read<T>(data: &[T], idx: usize) {
    #[cfg(target_arch = "x86_64")]
    if idx < data.len() {
        // SAFETY: the pointer is derived from an in-bounds index of a live
        // slice; prefetch dereferences nothing architecturally.
        unsafe {
            std::arch::x86_64::_mm_prefetch(
                data.as_ptr().add(idx) as *const i8,
                std::arch::x86_64::_MM_HINT_T0,
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (data, idx);
    }
}

/// Partition `0..n` into contiguous chunks of `chunk` items and run
/// `f(lo, hi)` for each, using up to `threads` participants from the
/// shared pool (inline when one thread suffices).  The common driver for
/// row-partitioned kernels: callers only supply the per-chunk body, so
/// the disjoint-slice reasoning lives at one call depth and the
/// inline-vs-pool dispatch in one place.  Returns the number of
/// participating threads (for per-thread scratch accounting).
pub fn run_chunked(
    n: usize,
    chunk: usize,
    threads: usize,
    f: &(dyn Fn(usize, usize) + Sync),
) -> usize {
    if n == 0 {
        return 0;
    }
    let chunk = chunk.max(1);
    let tasks = n.div_ceil(chunk);
    let threads = threads.clamp(1, tasks);
    let body = |task: usize| {
        let lo = task * chunk;
        f(lo, (lo + chunk).min(n));
    };
    if threads <= 1 {
        for t in 0..tasks {
            body(t);
        }
    } else {
        shared_pool().run(tasks, threads, &body);
    }
    threads
}

/// Simple parallel-for over an index range using scoped threads (no pool).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    let next = std::sync::atomic::AtomicUsize::new(0);
    // fresh OS threads start in the untagged scope; carry the caller's
    // attribution scope across the spawn boundary
    let scope = crate::obs::alloc::current_scope();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let _mem = crate::obs::alloc::MemScope::enter_scope(scope);
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    f(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_wait_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map_wait((0..32usize).collect(), |x| x * x);
        assert_eq!(out, (0..32usize).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_for_covers_range() {
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        par_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }

    #[test]
    fn scoped_pool_covers_range_and_reuses_workers() {
        let pool = ScopedPool::new(4);
        for round in 0..3 {
            let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
            pool.run(100, 4, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "round {round}: every index exactly once"
            );
        }
        // workers persist between runs (reusable, not respawned)
        assert!(pool.n_workers() >= 1 && pool.n_workers() <= 3);
    }

    #[test]
    fn scoped_pool_single_thread_runs_inline() {
        let pool = ScopedPool::new(4);
        let hits = AtomicUsize::new(0);
        pool.run(17, 1, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 17);
        assert_eq!(pool.n_workers(), 0, "threads=1 must not spawn workers");
    }

    #[test]
    fn scoped_pool_nested_run_does_not_deadlock() {
        // a pool job that itself calls run() on the SAME pool — the
        // reclaim path must keep the nested caller from waiting on a
        // ticket only it could serve (its one worker is the caller)
        let pool = ScopedPool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run(2, 2, &|_| {
            pool.run(8, 2, &|_| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(hits.load(Ordering::SeqCst), 16);
        // the global pool exists and serves the same protocol
        shared_pool().run(4, 2, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn scoped_pool_propagates_panics_after_barrier() {
        let pool = ScopedPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(10, 2, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // and the pool must still be usable afterwards
        let hits = AtomicUsize::new(0);
        pool.run(10, 2, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn run_chunked_covers_range_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        let threads = run_chunked(37, 8, 4, &|lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert!((1..=4).contains(&threads));
        assert_eq!(run_chunked(0, 8, 4, &|_, _| panic!("no work")), 0);
        // threads clamp to the task count
        assert_eq!(run_chunked(3, 8, 4, &|_, _| {}), 1);
    }

    #[test]
    fn scoped_pool_concurrent_callers() {
        // two OS threads hammering the same pool: jobs must not cross wires
        let pool = Arc::new(ScopedPool::new(3));
        let mut handles = Vec::new();
        for salt in 0..2usize {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let sums: Vec<AtomicUsize> = (0..32).map(|_| AtomicUsize::new(0)).collect();
                    pool.run(32, 3, &|i| {
                        sums[i].fetch_add(i + salt, Ordering::SeqCst);
                    });
                    for (i, s) in sums.iter().enumerate() {
                        assert_eq!(s.load(Ordering::SeqCst), i + salt);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
