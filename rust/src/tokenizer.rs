//! Scene tokenization: map elements + agent-timestep states -> the
//! (feature, pose, timestep, target) arrays the AOT model consumes, plus
//! the discrete action codebook (paper Sec. IV-B).
//!
//! Conventions shared with `python/compile/model.py` (via config):
//! * token order: `n_map_tokens` map tokens, then agent tokens ordered by
//!   (history step, agent index);
//! * map tokens carry visibility timestep -1 (visible to everyone), agent
//!   tokens their history step; padding would carry `PAD_T`;
//! * poses are expressed in the robot frame (agent 0 at the last history
//!   step) and downscaled by `pos_scale` so |p| <= ~4 (paper downscaling);
//! * features are frame-invariant (no absolute coordinates leak in).

use crate::config::{ModelConfig, SimConfig};
use crate::geometry::Pose;
use crate::sim::agent::{KinematicAction, MAX_ACCEL, MAX_YAW_RATE};
use crate::sim::{AgentKind, AgentState, MapElement, MapElementKind, Scenario};

/// Visibility timestep for padding tokens (mirrors flash_sdpa.PAD_T).
pub const PAD_T: i32 = 1 << 30;
/// Visibility timestep for map tokens.
pub const MAP_T: i32 = -1;
/// Target value meaning "no loss at this token".
pub const NO_TARGET: i32 = -1;

/// Uniform (accel x yaw-rate) action grid.
#[derive(Clone, Copy, Debug)]
pub struct ActionCodebook {
    pub n_accel: usize,
    pub n_yaw: usize,
}

impl ActionCodebook {
    /// 8 x 8 = 64 actions, matching `ModelConfig::n_actions`.
    pub fn default_for(n_actions: usize) -> ActionCodebook {
        let side = (n_actions as f64).sqrt().round() as usize;
        assert_eq!(side * side, n_actions, "n_actions must be a square");
        ActionCodebook {
            n_accel: side,
            n_yaw: side,
        }
    }

    pub fn len(&self) -> usize {
        self.n_accel * self.n_yaw
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn bin(v: f64, lo: f64, hi: f64, n: usize) -> usize {
        let t = ((v - lo) / (hi - lo) * n as f64).floor();
        (t.max(0.0) as usize).min(n - 1)
    }

    fn center(i: usize, lo: f64, hi: f64, n: usize) -> f64 {
        lo + (i as f64 + 0.5) * (hi - lo) / n as f64
    }

    /// Continuous action -> discrete id.
    pub fn encode(&self, a: &KinematicAction) -> usize {
        let ia = Self::bin(a.accel, -MAX_ACCEL, MAX_ACCEL, self.n_accel);
        let iy = Self::bin(a.yaw_rate, -MAX_YAW_RATE, MAX_YAW_RATE, self.n_yaw);
        ia * self.n_yaw + iy
    }

    /// Discrete id -> bin-center continuous action.
    pub fn decode(&self, id: usize) -> KinematicAction {
        let ia = id / self.n_yaw;
        let iy = id % self.n_yaw;
        KinematicAction {
            accel: Self::center(ia, -MAX_ACCEL, MAX_ACCEL, self.n_accel),
            yaw_rate: Self::center(iy, -MAX_YAW_RATE, MAX_YAW_RATE, self.n_yaw),
        }
    }
}

/// One tokenized scene, ready to batch into the model.
#[derive(Clone, Debug)]
pub struct TokenizedScene {
    /// Row-major (n_tokens, feat_dim).
    pub feat: Vec<f32>,
    /// Row-major (n_tokens, 3) — model units, robot frame.
    pub pose: Vec<f32>,
    /// (n_tokens,) visibility timesteps.
    pub tq: Vec<i32>,
    /// (n_tokens,) training targets (NO_TARGET where unlabeled).
    pub target: Vec<i32>,
    /// Robot frame used (world pose), needed to map outputs back.
    pub frame: Pose,
    pub n_map: usize,
    pub n_agents: usize,
    pub history_steps: usize,
}

impl TokenizedScene {
    /// Token index of (history step t, agent a).
    pub fn agent_token(&self, t: usize, a: usize) -> usize {
        self.n_map + t * self.n_agents + a
    }

    /// Tokens whose predictions drive the rollout: last history step.
    pub fn frontier_tokens(&self) -> Vec<usize> {
        (0..self.n_agents)
            .map(|a| self.agent_token(self.history_steps - 1, a))
            .collect()
    }
}

/// The tokenizer: holds the layout config and the action codebook.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub sim: SimConfig,
    pub feat_dim: usize,
    pub codebook: ActionCodebook,
}

impl Tokenizer {
    pub fn new(model: &ModelConfig, sim: &SimConfig) -> Tokenizer {
        Tokenizer {
            sim: sim.clone(),
            feat_dim: model.feat_dim,
            codebook: ActionCodebook::default_for(model.n_actions),
        }
    }

    /// World pose -> model pose (robot frame + downscale).
    pub fn to_model_frame(&self, frame: &Pose, world: &Pose) -> Pose {
        let rel = frame.relative_to(world);
        Pose {
            x: rel.x * self.sim.pos_scale,
            y: rel.y * self.sim.pos_scale,
            theta: rel.theta,
        }
    }

    /// Model-frame position -> world position.
    pub fn to_world(&self, frame: &Pose, mx: f64, my: f64) -> (f64, f64) {
        frame.transform_point(mx / self.sim.pos_scale, my / self.sim.pos_scale)
    }

    /// Feature row of one map element (frame-invariant; public so the
    /// incremental window cache can tokenize rows individually).
    pub fn map_features(&self, e: &MapElement, out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        match e.kind {
            MapElementKind::Lane => out[3] = 1.0,
            MapElementKind::Crosswalk => out[4] = 1.0,
            MapElementKind::Signal => out[5] = 1.0,
        }
        out[11] = (e.curvature * 20.0) as f32;
        out[12] = (e.speed_limit / 20.0) as f32;
        out[13] = e.signal_state as f32;
        out[15] = 1.0;
    }

    /// Feature row of one agent state (frame-invariant; public so the
    /// incremental window cache can tokenize only the frontier step).
    pub fn agent_features(&self, a: &AgentState, out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        match a.kind {
            AgentKind::Vehicle => out[0] = 1.0,
            AgentKind::Pedestrian => out[1] = 1.0,
            AgentKind::Cyclist => out[2] = 1.0,
        }
        out[6] = (a.speed / 10.0) as f32;
        out[7] = (a.length / 10.0) as f32;
        out[8] = (a.width / 10.0) as f32;
        out[9] = (a.last_action.accel / MAX_ACCEL) as f32;
        out[10] = (a.last_action.yaw_rate / MAX_YAW_RATE) as f32;
        out[14] = 1.0;
        out[15] = 1.0;
    }

    /// Tokenize an arbitrary history window.  `window[t][a]` is agent `a`
    /// at history step `t` (len == `sim.history_steps`); `targets[t][a]`
    /// optionally labels the action taken from that state.
    pub fn tokenize_window(
        &self,
        map_elements: &[MapElement],
        window: &[Vec<AgentState>],
        targets: Option<&[Vec<KinematicAction>]>,
    ) -> TokenizedScene {
        let h = self.sim.history_steps;
        assert_eq!(window.len(), h, "window length");
        let n_agents = window[0].len();
        let n_map = map_elements.len();
        let n_tokens = n_map + h * n_agents;
        let frame = window[h - 1][0].pose; // robot at latest step

        let mut feat = vec![0.0f32; n_tokens * self.feat_dim];
        let mut pose = vec![0.0f32; n_tokens * 3];
        let mut tq = vec![0i32; n_tokens];
        let mut target = vec![NO_TARGET; n_tokens];

        for (i, e) in map_elements.iter().enumerate() {
            self.map_features(e, &mut feat[i * self.feat_dim..(i + 1) * self.feat_dim]);
            let mp = self.to_model_frame(&frame, &e.pose);
            pose[i * 3] = mp.x as f32;
            pose[i * 3 + 1] = mp.y as f32;
            pose[i * 3 + 2] = mp.theta as f32;
            tq[i] = MAP_T;
        }

        for t in 0..h {
            for a in 0..n_agents {
                let idx = n_map + t * n_agents + a;
                let st = &window[t][a];
                self.agent_features(
                    st,
                    &mut feat[idx * self.feat_dim..(idx + 1) * self.feat_dim],
                );
                let mp = self.to_model_frame(&frame, &st.pose);
                pose[idx * 3] = mp.x as f32;
                pose[idx * 3 + 1] = mp.y as f32;
                pose[idx * 3 + 2] = mp.theta as f32;
                tq[idx] = t as i32;
                if let Some(acts) = targets {
                    target[idx] = self.codebook.encode(&acts[t][a]) as i32;
                }
            }
        }

        TokenizedScene {
            feat,
            pose,
            tq,
            target,
            frame,
            n_map,
            n_agents,
            history_steps: h,
        }
    }

    /// Tokenize a training example from a scenario: the history window
    /// ending at step `t0` (inclusive), targets from the recorded actions.
    pub fn tokenize_scenario(&self, s: &Scenario, t0: usize) -> TokenizedScene {
        let h = self.sim.history_steps;
        assert!(t0 + 1 >= h, "not enough history before t0");
        let window: Vec<Vec<AgentState>> =
            (t0 + 1 - h..=t0).map(|t| s.states[t].clone()).collect();
        let targets: Vec<Vec<KinematicAction>> =
            (t0 + 1 - h..=t0).map(|t| s.actions[t].clone()).collect();
        self.tokenize_window(&s.map_elements, &window, Some(&targets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::prng::Rng;
    use crate::sim::ScenarioGenerator;

    fn test_model_config() -> ModelConfig {
        ModelConfig::synthetic()
    }

    #[test]
    fn codebook_roundtrip_within_one_bin() {
        let cb = ActionCodebook::default_for(64);
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let a = KinematicAction {
                accel: rng.range(-MAX_ACCEL, MAX_ACCEL),
                yaw_rate: rng.range(-MAX_YAW_RATE, MAX_YAW_RATE),
            };
            let id = cb.encode(&a);
            assert!(id < 64);
            let back = cb.decode(id);
            assert!((back.accel - a.accel).abs() <= MAX_ACCEL / 8.0 + 1e-9);
            assert!(
                (back.yaw_rate - a.yaw_rate).abs() <= MAX_YAW_RATE / 8.0 + 1e-9
            );
        }
    }

    #[test]
    fn codebook_decode_encode_is_identity() {
        let cb = ActionCodebook::default_for(64);
        for id in 0..64 {
            assert_eq!(cb.encode(&cb.decode(id)), id);
        }
    }

    #[test]
    fn tokenized_scene_layout() {
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&test_model_config(), &sim);
        let s = ScenarioGenerator::new(sim.clone()).generate(3);
        let ts = tok.tokenize_scenario(&s, sim.history_steps - 1 + 4);
        let n_tokens = sim.tokens_per_scene();
        assert_eq!(ts.feat.len(), n_tokens * 16);
        assert_eq!(ts.pose.len(), n_tokens * 3);
        assert_eq!(ts.tq.len(), n_tokens);
        // map tokens first, timestep -1, no target
        for i in 0..sim.n_map_tokens {
            assert_eq!(ts.tq[i], MAP_T);
            assert_eq!(ts.target[i], NO_TARGET);
        }
        // agent tokens have valid targets + increasing timesteps
        for t in 0..sim.history_steps {
            for a in 0..sim.n_agents {
                let idx = ts.agent_token(t, a);
                assert_eq!(ts.tq[idx], t as i32);
                assert!(ts.target[idx] >= 0 && ts.target[idx] < 64);
            }
        }
    }

    #[test]
    fn robot_pose_is_origin_in_model_frame() {
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&test_model_config(), &sim);
        let s = ScenarioGenerator::new(sim.clone()).generate(9);
        let ts = tok.tokenize_scenario(&s, sim.history_steps - 1);
        let idx = ts.agent_token(sim.history_steps - 1, 0);
        assert!(ts.pose[idx * 3].abs() < 1e-6);
        assert!(ts.pose[idx * 3 + 1].abs() < 1e-6);
        assert!(ts.pose[idx * 3 + 2].abs() < 1e-6);
    }

    #[test]
    fn positions_are_downscaled() {
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&test_model_config(), &sim);
        for seed in 0..5 {
            let s = ScenarioGenerator::new(sim.clone()).generate(seed);
            let ts = tok.tokenize_scenario(&s, sim.history_steps - 1);
            for i in 0..ts.tq.len() {
                let r = (ts.pose[i * 3].powi(2) + ts.pose[i * 3 + 1].powi(2)).sqrt();
                assert!(r < 10.0, "|p|={r} too large (downscale broken?)");
            }
        }
    }

    #[test]
    fn world_roundtrip() {
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&test_model_config(), &sim);
        let frame = Pose::new(12.0, -7.0, 0.8);
        let world = Pose::new(20.0, 3.0, -0.4);
        let m = tok.to_model_frame(&frame, &world);
        let (wx, wy) = tok.to_world(&frame, m.x, m.y);
        assert!((wx - world.x).abs() < 1e-9);
        assert!((wy - world.y).abs() < 1e-9);
    }

    #[test]
    fn features_are_frame_invariant() {
        // identical scene content expressed in different world frames must
        // produce identical features (only poses change).
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&test_model_config(), &sim);
        let s = ScenarioGenerator::new(sim.clone()).generate(11);
        let ts = tok.tokenize_scenario(&s, sim.history_steps - 1);
        // shift the whole world by a rigid transform
        let mut s2 = s.clone();
        let z = Pose::new(100.0, -50.0, 1.0);
        for step in s2.states.iter_mut() {
            for a in step.iter_mut() {
                a.pose = z.compose(&a.pose);
            }
        }
        for e in s2.map_elements.iter_mut() {
            e.pose = z.compose(&e.pose);
        }
        let ts2 = tok.tokenize_scenario(&s2, sim.history_steps - 1);
        assert_eq!(ts.feat, ts2.feat, "features must not leak absolute pose");
        for (a, b) in ts.pose.iter().zip(ts2.pose.iter()) {
            assert!((a - b).abs() < 1e-4, "poses in robot frame match");
        }
    }
}
