//! Small dense linear algebra over f64 (row-major), sized for the paper's
//! per-block matrices: phi_q is 6 x (4F+2), phi_k is (4F+2) x 6, attention
//! heads are a few hundred wide.  Includes the spectral norm used by the
//! Fig. 3 reproduction (power iteration on A^T A — no SVD dependency).

use crate::prng::Rng;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self.at(r, c);
            }
        }
        out
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order for cache-friendly access to `other`
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| {
                self.row(r)
                    .iter()
                    .zip(x.iter())
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect()
    }

    /// x^T A (i.e. A^T x) without forming the transpose.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(r)) {
                *o += xr * a;
            }
        }
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Largest singular value via power iteration on A^T A.
    ///
    /// Deterministic start vector (seeded), tolerance on the Rayleigh
    /// quotient; ~60 iterations is plenty for the well-separated spectra of
    /// rotation-like matrices.
    pub fn spectral_norm(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let mut rng = Rng::new(0x5EC7_12A1);
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.normal()).collect();
        normalize(&mut v);
        let mut sigma2_prev = 0.0;
        for _ in 0..200 {
            // w = A^T (A v)
            let av = self.matvec(&v);
            let mut w = self.tmatvec(&av);
            let sigma2 = norm(&w).max(1e-300);
            normalize(&mut w);
            v = w;
            if (sigma2 - sigma2_prev).abs() <= 1e-12 * sigma2.max(1.0) {
                sigma2_prev = sigma2;
                break;
            }
            sigma2_prev = sigma2;
        }
        sigma2_prev.sqrt()
    }

    /// Place `block` at (r0, c0) — used to assemble block-diagonal phi's.
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Mat) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for r in 0..block.rows {
            for c in 0..block.cols {
                self[(r0 + r, c0 + c)] = block.at(r, c);
            }
        }
    }

    /// Block-diagonal assembly of possibly non-square blocks.
    pub fn block_diag(blocks: &[Mat]) -> Mat {
        let rows = blocks.iter().map(|b| b.rows).sum();
        let cols = blocks.iter().map(|b| b.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        let (mut r, mut c) = (0, 0);
        for b in blocks {
            out.set_block(r, c, b);
            r += b.rows;
            c += b.cols;
        }
        out
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &a| m.max(a.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|a| a * a).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) {
    let n = norm(x).max(1e-300);
    for a in x.iter_mut() {
        *a /= n;
    }
}

/// Numerically stable softmax (used by CPU attention baselines).
pub fn softmax_inplace(xs: &mut [f64]) {
    let m = xs.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
    if m == f64::NEG_INFINITY {
        for x in xs.iter_mut() {
            *x = 0.0;
        }
        return;
    }
    let mut z = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        z += *x;
    }
    for x in xs.iter_mut() {
        *x /= z;
    }
}

/// log(sum(exp(xs))) — used by the NLL metric.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    if m == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f32>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let mut a = Mat::zeros(4, 4);
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        let i = Mat::eye(4);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_rows(&[&[1.0, -2.0], &[0.5, 3.0], &[2.0, 2.0]]);
        let x = vec![2.0, 1.0];
        assert_eq!(a.matvec(&x), vec![0.0, 4.0, 6.0]);
        assert_eq!(a.tmatvec(&[1.0, 1.0, 1.0]), vec![3.5, 3.0]);
    }

    #[test]
    fn spectral_norm_of_rotation_is_one() {
        let t: f64 = 0.7;
        let r = Mat::from_rows(&[&[t.cos(), -t.sin()], &[t.sin(), t.cos()]]);
        assert!((r.spectral_norm() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spectral_norm_of_diag() {
        let d = Mat::from_rows(&[&[3.0, 0.0], &[0.0, -5.0]]);
        assert!((d.spectral_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn spectral_norm_vs_bruteforce_2x2() {
        // brute-force over unit vectors
        let a = Mat::from_rows(&[&[1.0, 2.0], &[-0.5, 0.3]]);
        let mut best: f64 = 0.0;
        for i in 0..5000 {
            let t = i as f64 / 5000.0 * std::f64::consts::TAU;
            let v = [t.cos(), t.sin()];
            let av = a.matvec(&v);
            best = best.max((av[0] * av[0] + av[1] * av[1]).sqrt());
        }
        assert!((a.spectral_norm() - best).abs() < 1e-3);
    }

    #[test]
    fn block_diag_shapes() {
        let a = Mat::zeros(2, 3);
        let b = Mat::eye(2);
        let m = Mat::block_diag(&[a, b]);
        assert_eq!((m.rows, m.cols), (4, 5));
        assert_eq!(m[(2, 3)], 1.0);
        assert_eq!(m[(3, 4)], 1.0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, 1000.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(xs[3] > 0.999);
    }

    #[test]
    fn logsumexp_stable() {
        let v = [1000.0f32, 1000.0];
        assert!((logsumexp(&v) - (1000.0 + 2.0f32.ln())).abs() < 1e-3);
    }
}
