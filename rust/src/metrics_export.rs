//! Metrics snapshot + export (DESIGN.md §15).
//!
//! [`MetricsSnapshot::collect`] walks every live telemetry primitive —
//! [`Counter`]/[`Gauge`]/[`LatencyHistogram`]/`CacheStats`/`ShardStats`/
//! `FamilyTelemetry` from [`crate::coordinator::telemetry`], the global
//! [`crate::trace::KernelProfile`], and span-ring totals — into a plain
//! data snapshot that can be rendered two ways:
//!
//! * [`MetricsSnapshot::to_prometheus`] — Prometheus text exposition
//!   (`# TYPE` headers, `name{label="v"} value` samples, cumulative
//!   `_bucket{le=...}` histogram series), scrape-ready.
//! * [`MetricsSnapshot::to_json`] / [`MetricsSnapshot::from_json`] — a
//!   JSON document that round-trips, so `se2attn stats --prev` can diff
//!   two snapshots into interval deltas ([`MetricsSnapshot::delta`]).
//!
//! Collection is read-only over relaxed atomics: it never blocks the
//! serving hot path, and the concurrent-consistency contract (exported
//! histogram count == Σ bucket counts even while writers hammer the
//! histogram) is regression-tested in `tests/observability.rs`.

use crate::coordinator::telemetry::{LatencyHistogram, ServerStats};
use crate::jsonio::Json;
use crate::sim::suite::FamilyId;
use crate::trace::{KernelProfile, Tracer};

/// Scalar metric kind, mapped onto the Prometheus `# TYPE` header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

impl MetricKind {
    fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }

    fn parse(s: &str) -> Option<MetricKind> {
        match s {
            "counter" => Some(MetricKind::Counter),
            "gauge" => Some(MetricKind::Gauge),
            _ => None,
        }
    }
}

/// One scalar sample: `name{labels} value`.
#[derive(Clone, Debug, PartialEq)]
pub struct Scalar {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub kind: MetricKind,
    pub value: u64,
}

/// One latency histogram, exported with its exact observed extremes.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub name: String,
    /// Per-bucket counts; bucket i covers `[2^i, 2^(i+1))` µs.
    pub buckets: Vec<u64>,
    pub sum_us: u64,
    pub count: u64,
    pub min_us: u64,
    pub max_us: u64,
}

impl HistogramSnapshot {
    fn of(name: &str, h: &LatencyHistogram) -> HistogramSnapshot {
        HistogramSnapshot {
            name: name.to_string(),
            buckets: h.bucket_counts(),
            sum_us: h.sum_us(),
            count: h.count(),
            min_us: h.min_us(),
            max_us: h.max_us(),
        }
    }
}

/// Point-in-time copy of every metric the serving stack exposes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub scalars: Vec<Scalar>,
    pub histograms: Vec<HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// Snapshot `stats` plus the global kernel profile; `tracer` adds
    /// span-ring totals when tracing is on.  Read-only relaxed loads —
    /// safe to call concurrently with the serving path.
    pub fn collect(stats: &ServerStats, tracer: Option<&Tracer>) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::default();
        let no_labels: Vec<(String, String)> = Vec::new();
        let mut push = |name: &str, labels: &[(String, String)], kind, value| {
            s.scalars.push(Scalar {
                name: name.to_string(),
                labels: labels.to_vec(),
                kind,
                value,
            });
        };

        use MetricKind::{Counter, Gauge};
        push("se2attn_requests_in_total", &no_labels, Counter, stats.requests_in.get());
        push("se2attn_requests_done_total", &no_labels, Counter, stats.requests_done.get());
        push(
            "se2attn_requests_failed_total",
            &no_labels,
            Counter,
            stats.requests_failed.get(),
        );
        push("se2attn_batches_total", &no_labels, Counter, stats.batches.get());
        push("se2attn_padded_slots_total", &no_labels, Counter, stats.padded_slots.get());
        push(
            "se2attn_queue_rejections_total",
            &no_labels,
            Counter,
            stats.queue_rejections.get(),
        );
        push("se2attn_queue_sheds_total", &no_labels, Counter, stats.queue_sheds.get());
        push(
            "se2attn_step_sessions_total",
            &no_labels,
            Counter,
            stats.step_sessions.get(),
        );

        for t in 0..stats.tenants.classes() {
            let labels = vec![("tenant".to_string(), t.to_string())];
            let t = t as u8;
            push(
                "se2attn_tenant_admitted_total",
                &labels,
                Counter,
                stats.tenants.admitted_count(t),
            );
            push(
                "se2attn_tenant_rejected_total",
                &labels,
                Counter,
                stats.tenants.rejected_count(t),
            );
            push("se2attn_tenant_sheds_total", &labels, Counter, stats.tenants.shed_count(t));
            push("se2attn_tenant_done_total", &labels, Counter, stats.tenants.done_count(t));
        }

        push("se2attn_cache_hits_total", &no_labels, Counter, stats.cache.hits.get());
        push("se2attn_cache_misses_total", &no_labels, Counter, stats.cache.misses.get());
        push(
            "se2attn_cache_evictions_total",
            &no_labels,
            Counter,
            stats.cache.evictions.get(),
        );
        push("se2attn_cache_map_hits_total", &no_labels, Counter, stats.cache.map_hits.get());
        push(
            "se2attn_cache_map_misses_total",
            &no_labels,
            Counter,
            stats.cache.map_misses.get(),
        );
        push(
            "se2attn_cache_resident_bytes",
            &no_labels,
            Gauge,
            stats.cache.resident_bytes.get(),
        );

        for (i, sh) in stats.shards.iter().enumerate() {
            let labels = vec![("shard".to_string(), i.to_string())];
            push("se2attn_shard_requests_total", &labels, Counter, sh.requests.get());
            push("se2attn_shard_done_total", &labels, Counter, sh.done.get());
            push("se2attn_shard_failed_total", &labels, Counter, sh.failed.get());
            push("se2attn_shard_rejected_total", &labels, Counter, sh.rejected.get());
            push("se2attn_shard_shed_total", &labels, Counter, sh.shed.get());
            push("se2attn_shard_batches_total", &labels, Counter, sh.batches.get());
            push("se2attn_shard_inflight", &labels, Gauge, sh.inflight.get());
            push("se2attn_shard_live_sessions", &labels, Gauge, sh.live_sessions.get());
            push("se2attn_shard_live", &labels, Gauge, sh.live.get());
        }

        // multi-process fleet (DESIGN.md §19): worker liveness churn and
        // session-migration volume; all-zero on the in-process path
        let mig = &stats.migration;
        push("se2attn_worker_deaths_total", &no_labels, Counter, mig.worker_deaths.get());
        push(
            "se2attn_worker_respawns_total",
            &no_labels,
            Counter,
            mig.worker_respawns.get(),
        );
        push(
            "se2attn_sessions_migrated_total",
            &no_labels,
            Counter,
            mig.sessions_migrated.get(),
        );
        push("se2attn_migration_bytes_total", &no_labels, Counter, mig.migration_bytes.get());
        push(
            "se2attn_envelopes_replayed_total",
            &no_labels,
            Counter,
            mig.envelopes_replayed.get(),
        );
        push("se2attn_wire_errors_total", &no_labels, Counter, mig.wire_errors.get());

        for f in FamilyId::ALL {
            let labels = vec![("family".to_string(), f.name().to_string())];
            push("se2attn_family_requests_total", &labels, Counter, stats.families.requests(f));
            push(
                "se2attn_family_ade_micrometers_total",
                &labels,
                Counter,
                stats.families.ade_micrometers(f),
            );
            push(
                "se2attn_family_ade_samples_total",
                &labels,
                Counter,
                stats.families.ade_samples(f),
            );
            push(
                "se2attn_family_collisions_total",
                &labels,
                Counter,
                stats.families.collisions(f),
            );
            push("se2attn_family_samples_total", &labels, Counter, stats.families.samples(f));
        }

        let profile = KernelProfile::snapshot();
        for (name, value) in profile.rows() {
            push(&format!("se2attn_{name}_total"), &no_labels, Counter, value);
        }

        if let Some(t) = tracer {
            let (recorded, dropped) = t.totals();
            push("se2attn_trace_spans_recorded_total", &no_labels, Counter, recorded);
            push("se2attn_trace_spans_dropped_total", &no_labels, Counter, dropped);
        }

        // memory attribution (DESIGN.md §16): the tracking allocator's
        // per-scope ledger, one label per subsystem, grouped per metric
        // family so each `# TYPE` header covers its whole series
        let mem = crate::obs::alloc::snapshot_all();
        let scope_labels = |sc: &crate::obs::alloc::ScopeSnapshot| {
            vec![("scope".to_string(), sc.scope.name().to_string())]
        };
        for sc in &mem {
            push("se2attn_mem_live_bytes", &scope_labels(sc), Gauge, sc.live_bytes);
        }
        for sc in &mem {
            push("se2attn_mem_peak_bytes", &scope_labels(sc), Gauge, sc.peak_bytes);
        }
        for sc in &mem {
            push("se2attn_mem_allocs_total", &scope_labels(sc), Counter, sc.allocs);
        }
        for sc in &mem {
            push("se2attn_mem_frees_total", &scope_labels(sc), Counter, sc.frees);
        }
        push(
            "se2attn_mem_resident_bytes",
            &no_labels,
            Gauge,
            crate::obs::alloc::total_live_bytes(),
        );
        if let Some(audit) = crate::obs::memreport::audit() {
            // the fitted growth exponent, in hundredths (gauges are u64;
            // 100 = exactly linear, 200 = quadratic)
            push(
                "se2attn_mem_audit_exponent_centi",
                &no_labels,
                Gauge,
                (audit.exponent * 100.0).round().max(0.0) as u64,
            );
            push("se2attn_mem_audit_samples", &no_labels, Gauge, audit.samples as u64);
        }

        s.histograms.push(HistogramSnapshot::of("se2attn_e2e_latency_us", &stats.e2e_latency));
        s.histograms.push(HistogramSnapshot::of(
            "se2attn_decode_latency_us",
            &stats.decode_latency,
        ));
        s.histograms.push(HistogramSnapshot::of("se2attn_queue_age_us", &stats.queue_age));
        s.histograms.push(HistogramSnapshot::of(
            "se2attn_resurrect_latency_us",
            &stats.migration.resurrect_latency,
        ));
        s
    }

    /// Interval delta `self - prev`: counters and histogram series
    /// subtract (saturating), gauges and observed extremes keep their
    /// current values.  Entries absent from `prev` pass through unchanged.
    pub fn delta(&self, prev: &MetricsSnapshot) -> MetricsSnapshot {
        let scalars = self
            .scalars
            .iter()
            .map(|cur| {
                let mut out = cur.clone();
                if cur.kind == MetricKind::Counter {
                    if let Some(p) = prev
                        .scalars
                        .iter()
                        .find(|p| p.name == cur.name && p.labels == cur.labels)
                    {
                        out.value = cur.value.saturating_sub(p.value);
                    }
                }
                out
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|cur| {
                let mut out = cur.clone();
                if let Some(p) = prev.histograms.iter().find(|p| p.name == cur.name) {
                    for (i, b) in out.buckets.iter_mut().enumerate() {
                        *b = b.saturating_sub(p.buckets.get(i).copied().unwrap_or(0));
                    }
                    out.sum_us = cur.sum_us.saturating_sub(p.sum_us);
                    out.count = cur.count.saturating_sub(p.count);
                }
                out
            })
            .collect();
        MetricsSnapshot { scalars, histograms }
    }

    // -- Prometheus text exposition ---------------------------------------

    /// Render as Prometheus text format.  `# TYPE` is emitted once per
    /// metric name; histogram series use cumulative `le` buckets ending
    /// in `+Inf`, with exact observed extremes as companion gauges.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_name = String::new();
        for s in &self.scalars {
            if s.name != last_name {
                out.push_str(&format!("# TYPE {} {}\n", s.name, s.kind.name()));
                last_name = s.name.clone();
            }
            out.push_str(&format!("{}{} {}\n", s.name, render_labels(&s.labels), s.value));
        }
        for h in &self.histograms {
            out.push_str(&format!("# TYPE {} histogram\n", h.name));
            let mut cum = 0u64;
            for (i, b) in h.buckets.iter().enumerate() {
                cum += b;
                out.push_str(&format!(
                    "{}_bucket{{le=\"{}\"}} {}\n",
                    h.name,
                    1u64 << (i + 1),
                    cum
                ));
            }
            out.push_str(&format!("{}_bucket{{le=\"+Inf\"}} {}\n", h.name, cum));
            out.push_str(&format!("{}_sum {}\n", h.name, h.sum_us));
            out.push_str(&format!("{}_count {}\n", h.name, h.count));
            out.push_str(&format!("# TYPE {}_min_us gauge\n", h.name));
            out.push_str(&format!("{}_min_us {}\n", h.name, h.min_us));
            out.push_str(&format!("# TYPE {}_max_us gauge\n", h.name));
            out.push_str(&format!("{}_max_us {}\n", h.name, h.max_us));
        }
        out
    }

    // -- JSON round-trip --------------------------------------------------

    /// JSON document (schema `se2attn-metrics-v1`) that round-trips
    /// through [`MetricsSnapshot::from_json`].  Values are stored as JSON
    /// numbers, exact up to 2^53.
    pub fn to_json(&self) -> Json {
        let scalars = self
            .scalars
            .iter()
            .map(|s| {
                let labels: std::collections::BTreeMap<String, Json> = s
                    .labels
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect();
                Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("labels", Json::Obj(labels)),
                    ("kind", Json::Str(s.kind.name().to_string())),
                    ("value", Json::Num(s.value as f64)),
                ])
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|h| {
                let buckets = h.buckets.iter().map(|b| Json::Num(*b as f64)).collect();
                Json::obj(vec![
                    ("name", Json::Str(h.name.clone())),
                    ("buckets", Json::Arr(buckets)),
                    ("sum_us", Json::Num(h.sum_us as f64)),
                    ("count", Json::Num(h.count as f64)),
                    ("min_us", Json::Num(h.min_us as f64)),
                    ("max_us", Json::Num(h.max_us as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Str("se2attn-metrics-v1".to_string())),
            ("scalars", Json::Arr(scalars)),
            ("histograms", Json::Arr(histograms)),
        ])
    }

    /// Parse a document produced by [`MetricsSnapshot::to_json`].
    pub fn from_json(doc: &Json) -> anyhow::Result<MetricsSnapshot> {
        let schema = doc.get("schema").and_then(|s| s.as_str()).unwrap_or("");
        if schema != "se2attn-metrics-v1" {
            anyhow::bail!("unsupported metrics schema {schema:?}");
        }
        let mut out = MetricsSnapshot::default();
        for s in doc
            .get("scalars")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("metrics json missing 'scalars' array"))?
        {
            let name = s
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("scalar missing name"))?;
            let kind = s
                .get("kind")
                .and_then(|v| v.as_str())
                .and_then(MetricKind::parse)
                .ok_or_else(|| anyhow::anyhow!("scalar {name} has bad kind"))?;
            let mut labels = Vec::new();
            if let Some(Json::Obj(m)) = s.get("labels") {
                for (k, v) in m {
                    let v = v
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("scalar {name} label {k} not a string"))?;
                    labels.push((k.clone(), v.to_string()));
                }
            }
            // clamp below at zero: a hand-edited or corrupted document
            // must not wrap a negative value to u64::MAX-ish garbage
            let value = s
                .get("value")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("scalar {name} missing value"))?
                .max(0.0) as u64;
            out.scalars.push(Scalar {
                name: name.to_string(),
                labels,
                kind,
                value,
            });
        }
        for h in doc
            .get("histograms")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("metrics json missing 'histograms' array"))?
        {
            let name = h
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("histogram missing name"))?;
            let buckets = h
                .get("buckets")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("histogram {name} missing buckets"))?
                .iter()
                .map(|b| b.as_f64().unwrap_or(0.0).max(0.0) as u64)
                .collect();
            let field =
                |key: &str| h.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0).max(0.0) as u64;
            out.histograms.push(HistogramSnapshot {
                name: name.to_string(),
                buckets,
                sum_us: field("sum_us"),
                count: field("count"),
                min_us: field("min_us"),
                max_us: field("max_us"),
            });
        }
        Ok(out)
    }
}

fn render_labels(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    // Prometheus label values escape exactly `\`, `"`, and newline; the
    // backslash must go first so later escapes are not double-escaped.
    let parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| {
            format!(
                "{k}=\"{}\"",
                v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
            )
        })
        .collect();
    format!("{{{}}}", parts.join(","))
}

// --------------------------------------------------------------------------
// line-format validation
// --------------------------------------------------------------------------

/// Sanity-check a Prometheus text-exposition document: every non-comment
/// line must be `name[{labels}] value`, names must be legal, every sample
/// must be preceded by a `# TYPE` for its base metric name.  Returns the
/// number of samples on success.
pub fn validate_prometheus(text: &str) -> anyhow::Result<usize> {
    let mut typed: Vec<String> = Vec::new();
    let mut samples = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if let Some(decl) = rest.strip_prefix("TYPE ") {
                let mut it = decl.split_whitespace();
                let name = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("line {}: TYPE without name", lineno + 1))?;
                let kind = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("line {}: TYPE without kind", lineno + 1))?;
                if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                    anyhow::bail!("line {}: unknown TYPE kind {kind:?}", lineno + 1);
                }
                if !valid_metric_name(name) {
                    anyhow::bail!("line {}: bad metric name {name:?}", lineno + 1);
                }
                typed.push(name.to_string());
            }
            continue;
        }
        // sample line: name[{labels}] value
        let (ident, value) = split_sample(line)
            .ok_or_else(|| anyhow::anyhow!("line {}: malformed sample {line:?}", lineno + 1))?;
        if !valid_label_escapes(ident) {
            anyhow::bail!(
                "line {}: invalid escape or unterminated label value in {ident:?}",
                lineno + 1
            );
        }
        let name = ident.split('{').next().unwrap_or(ident);
        if !valid_metric_name(name) {
            anyhow::bail!("line {}: bad metric name {name:?}", lineno + 1);
        }
        if value != "+Inf" && value != "-Inf" && value != "NaN" && value.parse::<f64>().is_err() {
            anyhow::bail!("line {}: bad sample value {value:?}", lineno + 1);
        }
        // histogram series (_bucket/_sum/_count and the exact-extreme
        // companions) are declared under their base or companion name
        if !typed.iter().any(|t| {
            name == t
                || name == format!("{t}_bucket")
                || name == format!("{t}_sum")
                || name == format!("{t}_count")
        }) {
            anyhow::bail!("line {}: sample {name:?} has no preceding # TYPE", lineno + 1);
        }
        samples += 1;
    }
    if samples == 0 {
        anyhow::bail!("no samples found");
    }
    Ok(samples)
}

/// Reject label values with invalid escape sequences: inside quotes a
/// backslash may only introduce `\\`, `\"`, or `\n` (the exact set
/// [`render_labels`] emits); quotes must be balanced.
fn valid_label_escapes(ident: &str) -> bool {
    let mut in_quotes = false;
    let mut chars = ident.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => in_quotes = !in_quotes,
            '\\' if in_quotes => match chars.next() {
                Some('\\') | Some('"') | Some('n') => {}
                _ => return false,
            },
            _ => {}
        }
    }
    !in_quotes
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split `name{labels} value` into (`name{labels}`, `value`), honouring
/// quotes inside label values (a quoted `} ` must not end the ident).
fn split_sample(line: &str) -> Option<(&str, &str)> {
    let bytes = line.as_bytes();
    let mut in_quotes = false;
    let mut escaped = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\\' if in_quotes && !escaped => {
                escaped = true;
                continue;
            }
            b'"' if !escaped => in_quotes = !in_quotes,
            b' ' | b'\t' if !in_quotes => {
                let value = line[i..].trim();
                if value.is_empty() {
                    return None;
                }
                return Some((&line[..i], value));
            }
            _ => {}
        }
        escaped = false;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::ServerStats;

    fn sample_stats() -> ServerStats {
        let stats = ServerStats::with_shards(2);
        stats.requests_in.add(10);
        stats.requests_done.add(9);
        stats.requests_failed.add(1);
        stats.batches.add(4);
        stats.e2e_latency.record_us(1500);
        stats.e2e_latency.record_us(900);
        stats.decode_latency.record_us(700);
        stats.cache.hits.add(5);
        stats.cache.resident_bytes.set(4096);
        stats.shards[0].requests.add(6);
        stats.shards[1].requests.add(4);
        stats.shards[1].inflight.add(2);
        stats.families.record(FamilyId::Roundabout, &[1.25], 1, 4);
        stats
    }

    #[test]
    fn collect_covers_all_primitives() {
        let stats = sample_stats();
        let snap = MetricsSnapshot::collect(&stats, None);
        let get = |name: &str| {
            snap.scalars
                .iter()
                .find(|s| s.name == name)
                .unwrap_or_else(|| panic!("missing {name}"))
                .value
        };
        assert_eq!(get("se2attn_requests_in_total"), 10);
        assert_eq!(get("se2attn_cache_hits_total"), 5);
        assert_eq!(get("se2attn_cache_resident_bytes"), 4096);
        let shard1_inflight = snap
            .scalars
            .iter()
            .find(|s| {
                s.name == "se2attn_shard_inflight"
                    && s.labels == vec![("shard".to_string(), "1".to_string())]
            })
            .unwrap();
        assert_eq!(shard1_inflight.value, 2);
        let fam = snap
            .scalars
            .iter()
            .find(|s| {
                s.name == "se2attn_family_requests_total"
                    && s.labels
                        == vec![("family".to_string(), FamilyId::Roundabout.name().to_string())]
            })
            .unwrap();
        assert_eq!(fam.value, 1);
        assert!(snap.scalars.iter().any(|s| s.name == "se2attn_kernel_calls_total"));
        let e2e = snap
            .histograms
            .iter()
            .find(|h| h.name == "se2attn_e2e_latency_us")
            .unwrap();
        assert_eq!(e2e.count, 2);
        assert_eq!(e2e.sum_us, 2400);
        assert_eq!(e2e.min_us, 900);
        assert_eq!(e2e.max_us, 1500);
        assert_eq!(e2e.buckets.iter().sum::<u64>(), e2e.count);
    }

    #[test]
    fn prometheus_output_validates_and_is_cumulative() {
        let stats = sample_stats();
        let snap = MetricsSnapshot::collect(&stats, None);
        let text = snap.to_prometheus();
        let n = validate_prometheus(&text).expect("exposition must validate");
        assert!(n > 20, "expected a rich sample count, got {n}");
        assert!(text.contains("# TYPE se2attn_requests_in_total counter"));
        assert!(text.contains("se2attn_shard_inflight{shard=\"1\"} 2"));
        assert!(text.contains("# TYPE se2attn_e2e_latency_us histogram"));
        assert!(text.contains("se2attn_e2e_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("se2attn_e2e_latency_us_count 2"));
        assert!(text.contains("se2attn_e2e_latency_us_max_us 1500"));
        // cumulative le series: every bucket count <= the +Inf count
        let inf = 2u64;
        for line in text.lines().filter(|l| l.contains("_bucket{le=")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v <= inf, "{line}");
        }
    }

    #[test]
    fn collect_covers_admission_metrics() {
        let stats = sample_stats();
        stats.queue_sheds.add(3);
        stats.step_sessions.add(24);
        stats.queue_age.record_us(1200);
        stats.tenants.admitted(1);
        stats.tenants.shed(1);
        stats.shards[0].shed.add(3);
        stats.shards[0].live_sessions.set(5);
        let snap = MetricsSnapshot::collect(&stats, None);
        let get = |name: &str| {
            snap.scalars
                .iter()
                .find(|s| s.name == name)
                .unwrap_or_else(|| panic!("missing {name}"))
                .value
        };
        assert_eq!(get("se2attn_queue_sheds_total"), 3);
        assert_eq!(get("se2attn_step_sessions_total"), 24);
        let tenant1 = |name: &str| {
            snap.scalars
                .iter()
                .find(|s| {
                    s.name == name && s.labels == vec![("tenant".to_string(), "1".to_string())]
                })
                .unwrap_or_else(|| panic!("missing {name} for tenant 1"))
                .value
        };
        assert_eq!(tenant1("se2attn_tenant_admitted_total"), 1);
        assert_eq!(tenant1("se2attn_tenant_sheds_total"), 1);
        let qage = snap
            .histograms
            .iter()
            .find(|h| h.name == "se2attn_queue_age_us")
            .unwrap();
        assert_eq!(qage.count, 1);
        let text = snap.to_prometheus();
        validate_prometheus(&text).expect("admission metrics must render valid exposition");
        assert!(text.contains("se2attn_shard_shed_total{shard=\"0\"} 3"));
        assert!(text.contains("se2attn_shard_live_sessions{shard=\"0\"} 5"));
        assert!(text.contains("# TYPE se2attn_queue_age_us histogram"));
    }

    #[test]
    fn json_roundtrip_preserves_snapshot() {
        let stats = sample_stats();
        let snap = MetricsSnapshot::collect(&stats, None);
        let doc = snap.to_json();
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let back = MetricsSnapshot::from_json(&parsed).unwrap();
        assert_eq!(snap, back);
    }

    #[test]
    fn from_json_rejects_other_schemas() {
        let doc = Json::obj(vec![("schema", Json::Str("bogus".into()))]);
        assert!(MetricsSnapshot::from_json(&doc).is_err());
    }

    #[test]
    fn delta_subtracts_counters_keeps_gauges() {
        let stats = sample_stats();
        let prev = MetricsSnapshot::collect(&stats, None);
        stats.requests_in.add(5);
        stats.cache.resident_bytes.set(8192);
        stats.e2e_latency.record_us(3000);
        let cur = MetricsSnapshot::collect(&stats, None);
        let d = cur.delta(&prev);
        let get = |name: &str| d.scalars.iter().find(|s| s.name == name).unwrap().value;
        assert_eq!(get("se2attn_requests_in_total"), 5);
        assert_eq!(get("se2attn_requests_done_total"), 0);
        // gauges report the current level, not a difference
        assert_eq!(get("se2attn_cache_resident_bytes"), 8192);
        let e2e = d
            .histograms
            .iter()
            .find(|h| h.name == "se2attn_e2e_latency_us")
            .unwrap();
        assert_eq!(e2e.count, 1);
        assert_eq!(e2e.sum_us, 3000);
        assert_eq!(e2e.buckets.iter().sum::<u64>(), 1);
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_prometheus("").is_err());
        assert!(validate_prometheus("no_type_header 1\n").is_err());
        assert!(
            validate_prometheus("# TYPE m counter\nm not-a-number\n").is_err(),
            "bad value must fail"
        );
        assert!(
            validate_prometheus("# TYPE 9bad counter\n9bad 1\n").is_err(),
            "bad name must fail"
        );
        let ok = "# TYPE m counter\nm{a=\"x y\"} 3\nm 4\n";
        assert_eq!(validate_prometheus(ok).unwrap(), 2);
    }

    #[test]
    fn collect_exports_memory_attribution_families() {
        let stats = ServerStats::with_shards(1);
        let snap = MetricsSnapshot::collect(&stats, None);
        for scope in crate::obs::alloc::Scope::ALL {
            let labels = vec![("scope".to_string(), scope.name().to_string())];
            for name in ["se2attn_mem_live_bytes", "se2attn_mem_peak_bytes"] {
                assert!(
                    snap.scalars.iter().any(|s| s.name == name && s.labels == labels),
                    "missing {name} for scope {:?}",
                    scope.name()
                );
            }
        }
        assert!(snap.scalars.iter().any(|s| s.name == "se2attn_mem_resident_bytes"));
        let text = snap.to_prometheus();
        validate_prometheus(&text).expect("mem families must render valid exposition");
        assert!(text.contains("se2attn_mem_live_bytes{scope=\"kvcache\"}"));
        assert!(text.contains("# TYPE se2attn_mem_allocs_total counter"));
    }

    #[test]
    fn delta_clamps_counter_resets_to_zero() {
        // a restarted process hands `stats --prev` a snapshot whose
        // counters are AHEAD of the current ones; the interval delta must
        // clamp at zero, never wrap to ~u64::MAX
        let stats = sample_stats();
        let cur = MetricsSnapshot::collect(&stats, None);
        let mut prev = cur.clone();
        for s in &mut prev.scalars {
            if s.kind == MetricKind::Counter {
                s.value += 1000;
            }
        }
        for h in &mut prev.histograms {
            h.count += 10;
            h.sum_us += 10_000;
            for b in &mut h.buckets {
                *b += 1;
            }
        }
        let d = cur.delta(&prev);
        for s in d.scalars.iter().filter(|s| s.kind == MetricKind::Counter) {
            assert_eq!(s.value, 0, "{} must clamp to zero", s.name);
        }
        for h in &d.histograms {
            assert_eq!(h.count, 0);
            assert_eq!(h.sum_us, 0);
            assert!(h.buckets.iter().all(|&b| b == 0));
        }
    }

    #[test]
    fn from_json_clamps_negative_values_to_zero() {
        let doc = Json::parse(
            r#"{"schema":"se2attn-metrics-v1",
                "scalars":[{"name":"m","labels":{},"kind":"counter","value":-5}],
                "histograms":[{"name":"h","buckets":[-1,2],"sum_us":-9,"count":-3,
                               "min_us":0,"max_us":0}]}"#,
        )
        .unwrap();
        let snap = MetricsSnapshot::from_json(&doc).unwrap();
        assert_eq!(snap.scalars[0].value, 0);
        assert_eq!(snap.histograms[0].buckets, vec![0, 2]);
        assert_eq!(snap.histograms[0].sum_us, 0);
        assert_eq!(snap.histograms[0].count, 0);
    }

    #[test]
    fn labels_escape_backslash_quote_and_newline() {
        let labels = vec![("path".to_string(), "a\\b\"c\nd".to_string())];
        let r = render_labels(&labels);
        assert_eq!(r, "{path=\"a\\\\b\\\"c\\nd\"}");
        // a document carrying that label round-trips the validator
        let text = format!("# TYPE m counter\nm{r} 1\n");
        assert_eq!(validate_prometheus(&text).unwrap(), 1);
        // but an invalid escape sequence is rejected
        assert!(validate_prometheus("# TYPE m counter\nm{a=\"x\\q\"} 1\n").is_err());
        // and so is an unterminated label value
        assert!(validate_prometheus("# TYPE m counter\nm{a=\"x} 1\n").is_err());
    }

    #[test]
    fn split_sample_honours_quoted_spaces() {
        let (ident, value) = split_sample("m{a=\"x } y\"} 7").unwrap();
        assert_eq!(ident, "m{a=\"x } y\"}");
        assert_eq!(value, "7");
        assert!(split_sample("novalue").is_none());
    }
}
