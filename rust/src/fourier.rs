//! Fourier machinery for SE(2) Fourier attention (paper Sec. III-B) plus the
//! analytic Bessel-series cross-check used by property tests.
//!
//! Mirrors `python/compile/kernels/basis.py`; the quadrature coefficients
//! here feed the Rust CPU attention baselines and the Fig. 3 / Fig. 4
//! reproductions.

use crate::geometry::Pose;
use crate::linalg::Mat;

/// Integer frequency of basis element i: 0, 1, 1, 2, 2, 3, 3, ... (Eq. 12).
pub fn basis_frequency(i: usize) -> usize {
    (i + 1) / 2
}

/// Evaluate g_i(z) (paper Eq. 12).
pub fn basis_fn(i: usize, z: f64) -> f64 {
    let k = basis_frequency(i) as f64;
    if i % 2 == 0 {
        (k * z).cos()
    } else {
        (k * z).sin()
    }
}

/// b_n = [g_0(theta), ..., g_{F-1}(theta)].
pub fn eval_basis(theta: f64, f: usize) -> Vec<f64> {
    (0..f).map(|i| basis_fn(i, theta)).collect()
}

/// The 2F-point uniform quadrature grid on [-pi, pi).
pub fn quadrature_grid(f: usize) -> Vec<f64> {
    (0..2 * f)
        .map(|j| -std::f64::consts::PI + std::f64::consts::PI * j as f64 / f as f64)
        .collect()
}

/// u_m^{(x)}(z) = x cos z + y sin z (Eq. 11).
pub fn u_x(x: f64, y: f64, z: f64) -> f64 {
    x * z.cos() + y * z.sin()
}

/// u_m^{(y)}(z) = -x sin z + y cos z (Eq. 18).
pub fn u_y(x: f64, y: f64, z: f64) -> f64 {
    -x * z.sin() + y * z.cos()
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    X,
    Y,
}

/// Precomputed 2F-point quadrature table: grid trig values and weighted
/// basis matrix.  Hot-path coefficient computation reduces to 2F sin_cos
/// evaluations plus a (2F x F) contraction per (token, axis) — ~3x faster
/// than re-evaluating `basis_fn` per element (EXPERIMENTS.md §Perf L3).
#[derive(Clone, Debug)]
pub struct QuadratureTable {
    pub f: usize,
    /// cos/sin of each grid point z_j.
    pub cos_z: Vec<f64>,
    pub sin_z: Vec<f64>,
    /// w[j * f + i] = g_i(z_j) * a_i / (2F).
    pub weights: Vec<f64>,
}

impl QuadratureTable {
    pub fn new(f: usize) -> QuadratureTable {
        let grid = quadrature_grid(f);
        let mut weights = vec![0.0; 2 * f * f];
        for (j, &z) in grid.iter().enumerate() {
            for i in 0..f {
                let a = if i == 0 { 1.0 } else { 2.0 };
                weights[j * f + i] = basis_fn(i, z) * a / (2.0 * f as f64);
            }
        }
        QuadratureTable {
            f,
            cos_z: grid.iter().map(|z| z.cos()).collect(),
            sin_z: grid.iter().map(|z| z.sin()).collect(),
            weights,
        }
    }

    /// Gamma/Lambda coefficients written into `gamma`/`lambda` (len F).
    pub fn coefficients_into(
        &self,
        x: f64,
        y: f64,
        axis: Axis,
        gamma: &mut [f64],
        lambda: &mut [f64],
    ) {
        let f = self.f;
        gamma.iter_mut().for_each(|g| *g = 0.0);
        lambda.iter_mut().for_each(|l| *l = 0.0);
        for j in 0..2 * f {
            let u = match axis {
                Axis::X => x * self.cos_z[j] + y * self.sin_z[j],
                Axis::Y => -x * self.sin_z[j] + y * self.cos_z[j],
            };
            let (su, cu) = u.sin_cos();
            let row = &self.weights[j * f..(j + 1) * f];
            for i in 0..f {
                gamma[i] += cu * row[i];
                lambda[i] += su * row[i];
            }
        }
    }
}

/// Fourier coefficients Gamma_m (of cos u) and Lambda_m (of sin u) for key
/// position (x, y), by the paper's 2F-point quadrature (Eq. 14/15).
pub fn coefficients(x: f64, y: f64, f: usize, axis: Axis) -> (Vec<f64>, Vec<f64>) {
    let grid = quadrature_grid(f);
    let mut gamma = vec![0.0; f];
    let mut lambda = vec![0.0; f];
    for &z in &grid {
        let u = match axis {
            Axis::X => u_x(x, y, z),
            Axis::Y => u_y(x, y, z),
        };
        let (su, cu) = u.sin_cos();
        for i in 0..f {
            let g = basis_fn(i, z);
            gamma[i] += cu * g;
            lambda[i] += su * g;
        }
    }
    for i in 0..f {
        let a = if i == 0 { 1.0 } else { 2.0 };
        gamma[i] *= a / (2.0 * f as f64);
        lambda[i] *= a / (2.0 * f as f64);
    }
    (gamma, lambda)
}

/// Reconstruct the truncated series sum_i c_i g_i(theta).
pub fn reconstruct(coeffs: &[f64], theta: f64) -> f64 {
    coeffs
        .iter()
        .enumerate()
        .map(|(i, c)| c * basis_fn(i, theta))
        .sum()
}

// --------------------------------------------------------------------------
// Analytic coefficients via Jacobi–Anger (Bessel functions) — the
// independent oracle for the quadrature implementation.
//
//   u(z) = x cos z + y sin z = R cos(z - psi),  R = |(x,y)|, psi = atan2(y,x)
//   cos(R cos w) = J_0(R) + 2 sum_k (-1)^k J_{2k}(R) cos(2k w)
//   sin(R cos w) = 2 sum_k (-1)^k J_{2k+1}(R) cos((2k+1) w)
// --------------------------------------------------------------------------

/// Bessel function of the first kind J_n(x) by ascending power series with
/// enough terms for |x| <= ~40 in f64.
pub fn bessel_j(n: usize, x: f64) -> f64 {
    let half = x / 2.0;
    // (x/2)^n / n!
    let mut term = 1.0;
    for k in 1..=n {
        term *= half / k as f64;
    }
    let mut sum = term;
    let x2 = half * half;
    for m in 1..200 {
        term *= -x2 / (m as f64 * (m + n) as f64);
        sum += term;
        if term.abs() < 1e-18 * sum.abs().max(1e-300) {
            break;
        }
    }
    sum
}

/// Analytic Fourier coefficients of cos(u^{(x)}(z)) in the g_i basis,
/// derived from Jacobi–Anger (exact up to Bessel truncation, no aliasing).
pub fn coefficients_analytic_cos_x(x: f64, y: f64, f: usize) -> Vec<f64> {
    let r = (x * x + y * y).sqrt();
    let psi = y.atan2(x);
    // cos(u) = J0(R) + 2 sum_{k>=1} (-1)^k J_{2k}(R) cos(2k (z - psi))
    // cos(2k(z-psi)) = cos(2k psi) cos(2k z) + sin(2k psi) sin(2k z)
    let mut coeffs = vec![0.0; f];
    if f > 0 {
        coeffs[0] = bessel_j(0, r);
    }
    for i in 1..f {
        let k = basis_frequency(i);
        if k % 2 != 0 {
            continue; // cos(u) has only even harmonics
        }
        let kk = k / 2; // harmonic index in Jacobi-Anger
        let sign = if kk % 2 == 0 { 1.0 } else { -1.0 };
        let amp = 2.0 * sign * bessel_j(k, r);
        let ang = k as f64 * psi;
        if i % 2 == 0 {
            coeffs[i] = amp * ang.cos(); // cos(k z) component
        } else {
            coeffs[i] = amp * ang.sin(); // sin(k z) component
        }
    }
    coeffs
}

// --------------------------------------------------------------------------
// Explicit phi matrices (paper Eq. 19) — single 6-wide block
// --------------------------------------------------------------------------

/// The exact target block diag[rho(x_rel), rho(y_rel), rho(theta_rel)]
/// (Eq. 10) for a relative pose.
pub fn phi_target_block(rel: &Pose) -> Mat {
    Mat::block_diag(&[
        crate::geometry::rot2(rel.x),
        crate::geometry::rot2(rel.y),
        crate::geometry::rot2(rel.theta),
    ])
}

/// phi_q(p_n): 6 x (4F+2) query-side factor (Eq. 19).
pub fn phi_q_block(p: &Pose, f: usize) -> Mat {
    let b = eval_basis(p.theta, f);
    let (st, ct) = p.theta.sin_cos();
    let vx = -p.x * ct - p.y * st;
    let vy = p.x * st - p.y * ct;

    let rot_outer = |v: f64| -> Mat {
        let (sv, cv) = v.sin_cos();
        let mut m = Mat::zeros(2, 2 * f);
        for i in 0..f {
            m[(0, i)] = cv * b[i];
            m[(0, f + i)] = -sv * b[i];
            m[(1, i)] = sv * b[i];
            m[(1, f + i)] = cv * b[i];
        }
        m
    };

    Mat::block_diag(&[
        rot_outer(vx),
        rot_outer(vy),
        crate::geometry::rot2(-p.theta),
    ])
}

/// phi_k(p_m): (4F+2) x 6 key-side factor (Eq. 19).
pub fn phi_k_block(p: &Pose, f: usize) -> Mat {
    let coeff_mat = |axis: Axis| -> Mat {
        let (gamma, lambda) = coefficients(p.x, p.y, f, axis);
        let mut m = Mat::zeros(2 * f, 2);
        for i in 0..f {
            m[(i, 0)] = gamma[i];
            m[(i, 1)] = -lambda[i];
            m[(f + i, 0)] = lambda[i];
            m[(f + i, 1)] = gamma[i];
        }
        m
    };
    Mat::block_diag(&[
        coeff_mat(Axis::X),
        coeff_mat(Axis::Y),
        crate::geometry::rot2(p.theta),
    ])
}

/// Spectral-norm approximation error
/// || phi(p_{n->m}) - phi_q(p_n) phi_k(p_m) ||_2  (paper Fig. 3).
pub fn approximation_error(pn: &Pose, pm: &Pose, f: usize) -> f64 {
    let target = phi_target_block(&pn.relative_to(pm));
    let approx = phi_q_block(pn, f).matmul(&phi_k_block(pm, f));
    target.sub(&approx).spectral_norm()
}

/// Machine-epsilon reference lines of Fig. 3: smallest eps with 1+eps
/// representable.
pub const FP16_EPS: f64 = 0.000976562; // 2^-10
pub const BF16_EPS: f64 = 0.0078125; // 2^-7

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn basis_matches_paper_table() {
        let z = 0.37;
        assert_eq!(basis_fn(0, z), 1.0);
        assert!((basis_fn(1, z) - z.sin()).abs() < 1e-15);
        assert!((basis_fn(2, z) - z.cos()).abs() < 1e-15);
        assert!((basis_fn(3, z) - (2.0 * z).sin()).abs() < 1e-15);
        assert!((basis_fn(4, z) - (2.0 * z).cos()).abs() < 1e-15);
    }

    #[test]
    fn quadrature_vs_analytic_bessel() {
        // The 2F-point quadrature coefficients must match Jacobi–Anger
        // (once F is large enough that aliasing is negligible).
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let x = rng.range(-3.0, 3.0);
            let y = rng.range(-3.0, 3.0);
            let f = 24;
            let (gamma, _) = coefficients(x, y, f, Axis::X);
            let analytic = coefficients_analytic_cos_x(x, y, f);
            for i in 0..16 {
                assert!(
                    (gamma[i] - analytic[i]).abs() < 1e-6,
                    "i={i} quad={} analytic={} at ({x},{y})",
                    gamma[i],
                    analytic[i]
                );
            }
        }
    }

    #[test]
    fn bessel_known_values() {
        assert!((bessel_j(0, 0.0) - 1.0).abs() < 1e-15);
        assert!((bessel_j(1, 0.0)).abs() < 1e-15);
        // J_0(2.404825557695773) ~ 0 (first zero)
        assert!(bessel_j(0, 2.404825557695773).abs() < 1e-10);
        // J_1(1.0) = 0.4400505857449335
        assert!((bessel_j(1, 1.0) - 0.4400505857449335).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_converges() {
        let (x, y) = (1.5, -0.8);
        let mut prev = f64::INFINITY;
        for &f in &[6usize, 12, 20, 28] {
            let (gamma, _) = coefficients(x, y, f, Axis::X);
            let mut max_err: f64 = 0.0;
            for j in 0..64 {
                let t = -std::f64::consts::PI
                    + std::f64::consts::TAU * j as f64 / 64.0;
                let exact = u_x(x, y, t).cos();
                max_err = max_err.max((reconstruct(&gamma, t) - exact).abs());
            }
            assert!(max_err < prev + 1e-12, "F={f}: {max_err} !< {prev}");
            prev = max_err;
        }
        assert!(prev < 1e-6, "F=28 error {prev}");
    }

    #[test]
    fn factorization_error_small() {
        // radius <= 2, F=18 -> error below ~fp16 eps (paper Fig. 3).
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            let pn = Pose::new(
                rng.range(-1.4, 1.4),
                rng.range(-1.4, 1.4),
                rng.range(-3.14, 3.14),
            );
            let pm = Pose::new(
                rng.range(-1.4, 1.4),
                rng.range(-1.4, 1.4),
                rng.range(-3.14, 3.14),
            );
            let err = approximation_error(&pn, &pm, 18);
            assert!(err < 2.0 * FP16_EPS, "err={err}");
        }
    }

    #[test]
    fn theta_block_is_exact() {
        // With zero translation the factorization is exact for any F.
        let pn = Pose::new(0.0, 0.0, 0.9);
        let pm = Pose::new(0.0, 0.0, -1.7);
        assert!(approximation_error(&pn, &pm, 4) < 1e-9);
    }

    #[test]
    fn phi_shapes() {
        let p = Pose::new(1.0, 2.0, 0.5);
        let f = 9;
        let q = phi_q_block(&p, f);
        let k = phi_k_block(&p, f);
        assert_eq!((q.rows, q.cols), (6, 4 * f + 2));
        assert_eq!((k.rows, k.cols), (4 * f + 2, 6));
    }
}
