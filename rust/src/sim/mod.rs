//! Synthetic driving scenario simulator.
//!
//! Substitute for the paper's private 33M-scenario dataset (DESIGN.md §6):
//! procedural lane-graph maps, kinematic-bicycle agents with lane-following
//! / changing / yielding / stopping policies, and pedestrians near
//! crosswalks.  The [`suite`] module generalizes the single corridor map
//! into a registry of named scenario families (merges, signalized
//! crossings, roundabouts, parking lots, urban crossings) plus a weighted
//! workload mixer (DESIGN.md §11).  Every generator is seeded and fully
//! deterministic, so dataset shards and Table-I runs are reproducible
//! bit-for-bit.
//!
//! World units are meters/seconds; the tokenizer downscales positions into
//! the model's |p| <= 4 band (paper Sec. IV-B).

pub mod agent;
pub mod map;
pub mod render;
pub mod scenario;
pub mod suite;

pub use agent::{AgentKind, AgentState, KinematicAction};
pub use map::{LaneGraph, MapElement, MapElementKind};
pub use scenario::{Scenario, ScenarioGenerator, TrajectoryClass};
pub use suite::{Family, FamilyId, MixGenerator, WorkloadMix};
