//! Scenario generation: roll agent policies forward and record the full
//! (state, action) history — the raw material for the dataset pipeline and
//! the minADE ground truth.

use crate::config::SimConfig;
use crate::geometry::wrap_angle;
use crate::prng::Rng;

use super::agent::{plan, spawn, AgentState, KinematicAction, Policy};
use super::map::{LaneGraph, MapElement};
use super::suite::FamilyId;

/// Ground-truth trajectory category (paper Table I columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrajectoryClass {
    Stationary,
    Straight,
    Turning,
}

impl TrajectoryClass {
    pub fn name(&self) -> &'static str {
        match self {
            TrajectoryClass::Stationary => "stationary",
            TrajectoryClass::Straight => "straight",
            TrajectoryClass::Turning => "turning",
        }
    }
}

/// A complete simulated scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub map: LaneGraph,
    pub map_elements: Vec<MapElement>,
    /// states[t][a]: agent `a` at step `t`; t in [0, history+future].
    pub states: Vec<Vec<AgentState>>,
    /// actions[t][a]: the action agent `a` took between steps t and t+1.
    pub actions: Vec<Vec<KinematicAction>>,
    pub seed: u64,
    /// Which scenario family generated this world (per-family evaluation).
    pub family: FamilyId,
}

impl Scenario {
    pub fn n_agents(&self) -> usize {
        self.states[0].len()
    }

    pub fn n_steps(&self) -> usize {
        self.states.len()
    }

    /// Classify the *future* trajectory of agent `a` from step `t0`
    /// (paper Sec. IV-B: stationary / straight / turning).
    pub fn classify_future(&self, a: usize, t0: usize) -> TrajectoryClass {
        let last = self.n_steps() - 1;
        // an empty future (t0 at or past the last recorded step) is pinned
        // to Stationary rather than indexing out of range
        if t0 >= last {
            return TrajectoryClass::Stationary;
        }
        let start = &self.states[t0][a];
        let end = &self.states[last][a];
        let displacement = start.pose.dist(&end.pose);
        if displacement < 1.0 {
            return TrajectoryClass::Stationary;
        }
        let dtheta = wrap_angle(end.pose.theta - start.pose.theta).abs();
        if dtheta > std::f64::consts::PI / 6.0 {
            TrajectoryClass::Turning
        } else {
            TrajectoryClass::Straight
        }
    }

    /// Stable scene identity for cache keying: mixes the family into the
    /// seed, so same-seed scenarios from *different* families never share
    /// cached map rows (the KV pool's map registry is keyed by this).
    pub fn scene_id(&self) -> u64 {
        crate::prng::SplitMix64::new(
            self.seed ^ ((self.family.index() as u64 + 1) << 48),
        )
        .next_u64()
    }

    /// Ground-truth future positions of agent `a` after `t0` (world frame).
    pub fn future_positions(&self, a: usize, t0: usize) -> Vec<(f64, f64)> {
        (t0 + 1..self.n_steps())
            .map(|t| (self.states[t][a].pose.x, self.states[t][a].pose.y))
            .collect()
    }
}

/// Deterministic scenario factory.
pub struct ScenarioGenerator {
    pub sim: SimConfig,
}

impl ScenarioGenerator {
    pub fn new(sim: SimConfig) -> ScenarioGenerator {
        ScenarioGenerator { sim }
    }

    /// Generate scenario `seed` (independent of call order).
    pub fn generate(&self, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed ^ 0x5CEA_A210_u64);
        let map = LaneGraph::generate(&mut rng);
        let map_elements = map.elements(self.sim.n_map_tokens);

        // policy mix tuned so all three Table-I classes occur: the turning
        // lane and the stop-line create turning/stationary futures.
        let mut policies: Vec<Policy> = Vec::new();
        let turn_lane = map.lanes.len().saturating_sub(2).max(2).min(map.lanes.len() - 1);
        for a in 0..self.sim.n_agents {
            let roll = rng.uniform();
            let p = if a == 0 {
                // the "robot" is always a moving vehicle on the corridor
                Policy::LaneFollow {
                    lane: 0,
                    target_speed: rng.range(6.0, 12.0),
                    stop_at: None,
                }
            } else if roll < 0.30 {
                Policy::LaneFollow {
                    lane: turn_lane,
                    target_speed: rng.range(4.0, 8.0),
                    stop_at: None,
                }
            } else if roll < 0.45 {
                Policy::LaneFollow {
                    lane: rng.below(map.lanes.len()),
                    target_speed: rng.range(6.0, 12.0),
                    stop_at: Some(rng.range(30.0, 70.0)),
                }
            } else if roll < 0.60 {
                if map.crosswalks.is_empty() {
                    Policy::Stationary
                } else {
                    Policy::Wander {
                        goal: (rng.range(-20.0, 20.0), rng.range(-20.0, 20.0)),
                        speed: rng.range(0.8, 1.8),
                    }
                }
            } else if roll < 0.72 {
                Policy::Stationary
            } else {
                Policy::LaneFollow {
                    lane: rng.below(map.lanes.len()),
                    target_speed: rng.range(6.0, 13.0),
                    stop_at: None,
                }
            };
            policies.push(p);
        }

        let agents: Vec<AgentState> =
            policies.iter().map(|p| spawn(p, &map, &mut rng)).collect();

        roll_forward(
            map,
            map_elements,
            policies,
            agents,
            &self.sim,
            &mut rng,
            seed,
            FamilyId::Corridor,
        )
    }
}

/// Roll a fully assembled world (map + policies + initial agent states)
/// forward for `history + future` steps, recording every state and action.
/// Shared by the legacy [`ScenarioGenerator`] and every
/// [`super::suite::Family`] generator.
#[allow(clippy::too_many_arguments)]
pub fn roll_forward(
    map: LaneGraph,
    map_elements: Vec<MapElement>,
    mut policies: Vec<Policy>,
    mut agents: Vec<AgentState>,
    sim: &SimConfig,
    rng: &mut Rng,
    seed: u64,
    family: FamilyId,
) -> Scenario {
    assert_eq!(policies.len(), agents.len(), "one policy per agent");
    let total_steps = sim.history_steps + sim.future_steps;
    let mut states = Vec::with_capacity(total_steps + 1);
    let mut actions = Vec::with_capacity(total_steps);
    states.push(agents.clone());
    for _ in 0..total_steps {
        let snapshot = agents.clone();
        let mut step_actions = Vec::with_capacity(agents.len());
        for (i, agent) in agents.iter_mut().enumerate() {
            let others: Vec<AgentState> = snapshot
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, s)| *s)
                .collect();
            let (action, new_policy) = plan(&policies[i], agent, &others, &map, rng);
            *agent = agent.step(action, sim.dt);
            policies[i] = new_policy;
            step_actions.push(action);
        }
        states.push(agents.clone());
        actions.push(step_actions);
    }

    Scenario {
        map,
        map_elements,
        states,
        actions,
        seed,
        family,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generator() -> ScenarioGenerator {
        ScenarioGenerator::new(SimConfig::default())
    }

    #[test]
    fn deterministic_from_seed() {
        let g = generator();
        let a = g.generate(17);
        let b = g.generate(17);
        for (sa, sb) in a.states.iter().zip(b.states.iter()) {
            for (x, y) in sa.iter().zip(sb.iter()) {
                assert_eq!(x.pose, y.pose);
                assert_eq!(x.speed, y.speed);
            }
        }
    }

    #[test]
    fn shapes_match_config() {
        let g = generator();
        let s = g.generate(0);
        let cfg = SimConfig::default();
        assert_eq!(s.n_agents(), cfg.n_agents);
        assert_eq!(s.n_steps(), cfg.history_steps + cfg.future_steps + 1);
        assert_eq!(s.actions.len(), cfg.history_steps + cfg.future_steps);
        assert_eq!(s.map_elements.len(), cfg.n_map_tokens);
    }

    #[test]
    fn all_trajectory_classes_occur() {
        let g = generator();
        let mut seen = std::collections::HashSet::new();
        for seed in 0..60 {
            let s = g.generate(seed);
            for a in 0..s.n_agents() {
                seen.insert(s.classify_future(a, SimConfig::default().history_steps));
            }
            if seen.len() == 3 {
                break;
            }
        }
        assert_eq!(seen.len(), 3, "classes seen: {seen:?}");
    }

    /// Minimal scenario from raw per-step poses of a single agent.
    fn synthetic(poses: Vec<crate::geometry::Pose>) -> Scenario {
        use super::super::agent::AgentKind;
        let states: Vec<Vec<AgentState>> = poses
            .into_iter()
            .map(|pose| {
                vec![AgentState {
                    pose,
                    speed: 0.0,
                    kind: AgentKind::Vehicle,
                    length: 4.8,
                    width: 2.0,
                    last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
                }]
            })
            .collect();
        Scenario {
            map: LaneGraph::empty(),
            map_elements: vec![],
            states,
            actions: vec![],
            seed: 0,
            family: FamilyId::Corridor,
        }
    }

    #[test]
    fn classify_future_pins_empty_future_to_stationary() {
        use crate::geometry::Pose;
        let s = synthetic(vec![
            Pose::new(0.0, 0.0, 0.0),
            Pose::new(10.0, 0.0, 0.0),
            Pose::new(20.0, 0.0, 0.0),
        ]);
        // t0 at the last step: no future steps exist
        assert_eq!(s.classify_future(0, 2), TrajectoryClass::Stationary);
        // t0 past the last step must not panic either
        assert_eq!(s.classify_future(0, 99), TrajectoryClass::Stationary);
        // a real future from the first step is Straight
        assert_eq!(s.classify_future(0, 0), TrajectoryClass::Straight);
    }

    #[test]
    fn classify_future_handles_heading_wrap_near_pi() {
        use crate::geometry::Pose;
        let pi = std::f64::consts::PI;
        // heading drifts 3.10 -> -3.10 across the +-pi seam: the wrapped
        // delta is ~0.08 rad, NOT ~6.2 — this must classify as Straight
        let s = synthetic(vec![
            Pose::new(0.0, 0.0, 3.10),
            Pose::new(-10.0, 0.5, pi),
            Pose::new(-20.0, 1.0, -3.10),
        ]);
        assert_eq!(s.classify_future(0, 0), TrajectoryClass::Straight);
        // a genuine turn that crosses the seam stays Turning
        let t = synthetic(vec![
            Pose::new(0.0, 0.0, 2.6),
            Pose::new(-8.0, 4.0, pi),
            Pose::new(-14.0, 10.0, -2.6),
        ]);
        assert_eq!(t.classify_future(0, 0), TrajectoryClass::Turning);
    }

    #[test]
    fn classify_future_displacement_threshold() {
        use crate::geometry::Pose;
        // displacement just under 1 m is Stationary, just over is not
        let under = synthetic(vec![
            Pose::new(0.0, 0.0, 0.0),
            Pose::new(0.99, 0.0, 0.0),
        ]);
        assert_eq!(under.classify_future(0, 0), TrajectoryClass::Stationary);
        let over = synthetic(vec![
            Pose::new(0.0, 0.0, 0.0),
            Pose::new(1.01, 0.0, 0.0),
        ]);
        assert_eq!(over.classify_future(0, 0), TrajectoryClass::Straight);
    }

    #[test]
    fn agents_stay_in_scene_bounds() {
        let g = generator();
        for seed in 0..10 {
            let s = g.generate(seed);
            for step in &s.states {
                for a in step {
                    assert!(
                        a.pose.radius() < 150.0,
                        "agent escaped: {:?}",
                        a.pose
                    );
                }
            }
        }
    }

    #[test]
    fn actions_respect_limits() {
        let g = generator();
        let s = g.generate(5);
        for step in &s.actions {
            for act in step {
                assert!(act.accel.abs() <= super::super::agent::MAX_ACCEL + 1e-9);
                assert!(act.yaw_rate.abs() <= super::super::agent::MAX_YAW_RATE + 1e-9);
            }
        }
    }
}
