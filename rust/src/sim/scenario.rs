//! Scenario generation: roll agent policies forward and record the full
//! (state, action) history — the raw material for the dataset pipeline and
//! the minADE ground truth.

use crate::config::SimConfig;
use crate::geometry::wrap_angle;
use crate::prng::Rng;

use super::agent::{plan, spawn, AgentState, KinematicAction, Policy};
use super::map::{LaneGraph, MapElement};

/// Ground-truth trajectory category (paper Table I columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrajectoryClass {
    Stationary,
    Straight,
    Turning,
}

impl TrajectoryClass {
    pub fn name(&self) -> &'static str {
        match self {
            TrajectoryClass::Stationary => "stationary",
            TrajectoryClass::Straight => "straight",
            TrajectoryClass::Turning => "turning",
        }
    }
}

/// A complete simulated scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub map: LaneGraph,
    pub map_elements: Vec<MapElement>,
    /// states[t][a]: agent `a` at step `t`; t in [0, history+future].
    pub states: Vec<Vec<AgentState>>,
    /// actions[t][a]: the action agent `a` took between steps t and t+1.
    pub actions: Vec<Vec<KinematicAction>>,
    pub seed: u64,
}

impl Scenario {
    pub fn n_agents(&self) -> usize {
        self.states[0].len()
    }

    pub fn n_steps(&self) -> usize {
        self.states.len()
    }

    /// Classify the *future* trajectory of agent `a` from step `t0`
    /// (paper Sec. IV-B: stationary / straight / turning).
    pub fn classify_future(&self, a: usize, t0: usize) -> TrajectoryClass {
        let last = self.n_steps() - 1;
        let start = &self.states[t0][a];
        let end = &self.states[last][a];
        let displacement = start.pose.dist(&end.pose);
        if displacement < 1.0 {
            return TrajectoryClass::Stationary;
        }
        let dtheta = wrap_angle(end.pose.theta - start.pose.theta).abs();
        if dtheta > std::f64::consts::PI / 6.0 {
            TrajectoryClass::Turning
        } else {
            TrajectoryClass::Straight
        }
    }

    /// Ground-truth future positions of agent `a` after `t0` (world frame).
    pub fn future_positions(&self, a: usize, t0: usize) -> Vec<(f64, f64)> {
        (t0 + 1..self.n_steps())
            .map(|t| (self.states[t][a].pose.x, self.states[t][a].pose.y))
            .collect()
    }
}

/// Deterministic scenario factory.
pub struct ScenarioGenerator {
    pub sim: SimConfig,
}

impl ScenarioGenerator {
    pub fn new(sim: SimConfig) -> ScenarioGenerator {
        ScenarioGenerator { sim }
    }

    /// Generate scenario `seed` (independent of call order).
    pub fn generate(&self, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed ^ 0x5CEA_A210_u64);
        let map = LaneGraph::generate(&mut rng);
        let map_elements = map.elements(self.sim.n_map_tokens);

        // policy mix tuned so all three Table-I classes occur: the turning
        // lane and the stop-line create turning/stationary futures.
        let mut policies: Vec<Policy> = Vec::new();
        let turn_lane = map.lanes.len().saturating_sub(2).max(2).min(map.lanes.len() - 1);
        for a in 0..self.sim.n_agents {
            let roll = rng.uniform();
            let p = if a == 0 {
                // the "robot" is always a moving vehicle on the corridor
                Policy::LaneFollow {
                    lane: 0,
                    target_speed: rng.range(6.0, 12.0),
                    stop_at: None,
                }
            } else if roll < 0.30 {
                Policy::LaneFollow {
                    lane: turn_lane,
                    target_speed: rng.range(4.0, 8.0),
                    stop_at: None,
                }
            } else if roll < 0.45 {
                Policy::LaneFollow {
                    lane: rng.below(map.lanes.len()),
                    target_speed: rng.range(6.0, 12.0),
                    stop_at: Some(rng.range(30.0, 70.0)),
                }
            } else if roll < 0.60 {
                if map.crosswalks.is_empty() {
                    Policy::Stationary
                } else {
                    Policy::Wander {
                        goal: (rng.range(-20.0, 20.0), rng.range(-20.0, 20.0)),
                        speed: rng.range(0.8, 1.8),
                    }
                }
            } else if roll < 0.72 {
                Policy::Stationary
            } else {
                Policy::LaneFollow {
                    lane: rng.below(map.lanes.len()),
                    target_speed: rng.range(6.0, 13.0),
                    stop_at: None,
                }
            };
            policies.push(p);
        }

        let mut agents: Vec<AgentState> =
            policies.iter().map(|p| spawn(p, &map, &mut rng)).collect();

        let total_steps = self.sim.history_steps + self.sim.future_steps;
        let mut states = Vec::with_capacity(total_steps + 1);
        let mut actions = Vec::with_capacity(total_steps);
        states.push(agents.clone());
        for _ in 0..total_steps {
            let snapshot = agents.clone();
            let mut step_actions = Vec::with_capacity(agents.len());
            for (i, agent) in agents.iter_mut().enumerate() {
                let others: Vec<AgentState> = snapshot
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .map(|(_, s)| *s)
                    .collect();
                let (action, new_policy) =
                    plan(&policies[i], agent, &others, &map, &mut rng);
                *agent = agent.step(action, self.sim.dt);
                policies[i] = new_policy;
                step_actions.push(action);
            }
            states.push(agents.clone());
            actions.push(step_actions);
        }

        Scenario {
            map,
            map_elements,
            states,
            actions,
            seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generator() -> ScenarioGenerator {
        ScenarioGenerator::new(SimConfig::default())
    }

    #[test]
    fn deterministic_from_seed() {
        let g = generator();
        let a = g.generate(17);
        let b = g.generate(17);
        for (sa, sb) in a.states.iter().zip(b.states.iter()) {
            for (x, y) in sa.iter().zip(sb.iter()) {
                assert_eq!(x.pose, y.pose);
                assert_eq!(x.speed, y.speed);
            }
        }
    }

    #[test]
    fn shapes_match_config() {
        let g = generator();
        let s = g.generate(0);
        let cfg = SimConfig::default();
        assert_eq!(s.n_agents(), cfg.n_agents);
        assert_eq!(s.n_steps(), cfg.history_steps + cfg.future_steps + 1);
        assert_eq!(s.actions.len(), cfg.history_steps + cfg.future_steps);
        assert_eq!(s.map_elements.len(), cfg.n_map_tokens);
    }

    #[test]
    fn all_trajectory_classes_occur() {
        let g = generator();
        let mut seen = std::collections::HashSet::new();
        for seed in 0..60 {
            let s = g.generate(seed);
            for a in 0..s.n_agents() {
                seen.insert(s.classify_future(a, SimConfig::default().history_steps));
            }
            if seen.len() == 3 {
                break;
            }
        }
        assert_eq!(seen.len(), 3, "classes seen: {seen:?}");
    }

    #[test]
    fn agents_stay_in_scene_bounds() {
        let g = generator();
        for seed in 0..10 {
            let s = g.generate(seed);
            for step in &s.states {
                for a in step {
                    assert!(
                        a.pose.radius() < 150.0,
                        "agent escaped: {:?}",
                        a.pose
                    );
                }
            }
        }
    }

    #[test]
    fn actions_respect_limits() {
        let g = generator();
        let s = g.generate(5);
        for step in &s.actions {
            for act in step {
                assert!(act.accel.abs() <= super::super::agent::MAX_ACCEL + 1e-9);
                assert!(act.yaw_rate.abs() <= super::super::agent::MAX_YAW_RATE + 1e-9);
            }
        }
    }
}
