//! ASCII scene renderer — debugging/inspection tool for scenarios and
//! rollouts (the simulator's answer to a bird's-eye-view plot).

use crate::geometry::Pose;

use super::map::MapElementKind;
use super::{AgentKind, Scenario};

/// Fixed-size character canvas over a metric window.
pub struct Canvas {
    pub width: usize,
    pub height: usize,
    /// meters per character column (rows use 2x to offset font aspect).
    pub scale: f64,
    pub center: (f64, f64),
    cells: Vec<char>,
}

impl Canvas {
    pub fn new(width: usize, height: usize, scale: f64, center: (f64, f64)) -> Canvas {
        Canvas {
            width,
            height,
            scale,
            center,
            cells: vec![' '; width * height],
        }
    }

    fn index(&self, x: f64, y: f64) -> Option<usize> {
        let col = ((x - self.center.0) / self.scale + self.width as f64 / 2.0).round();
        let row =
            (-(y - self.center.1) / (self.scale * 2.0) + self.height as f64 / 2.0).round();
        if col < 0.0 || row < 0.0 || col >= self.width as f64 || row >= self.height as f64 {
            return None;
        }
        Some(row as usize * self.width + col as usize)
    }

    pub fn plot(&mut self, x: f64, y: f64, ch: char) {
        if let Some(i) = self.index(x, y) {
            self.cells[i] = ch;
        }
    }

    /// Plot only if the cell is currently background.
    pub fn plot_soft(&mut self, x: f64, y: f64, ch: char) {
        if let Some(i) = self.index(x, y) {
            if self.cells[i] == ' ' {
                self.cells[i] = ch;
            }
        }
    }

    pub fn to_string_framed(&self) -> String {
        let mut s = String::with_capacity((self.width + 3) * (self.height + 2));
        s.push('+');
        s.push_str(&"-".repeat(self.width));
        s.push_str("+\n");
        for r in 0..self.height {
            s.push('|');
            s.extend(self.cells[r * self.width..(r + 1) * self.width].iter());
            s.push_str("|\n");
        }
        s.push('+');
        s.push_str(&"-".repeat(self.width));
        s.push('+');
        s
    }
}

/// Heading to one of 8 arrow glyphs (agent captions under the canvas).
pub fn heading_glyph(theta: f64) -> char {
    const GLYPHS: [char; 8] = ['>', '/', '^', '\\', '<', '/', 'v', '\\'];
    let sector = ((theta + std::f64::consts::PI / 8.0).rem_euclid(std::f64::consts::TAU)
        / (std::f64::consts::FRAC_PI_4)) as usize;
    GLYPHS[sector.min(7)]
}

/// Agent-kind glyph: V vehicle, P pedestrian, C cyclist — every scenario
/// family is visually debuggable by composition alone.
pub fn kind_glyph(kind: AgentKind) -> char {
    match kind {
        AgentKind::Vehicle => 'V',
        AgentKind::Pedestrian => 'P',
        AgentKind::Cyclist => 'C',
    }
}

/// Render a scenario at step `t` (agents as arrows, map as dots) plus
/// optional predicted trajectories (samples as '*').
pub fn render_scenario(
    s: &Scenario,
    t: usize,
    predictions: Option<&[Vec<Vec<(f64, f64)>>]>,
    width: usize,
    height: usize,
) -> String {
    let mut canvas = Canvas::new(width, height, 160.0 / width as f64, (0.0, 0.0));
    // lanes
    for lane in &s.map.lanes {
        for p in &lane.points {
            canvas.plot_soft(p.x, p.y, '.');
        }
    }
    for e in &s.map_elements {
        let ch = match e.kind {
            MapElementKind::Lane => '.',
            MapElementKind::Crosswalk => '=',
            MapElementKind::Signal => '!',
        };
        canvas.plot_soft(e.pose.x, e.pose.y, ch);
    }
    // predicted futures (under the agents)
    if let Some(samples) = predictions {
        for sample in samples {
            for track in sample {
                for &(x, y) in track {
                    canvas.plot_soft(x, y, '*');
                }
            }
        }
    }
    // agents (robot = R, others by kind: V/P/C)
    for (a, st) in s.states[t].iter().enumerate() {
        let ch = if a == 0 { 'R' } else { kind_glyph(st.kind) };
        canvas.plot(st.pose.x, st.pose.y, ch);
    }
    canvas.to_string_framed()
}

/// Render the ground-truth future of every agent from step `t0` as a
/// trajectory overlay (for eyeballing the stationary/straight/turning
/// classes).
pub fn render_futures(s: &Scenario, t0: usize, width: usize, height: usize) -> String {
    let mut canvas = Canvas::new(width, height, 160.0 / width as f64, (0.0, 0.0));
    for lane in &s.map.lanes {
        for p in &lane.points {
            canvas.plot_soft(p.x, p.y, '.');
        }
    }
    for a in 0..s.n_agents() {
        for (x, y) in s.future_positions(a, t0) {
            canvas.plot_soft(x, y, char::from_digit(a as u32 % 10, 10).unwrap());
        }
    }
    for (a, st) in s.states[t0].iter().enumerate() {
        canvas.plot(st.pose.x, st.pose.y, if a == 0 { 'R' } else { 'A' });
    }
    canvas.to_string_framed()
}

/// Convenience used by tests: does the rendered scene contain glyph?
pub fn contains_glyph(rendered: &str, ch: char) -> bool {
    rendered.chars().any(|c| c == ch)
}

#[allow(dead_code)]
fn _pose_debug(p: &Pose) -> String {
    format!("({:.1}, {:.1}, {:.2})", p.x, p.y, p.theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::ScenarioGenerator;

    #[test]
    fn canvas_plots_inside_only() {
        let mut c = Canvas::new(20, 10, 1.0, (0.0, 0.0));
        c.plot(0.0, 0.0, 'X');
        c.plot(1e9, 1e9, 'Y'); // out of bounds: ignored
        let s = c.to_string_framed();
        assert!(s.contains('X'));
        assert!(!s.contains('Y'));
        // frame is intact
        assert_eq!(s.lines().count(), 12);
    }

    #[test]
    fn soft_plot_does_not_overwrite() {
        let mut c = Canvas::new(8, 4, 1.0, (0.0, 0.0));
        c.plot(0.0, 0.0, 'A');
        c.plot_soft(0.0, 0.0, 'B');
        assert!(c.to_string_framed().contains('A'));
        assert!(!c.to_string_framed().contains('B'));
    }

    #[test]
    fn scenario_render_has_robot_and_map() {
        let gen = ScenarioGenerator::new(SimConfig::default());
        let s = gen.generate(4);
        let r = render_scenario(&s, 0, None, 72, 24);
        assert!(contains_glyph(&r, 'R'), "robot visible:\n{r}");
        assert!(contains_glyph(&r, '.'), "lanes visible");
    }

    #[test]
    fn future_render_shows_trajectories() {
        let cfg = SimConfig::default();
        let gen = ScenarioGenerator::new(cfg.clone());
        let s = gen.generate(4);
        let r = render_futures(&s, cfg.history_steps - 1, 72, 24);
        // at least one agent's digit trail appears
        assert!((0..6).any(|a| contains_glyph(&r, char::from_digit(a, 10).unwrap())), "{r}");
    }

    #[test]
    fn scenario_render_uses_kind_glyphs() {
        let gen = ScenarioGenerator::new(SimConfig::default());
        let any_vehicle_glyph = (0..8).any(|seed| {
            let s = gen.generate(seed);
            contains_glyph(&render_scenario(&s, 0, None, 100, 30), 'V')
        });
        assert!(any_vehicle_glyph, "vehicles drawn as V");
    }

    #[test]
    fn family_scenarios_render_their_kinds() {
        use crate::sim::suite::{Family, FamilyId};
        let sim = SimConfig::default();
        let s = Family::new(FamilyId::UrbanCrossing).generate(&sim, 2);
        let r = render_scenario(&s, 0, None, 120, 40);
        assert!(contains_glyph(&r, 'R'), "robot visible:\n{r}");
        assert!(
            contains_glyph(&r, 'P') || contains_glyph(&r, 'C'),
            "pedestrians/cyclists visible:\n{r}"
        );
        assert_eq!(kind_glyph(AgentKind::Vehicle), 'V');
        assert_eq!(kind_glyph(AgentKind::Pedestrian), 'P');
        assert_eq!(kind_glyph(AgentKind::Cyclist), 'C');
    }

    #[test]
    fn heading_glyphs_cover_circle() {
        let east = heading_glyph(0.0);
        let north = heading_glyph(std::f64::consts::FRAC_PI_2);
        let west = heading_glyph(std::f64::consts::PI);
        let south = heading_glyph(-std::f64::consts::FRAC_PI_2);
        assert_eq!(east, '>');
        assert_eq!(north, '^');
        assert_eq!(west, '<');
        assert_eq!(south, 'v');
    }
}
