//! Procedural lane-graph maps: straights, arcs, intersections, crosswalks.
//!
//! Lanes are polylines of SE(2) poses (position + tangent heading) sampled
//! at a fixed arc-length step.  Curvature is carried per lane so the
//! tokenizer can expose "turning-ness" as a feature and agents know the
//! yaw-rate required to track the lane.

use crate::geometry::Pose;
use crate::prng::Rng;

pub const LANE_SAMPLE_STEP_M: f64 = 4.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapElementKind {
    Lane,
    Crosswalk,
    Signal,
}

/// One tokenizable map element: a pose plus descriptive features.
#[derive(Clone, Debug)]
pub struct MapElement {
    pub kind: MapElementKind,
    pub pose: Pose,
    /// Signed curvature 1/m (lanes only).
    pub curvature: f64,
    /// Speed limit m/s (lanes only).
    pub speed_limit: f64,
    /// Signal state in [0, 1]: 0 red, 0.5 yellow, 1 green.
    pub signal_state: f64,
}

/// A lane centerline.
#[derive(Clone, Debug)]
pub struct Lane {
    pub points: Vec<Pose>,
    pub curvature: f64,
    pub speed_limit: f64,
}

impl Lane {
    /// Arc-length position -> interpolated pose on the centerline.
    pub fn pose_at(&self, s: f64) -> Pose {
        let step = LANE_SAMPLE_STEP_M;
        let total = (self.points.len() - 1) as f64 * step;
        let s = s.clamp(0.0, total - 1e-9);
        let i = (s / step) as usize;
        let frac = (s - i as f64 * step) / step;
        let a = &self.points[i];
        let b = &self.points[(i + 1).min(self.points.len() - 1)];
        Pose::new(
            a.x + frac * (b.x - a.x),
            a.y + frac * (b.y - a.y),
            a.theta + frac * crate::geometry::wrap_angle(b.theta - a.theta),
        )
    }

    pub fn length(&self) -> f64 {
        (self.points.len() - 1) as f64 * LANE_SAMPLE_STEP_M
    }
}

/// A generated map: lanes plus point elements (crosswalks, signals).
#[derive(Clone, Debug)]
pub struct LaneGraph {
    pub lanes: Vec<Lane>,
    pub crosswalks: Vec<Pose>,
    pub signals: Vec<(Pose, f64)>,
}

/// Build a lane from a start pose with constant curvature (public so the
/// scenario-suite map builders in [`super::suite`] can assemble family
/// geometries from the same primitive the legacy generator uses).
pub fn trace_lane(start: Pose, curvature: f64, length_m: f64, speed_limit: f64) -> Lane {
    let n = (length_m / LANE_SAMPLE_STEP_M).ceil() as usize + 1;
    let mut points = Vec::with_capacity(n);
    let mut p = start;
    for _ in 0..n {
        points.push(p);
        let dth = curvature * LANE_SAMPLE_STEP_M;
        // advance along the arc
        let (s, c) = p.theta.sin_cos();
        p = Pose::new(
            p.x + c * LANE_SAMPLE_STEP_M,
            p.y + s * LANE_SAMPLE_STEP_M,
            p.theta + dth,
        );
    }
    Lane {
        points,
        curvature,
        speed_limit,
    }
}

impl LaneGraph {
    /// An empty graph (synthetic-test substrate).
    pub fn empty() -> LaneGraph {
        LaneGraph {
            lanes: Vec::new(),
            crosswalks: Vec::new(),
            signals: Vec::new(),
        }
    }

    /// The graph with every pose pushed through a rigid transform `z`
    /// (lane geometry, crosswalks and signals alike).  Family builders
    /// construct maps in a canonical frame and then scatter them over
    /// SE(2) with this, so no family is axis-aligned in world coordinates.
    pub fn transformed(&self, z: &Pose) -> LaneGraph {
        LaneGraph {
            lanes: self
                .lanes
                .iter()
                .map(|l| Lane {
                    points: l.points.iter().map(|p| z.compose(p)).collect(),
                    curvature: l.curvature,
                    speed_limit: l.speed_limit,
                })
                .collect(),
            crosswalks: self.crosswalks.iter().map(|p| z.compose(p)).collect(),
            signals: self
                .signals
                .iter()
                .map(|(p, s)| (z.compose(p), *s))
                .collect(),
        }
    }

    /// Generate a random map around the origin: a mix of straight lanes,
    /// arcs (left/right turns) and an optional crossing road, with
    /// crosswalks and signals near the center.
    pub fn generate(rng: &mut Rng) -> LaneGraph {
        let mut lanes = Vec::new();
        let main_heading = rng.range(-std::f64::consts::PI, std::f64::consts::PI);
        let speed = rng.range(8.0, 15.0);

        // main corridor: two parallel lanes through the origin
        for off in [-2.0, 2.0] {
            let (s, c) = main_heading.sin_cos();
            let start = Pose::new(
                -60.0 * c - off * s,
                -60.0 * s + off * c,
                main_heading,
            );
            lanes.push(trace_lane(start, 0.0, 120.0, speed));
        }

        // turning lane: an arc splitting off near the center
        let turn_dir = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        let curvature = turn_dir / rng.range(12.0, 30.0); // radius 12-30 m
        let (s, c) = main_heading.sin_cos();
        let turn_start = Pose::new(-20.0 * c, -20.0 * s, main_heading);
        lanes.push(trace_lane(turn_start, curvature, 45.0, speed * 0.6));

        // crossing road through the origin (intersection)
        if rng.bernoulli(0.7) {
            let cross_heading = main_heading + std::f64::consts::FRAC_PI_2
                + rng.range(-0.3, 0.3);
            let (s2, c2) = cross_heading.sin_cos();
            let start = Pose::new(-50.0 * c2, -50.0 * s2, cross_heading);
            lanes.push(trace_lane(start, 0.0, 100.0, speed * 0.8));
        }

        // crosswalk poses near the intersection
        let mut crosswalks = Vec::new();
        for _ in 0..2 {
            crosswalks.push(Pose::new(
                rng.range(-12.0, 12.0),
                rng.range(-12.0, 12.0),
                rng.range(-std::f64::consts::PI, std::f64::consts::PI),
            ));
        }

        // signals with random state
        let signals = vec![(
            Pose::new(rng.range(-8.0, 8.0), rng.range(-8.0, 8.0), main_heading),
            *rng.choice(&[0.0, 0.5, 1.0]),
        )];

        LaneGraph {
            lanes,
            crosswalks,
            signals,
        }
    }

    /// Flatten to exactly `n` tokenizable elements (stable order: lane
    /// samples round-robin, then crosswalks, then signals, padded by
    /// repeating the last element).
    pub fn elements(&self, n: usize) -> Vec<MapElement> {
        let mut out = Vec::with_capacity(n);
        // sample each lane at a few arc positions
        let lane_budget = n.saturating_sub(self.crosswalks.len() + self.signals.len());
        let per_lane = (lane_budget / self.lanes.len().max(1)).max(1);
        for lane in &self.lanes {
            for i in 0..per_lane {
                let s = lane.length() * (i as f64 + 0.5) / per_lane as f64;
                out.push(MapElement {
                    kind: MapElementKind::Lane,
                    pose: lane.pose_at(s),
                    curvature: lane.curvature,
                    speed_limit: lane.speed_limit,
                    signal_state: 0.0,
                });
            }
        }
        for cw in &self.crosswalks {
            out.push(MapElement {
                kind: MapElementKind::Crosswalk,
                pose: *cw,
                curvature: 0.0,
                speed_limit: 0.0,
                signal_state: 0.0,
            });
        }
        for (pose, state) in &self.signals {
            out.push(MapElement {
                kind: MapElementKind::Signal,
                pose: *pose,
                curvature: 0.0,
                speed_limit: 0.0,
                signal_state: *state,
            });
        }
        out.truncate(n);
        while out.len() < n {
            let last = out.last().cloned().unwrap_or(MapElement {
                kind: MapElementKind::Lane,
                pose: Pose::IDENTITY,
                curvature: 0.0,
                speed_limit: 10.0,
                signal_state: 0.0,
            });
            out.push(last);
        }
        out
    }

    /// Closest lane (index, arc position, distance) to a world point.
    pub fn nearest_lane(&self, x: f64, y: f64) -> Option<(usize, f64, f64)> {
        let mut best: Option<(usize, f64, f64)> = None;
        for (li, lane) in self.lanes.iter().enumerate() {
            for (pi, p) in lane.points.iter().enumerate() {
                let d = ((p.x - x).powi(2) + (p.y - y).powi(2)).sqrt();
                if best.map_or(true, |(_, _, bd)| d < bd) {
                    best = Some((li, pi as f64 * LANE_SAMPLE_STEP_M, d));
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straight_lane_geometry() {
        let lane = trace_lane(Pose::new(0.0, 0.0, 0.0), 0.0, 40.0, 10.0);
        assert!(lane.length() >= 40.0);
        let p = lane.pose_at(20.0);
        assert!((p.x - 20.0).abs() < 1e-9 && p.y.abs() < 1e-9);
    }

    #[test]
    fn arc_lane_turns() {
        let curvature = 1.0 / 20.0;
        let lane = trace_lane(Pose::new(0.0, 0.0, 0.0), curvature, 30.0, 8.0);
        let end = lane.points.last().unwrap();
        assert!(end.theta > 0.5, "arc should accumulate heading: {}", end.theta);
    }

    #[test]
    fn generated_maps_have_structure() {
        let mut rng = Rng::new(42);
        for _ in 0..10 {
            let map = LaneGraph::generate(&mut rng);
            assert!(map.lanes.len() >= 3);
            assert!(!map.crosswalks.is_empty());
            let els = map.elements(16);
            assert_eq!(els.len(), 16);
            assert!(els.iter().any(|e| e.kind == MapElementKind::Lane));
            assert!(els.iter().any(|e| e.kind == MapElementKind::Crosswalk));
        }
    }

    #[test]
    fn elements_pad_to_requested_size() {
        let mut rng = Rng::new(1);
        let map = LaneGraph::generate(&mut rng);
        for n in [4usize, 16, 64] {
            assert_eq!(map.elements(n).len(), n);
        }
    }

    #[test]
    fn nearest_lane_finds_origin_corridor() {
        let mut rng = Rng::new(2);
        let map = LaneGraph::generate(&mut rng);
        let (_, _, d) = map.nearest_lane(0.0, 0.0).unwrap();
        assert!(d < 10.0, "main corridor passes near origin, d={d}");
    }

    #[test]
    fn transformed_preserves_intrinsic_geometry() {
        let mut rng = Rng::new(6);
        let map = LaneGraph::generate(&mut rng);
        let z = Pose::new(40.0, -25.0, 1.3);
        let moved = map.transformed(&z);
        assert_eq!(moved.lanes.len(), map.lanes.len());
        assert_eq!(moved.crosswalks.len(), map.crosswalks.len());
        assert_eq!(moved.signals.len(), map.signals.len());
        for (a, b) in map.lanes.iter().zip(moved.lanes.iter()) {
            assert_eq!(a.points.len(), b.points.len());
            // pairwise distances along the lane are rigid-invariant
            for w in 0..a.points.len() - 1 {
                let da = a.points[w].dist(&a.points[w + 1]);
                let db = b.points[w].dist(&b.points[w + 1]);
                assert!((da - db).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn lane_pose_interpolation_is_continuous() {
        let lane = trace_lane(Pose::new(0.0, 0.0, 0.3), 0.01, 50.0, 10.0);
        let mut prev = lane.pose_at(0.0);
        for i in 1..100 {
            let p = lane.pose_at(i as f64 * 0.5);
            assert!(prev.dist(&p) < 1.0, "jump at {i}");
            prev = p;
        }
    }
}
