//! Kinematic agents and their driving policies.
//!
//! Vehicles track lanes with a pure-pursuit steering law and an IDM-style
//! speed controller (leader- and signal-aware); pedestrians amble near
//! crosswalks.  The policy's (accel, yaw-rate) output at each step is the
//! ground-truth *action* the model learns to predict after discretization
//! by the tokenizer's action codebook.

use crate::geometry::{wrap_angle, Pose};
use crate::prng::Rng;

use super::map::LaneGraph;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgentKind {
    Vehicle,
    Pedestrian,
    Cyclist,
}

/// Continuous control: longitudinal acceleration (m/s^2) + yaw rate (rad/s).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KinematicAction {
    pub accel: f64,
    pub yaw_rate: f64,
}

pub const MAX_ACCEL: f64 = 4.0;
pub const MAX_YAW_RATE: f64 = 1.0;

impl KinematicAction {
    pub fn clamped(self) -> KinematicAction {
        KinematicAction {
            accel: self.accel.clamp(-MAX_ACCEL, MAX_ACCEL),
            yaw_rate: self.yaw_rate.clamp(-MAX_YAW_RATE, MAX_YAW_RATE),
        }
    }
}

/// Dynamic state of one agent.
#[derive(Clone, Copy, Debug)]
pub struct AgentState {
    pub pose: Pose,
    pub speed: f64,
    pub kind: AgentKind,
    pub length: f64,
    pub width: f64,
    /// Last applied action (exposed as a token feature).
    pub last_action: KinematicAction,
}

impl AgentState {
    /// Unicycle/kinematic-bicycle step (the same integrator the rollout
    /// scheduler applies to *predicted* actions — train/test dynamics
    /// match by construction).
    pub fn step(&self, action: KinematicAction, dt: f64) -> AgentState {
        let a = action.clamped();
        let speed = (self.speed + a.accel * dt).max(0.0);
        let theta = wrap_angle(self.pose.theta + a.yaw_rate * dt);
        // integrate at mid-heading for better arc fidelity
        let mid = wrap_angle(self.pose.theta + 0.5 * a.yaw_rate * dt);
        let (s, c) = mid.sin_cos();
        AgentState {
            pose: Pose::new(
                self.pose.x + speed * c * dt,
                self.pose.y + speed * s * dt,
                theta,
            ),
            speed,
            last_action: a,
            ..*self
        }
    }
}

/// Per-agent behavior controller.
#[derive(Clone, Debug)]
pub enum Policy {
    /// Track `lane` starting near arc position `s0`; `stop_at` optionally
    /// forces a stop at that arc position (red signal / stop sign).
    LaneFollow {
        lane: usize,
        target_speed: f64,
        stop_at: Option<f64>,
    },
    /// Follow `from`, then merge into `to` once past arc position
    /// `trigger_s` on the source lane (highway on-ramps, overtakes).
    LaneChange {
        from: usize,
        to: usize,
        target_speed: f64,
        trigger_s: f64,
    },
    /// Follow `lane` and hold at arc position `merge_s` while any moving
    /// agent is within `clear_radius` of `merge_point`; once clear and at
    /// the line, continue on `next_lane` (roundabout / ramp yield-on-entry).
    YieldEntry {
        lane: usize,
        next_lane: usize,
        target_speed: f64,
        merge_s: f64,
        merge_point: (f64, f64),
        clear_radius: f64,
    },
    /// Pedestrian: walk toward a goal point, then pick a new one.
    Wander { goal: (f64, f64), speed: f64 },
    /// Parked / stationary agent.
    Stationary,
}

/// Lookahead distance for pure pursuit (m).
const LOOKAHEAD_M: f64 = 6.0;
/// IDM-ish time headway (s) and minimum gap (m).
const HEADWAY_S: f64 = 1.5;
const MIN_GAP_M: f64 = 4.0;

/// Nearest arc position of `pose` on lane `lane` (ties resolve to the
/// earliest sample, so self-overlapping lanes — roundabout loops — keep a
/// stable notion of progress).
fn lane_progress(map: &LaneGraph, lane: usize, pose: &Pose) -> f64 {
    let step = super::map::LANE_SAMPLE_STEP_M;
    let mut best_s = 0.0;
    let mut best_d = f64::INFINITY;
    for (pi, p) in map.lanes[lane].points.iter().enumerate() {
        let d = p.dist(pose);
        if d < best_d {
            best_d = d;
            best_s = pi as f64 * step;
        }
    }
    best_s
}

/// Pure-pursuit steering + IDM-style speed control toward `lane`,
/// optionally stopping at arc position `stop_at`.  Shared by every
/// lane-tracking policy (follow, change, yield).
fn lane_follow_action(
    agent: &AgentState,
    others: &[AgentState],
    map: &LaneGraph,
    lane: usize,
    target_speed: f64,
    stop_at: Option<f64>,
) -> KinematicAction {
    let best_s = lane_progress(map, lane, &agent.pose);
    lane_follow_action_at(agent, others, map, lane, best_s, target_speed, stop_at)
}

/// [`lane_follow_action`] with the agent's arc progress on `lane` already
/// known — policies that computed it for their own transition logic
/// (lane change trigger, yield line) skip the second O(lane-points) scan.
fn lane_follow_action_at(
    agent: &AgentState,
    others: &[AgentState],
    map: &LaneGraph,
    lane: usize,
    best_s: f64,
    target_speed: f64,
    stop_at: Option<f64>,
) -> KinematicAction {
    let lane_ref = &map.lanes[lane];
    // pure pursuit toward a lookahead point
    let target = lane_ref.pose_at(best_s + LOOKAHEAD_M);
    let dx = target.x - agent.pose.x;
    let dy = target.y - agent.pose.y;
    let desired_heading = dy.atan2(dx);
    let herr = wrap_angle(desired_heading - agent.pose.theta);
    let yaw_rate = (1.5 * herr).clamp(-MAX_YAW_RATE, MAX_YAW_RATE);

    // speed control: target speed, reduced by leader and stop line
    let mut desired = target_speed;
    // leader: nearest other agent ahead within a cone
    for o in others {
        let rel = agent.pose.relative_to(&o.pose);
        if rel.x > 0.0 && rel.x < 30.0 && rel.y.abs() < 2.5 {
            let gap = rel.x - MIN_GAP_M;
            let safe = (gap / HEADWAY_S).max(0.0);
            desired = desired.min(safe.min(o.speed + gap * 0.3));
        }
    }
    // stop line (if any) and the end of the lane both cap speed
    // with a comfortable braking profile v = sqrt(2 a d)
    let route_end = lane_ref.length() - LOOKAHEAD_M;
    let stop_s = stop_at.map_or(route_end, |s| s.min(route_end));
    let dist_to_stop = stop_s - best_s;
    if dist_to_stop > 0.0 {
        desired = desired.min((2.0 * 2.0 * dist_to_stop).sqrt());
    } else {
        desired = 0.0;
    }
    let accel = ((desired - agent.speed) * 1.2).clamp(-MAX_ACCEL, 2.5);
    KinematicAction { accel, yaw_rate }.clamped()
}

/// Compute the policy's action for `agent` given the world state.
pub fn plan(
    policy: &Policy,
    agent: &AgentState,
    others: &[AgentState],
    map: &LaneGraph,
    rng: &mut Rng,
) -> (KinematicAction, Policy) {
    match policy {
        Policy::Stationary => (
            KinematicAction {
                accel: -agent.speed.min(1.0),
                yaw_rate: 0.0,
            },
            policy.clone(),
        ),
        Policy::Wander { goal, speed } => {
            let (gx, gy) = *goal;
            let dx = gx - agent.pose.x;
            let dy = gy - agent.pose.y;
            let dist = (dx * dx + dy * dy).sqrt();
            let new_policy = if dist < 2.0 {
                Policy::Wander {
                    goal: (
                        agent.pose.x + rng.range(-15.0, 15.0),
                        agent.pose.y + rng.range(-15.0, 15.0),
                    ),
                    speed: *speed,
                }
            } else {
                policy.clone()
            };
            let desired_heading = dy.atan2(dx);
            let herr = wrap_angle(desired_heading - agent.pose.theta);
            let yaw_rate = (2.0 * herr).clamp(-MAX_YAW_RATE, MAX_YAW_RATE);
            let accel = (speed - agent.speed).clamp(-1.5, 1.0);
            (KinematicAction { accel, yaw_rate }.clamped(), new_policy)
        }
        Policy::LaneFollow {
            lane,
            target_speed,
            stop_at,
        } => (
            lane_follow_action(agent, others, map, *lane, *target_speed, *stop_at),
            policy.clone(),
        ),
        Policy::LaneChange {
            from,
            to,
            target_speed,
            trigger_s,
        } => {
            let s_from = lane_progress(map, *from, &agent.pose);
            if s_from >= *trigger_s {
                // past the trigger: commit to the target lane for good
                (
                    lane_follow_action(agent, others, map, *to, *target_speed, None),
                    Policy::LaneFollow {
                        lane: *to,
                        target_speed: *target_speed,
                        stop_at: None,
                    },
                )
            } else {
                (
                    lane_follow_action_at(
                        agent,
                        others,
                        map,
                        *from,
                        s_from,
                        *target_speed,
                        None,
                    ),
                    policy.clone(),
                )
            }
        }
        Policy::YieldEntry {
            lane,
            next_lane,
            target_speed,
            merge_s,
            merge_point,
            clear_radius,
        } => {
            let conflict = others.iter().any(|o| {
                let dx = o.pose.x - merge_point.0;
                let dy = o.pose.y - merge_point.1;
                (dx * dx + dy * dy).sqrt() < *clear_radius && o.speed > 0.5
            });
            let s_own = lane_progress(map, *lane, &agent.pose);
            if !conflict && s_own + LOOKAHEAD_M >= *merge_s {
                // gap accepted: enter the target lane
                (
                    lane_follow_action(agent, others, map, *next_lane, *target_speed, None),
                    Policy::LaneFollow {
                        lane: *next_lane,
                        target_speed: *target_speed,
                        stop_at: None,
                    },
                )
            } else {
                // approach (or hold at) the yield line on the entry lane
                (
                    lane_follow_action_at(
                        agent,
                        others,
                        map,
                        *lane,
                        s_own,
                        *target_speed,
                        Some(*merge_s),
                    ),
                    policy.clone(),
                )
            }
        }
    }
}

/// Vehicle state at a pose with an explicit initial speed — the single
/// home of the vehicle dimension distributions, shared by the legacy
/// spawner and the family builders in [`super::suite`].
pub fn vehicle_state(pose: Pose, speed: f64, rng: &mut Rng) -> AgentState {
    AgentState {
        pose,
        speed,
        kind: AgentKind::Vehicle,
        length: rng.range(4.2, 5.4),
        width: rng.range(1.8, 2.2),
        last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
    }
}

/// Vehicle placed on a lane at arc position `s0`, rolling at a random
/// fraction of `target_speed` (the legacy spawn distribution).
pub fn vehicle_on_lane(
    map: &LaneGraph,
    lane: usize,
    s0: f64,
    target_speed: f64,
    rng: &mut Rng,
) -> AgentState {
    let pose = map.lanes[lane].pose_at(s0);
    let speed = rng.range(0.3, 1.0) * target_speed;
    vehicle_state(pose, speed, rng)
}

/// Spawn an agent appropriate for the policy.
pub fn spawn(policy: &Policy, map: &LaneGraph, rng: &mut Rng) -> AgentState {
    match policy {
        Policy::LaneFollow { lane, target_speed, .. } => {
            let s0 = rng.range(0.0, map.lanes[*lane].length() * 0.5);
            vehicle_on_lane(map, *lane, s0, *target_speed, rng)
        }
        Policy::LaneChange { from, target_speed, trigger_s, .. } => {
            let s0 = rng.range(0.0, trigger_s.min(map.lanes[*from].length()) * 0.6);
            vehicle_on_lane(map, *from, s0, *target_speed, rng)
        }
        Policy::YieldEntry { lane, target_speed, merge_s, .. } => {
            let s0 = rng.range(0.0, merge_s.min(map.lanes[*lane].length()) * 0.6);
            vehicle_on_lane(map, *lane, s0, *target_speed, rng)
        }
        Policy::Wander { .. } => {
            let cw = rng.choice(&map.crosswalks);
            AgentState {
                pose: Pose::new(
                    cw.x + rng.range(-4.0, 4.0),
                    cw.y + rng.range(-4.0, 4.0),
                    rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                ),
                speed: rng.range(0.6, 1.8),
                kind: AgentKind::Pedestrian,
                length: 0.6,
                width: 0.6,
                last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
            }
        }
        Policy::Stationary => {
            let lane = rng.choice(&map.lanes);
            let s0 = rng.range(0.0, lane.length());
            let p = lane.pose_at(s0);
            AgentState {
                pose: Pose::new(p.x + rng.range(-3.0, 3.0), p.y + rng.range(-3.0, 3.0), p.theta),
                speed: 0.0,
                kind: AgentKind::Vehicle,
                length: 4.8,
                width: 2.0,
                last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vehicle_at(pose: Pose, speed: f64) -> AgentState {
        AgentState {
            pose,
            speed,
            kind: AgentKind::Vehicle,
            length: 4.8,
            width: 2.0,
            last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
        }
    }

    #[test]
    fn step_integrates_straight_motion() {
        let a = vehicle_at(Pose::new(0.0, 0.0, 0.0), 10.0);
        let next = a.step(KinematicAction { accel: 0.0, yaw_rate: 0.0 }, 0.5);
        assert!((next.pose.x - 5.0).abs() < 1e-9);
        assert!(next.pose.y.abs() < 1e-12);
    }

    #[test]
    fn step_clamps_speed_at_zero() {
        let a = vehicle_at(Pose::new(0.0, 0.0, 0.0), 0.5);
        let next = a.step(KinematicAction { accel: -4.0, yaw_rate: 0.0 }, 0.5);
        assert_eq!(next.speed, 0.0);
    }

    #[test]
    fn step_turns_with_yaw_rate() {
        let a = vehicle_at(Pose::new(0.0, 0.0, 0.0), 8.0);
        let next = a.step(KinematicAction { accel: 0.0, yaw_rate: 0.5 }, 0.5);
        assert!((next.pose.theta - 0.25).abs() < 1e-9);
        assert!(next.pose.y > 0.0, "turning left curves upward");
    }

    #[test]
    fn lane_follow_tracks_lane() {
        let mut rng = Rng::new(3);
        let map = LaneGraph::generate(&mut rng);
        let policy = Policy::LaneFollow {
            lane: 0,
            target_speed: 10.0,
            stop_at: None,
        };
        let mut agent = spawn(&policy, &map, &mut rng);
        // place near the lane start so the route end is far away
        agent.pose = map.lanes[0].pose_at(2.0);
        let mut p = policy;
        let mut moved = 0.0;
        for _ in 0..12 {
            let (action, np) = plan(&p, &agent, &[], &map, &mut rng);
            let next = agent.step(action, 0.5);
            moved += agent.pose.dist(&next.pose);
            agent = next;
            p = np;
        }
        let (_, _, d) = map.nearest_lane(agent.pose.x, agent.pose.y).unwrap();
        assert!(d < 5.0, "vehicle strayed {d} m from lane network");
        assert!(moved > 10.0, "vehicle should be moving, moved {moved} m");
    }

    #[test]
    fn stop_at_brings_vehicle_to_rest() {
        let mut rng = Rng::new(4);
        let map = LaneGraph::generate(&mut rng);
        let policy = Policy::LaneFollow {
            lane: 0,
            target_speed: 12.0,
            stop_at: Some(20.0),
        };
        let mut agent = spawn(&policy, &map, &mut rng);
        // place near lane start
        agent.pose = map.lanes[0].pose_at(0.0);
        agent.speed = 8.0;
        let mut p = policy;
        for _ in 0..60 {
            let (action, np) = plan(&p, &agent, &[], &map, &mut rng);
            agent = agent.step(action, 0.5);
            p = np;
        }
        assert!(agent.speed < 0.8, "vehicle should stop, v={}", agent.speed);
    }

    /// Two parallel straight lanes 4 m apart (synthetic lane-change arena).
    fn two_lane_map() -> LaneGraph {
        LaneGraph {
            lanes: vec![
                super::super::map::trace_lane(Pose::new(0.0, 0.0, 0.0), 0.0, 120.0, 12.0),
                super::super::map::trace_lane(Pose::new(0.0, 4.0, 0.0), 0.0, 120.0, 12.0),
            ],
            crosswalks: vec![],
            signals: vec![],
        }
    }

    #[test]
    fn lane_change_merges_into_target_lane() {
        let map = two_lane_map();
        let mut rng = Rng::new(11);
        let mut agent = vehicle_at(Pose::new(2.0, 0.0, 0.0), 8.0);
        let mut p = Policy::LaneChange {
            from: 0,
            to: 1,
            target_speed: 10.0,
            trigger_s: 16.0,
        };
        let mut switched = false;
        for _ in 0..40 {
            let (action, np) = plan(&p, &agent, &[], &map, &mut rng);
            agent = agent.step(action, 0.5);
            if matches!(np, Policy::LaneFollow { lane: 1, .. }) {
                switched = true;
            }
            p = np;
        }
        assert!(switched, "lane change must trigger past trigger_s");
        assert!(
            (agent.pose.y - 4.0).abs() < 1.5,
            "vehicle should settle on the target lane, y={}",
            agent.pose.y
        );
    }

    #[test]
    fn yield_entry_waits_for_conflict_then_merges() {
        let map = two_lane_map();
        let mut rng = Rng::new(12);
        let merge_point = (40.0, 4.0);
        let policy = Policy::YieldEntry {
            lane: 0,
            next_lane: 1,
            target_speed: 9.0,
            merge_s: 36.0,
            merge_point,
            clear_radius: 10.0,
        };
        // a mover parked on the merge point keeps the entry blocked
        let blocker = vehicle_at(Pose::new(merge_point.0, merge_point.1, 0.0), 6.0);
        let mut agent = vehicle_at(Pose::new(0.0, 0.0, 0.0), 8.0);
        let mut p = policy.clone();
        for _ in 0..60 {
            let (action, np) = plan(&p, &agent, &[blocker], &map, &mut rng);
            agent = agent.step(action, 0.5);
            p = np;
        }
        assert!(
            matches!(p, Policy::YieldEntry { .. }),
            "blocked entry must keep yielding"
        );
        assert!(
            agent.pose.x < merge_point.0 + LOOKAHEAD_M,
            "blocked vehicle must hold near the line, x={}",
            agent.pose.x
        );
        assert!(agent.speed < 1.0, "held vehicle stops, v={}", agent.speed);

        // conflict gone: the same agent accepts the gap and merges
        for _ in 0..40 {
            let (action, np) = plan(&p, &agent, &[], &map, &mut rng);
            agent = agent.step(action, 0.5);
            p = np;
        }
        assert!(
            matches!(p, Policy::LaneFollow { lane: 1, .. }),
            "cleared entry must transition to the target lane: {p:?}"
        );
        assert!(agent.speed > 2.0, "merged vehicle is moving again");
    }

    #[test]
    fn follower_does_not_rear_end_leader() {
        let mut rng = Rng::new(5);
        let map = LaneGraph::generate(&mut rng);
        let lane = &map.lanes[0];
        let mut follower = vehicle_at(lane.pose_at(0.0), 12.0);
        let leader = vehicle_at(lane.pose_at(25.0), 0.0); // stopped ahead
        let policy = Policy::LaneFollow {
            lane: 0,
            target_speed: 12.0,
            stop_at: None,
        };
        for _ in 0..40 {
            let (action, _) = plan(&policy, &follower, &[leader], &map, &mut rng);
            follower = follower.step(action, 0.5);
        }
        let gap = follower.pose.dist(&leader.pose);
        assert!(gap > 1.5, "collision: gap {gap}");
    }
}
