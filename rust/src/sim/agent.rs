//! Kinematic agents and their driving policies.
//!
//! Vehicles track lanes with a pure-pursuit steering law and an IDM-style
//! speed controller (leader- and signal-aware); pedestrians amble near
//! crosswalks.  The policy's (accel, yaw-rate) output at each step is the
//! ground-truth *action* the model learns to predict after discretization
//! by the tokenizer's action codebook.

use crate::geometry::{wrap_angle, Pose};
use crate::prng::Rng;

use super::map::LaneGraph;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgentKind {
    Vehicle,
    Pedestrian,
    Cyclist,
}

/// Continuous control: longitudinal acceleration (m/s^2) + yaw rate (rad/s).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KinematicAction {
    pub accel: f64,
    pub yaw_rate: f64,
}

pub const MAX_ACCEL: f64 = 4.0;
pub const MAX_YAW_RATE: f64 = 1.0;

impl KinematicAction {
    pub fn clamped(self) -> KinematicAction {
        KinematicAction {
            accel: self.accel.clamp(-MAX_ACCEL, MAX_ACCEL),
            yaw_rate: self.yaw_rate.clamp(-MAX_YAW_RATE, MAX_YAW_RATE),
        }
    }
}

/// Dynamic state of one agent.
#[derive(Clone, Copy, Debug)]
pub struct AgentState {
    pub pose: Pose,
    pub speed: f64,
    pub kind: AgentKind,
    pub length: f64,
    pub width: f64,
    /// Last applied action (exposed as a token feature).
    pub last_action: KinematicAction,
}

impl AgentState {
    /// Unicycle/kinematic-bicycle step (the same integrator the rollout
    /// scheduler applies to *predicted* actions — train/test dynamics
    /// match by construction).
    pub fn step(&self, action: KinematicAction, dt: f64) -> AgentState {
        let a = action.clamped();
        let speed = (self.speed + a.accel * dt).max(0.0);
        let theta = wrap_angle(self.pose.theta + a.yaw_rate * dt);
        // integrate at mid-heading for better arc fidelity
        let mid = wrap_angle(self.pose.theta + 0.5 * a.yaw_rate * dt);
        let (s, c) = mid.sin_cos();
        AgentState {
            pose: Pose::new(
                self.pose.x + speed * c * dt,
                self.pose.y + speed * s * dt,
                theta,
            ),
            speed,
            last_action: a,
            ..*self
        }
    }
}

/// Per-agent behavior controller.
#[derive(Clone, Debug)]
pub enum Policy {
    /// Track `lane` starting near arc position `s0`; `stop_at` optionally
    /// forces a stop at that arc position (red signal / stop sign).
    LaneFollow {
        lane: usize,
        target_speed: f64,
        stop_at: Option<f64>,
    },
    /// Pedestrian: walk toward a goal point, then pick a new one.
    Wander { goal: (f64, f64), speed: f64 },
    /// Parked / stationary agent.
    Stationary,
}

/// Lookahead distance for pure pursuit (m).
const LOOKAHEAD_M: f64 = 6.0;
/// IDM-ish time headway (s) and minimum gap (m).
const HEADWAY_S: f64 = 1.5;
const MIN_GAP_M: f64 = 4.0;

/// Compute the policy's action for `agent` given the world state.
pub fn plan(
    policy: &Policy,
    agent: &AgentState,
    others: &[AgentState],
    map: &LaneGraph,
    rng: &mut Rng,
) -> (KinematicAction, Policy) {
    match policy {
        Policy::Stationary => (
            KinematicAction {
                accel: -agent.speed.min(1.0),
                yaw_rate: 0.0,
            },
            policy.clone(),
        ),
        Policy::Wander { goal, speed } => {
            let (gx, gy) = *goal;
            let dx = gx - agent.pose.x;
            let dy = gy - agent.pose.y;
            let dist = (dx * dx + dy * dy).sqrt();
            let new_policy = if dist < 2.0 {
                Policy::Wander {
                    goal: (
                        agent.pose.x + rng.range(-15.0, 15.0),
                        agent.pose.y + rng.range(-15.0, 15.0),
                    ),
                    speed: *speed,
                }
            } else {
                policy.clone()
            };
            let desired_heading = dy.atan2(dx);
            let herr = wrap_angle(desired_heading - agent.pose.theta);
            let yaw_rate = (2.0 * herr).clamp(-MAX_YAW_RATE, MAX_YAW_RATE);
            let accel = (speed - agent.speed).clamp(-1.5, 1.0);
            (KinematicAction { accel, yaw_rate }.clamped(), new_policy)
        }
        Policy::LaneFollow {
            lane,
            target_speed,
            stop_at,
        } => {
            let lane_ref = &map.lanes[*lane];
            // progress: nearest arc position on own lane
            let mut best_s = 0.0;
            let mut best_d = f64::INFINITY;
            let step = super::map::LANE_SAMPLE_STEP_M;
            for (pi, p) in lane_ref.points.iter().enumerate() {
                let d = p.dist(&agent.pose);
                if d < best_d {
                    best_d = d;
                    best_s = pi as f64 * step;
                }
            }
            // pure pursuit toward a lookahead point
            let target = lane_ref.pose_at(best_s + LOOKAHEAD_M);
            let dx = target.x - agent.pose.x;
            let dy = target.y - agent.pose.y;
            let desired_heading = dy.atan2(dx);
            let herr = wrap_angle(desired_heading - agent.pose.theta);
            let yaw_rate = (1.5 * herr).clamp(-MAX_YAW_RATE, MAX_YAW_RATE);

            // speed control: target speed, reduced by leader and stop line
            let mut desired = *target_speed;
            // leader: nearest other agent ahead within a cone
            for o in others {
                let rel = agent.pose.relative_to(&o.pose);
                if rel.x > 0.0 && rel.x < 30.0 && rel.y.abs() < 2.5 {
                    let gap = rel.x - MIN_GAP_M;
                    let safe = (gap / HEADWAY_S).max(0.0);
                    desired = desired.min(safe.min(o.speed + gap * 0.3));
                }
            }
            // stop line (if any) and the end of the lane both cap speed
            // with a comfortable braking profile v = sqrt(2 a d)
            let route_end = lane_ref.length() - LOOKAHEAD_M;
            let stop_s = stop_at.map_or(route_end, |s| s.min(route_end));
            let dist_to_stop = stop_s - best_s;
            if dist_to_stop > 0.0 {
                desired = desired.min((2.0 * 2.0 * dist_to_stop).sqrt());
            } else {
                desired = 0.0;
            }
            let accel = ((desired - agent.speed) * 1.2).clamp(-MAX_ACCEL, 2.5);
            (KinematicAction { accel, yaw_rate }.clamped(), policy.clone())
        }
    }
}

/// Spawn an agent appropriate for the policy.
pub fn spawn(policy: &Policy, map: &LaneGraph, rng: &mut Rng) -> AgentState {
    match policy {
        Policy::LaneFollow { lane, target_speed, .. } => {
            let lane_ref = &map.lanes[*lane];
            let s0 = rng.range(0.0, lane_ref.length() * 0.5);
            let pose = lane_ref.pose_at(s0);
            AgentState {
                pose,
                speed: rng.range(0.3, 1.0) * target_speed,
                kind: AgentKind::Vehicle,
                length: rng.range(4.2, 5.4),
                width: rng.range(1.8, 2.2),
                last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
            }
        }
        Policy::Wander { .. } => {
            let cw = rng.choice(&map.crosswalks);
            AgentState {
                pose: Pose::new(
                    cw.x + rng.range(-4.0, 4.0),
                    cw.y + rng.range(-4.0, 4.0),
                    rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                ),
                speed: rng.range(0.6, 1.8),
                kind: AgentKind::Pedestrian,
                length: 0.6,
                width: 0.6,
                last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
            }
        }
        Policy::Stationary => {
            let lane = rng.choice(&map.lanes);
            let s0 = rng.range(0.0, lane.length());
            let p = lane.pose_at(s0);
            AgentState {
                pose: Pose::new(p.x + rng.range(-3.0, 3.0), p.y + rng.range(-3.0, 3.0), p.theta),
                speed: 0.0,
                kind: AgentKind::Vehicle,
                length: 4.8,
                width: 2.0,
                last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vehicle_at(pose: Pose, speed: f64) -> AgentState {
        AgentState {
            pose,
            speed,
            kind: AgentKind::Vehicle,
            length: 4.8,
            width: 2.0,
            last_action: KinematicAction { accel: 0.0, yaw_rate: 0.0 },
        }
    }

    #[test]
    fn step_integrates_straight_motion() {
        let a = vehicle_at(Pose::new(0.0, 0.0, 0.0), 10.0);
        let next = a.step(KinematicAction { accel: 0.0, yaw_rate: 0.0 }, 0.5);
        assert!((next.pose.x - 5.0).abs() < 1e-9);
        assert!(next.pose.y.abs() < 1e-12);
    }

    #[test]
    fn step_clamps_speed_at_zero() {
        let a = vehicle_at(Pose::new(0.0, 0.0, 0.0), 0.5);
        let next = a.step(KinematicAction { accel: -4.0, yaw_rate: 0.0 }, 0.5);
        assert_eq!(next.speed, 0.0);
    }

    #[test]
    fn step_turns_with_yaw_rate() {
        let a = vehicle_at(Pose::new(0.0, 0.0, 0.0), 8.0);
        let next = a.step(KinematicAction { accel: 0.0, yaw_rate: 0.5 }, 0.5);
        assert!((next.pose.theta - 0.25).abs() < 1e-9);
        assert!(next.pose.y > 0.0, "turning left curves upward");
    }

    #[test]
    fn lane_follow_tracks_lane() {
        let mut rng = Rng::new(3);
        let map = LaneGraph::generate(&mut rng);
        let policy = Policy::LaneFollow {
            lane: 0,
            target_speed: 10.0,
            stop_at: None,
        };
        let mut agent = spawn(&policy, &map, &mut rng);
        // place near the lane start so the route end is far away
        agent.pose = map.lanes[0].pose_at(2.0);
        let mut p = policy;
        let mut moved = 0.0;
        for _ in 0..12 {
            let (action, np) = plan(&p, &agent, &[], &map, &mut rng);
            let next = agent.step(action, 0.5);
            moved += agent.pose.dist(&next.pose);
            agent = next;
            p = np;
        }
        let (_, _, d) = map.nearest_lane(agent.pose.x, agent.pose.y).unwrap();
        assert!(d < 5.0, "vehicle strayed {d} m from lane network");
        assert!(moved > 10.0, "vehicle should be moving, moved {moved} m");
    }

    #[test]
    fn stop_at_brings_vehicle_to_rest() {
        let mut rng = Rng::new(4);
        let map = LaneGraph::generate(&mut rng);
        let policy = Policy::LaneFollow {
            lane: 0,
            target_speed: 12.0,
            stop_at: Some(20.0),
        };
        let mut agent = spawn(&policy, &map, &mut rng);
        // place near lane start
        agent.pose = map.lanes[0].pose_at(0.0);
        agent.speed = 8.0;
        let mut p = policy;
        for _ in 0..60 {
            let (action, np) = plan(&p, &agent, &[], &map, &mut rng);
            agent = agent.step(action, 0.5);
            p = np;
        }
        assert!(agent.speed < 0.8, "vehicle should stop, v={}", agent.speed);
    }

    #[test]
    fn follower_does_not_rear_end_leader() {
        let mut rng = Rng::new(5);
        let map = LaneGraph::generate(&mut rng);
        let lane = &map.lanes[0];
        let mut follower = vehicle_at(lane.pose_at(0.0), 12.0);
        let leader = vehicle_at(lane.pose_at(25.0), 0.0); // stopped ahead
        let policy = Policy::LaneFollow {
            lane: 0,
            target_speed: 12.0,
            stop_at: None,
        };
        for _ in 0..40 {
            let (action, _) = plan(&policy, &follower, &[leader], &map, &mut rng);
            follower = follower.step(action, 0.5);
        }
        let gap = follower.pose.dist(&leader.pose);
        assert!(gap > 1.5, "collision: gap {gap}");
    }
}
