//! Scenario suite: a registry of named procedural world families plus a
//! weighted workload mixer (DESIGN.md §11).
//!
//! The paper's core claim is viewpoint/geometry generalization *without*
//! augmentation; a single hardcoded corridor cannot exercise that.  Each
//! [`Family`] here is a deterministic seed→scenario generator over a
//! distinct world geometry (merges, signalized crossings, roundabouts,
//! parking grids, pedestrian-heavy crossings), with difficulty knobs for
//! agent count, map extent and speed range.  The [`WorkloadMix`] drives
//! `gen-data` / `simulate` with a weighted family mix so dataset shards
//! and server load are tagged per family and evaluated per family.
//!
//! Every family scatters its canonical-frame geometry over a random SE(2)
//! world pose, so the invariance property (`tests/suite_invariance.rs`)
//! is exercised against genuinely different frames per seed.

mod maps;

use anyhow::{bail, Result};

use crate::config::SimConfig;
use crate::geometry::Pose;
use crate::prng::{Rng, SplitMix64};

use super::scenario::{roll_forward, Scenario, ScenarioGenerator};

/// Stable identity of a scenario family.  `Corridor` is the legacy
/// single-map generator (kept registered so old shards/configs stay
/// expressible); the rest are the procedural suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FamilyId {
    Corridor,
    HighwayMerge,
    FourWaySignalized,
    Roundabout,
    ParkingLot,
    UrbanCrossing,
}

impl FamilyId {
    pub const ALL: [FamilyId; 6] = [
        FamilyId::Corridor,
        FamilyId::HighwayMerge,
        FamilyId::FourWaySignalized,
        FamilyId::Roundabout,
        FamilyId::ParkingLot,
        FamilyId::UrbanCrossing,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FamilyId::Corridor => "corridor",
            FamilyId::HighwayMerge => "highway-merge",
            FamilyId::FourWaySignalized => "four-way-signalized",
            FamilyId::Roundabout => "roundabout",
            FamilyId::ParkingLot => "parking-lot",
            FamilyId::UrbanCrossing => "urban-crossing",
        }
    }

    /// Stable index into [`Self::ALL`] (shard tags, telemetry slots).
    pub fn index(&self) -> usize {
        Self::ALL.iter().position(|f| f == self).expect("in ALL")
    }

    pub fn from_index(i: usize) -> Option<FamilyId> {
        Self::ALL.get(i).copied()
    }

    pub fn parse(s: &str) -> Result<FamilyId> {
        for f in Self::ALL {
            if f.name() == s {
                return Ok(f);
            }
        }
        let known: Vec<&str> = Self::ALL.iter().map(|f| f.name()).collect();
        bail!("unknown scenario family '{s}' (expected one of: {})", known.join(", "))
    }
}

/// Difficulty knobs of one family: defaults live in [`Family::new`]; the
/// model-facing agent count is always taken from [`SimConfig`] so the
/// token budget the artifacts were lowered at is never violated.
#[derive(Clone, Copy, Debug)]
pub struct FamilyKnobs {
    /// Recommended agent count when generating standalone (benches,
    /// rendering); [`Family::generate`] uses `SimConfig::n_agents` instead.
    pub n_agents: usize,
    /// Half-extent of the map in meters (kept <= ~80 so the tokenizer's
    /// `pos_scale` downscaling stays within the paper's |p| <= 4 band).
    pub map_extent: f64,
    /// Vehicle target-speed band (m/s).
    pub speed_range: (f64, f64),
}

/// One registered scenario family: identity, knobs, deterministic
/// seed→scenario generation.
#[derive(Clone, Debug)]
pub struct Family {
    pub id: FamilyId,
    pub about: &'static str,
    pub knobs: FamilyKnobs,
}

/// All registered families, default knobs.
pub fn registry() -> Vec<Family> {
    FamilyId::ALL.iter().map(|id| Family::new(*id)).collect()
}

impl Family {
    pub fn new(id: FamilyId) -> Family {
        let (about, knobs) = match id {
            FamilyId::Corridor => (
                "legacy two-lane corridor with a turn lane and optional crossing road",
                FamilyKnobs { n_agents: 6, map_extent: 60.0, speed_range: (6.0, 13.0) },
            ),
            FamilyId::HighwayMerge => (
                "3 parallel lanes plus an on-ramp; ramp traffic lane-changes into the flow",
                FamilyKnobs { n_agents: 8, map_extent: 70.0, speed_range: (8.0, 16.0) },
            ),
            FamilyId::FourWaySignalized => (
                "two crossing corridors gated by a signal phase; red side queues stop-and-go",
                FamilyKnobs { n_agents: 8, map_extent: 60.0, speed_range: (6.0, 12.0) },
            ),
            FamilyId::Roundabout => (
                "circular lane with tangential entries yielding on entry",
                FamilyKnobs { n_agents: 6, map_extent: 50.0, speed_range: (5.0, 9.0) },
            ),
            FamilyId::ParkingLot => (
                "dense stationary grid with crawling vehicles on the aisles",
                FamilyKnobs { n_agents: 10, map_extent: 40.0, speed_range: (1.5, 4.0) },
            ),
            FamilyId::UrbanCrossing => (
                "pedestrian/cyclist-heavy corridor, vehicles gated by crosswalks",
                FamilyKnobs { n_agents: 8, map_extent: 50.0, speed_range: (3.0, 9.0) },
            ),
        };
        Family { id, about, knobs }
    }

    pub fn with_knobs(mut self, knobs: FamilyKnobs) -> Family {
        self.knobs = knobs;
        self
    }

    /// Generate scenario `seed` with the model-compatible agent count
    /// (`sim.n_agents`).  Deterministic: (family, knobs, seed) fully
    /// determine the output, independent of call order.
    pub fn generate(&self, sim: &SimConfig, seed: u64) -> Scenario {
        self.generate_n(sim, sim.n_agents, seed)
    }

    /// Generate with an explicit agent count (standalone/bench use; the
    /// model path must stick to `sim.n_agents`).
    pub fn generate_n(&self, sim: &SimConfig, n_agents: usize, seed: u64) -> Scenario {
        if self.id == FamilyId::Corridor {
            // byte-compatible with the legacy generator for the default
            // agent count, so `corridor` shards match pre-suite shards
            let mut sim2 = sim.clone();
            sim2.n_agents = n_agents;
            return ScenarioGenerator::new(sim2).generate(seed);
        }
        let mut rng = Rng::new(
            seed ^ 0xFA31_15EE_D000_0000_u64
                .wrapping_add((self.id.index() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        let (mut map, mut policies, mut agents) = match self.id {
            FamilyId::Corridor => unreachable!("handled above"),
            FamilyId::HighwayMerge => maps::highway_merge(&self.knobs, n_agents, &mut rng),
            FamilyId::FourWaySignalized => {
                maps::four_way_signalized(&self.knobs, n_agents, &mut rng)
            }
            FamilyId::Roundabout => maps::roundabout(&self.knobs, n_agents, &mut rng),
            FamilyId::ParkingLot => maps::parking_lot(&self.knobs, n_agents, &mut rng),
            FamilyId::UrbanCrossing => maps::urban_crossing(&self.knobs, n_agents, &mut rng),
        };
        // scatter the canonical-frame world over a random SE(2) pose so no
        // family is axis-aligned in world coordinates
        let z = Pose::new(
            rng.range(-15.0, 15.0),
            rng.range(-15.0, 15.0),
            rng.range(-std::f64::consts::PI, std::f64::consts::PI),
        );
        maps::apply_world_frame(&z, &mut map, &mut policies, &mut agents);
        let map_elements = map.elements(sim.n_map_tokens);
        roll_forward(
            map,
            map_elements,
            policies,
            agents,
            sim,
            &mut rng,
            seed,
            self.id,
        )
    }
}

/// A weighted mix of families: the workload generator behind
/// `gen-data --mix` and `simulate --mix`.  Family assignment is a pure
/// function of the scenario seed, so shards and load tests are
/// reproducible and every scenario seed maps to exactly one world.
#[derive(Clone, Debug)]
pub struct WorkloadMix {
    entries: Vec<(FamilyId, f64)>,
}

impl WorkloadMix {
    pub fn single(id: FamilyId) -> WorkloadMix {
        WorkloadMix { entries: vec![(id, 1.0)] }
    }

    /// Equal weights over `ids`.
    pub fn uniform(ids: &[FamilyId]) -> WorkloadMix {
        assert!(!ids.is_empty(), "empty mix");
        WorkloadMix {
            entries: ids.iter().map(|id| (*id, 1.0)).collect(),
        }
    }

    /// Parse a spec like `highway-merge:2,roundabout:1` (weights optional;
    /// a bare name means weight 1).
    pub fn parse(spec: &str) -> Result<WorkloadMix> {
        let mut entries = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, weight) = match part.split_once(':') {
                Some((n, w)) => {
                    let w: f64 = w
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad mix weight in '{part}'"))?;
                    (n.trim(), w)
                }
                None => (part, 1.0),
            };
            if !weight.is_finite() || weight <= 0.0 {
                bail!("mix weight must be positive in '{part}'");
            }
            entries.push((FamilyId::parse(name)?, weight));
        }
        if entries.is_empty() {
            bail!("empty workload mix spec '{spec}'");
        }
        Ok(WorkloadMix { entries })
    }

    pub fn entries(&self) -> &[(FamilyId, f64)] {
        &self.entries
    }

    /// Deterministic seed→family assignment (stateless hash of the seed,
    /// weighted by the mix) — independent of generation order.
    pub fn family_for_seed(&self, seed: u64) -> FamilyId {
        if self.entries.len() == 1 {
            return self.entries[0].0;
        }
        let mut sm = SplitMix64::new(seed ^ 0x5CE2_A710_F00D_5EED);
        let u = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let total: f64 = self.entries.iter().map(|(_, w)| w).sum();
        let mut t = u * total;
        for (id, w) in &self.entries {
            t -= w;
            if t <= 0.0 {
                return *id;
            }
        }
        self.entries.last().expect("non-empty").0
    }
}

/// Seed→scenario generator over a workload mix (the mixed-traffic
/// counterpart of [`ScenarioGenerator`]).
pub struct MixGenerator {
    pub sim: SimConfig,
    pub mix: WorkloadMix,
}

impl MixGenerator {
    pub fn new(sim: SimConfig, mix: WorkloadMix) -> MixGenerator {
        MixGenerator { sim, mix }
    }

    /// Generate scenario `seed`: its family comes from the mix, the world
    /// from that family's generator; the result carries the family tag.
    pub fn generate(&self, seed: u64) -> Scenario {
        Family::new(self.mix.family_for_seed(seed)).generate(&self.sim, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::super::agent::AgentKind;
    use super::*;

    fn sim() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn registry_exposes_all_families() {
        let reg = registry();
        assert!(reg.len() >= 5, "at least five families: {}", reg.len());
        let names: std::collections::BTreeSet<&str> =
            reg.iter().map(|f| f.id.name()).collect();
        assert_eq!(names.len(), reg.len(), "names must be unique");
        for f in &reg {
            assert_eq!(FamilyId::parse(f.id.name()).unwrap(), f.id);
            assert_eq!(FamilyId::from_index(f.id.index()), Some(f.id));
            assert!(!f.about.is_empty());
        }
        assert!(FamilyId::parse("bogus").is_err());
    }

    #[test]
    fn every_family_generates_deterministic_well_shaped_scenarios() {
        let sim = sim();
        for fam in registry() {
            let a = fam.generate(&sim, 7);
            let b = fam.generate(&sim, 7);
            assert_eq!(a.family, fam.id);
            assert_eq!(a.n_agents(), sim.n_agents, "{}", fam.id.name());
            assert_eq!(
                a.n_steps(),
                sim.history_steps + sim.future_steps + 1,
                "{}",
                fam.id.name()
            );
            assert_eq!(a.map_elements.len(), sim.n_map_tokens);
            for (sa, sb) in a.states.iter().zip(b.states.iter()) {
                for (x, y) in sa.iter().zip(sb.iter()) {
                    assert_eq!(x.pose, y.pose, "{} must be deterministic", fam.id.name());
                }
            }
            // different seeds give different worlds
            let c = fam.generate(&sim, 8);
            assert_ne!(
                a.states[0][0].pose, c.states[0][0].pose,
                "{} seeds must differ",
                fam.id.name()
            );
            // agents stay within a sane radius of the scene
            for step in &a.states {
                for st in step {
                    assert!(
                        st.pose.radius() < 250.0,
                        "{}: agent escaped to {:?}",
                        fam.id.name(),
                        st.pose
                    );
                }
            }
        }
    }

    #[test]
    fn families_have_their_distinctive_content() {
        let sim = sim();
        let gen = |id: FamilyId| Family::new(id).generate(&sim, 3);

        let hw = gen(FamilyId::HighwayMerge);
        assert!(hw.map.lanes.len() >= 4, "3 mainline lanes + ramp");

        let fw = gen(FamilyId::FourWaySignalized);
        assert!(fw.map.lanes.len() >= 4, "two crossing corridors");
        assert!(!fw.map.signals.is_empty(), "signal present");
        assert!(!fw.map.crosswalks.is_empty(), "crosswalks present");

        let rb = gen(FamilyId::Roundabout);
        assert!(
            rb.map.lanes[0].curvature.abs() > 1e-3,
            "circulating lane is curved"
        );
        assert!(rb.map.lanes.len() >= 3, "circle plus entries");

        let pl = gen(FamilyId::ParkingLot);
        let stationary = pl.states[0].iter().filter(|a| a.speed == 0.0).count();
        assert!(stationary >= 3, "dense parked grid: {stationary}");

        let uc = gen(FamilyId::UrbanCrossing);
        let kinds: std::collections::BTreeSet<_> = uc.states[0]
            .iter()
            .map(|a| format!("{:?}", a.kind))
            .collect();
        assert!(
            uc.states[0].iter().any(|a| a.kind == AgentKind::Pedestrian),
            "pedestrians present: {kinds:?}"
        );
        assert!(
            uc.states[0].iter().any(|a| a.kind == AgentKind::Cyclist),
            "cyclists present: {kinds:?}"
        );
        assert!(!uc.map.crosswalks.is_empty());
    }

    #[test]
    fn robot_agent_moves_in_every_family() {
        // agent 0 anchors the tokenizer frame; a frozen robot would make
        // every window identical and the rollout degenerate
        let sim = sim();
        for fam in registry() {
            let s = fam.generate(&sim, 11);
            let start = s.states[0][0].pose;
            let end = s.states[s.n_steps() - 1][0].pose;
            assert!(
                start.dist(&end) > 1.0,
                "{}: robot barely moved ({:.2} m)",
                fam.id.name(),
                start.dist(&end)
            );
        }
    }

    #[test]
    fn mix_parse_and_weighting() {
        let mix = WorkloadMix::parse("highway-merge:3, roundabout:1").unwrap();
        assert_eq!(mix.entries().len(), 2);
        // deterministic per seed
        for seed in 0..50 {
            assert_eq!(mix.family_for_seed(seed), mix.family_for_seed(seed));
        }
        // heavy family dominates over many seeds
        let mut counts = std::collections::BTreeMap::new();
        for seed in 0..400 {
            *counts.entry(mix.family_for_seed(seed)).or_insert(0usize) += 1;
        }
        let hw = counts.get(&FamilyId::HighwayMerge).copied().unwrap_or(0);
        let rb = counts.get(&FamilyId::Roundabout).copied().unwrap_or(0);
        assert!(hw > rb, "weights respected: hw={hw} rb={rb}");
        assert!(rb > 0, "light family still occurs");

        // bare names get weight 1; junk is rejected
        assert!(WorkloadMix::parse("corridor,parking-lot").is_ok());
        assert!(WorkloadMix::parse("").is_err());
        assert!(WorkloadMix::parse("nope:1").is_err());
        assert!(WorkloadMix::parse("corridor:-1").is_err());
        assert!(WorkloadMix::parse("corridor:x").is_err());
    }

    #[test]
    fn mix_generator_tags_scenarios() {
        let mix = WorkloadMix::uniform(&[FamilyId::Roundabout, FamilyId::ParkingLot]);
        let gen = MixGenerator::new(sim(), mix.clone());
        for seed in 0..6 {
            let s = gen.generate(seed);
            assert_eq!(s.family, mix.family_for_seed(seed));
            assert_eq!(s.seed, seed);
        }
    }

    #[test]
    fn knobs_shape_the_generated_world() {
        let sim = sim();
        let base = Family::new(FamilyId::HighwayMerge);
        let shrunk = Family::new(FamilyId::HighwayMerge).with_knobs(FamilyKnobs {
            n_agents: 4,
            map_extent: 40.0,
            speed_range: (20.0, 21.0),
        });
        let a = base.generate(&sim, 5);
        let b = shrunk.generate(&sim, 5);
        // map extent drives mainline lane length (2x the half-extent)
        assert!(b.map.lanes[0].length() < a.map.lanes[0].length());
        assert!((b.map.lanes[0].length() - 80.0).abs() < 8.0);
        // speed band flows into the lane speed limits
        assert!(b.map.lanes[0].speed_limit >= 20.0 && b.map.lanes[0].speed_limit <= 21.0);
        // advisory agent count is honored on the standalone path only
        assert_eq!(shrunk.generate_n(&sim, shrunk.knobs.n_agents, 5).n_agents(), 4);
        assert_eq!(b.n_agents(), sim.n_agents, "serving path pins the count");
    }

    #[test]
    fn scene_id_disambiguates_families_sharing_a_seed() {
        // the KV cache pool keys shared map rows by scene id; every family
        // pads its map to the same token count, so the id itself must
        // carry the family or same-seed requests would cross-pollute
        let sim = sim();
        let mut seen = std::collections::BTreeSet::new();
        for fam in registry() {
            let s = fam.generate(&sim, 7);
            assert_eq!(s.scene_id(), fam.generate(&sim, 7).scene_id());
            assert!(seen.insert(s.scene_id()), "{} collided", fam.id.name());
        }
        assert_eq!(seen.len(), FamilyId::ALL.len());
    }

    #[test]
    fn corridor_family_matches_legacy_generator() {
        let sim = sim();
        let legacy = ScenarioGenerator::new(sim.clone()).generate(42);
        let fam = Family::new(FamilyId::Corridor).generate(&sim, 42);
        for (a, b) in legacy.states.iter().zip(fam.states.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.pose, y.pose);
            }
        }
        assert_eq!(fam.family, FamilyId::Corridor);
    }
}
