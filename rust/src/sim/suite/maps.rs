//! Family-parameterized map + traffic builders (refactored out of the
//! single hardcoded `sim/map.rs` world).  Each builder assembles a
//! canonical-frame `LaneGraph` plus one policy and one initial state per
//! agent; [`apply_world_frame`] then scatters the whole world over a
//! random SE(2) pose so absolute coordinates carry no family signature.

use crate::geometry::Pose;
use crate::prng::Rng;

use super::super::agent::{vehicle_state as vehicle, AgentKind, AgentState, KinematicAction, Policy};
use super::super::map::{trace_lane, LaneGraph};
use super::FamilyKnobs;

const STILL: KinematicAction = KinematicAction { accel: 0.0, yaw_rate: 0.0 };

/// The builder output: world geometry, one policy per agent, one initial
/// state per agent (same index).
pub(super) type World = (LaneGraph, Vec<Policy>, Vec<AgentState>);

fn pedestrian(pose: Pose, speed: f64) -> AgentState {
    AgentState {
        pose,
        speed,
        kind: AgentKind::Pedestrian,
        length: 0.6,
        width: 0.6,
        last_action: STILL,
    }
}

fn cyclist(pose: Pose, speed: f64) -> AgentState {
    AgentState {
        pose,
        speed,
        kind: AgentKind::Cyclist,
        length: 1.8,
        width: 0.6,
        last_action: STILL,
    }
}

/// Push the whole canonical-frame world through a rigid transform `z`:
/// the lane graph, every agent pose, and every world-coordinate waypoint
/// a policy carries (wander goals, merge points).
pub(super) fn apply_world_frame(
    z: &Pose,
    map: &mut LaneGraph,
    policies: &mut [Policy],
    agents: &mut [AgentState],
) {
    *map = map.transformed(z);
    for a in agents.iter_mut() {
        a.pose = z.compose(&a.pose);
    }
    for p in policies.iter_mut() {
        match p {
            Policy::Wander { goal, .. } => {
                *goal = z.transform_point(goal.0, goal.1);
            }
            Policy::YieldEntry { merge_point, .. } => {
                *merge_point = z.transform_point(merge_point.0, merge_point.1);
            }
            _ => {}
        }
    }
}

/// 3+ parallel mainline lanes with an on-ramp; ramp traffic lane-changes
/// into lane 0, mainline traffic occasionally changes between lanes.
pub(super) fn highway_merge(knobs: &FamilyKnobs, n_agents: usize, rng: &mut Rng) -> World {
    let e = knobs.map_extent;
    let speed = rng.range(knobs.speed_range.0, knobs.speed_range.1);
    let mut lanes = Vec::new();
    // mainline lanes 0..2 at lateral offsets 0 / 4 / 8, driving +x
    for off in [0.0, 4.0, 8.0] {
        lanes.push(trace_lane(Pose::new(-e, off, 0.0), 0.0, 2.0 * e, speed));
    }
    // on-ramp (lane 3): starts angled toward the mainline, straightens out
    // and ends next to lane 0 — a constant-curvature arc
    let ramp_len = 55.0;
    let entry_heading = 0.25;
    let curvature = -entry_heading / ramp_len;
    // the arc gains ~ramp_len * sin(heading/2) of lateral distance
    let dy = ramp_len * (entry_heading / 2.0).sin();
    let ramp_start = Pose::new(-e * 0.5, -dy, entry_heading);
    lanes.push(trace_lane(ramp_start, curvature, ramp_len, speed * 0.7));
    let map = LaneGraph { lanes, crosswalks: vec![], signals: vec![] };

    let mut policies = Vec::with_capacity(n_agents);
    let mut agents = Vec::with_capacity(n_agents);
    for i in 0..n_agents {
        let (policy, state) = if i == 0 {
            // robot: mainline lane 0 through the merge zone
            let p = Policy::LaneFollow { lane: 0, target_speed: speed, stop_at: None };
            let st = vehicle(map.lanes[0].pose_at(e * 0.4), speed * 0.8, rng);
            (p, st)
        } else if i % 3 == 1 {
            // ramp traffic: follow the ramp, then change into lane 0
            let trigger = rng.range(0.5, 0.8) * ramp_len;
            let p = Policy::LaneChange {
                from: 3,
                to: 0,
                target_speed: speed * rng.range(0.7, 0.95),
                trigger_s: trigger,
            };
            // stagger ramp spawns so queued entries never overlap
            let s0 = (i as f64 * 5.0) % (ramp_len * 0.4) + rng.range(0.0, 3.0);
            let st = vehicle(map.lanes[3].pose_at(s0), speed * 0.5, rng);
            (p, st)
        } else if i % 3 == 2 {
            // mainline lane-changer between parallel lanes
            let from = 1 + rng.below(2);
            let to = if from == 1 { 2 } else { 1 };
            let p = Policy::LaneChange {
                from,
                to,
                target_speed: speed * rng.range(0.8, 1.0),
                trigger_s: rng.range(0.3, 0.6) * 2.0 * e,
            };
            let s0 = rng.range(0.1, 0.45) * 2.0 * e;
            let st = vehicle(map.lanes[from].pose_at(s0), speed * 0.8, rng);
            (p, st)
        } else {
            // plain mainline follower, staggered to avoid spawn collisions
            let lane = rng.below(3);
            let p = Policy::LaneFollow {
                lane,
                target_speed: speed * rng.range(0.75, 1.0),
                stop_at: None,
            };
            let s0 = (i as f64 * 17.0 + rng.range(0.0, 8.0)) % (1.2 * e);
            let st = vehicle(map.lanes[lane].pose_at(s0), speed * 0.7, rng);
            (p, st)
        };
        policies.push(policy);
        agents.push(state);
    }
    (map, policies, agents)
}

/// Two crossing corridors through the origin, gated by a sampled signal
/// phase: the red side queues at its stop line (stop-and-go emerges from
/// the leader-following controller), the green side flows.
pub(super) fn four_way_signalized(
    knobs: &FamilyKnobs,
    n_agents: usize,
    rng: &mut Rng,
) -> World {
    let e = knobs.map_extent;
    let speed = rng.range(knobs.speed_range.0, knobs.speed_range.1);
    // lanes 0/1: east-west corridor (one lane per direction);
    // lanes 2/3: north-south corridor
    let lanes = vec![
        trace_lane(Pose::new(-e, -2.0, 0.0), 0.0, 2.0 * e, speed),
        trace_lane(Pose::new(e, 2.0, std::f64::consts::PI), 0.0, 2.0 * e, speed),
        trace_lane(Pose::new(2.0, -e, std::f64::consts::FRAC_PI_2), 0.0, 2.0 * e, speed * 0.9),
        trace_lane(Pose::new(-2.0, e, -std::f64::consts::FRAC_PI_2), 0.0, 2.0 * e, speed * 0.9),
    ];
    // phase: 0 = EW green, 1 = NS green, 2 = all-stop (yellow clearance)
    let phase = rng.below(3);
    let signal_state = match phase {
        0 => 1.0,
        1 => 0.0,
        _ => 0.5,
    };
    let crosswalks = vec![
        Pose::new(0.0, 9.0, 0.0),
        Pose::new(0.0, -9.0, 0.0),
        Pose::new(9.0, 0.0, std::f64::consts::FRAC_PI_2),
        Pose::new(-9.0, 0.0, std::f64::consts::FRAC_PI_2),
    ];
    let signals = vec![(Pose::new(6.0, 6.0, 0.0), signal_state)];
    let map = LaneGraph { lanes, crosswalks, signals };

    // stop line: just before the intersection box, measured along the lane
    let stop_s = e - 10.0;
    let ew_stops = phase != 0;
    let ns_stops = phase != 1;
    let mut policies = Vec::with_capacity(n_agents);
    let mut agents = Vec::with_capacity(n_agents);
    for i in 0..n_agents {
        let (policy, state) = if i == 0 {
            // robot: always on the flowing corridor (or approaching the
            // line during all-stop — still moving through history)
            let lane = if !ew_stops { 0 } else if !ns_stops { 2 } else { 0 };
            let stop = if phase == 2 { Some(stop_s) } else { None };
            let p = Policy::LaneFollow { lane, target_speed: speed, stop_at: stop };
            let st = vehicle(map.lanes[lane].pose_at(e * 0.3), speed * 0.8, rng);
            (p, st)
        } else if i % 4 == 3 && !map.crosswalks.is_empty() {
            // corner pedestrian
            let cw = *rng.choice(&map.crosswalks);
            let p = Policy::Wander {
                goal: (cw.x + rng.range(-8.0, 8.0), cw.y + rng.range(-8.0, 8.0)),
                speed: rng.range(0.8, 1.6),
            };
            let st = pedestrian(
                Pose::new(
                    cw.x + rng.range(-3.0, 3.0),
                    cw.y + rng.range(-3.0, 3.0),
                    rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                ),
                rng.range(0.6, 1.4),
            );
            (p, st)
        } else {
            // corridor traffic: queue on red, flow on green
            let lane = rng.below(4);
            let stops = if lane < 2 { ew_stops } else { ns_stops };
            let p = Policy::LaneFollow {
                lane,
                target_speed: speed * rng.range(0.7, 1.0),
                stop_at: if stops { Some(stop_s) } else { None },
            };
            // stagger approach positions so red corridors form a queue
            let s0 = ((i * 13) % 40) as f64 + 4.0 + rng.range(0.0, 3.0);
            let st = vehicle(map.lanes[lane].pose_at(s0), speed * 0.6, rng);
            (p, st)
        };
        policies.push(policy);
        agents.push(state);
    }
    (map, policies, agents)
}

/// A circulating lane (2.5 loops: the farthest spawn plus a whole
/// episode of max-speed travel still ends >1 loop short of the polyline
/// end, so the end-of-lane braking cap can never fire mid-roundabout)
/// with tangential entry lanes yielding on entry.
pub(super) fn roundabout(knobs: &FamilyKnobs, n_agents: usize, rng: &mut Rng) -> World {
    let radius = rng.range(16.0, 24.0) * (knobs.map_extent / 50.0);
    let speed = rng.range(knobs.speed_range.0, knobs.speed_range.1);
    let circumference = std::f64::consts::TAU * radius;
    let mut lanes = vec![trace_lane(
        Pose::new(radius, 0.0, std::f64::consts::FRAC_PI_2),
        1.0 / radius,
        2.5 * circumference,
        speed,
    )];
    // tangential entry lanes at sampled angles
    let n_entries = 2 + rng.below(2);
    let entry_len = 42.0;
    let mut merges = Vec::new(); // (entry lane idx, merge_s, merge point)
    for k in 0..n_entries {
        let phi = k as f64 * std::f64::consts::TAU / n_entries as f64 + rng.range(-0.2, 0.2);
        let (tx, ty) = (-phi.sin(), phi.cos()); // tangent direction (ccw)
        let (px, py) = (radius * phi.cos(), radius * phi.sin());
        let start = Pose::new(px - entry_len * tx, py - entry_len * ty, ty.atan2(tx));
        lanes.push(trace_lane(start, 0.0, entry_len, speed * 0.7));
        merges.push((lanes.len() - 1, entry_len - 4.0, (px, py)));
    }
    let map = LaneGraph { lanes, crosswalks: vec![], signals: vec![] };

    let mut policies = Vec::with_capacity(n_agents);
    let mut agents = Vec::with_capacity(n_agents);
    for i in 0..n_agents {
        let (policy, state) = if i % 2 == 0 {
            // circulating traffic (agent 0 = robot rides the circle)
            let p = Policy::LaneFollow { lane: 0, target_speed: speed, stop_at: None };
            let s0 = (i as f64 / n_agents as f64) * circumference + rng.range(0.0, 10.0);
            let st = vehicle(map.lanes[0].pose_at(s0), speed * 0.7, rng);
            (p, st)
        } else {
            // entering traffic: yield at the merge point
            let (lane, merge_s, merge_point) = merges[(i / 2) % merges.len()];
            let p = Policy::YieldEntry {
                lane,
                next_lane: 0,
                target_speed: speed * rng.range(0.7, 0.95),
                merge_s,
                merge_point,
                clear_radius: 11.0,
            };
            let s0 = rng.range(0.0, merge_s * 0.5);
            let st = vehicle(map.lanes[lane].pose_at(s0), speed * 0.5, rng);
            (p, st)
        };
        policies.push(policy);
        agents.push(state);
    }
    (map, policies, agents)
}

/// Two crawl-speed aisles flanked by a dense grid of parked vehicles.
pub(super) fn parking_lot(knobs: &FamilyKnobs, n_agents: usize, rng: &mut Rng) -> World {
    let e = knobs.map_extent;
    let crawl = rng.range(knobs.speed_range.0, knobs.speed_range.1);
    let lanes = vec![
        trace_lane(Pose::new(-e * 0.6, 0.0, 0.0), 0.0, 1.2 * e, crawl),
        trace_lane(Pose::new(e * 0.6, 12.0, std::f64::consts::PI), 0.0, 1.2 * e, crawl),
    ];
    let map = LaneGraph { lanes, crosswalks: vec![], signals: vec![] };

    // parked slots: rows offset from each aisle, stalls every 3.5 m
    let rows = [-5.0, 5.0, 7.0, 17.0];
    let stalls_per_row = ((1.2 * e) / 3.5) as usize;
    let mut policies = Vec::with_capacity(n_agents);
    let mut agents = Vec::with_capacity(n_agents);
    for i in 0..n_agents {
        let (policy, state) = if i < 2 {
            // crawling vehicles on the aisles (agent 0 = robot)
            let lane = i % 2;
            let p = Policy::LaneFollow {
                lane,
                target_speed: crawl * rng.range(0.8, 1.0),
                stop_at: None,
            };
            let s0 = rng.range(0.05, 0.4) * 1.2 * e;
            let st = vehicle(map.lanes[lane].pose_at(s0), crawl * 0.6, rng);
            (p, st)
        } else {
            // stationary grid fill: deterministic stall per agent index
            let row = rows[i % rows.len()];
            let stall = (i * 5) % stalls_per_row.max(1);
            let x = -e * 0.6 + stall as f64 * 3.5;
            let heading = if row < 6.0 {
                std::f64::consts::FRAC_PI_2
            } else {
                -std::f64::consts::FRAC_PI_2
            };
            let st = AgentState {
                pose: Pose::new(x, row, heading),
                speed: 0.0,
                kind: AgentKind::Vehicle,
                length: 4.8,
                width: 2.0,
                last_action: STILL,
            };
            (Policy::Stationary, st)
        };
        policies.push(policy);
        agents.push(state);
    }
    (map, policies, agents)
}

/// A two-lane corridor gated by crosswalks, dominated by pedestrians and
/// cyclists.
pub(super) fn urban_crossing(knobs: &FamilyKnobs, n_agents: usize, rng: &mut Rng) -> World {
    let e = knobs.map_extent;
    let speed = rng.range(knobs.speed_range.0 + 2.0, knobs.speed_range.1);
    let lanes = vec![
        trace_lane(Pose::new(-e, -2.0, 0.0), 0.0, 2.0 * e, speed),
        trace_lane(Pose::new(e, 2.0, std::f64::consts::PI), 0.0, 2.0 * e, speed),
    ];
    let crosswalks: Vec<Pose> = [-0.4, 0.0, 0.4]
        .iter()
        .map(|f| Pose::new(f * e + rng.range(-4.0, 4.0), 0.0, std::f64::consts::FRAC_PI_2))
        .collect();
    let map = LaneGraph { lanes, crosswalks, signals: vec![] };

    let mut policies = Vec::with_capacity(n_agents);
    let mut agents = Vec::with_capacity(n_agents);
    for i in 0..n_agents {
        let (policy, state) = if i == 0 {
            // robot: corridor vehicle, free-flowing
            let p = Policy::LaneFollow { lane: 0, target_speed: speed, stop_at: None };
            let st = vehicle(map.lanes[0].pose_at(e * 0.2), speed * 0.7, rng);
            (p, st)
        } else if i % 4 == 1 {
            // crosswalk-gated vehicle: stops short of the middle crosswalk
            let lane = rng.below(2);
            let cw_s = e - 8.0; // crosswalks sit near the corridor middle
            let p = Policy::LaneFollow {
                lane,
                target_speed: speed * rng.range(0.7, 1.0),
                stop_at: Some(cw_s),
            };
            let s0 = rng.range(0.1, 0.5) * cw_s;
            let st = vehicle(map.lanes[lane].pose_at(s0), speed * 0.6, rng);
            (p, st)
        } else if i % 4 == 2 {
            // cyclist sharing the corridor
            let lane = rng.below(2);
            let bike_speed = rng.range(3.0, 5.5);
            let p = Policy::LaneFollow {
                lane,
                target_speed: bike_speed,
                stop_at: None,
            };
            let s0 = rng.range(0.1, 0.8) * 2.0 * e;
            let mut st = cyclist(map.lanes[lane].pose_at(s0), bike_speed * 0.8);
            st.pose = Pose::new(st.pose.x, st.pose.y + rng.range(-0.8, 0.8), st.pose.theta);
            (p, st)
        } else {
            // pedestrians clustered around the crosswalks
            let cw = *rng.choice(&map.crosswalks);
            let p = Policy::Wander {
                goal: (cw.x + rng.range(-10.0, 10.0), cw.y + rng.range(-10.0, 10.0)),
                speed: rng.range(0.8, 1.8),
            };
            let st = pedestrian(
                Pose::new(
                    cw.x + rng.range(-4.0, 4.0),
                    cw.y + rng.range(-4.0, 4.0),
                    rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                ),
                rng.range(0.6, 1.6),
            );
            (p, st)
        };
        policies.push(policy);
        agents.push(state);
    }
    (map, policies, agents)
}
