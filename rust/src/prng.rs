//! Deterministic pseudo-random number generation (substrate for the absent
//! `rand` crate).
//!
//! `SplitMix64` seeds `Xoshiro256PlusPlus` (the same construction the `rand`
//! ecosystem uses); distributions cover everything the simulator, dataset
//! pipeline and property-testing framework need.  All generators are
//! deterministic from their seed so every experiment in EXPERIMENTS.md is
//! exactly reproducible.

/// SplitMix64 — used for seeding and as a cheap stateless hash.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-scenario / per-worker
    /// determinism regardless of iteration order).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        Rng::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift bounded rejection-free approximation is
        // fine here; exactness of the bound matters, tiny bias does not.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Pick an element of a slice uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Vector of f32 standard normals (fills model tensors).
    pub fn normal_vec_f32(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| sigma * self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
        assert!(counts[2] > counts[1] * 4);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
