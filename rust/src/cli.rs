//! Declarative command-line parsing (substrate for the absent `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub help: String,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: String,
    pub about: String,
    pub args: Vec<ArgSpec>,
    /// Help text for positional (non `--`) arguments; `None` means the
    /// command rejects positionals, as every command did before
    /// `bench-report --compare OLD NEW` needed them.
    pub free_args: Option<String>,
    /// Omit from the top-level command list.  For internal entry points
    /// (the `worker` process spawned by `simulate --worker-procs`) that
    /// must parse like any command but are not part of the user-facing
    /// surface.  Still runs and still answers `<name> --help`.
    pub hidden: bool,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Command {
        Command {
            name: name.into(),
            about: about.into(),
            args: Vec::new(),
            free_args: None,
            hidden: false,
        }
    }

    /// Hide this command from the top-level usage listing.
    pub fn hidden(mut self) -> Command {
        self.hidden = true;
        self
    }

    /// Accept positional arguments (collected in order into
    /// [`Matches::free`]); `help` describes them in `--help` output.
    pub fn free_args(mut self, help: &str) -> Command {
        self.free_args = Some(help.into());
        self
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Command {
        self.args.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    pub fn required(mut self, name: &str, help: &str) -> Command {
        self.args.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Command {
        self.args.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }
}

/// Parsed argument values for one subcommand.
#[derive(Clone, Debug)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    free: Vec<String>,
}

impl Matches {
    /// Positional arguments, in order (empty unless the command opted in
    /// via [`Command::free_args`]).
    pub fn free(&self) -> &[String] {
        &self.free
    }
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("unknown arg '{name}'"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    /// Optional string option: `None` when the value is empty — the idiom
    /// for opts whose default is `""` (paths, mix specs, ...).
    pub fn get_opt(&self, name: &str) -> Option<&str> {
        let v = self.get(name);
        if v.is_empty() {
            None
        } else {
            Some(v)
        }
    }
}

/// A multi-command CLI application.
pub struct App {
    pub name: String,
    pub about: String,
    pub commands: Vec<Command>,
}

pub enum ParseOutcome {
    Run(Matches),
    Help(String),
    Error(String),
}

impl App {
    pub fn new(name: &str, about: &str) -> App {
        App {
            name: name.into(),
            about: about.into(),
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, c: Command) -> App {
        self.commands.push(c);
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in self.commands.iter().filter(|c| !c.hidden) {
            s.push_str(&format!("  {:<24} {}\n", c.name, c.about));
        }
        s.push_str("\nRun '<command> --help' for command options.\n");
        s
    }

    fn command_usage(&self, c: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, c.name, c.about);
        for a in &c.args {
            let d = match (&a.default, a.is_flag) {
                (_, true) => "flag".to_string(),
                (Some(d), _) => format!("default: {d}"),
                (None, _) => "required".to_string(),
            };
            s.push_str(&format!("  --{:<22} {} [{}]\n", a.name, a.help, d));
        }
        if let Some(free) = &c.free_args {
            s.push_str(&format!("\nARGS:\n  {free}\n"));
        }
        s
    }

    /// Parse argv (without program name).
    pub fn parse(&self, argv: &[String]) -> ParseOutcome {
        if argv.is_empty()
            || argv[0] == "--help"
            || argv[0] == "-h"
            || argv[0] == "help"
        {
            return ParseOutcome::Help(self.usage());
        }
        let cmd = match self.commands.iter().find(|c| c.name == argv[0]) {
            Some(c) => c,
            None => {
                return ParseOutcome::Error(format!(
                    "unknown command '{}'\n\n{}",
                    argv[0],
                    self.usage()
                ))
            }
        };
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for a in &cmd.args {
            if a.is_flag {
                flags.insert(a.name.clone(), false);
            } else if let Some(d) = &a.default {
                values.insert(a.name.clone(), d.clone());
            }
        }
        let mut free = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return ParseOutcome::Help(self.command_usage(cmd));
            }
            let Some(stripped) = tok.strip_prefix("--") else {
                if cmd.free_args.is_some() {
                    free.push(tok.clone());
                    i += 1;
                    continue;
                }
                return ParseOutcome::Error(format!("unexpected argument '{tok}'"));
            };
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            let Some(spec) = cmd.args.iter().find(|a| a.name == key) else {
                return ParseOutcome::Error(format!(
                    "unknown option '--{key}' for '{}'\n\n{}",
                    cmd.name,
                    self.command_usage(cmd)
                ));
            };
            if spec.is_flag {
                flags.insert(key, true);
                i += 1;
            } else if let Some(v) = inline_val {
                values.insert(key, v);
                i += 1;
            } else {
                if i + 1 >= argv.len() {
                    return ParseOutcome::Error(format!("--{key} needs a value"));
                }
                values.insert(key, argv[i + 1].clone());
                i += 2;
            }
        }
        for a in &cmd.args {
            if !a.is_flag && !values.contains_key(&a.name) {
                return ParseOutcome::Error(format!(
                    "missing required option --{} for '{}'",
                    a.name, cmd.name
                ));
            }
        }
        ParseOutcome::Run(Matches {
            command: cmd.name.clone(),
            values,
            flags,
            free,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("t", "test app").command(
            Command::new("serve", "run server")
                .opt("port", "8080", "port to listen on")
                .required("model", "artifact name")
                .flag("verbose", "chatty"),
        )
    }

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_values() {
        let m = match app().parse(&args(&["serve", "--model", "fwd", "--verbose"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!("expected run"),
        };
        assert_eq!(m.get("port"), "8080");
        assert_eq!(m.get("model"), "fwd");
        assert!(m.get_flag("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let m = match app().parse(&args(&["serve", "--model=x", "--port=9"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!(),
        };
        assert_eq!(m.get_usize("port"), 9);
    }

    #[test]
    fn get_opt_distinguishes_empty_from_set() {
        let app = App::new("t", "x").command(
            Command::new("run", "r")
                .opt("data", "", "optional path")
                .opt("port", "8080", "port"),
        );
        let m = match app.parse(&args(&["run"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!(),
        };
        assert_eq!(m.get_opt("data"), None);
        assert_eq!(m.get_opt("port"), Some("8080"));
        let m = match app.parse(&args(&["run", "--data", "x.shard"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!(),
        };
        assert_eq!(m.get_opt("data"), Some("x.shard"));
    }

    #[test]
    fn free_args_collected_in_order_when_opted_in() {
        let app = App::new("t", "x").command(
            Command::new("compare", "diff reports")
                .flag("strict", "fail on regression")
                .free_args("OLD NEW — report files to diff"),
        );
        let m = match app.parse(&args(&["compare", "old.json", "--strict", "new.json"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!("expected run"),
        };
        assert_eq!(m.free(), &["old.json".to_string(), "new.json".to_string()]);
        assert!(m.get_flag("strict"));
        // help mentions the positional usage
        match app.parse(&args(&["compare", "--help"])) {
            ParseOutcome::Help(h) => assert!(h.contains("OLD NEW"), "{h}"),
            _ => panic!("expected help"),
        }
    }

    #[test]
    fn positionals_still_rejected_without_opt_in() {
        let m = app().parse(&args(&["serve", "--model", "fwd", "stray"]));
        match m {
            ParseOutcome::Error(e) => assert!(e.contains("stray"), "{e}"),
            _ => panic!("expected error"),
        }
        // and a command that never opted in reports empty free()
        let m = match app().parse(&args(&["serve", "--model", "fwd"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!(),
        };
        assert!(m.free().is_empty());
    }

    #[test]
    fn missing_required_is_error() {
        assert!(matches!(
            app().parse(&args(&["serve"])),
            ParseOutcome::Error(_)
        ));
    }

    #[test]
    fn unknown_command_is_error() {
        assert!(matches!(
            app().parse(&args(&["nope"])),
            ParseOutcome::Error(_)
        ));
    }

    #[test]
    fn hidden_commands_run_but_stay_out_of_usage() {
        let app = App::new("t", "x")
            .command(Command::new("serve", "run server"))
            .command(Command::new("worker", "internal entry point").hidden().opt(
                "id",
                "0",
                "slot index",
            ));
        assert!(!app.usage().contains("worker"), "{}", app.usage());
        let m = match app.parse(&args(&["worker", "--id", "3"])) {
            ParseOutcome::Run(m) => m,
            _ => panic!("hidden command must still parse"),
        };
        assert_eq!(m.get_usize("id"), 3);
        // and still answers --help directly
        assert!(matches!(app.parse(&args(&["worker", "--help"])), ParseOutcome::Help(_)));
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&args(&[])), ParseOutcome::Help(_)));
        assert!(matches!(
            app().parse(&args(&["serve", "--help"])),
            ParseOutcome::Help(_)
        ));
    }
}
