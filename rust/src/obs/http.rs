//! Live introspection server: hand-rolled HTTP/1.1 over
//! `std::net::TcpListener` (the substrate for the absent `hyper`).
//!
//! Started by `simulate --obs-addr 127.0.0.1:9464`, or embedded via
//! [`ObsServer::start`].  One accept thread (non-blocking poll so
//! shutdown never hangs on `accept`), one short-lived handler thread
//! per connection (bodies are small, endpoints are operator-driven),
//! and one background **watermark sampler** polling
//! inflight/queue-depth/resident-bytes into a bounded ring for
//! `/vars`.  `Connection: close` on every response — no keep-alive
//! state machine.
//!
//! | endpoint          | body                                             |
//! |-------------------|--------------------------------------------------|
//! | `/metrics`        | Prometheus text exposition (live snapshot)       |
//! | `/metrics.json`   | JSON snapshot (schema `se2attn-metrics-v1`)      |
//! | `/memory`         | allocator scope table (`?format=json` for JSON)  |
//! | `/trace`          | Chrome trace of the span rings so far            |
//! | `/healthz`        | 200 `ok` / 503 `degraded` (liveness+saturation)  |
//! | `/vars?watch=N`   | last N sampler readings + watermarks (JSON)      |

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ObsConfig;
use crate::coordinator::telemetry::ServerStats;
use crate::jsonio::Json;
use crate::metrics_export::MetricsSnapshot;
use crate::obs::{alloc, memreport};
use crate::trace::Tracer;

/// Data sources the endpoints read from.  Everything is shared-ownership
/// and lock-free to read, so the server can outlive (or predate) the
/// serving [`crate::coordinator::Server`] that populates it.
pub struct ObsSources {
    pub stats: Arc<ServerStats>,
    /// Span rings for `/trace` (`None` when tracing is disabled).
    pub tracer: Option<Arc<Tracer>>,
    /// Per-shard admission-queue capacity
    /// ([`crate::coordinator::admission::AdmissionConfig::max_queue`]);
    /// `queue_depth >= max_queue` flips `/healthz` to 503.  0 disables
    /// the saturation check.
    pub max_queue: usize,
}

/// One `/vars` sampler reading.
#[derive(Clone, Copy, Debug)]
struct Sample {
    /// Milliseconds since the server started.
    t_ms: u64,
    inflight: u64,
    queue_depth: u64,
    /// Total live Rust-heap bytes (all allocator scopes).
    resident_bytes: u64,
    /// Live bytes attributed to the kvcache scope.
    kvcache_bytes: u64,
}

#[derive(Default)]
struct Watermarks {
    inflight: AtomicU64,
    queue_depth: AtomicU64,
    resident_bytes: AtomicU64,
    kvcache_bytes: AtomicU64,
}

struct Shared {
    sources: ObsSources,
    started: Instant,
    interval: Duration,
    history: usize,
    samples: Mutex<VecDeque<Sample>>,
    watermarks: Watermarks,
    stop: AtomicBool,
}

impl Shared {
    fn take_sample(&self) {
        let shards = &self.sources.stats.shards;
        let s = Sample {
            t_ms: self.started.elapsed().as_millis() as u64,
            inflight: shards.iter().map(|s| s.inflight.get()).sum(),
            queue_depth: shards.iter().map(|s| s.queue_depth.get()).sum(),
            resident_bytes: alloc::total_live_bytes(),
            kvcache_bytes: alloc::snapshot(alloc::Scope::KvCache).live_bytes,
        };
        let w = &self.watermarks;
        w.inflight.fetch_max(s.inflight, Ordering::Relaxed);
        w.queue_depth.fetch_max(s.queue_depth, Ordering::Relaxed);
        w.resident_bytes.fetch_max(s.resident_bytes, Ordering::Relaxed);
        w.kvcache_bytes.fetch_max(s.kvcache_bytes, Ordering::Relaxed);
        let mut ring = self.samples.lock().unwrap();
        if ring.len() >= self.history.max(1) {
            ring.pop_front();
        }
        ring.push_back(s);
    }
}

/// Running introspection server.  [`ObsServer::stop`] (or drop) joins
/// the accept and sampler threads; in-flight connection handlers finish
/// on their own (they hold only `Arc<Shared>`).
pub struct ObsServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    sampler: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `cfg.addr` and start serving.  Port 0 binds an ephemeral
    /// port; read the result back from [`ObsServer::addr`].
    pub fn start(cfg: &ObsConfig, sources: ObsSources) -> std::io::Result<ObsServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        // Non-blocking accept + poll loop: shutdown is a flag check away,
        // no self-connect trick needed to unblock `accept`.
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            sources,
            started: Instant::now(),
            interval: cfg.sample_interval.max(Duration::from_millis(10)),
            history: cfg.history.max(1),
            samples: Mutex::new(VecDeque::new()),
            watermarks: Watermarks::default(),
            stop: AtomicBool::new(false),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("se2attn-obs".to_string())
                .spawn(move || accept_loop(listener, shared))?
        };
        let sampler = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("se2attn-obs-sampler".to_string())
                .spawn(move || sampler_loop(shared))?
        };
        Ok(ObsServer {
            addr,
            shared,
            accept: Some(accept),
            sampler: Some(sampler),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, join the server threads.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sampler.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let shared = Arc::clone(&shared);
                // Handler threads are detached: they only touch
                // Arc<Shared>, so they may safely outlive stop().
                let _ = std::thread::Builder::new()
                    .name("se2attn-obs-conn".to_string())
                    .spawn(move || {
                        let _ = handle_conn(stream, &shared);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn sampler_loop(shared: Arc<Shared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        shared.take_sample();
        // sleep in small steps so stop() never waits a full interval
        let mut left = shared.interval;
        while !left.is_zero() && !shared.stop.load(Ordering::SeqCst) {
            let step = left.min(Duration::from_millis(20));
            std::thread::sleep(step);
            left = left.saturating_sub(step);
        }
    }
}

fn handle_conn(mut stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    // read until end of headers (we ignore them) or a sane cap
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() > 8192 {
            return respond(&mut stream, 431, "Request Header Fields Too Large", "text/plain", "");
        }
        match stream.read(&mut buf) {
            Ok(0) => return Ok(()), // peer went away
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => return Ok(()), // timeout / reset: nothing to answer
        }
    }
    let text = String::from_utf8_lossy(&head);
    let request_line = text.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m, t),
        _ => return respond(&mut stream, 400, "Bad Request", "text/plain", "bad request line\n"),
    };
    if method != "GET" {
        return respond(
            &mut stream,
            405,
            "Method Not Allowed",
            "text/plain",
            "only GET is supported\n",
        );
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    route(&mut stream, shared, path, query)
}

fn route(stream: &mut TcpStream, shared: &Shared, path: &str, query: &str) -> std::io::Result<()> {
    let src = &shared.sources;
    match path {
        "/metrics" => {
            let snap = MetricsSnapshot::collect(&src.stats, src.tracer.as_deref());
            respond(
                stream,
                200,
                "OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &snap.to_prometheus(),
            )
        }
        "/metrics.json" => {
            let snap = MetricsSnapshot::collect(&src.stats, src.tracer.as_deref());
            respond(stream, 200, "OK", "application/json", &snap.to_json().to_string())
        }
        "/memory" => {
            let report = memreport::collect();
            if query_param(query, "format") == Some("json") {
                respond(stream, 200, "OK", "application/json", &report.to_json().to_string())
            } else {
                respond(stream, 200, "OK", "text/plain; charset=utf-8", &report.render_table())
            }
        }
        "/trace" => match &src.tracer {
            Some(t) => respond(stream, 200, "OK", "application/json", &t.to_chrome_trace().to_string()),
            None => respond(
                stream,
                404,
                "Not Found",
                "text/plain",
                "tracing disabled (start with trace enabled, e.g. simulate --trace-out)\n",
            ),
        },
        "/healthz" => {
            let (ok, body) = health_report(src);
            if ok {
                respond(stream, 200, "OK", "text/plain; charset=utf-8", &body)
            } else {
                respond(stream, 503, "Service Unavailable", "text/plain; charset=utf-8", &body)
            }
        }
        "/vars" => {
            let watch = query_param(query, "watch")
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(1)
                .clamp(1, shared.history);
            respond(stream, 200, "OK", "application/json", &vars_json(shared, watch).to_string())
        }
        "/" => respond(
            stream,
            200,
            "OK",
            "text/plain; charset=utf-8",
            "se2attn introspection endpoints:\n\
             /metrics        Prometheus text exposition\n\
             /metrics.json   JSON metrics snapshot\n\
             /memory         allocator scope table (?format=json)\n\
             /trace          Chrome trace of the span rings\n\
             /healthz        liveness + queue saturation (503 on degradation)\n\
             /vars?watch=N   sampler time series + watermarks\n",
        ),
        _ => respond(stream, 404, "Not Found", "text/plain", "unknown endpoint (try /)\n"),
    }
}

/// Shard liveness + queue saturation.  Degraded when any shard's worker
/// is not running, or any shard's queue sits at capacity.
fn health_report(src: &ObsSources) -> (bool, String) {
    let shards = &src.stats.shards;
    let mut problems = Vec::new();
    if shards.is_empty() {
        problems.push("no shards registered".to_string());
    }
    for (i, sh) in shards.iter().enumerate() {
        if sh.live.get() == 0 {
            problems.push(format!("shard {i}: worker not running"));
        }
        let depth = sh.queue_depth.get();
        if src.max_queue > 0 && depth >= src.max_queue as u64 {
            problems.push(format!("shard {i}: queue saturated ({depth}/{})", src.max_queue));
        }
    }
    if problems.is_empty() {
        (true, format!("ok: {} shards live\n", shards.len()))
    } else {
        (false, format!("degraded:\n{}\n", problems.join("\n")))
    }
}

fn sample_json(s: &Sample) -> Json {
    Json::obj(vec![
        ("t_ms", Json::Num(s.t_ms as f64)),
        ("inflight", Json::Num(s.inflight as f64)),
        ("queue_depth", Json::Num(s.queue_depth as f64)),
        ("resident_bytes", Json::Num(s.resident_bytes as f64)),
        ("kvcache_bytes", Json::Num(s.kvcache_bytes as f64)),
    ])
}

fn vars_json(shared: &Shared, watch: usize) -> Json {
    let ring = shared.samples.lock().unwrap();
    let tail: Vec<Json> = ring
        .iter()
        .skip(ring.len().saturating_sub(watch))
        .map(sample_json)
        .collect();
    let w = &shared.watermarks;
    drop(ring);
    Json::obj(vec![
        ("interval_ms", Json::Num(shared.interval.as_millis() as f64)),
        ("samples", Json::Arr(tail)),
        (
            "watermarks",
            Json::obj(vec![
                ("inflight", Json::Num(w.inflight.load(Ordering::Relaxed) as f64)),
                ("queue_depth", Json::Num(w.queue_depth.load(Ordering::Relaxed) as f64)),
                ("resident_bytes", Json::Num(w.resident_bytes.load(Ordering::Relaxed) as f64)),
                ("kvcache_bytes", Json::Num(w.kvcache_bytes.load(Ordering::Relaxed) as f64)),
            ]),
        ),
    ])
}

fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_params_parse() {
        assert_eq!(query_param("watch=5", "watch"), Some("5"));
        assert_eq!(query_param("a=1&watch=12&b=2", "watch"), Some("12"));
        assert_eq!(query_param("", "watch"), None);
        assert_eq!(query_param("watch", "watch"), None);
        assert_eq!(query_param("format=json", "format"), Some("json"));
    }

    #[test]
    fn health_flips_on_saturation_and_death() {
        let stats = Arc::new(ServerStats::with_shards(2));
        let src = ObsSources {
            stats: Arc::clone(&stats),
            tracer: None,
            max_queue: 8,
        };
        // both workers up, queues empty -> healthy
        stats.shards[0].live.set(1);
        stats.shards[1].live.set(1);
        let (ok, body) = health_report(&src);
        assert!(ok, "{body}");
        assert!(body.contains("2 shards live"));
        // one queue at capacity -> degraded
        stats.shards[1].queue_depth.set(8);
        let (ok, body) = health_report(&src);
        assert!(!ok);
        assert!(body.contains("shard 1: queue saturated (8/8)"), "{body}");
        // drain the queue, kill a worker -> still degraded
        stats.shards[1].queue_depth.set(0);
        stats.shards[0].live.set(0);
        let (ok, body) = health_report(&src);
        assert!(!ok);
        assert!(body.contains("shard 0: worker not running"), "{body}");
        // recovery
        stats.shards[0].live.set(1);
        assert!(health_report(&src).0);
    }

    #[test]
    fn health_with_no_shards_is_degraded() {
        let src = ObsSources {
            stats: Arc::new(ServerStats::default()),
            tracer: None,
            max_queue: 8,
        };
        let (ok, body) = health_report(&src);
        assert!(!ok);
        assert!(body.contains("no shards registered"), "{body}");
    }

    #[test]
    fn sampler_ring_is_bounded_and_watermarked() {
        let stats = Arc::new(ServerStats::with_shards(1));
        stats.shards[0].inflight.set(3);
        stats.shards[0].queue_depth.set(2);
        let shared = Shared {
            sources: ObsSources {
                stats: Arc::clone(&stats),
                tracer: None,
                max_queue: 8,
            },
            started: Instant::now(),
            interval: Duration::from_millis(10),
            history: 4,
            samples: Mutex::new(VecDeque::new()),
            watermarks: Watermarks::default(),
            stop: AtomicBool::new(false),
        };
        for _ in 0..10 {
            shared.take_sample();
        }
        stats.shards[0].inflight.set(1); // drops below the watermark
        shared.take_sample();
        assert_eq!(shared.samples.lock().unwrap().len(), 4, "ring must cap at history");
        let doc = Json::parse(&vars_json(&shared, 3).to_string()).expect("vars json parses");
        let samples = doc.get("samples").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(samples.len(), 3);
        let last = samples.last().unwrap();
        assert_eq!(last.get("inflight").and_then(|v| v.as_f64()), Some(1.0));
        let peak = doc
            .get("watermarks")
            .and_then(|w| w.get("inflight"))
            .and_then(|v| v.as_f64());
        assert_eq!(peak, Some(3.0), "watermark must retain the peak");
        assert!(
            last.get("resident_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "resident bytes should never read zero on a live process"
        );
    }
}
