//! Memory attribution report: scope table rendering, `memmodel`
//! cross-checks, and the linear-memory growth audit.
//!
//! The tracking allocator ([`super::alloc`]) answers *"how many bytes
//! does subsystem X hold right now?"*; this module answers the two
//! questions the paper's claim actually needs:
//!
//! 1. **Do measured bytes agree with the byte model?**
//!    [`crosscheck`] compares a scope's live bytes against a
//!    [`crate::attention::memmodel`] prediction (e.g. the kvcache scope
//!    against `Σ window_cache_bytes(session)`), with a tolerance that
//!    absorbs allocator headers and container capacity rounding.
//! 2. **Does the measured peak grow linearly in scene size?**
//!    [`record_peak_sample`] accumulates `(N, measured peak bytes)`
//!    pairs from sweeps (benches, tests, operators poking `/memory`);
//!    [`audit`] fits a log-log growth exponent over them — ~1 for the
//!    paper's Algorithm 2, ~2 for an accidental O(N·M) materialization.
//!    The exponent is exported as `se2attn_mem_audit_exponent_centi`
//!    and shown by the `/memory` endpoint.

use std::sync::Mutex;

use crate::jsonio::Json;

use super::alloc::{self, Scope, ScopeSnapshot, N_SCOPES};

// ---------------------------------------------------------------------------
// Scope table report
// ---------------------------------------------------------------------------

/// A point-in-time view of the allocator's scope table plus the growth
/// audit, renderable as an aligned text table (`/memory`) or JSON
/// (`/memory?format=json`).
#[derive(Clone, Debug)]
pub struct MemReport {
    pub scopes: [ScopeSnapshot; N_SCOPES],
    pub total_live_bytes: u64,
    pub audit: Option<GrowthAudit>,
}

/// Collect the current report (relaxed atomic loads — safe while
/// serving).
pub fn collect() -> MemReport {
    MemReport {
        scopes: alloc::snapshot_all(),
        total_live_bytes: alloc::total_live_bytes(),
        audit: audit(),
    }
}

impl MemReport {
    /// Plain-text attribution table (the `/memory` endpoint body).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16} {:>14} {:>14} {:>12} {:>12}\n",
            "scope", "live_bytes", "peak_bytes", "allocs", "frees"
        ));
        for s in &self.scopes {
            out.push_str(&format!(
                "{:<16} {:>14} {:>14} {:>12} {:>12}\n",
                s.scope.name(),
                s.live_bytes,
                s.peak_bytes,
                s.allocs,
                s.frees
            ));
        }
        out.push_str(&format!("{:<16} {:>14}\n", "total_live", self.total_live_bytes));
        match &self.audit {
            Some(a) => out.push_str(&format!(
                "linear_audit: exponent {:.2} over {} samples — {}\n",
                a.exponent,
                a.samples,
                if a.is_linear() {
                    "linear (O(N))"
                } else {
                    "SUPERLINEAR — possible O(N*M) materialization"
                }
            )),
            None => out.push_str("linear_audit: no peak samples recorded\n"),
        }
        out
    }

    /// JSON rendering of the same table.
    pub fn to_json(&self) -> Json {
        let scopes = self
            .scopes
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("scope", Json::Str(s.scope.name().to_string())),
                    ("live_bytes", Json::Num(s.live_bytes as f64)),
                    ("peak_bytes", Json::Num(s.peak_bytes as f64)),
                    ("allocs", Json::Num(s.allocs as f64)),
                    ("frees", Json::Num(s.frees as f64)),
                ])
            })
            .collect();
        let audit = match &self.audit {
            Some(a) => Json::obj(vec![
                ("exponent", Json::Num(a.exponent)),
                ("samples", Json::Num(a.samples as f64)),
                ("linear", Json::Bool(a.is_linear())),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("scopes", Json::Arr(scopes)),
            ("total_live_bytes", Json::Num(self.total_live_bytes as f64)),
            ("linear_audit", audit),
        ])
    }
}

// ---------------------------------------------------------------------------
// memmodel cross-check
// ---------------------------------------------------------------------------

/// Measured-vs-modeled comparison for one scope.
#[derive(Clone, Copy, Debug)]
pub struct CrossCheck {
    pub scope: Scope,
    /// Live bytes the allocator attributes to the scope.
    pub measured_bytes: u64,
    /// Bytes the `memmodel` formulas predict for the same contents.
    pub modeled_bytes: u64,
}

impl CrossCheck {
    /// measured / modeled (∞ when the model predicts zero but bytes
    /// exist; 1.0 when both are zero).
    pub fn ratio(&self) -> f64 {
        if self.modeled_bytes == 0 {
            if self.measured_bytes == 0 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            self.measured_bytes as f64 / self.modeled_bytes as f64
        }
    }

    /// True when measured is within `tol` relative error of modeled
    /// (`tol = 0.1` is the shipped gate: allocator headers and Vec
    /// capacity rounding live inside it).
    pub fn within(&self, tol: f64) -> bool {
        (self.ratio() - 1.0).abs() <= tol
    }
}

/// Compare a scope's current live bytes against a byte-model
/// prediction computed by the caller (the caller knows which sessions/
/// rings/windows are resident; the allocator only knows bytes).
pub fn crosscheck(scope: Scope, modeled_bytes: usize) -> CrossCheck {
    CrossCheck {
        scope,
        measured_bytes: alloc::snapshot(scope).live_bytes,
        modeled_bytes: modeled_bytes as u64,
    }
}

// ---------------------------------------------------------------------------
// Linear-memory growth audit
// ---------------------------------------------------------------------------

/// Result of fitting `peak_bytes ~ N^exponent` over recorded samples.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GrowthAudit {
    /// Least-squares slope of `ln(peak)` vs `ln(N)`.
    pub exponent: f64,
    pub samples: usize,
}

impl GrowthAudit {
    /// The verdict threshold sits halfway between O(N) and O(N²):
    /// constant offsets pull real linear sweeps slightly above 1, and
    /// sub-quadratic-but-superlinear blowups still deserve a flag.
    pub fn is_linear(&self) -> bool {
        self.exponent < 1.5
    }
}

/// Least-squares growth exponent over `(n, bytes)` points in log-log
/// space.  Returns `None` without at least two distinct positive `n`.
pub fn fit_growth_exponent(samples: &[(f64, f64)]) -> Option<f64> {
    let pts: Vec<(f64, f64)> = samples
        .iter()
        .filter(|(n, b)| *n > 0.0 && *b > 0.0)
        .map(|(n, b)| (n.ln(), b.ln()))
        .collect();
    let k = pts.len() as f64;
    if pts.len() < 2 {
        return None;
    }
    let mean_x = pts.iter().map(|(x, _)| x).sum::<f64>() / k;
    let mean_y = pts.iter().map(|(_, y)| y).sum::<f64>() / k;
    let sxx: f64 = pts.iter().map(|(x, _)| (x - mean_x) * (x - mean_x)).sum();
    let sxy: f64 = pts.iter().map(|(x, y)| (x - mean_x) * (y - mean_y)).sum();
    if sxx <= 0.0 {
        return None; // all samples at the same N
    }
    Some(sxy / sxx)
}

/// Global `(N, peak bytes)` sample store feeding [`audit`].  Bounded so
/// a looping caller cannot grow it without bound.
const MAX_AUDIT_SAMPLES: usize = 64;

static AUDIT_SAMPLES: Mutex<Vec<(u64, u64)>> = Mutex::new(Vec::new());

/// Record one `(scene size, measured peak bytes)` observation for the
/// process-wide linear-memory audit (oldest samples are dropped past
/// [`MAX_AUDIT_SAMPLES`]).
pub fn record_peak_sample(n: usize, peak_bytes: u64) {
    let mut s = AUDIT_SAMPLES.lock().unwrap();
    if s.len() >= MAX_AUDIT_SAMPLES {
        s.remove(0);
    }
    s.push((n as u64, peak_bytes));
}

/// The recorded samples (test/report introspection).
pub fn peak_samples() -> Vec<(u64, u64)> {
    AUDIT_SAMPLES.lock().unwrap().clone()
}

/// Drop all recorded samples (tests isolate their sweeps with this).
pub fn clear_peak_samples() {
    AUDIT_SAMPLES.lock().unwrap().clear();
}

/// Fit the growth exponent over the recorded samples, if any.
pub fn audit() -> Option<GrowthAudit> {
    let samples = peak_samples();
    let pts: Vec<(f64, f64)> = samples
        .iter()
        .map(|(n, b)| (*n as f64, *b as f64))
        .collect();
    fit_growth_exponent(&pts).map(|exponent| GrowthAudit {
        exponent,
        samples: samples.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_linear_and_quadratic_slopes() {
        let lin: Vec<(f64, f64)> = [64.0, 128.0, 256.0, 512.0]
            .iter()
            .map(|n| (*n, 1000.0 * n + 50_000.0))
            .collect();
        let e = fit_growth_exponent(&lin).unwrap();
        assert!(e < 1.5, "linear sweep fit {e}");

        let quad: Vec<(f64, f64)> = [64.0, 128.0, 256.0, 512.0]
            .iter()
            .map(|n| (*n, 12.0 * n * n))
            .collect();
        let e = fit_growth_exponent(&quad).unwrap();
        assert!((e - 2.0).abs() < 0.05, "quadratic sweep fit {e}");
        assert!(!GrowthAudit { exponent: e, samples: 4 }.is_linear());
    }

    #[test]
    fn fit_needs_two_distinct_ns() {
        assert_eq!(fit_growth_exponent(&[]), None);
        assert_eq!(fit_growth_exponent(&[(64.0, 1.0)]), None);
        assert_eq!(fit_growth_exponent(&[(64.0, 1.0), (64.0, 2.0)]), None);
        assert_eq!(fit_growth_exponent(&[(0.0, 1.0), (64.0, 2.0)]), None);
    }

    #[test]
    fn crosscheck_ratio_edges() {
        let c = CrossCheck {
            scope: Scope::KvCache,
            measured_bytes: 105,
            modeled_bytes: 100,
        };
        assert!(c.within(0.1));
        assert!(!c.within(0.01));
        let zero = CrossCheck {
            scope: Scope::KvCache,
            measured_bytes: 0,
            modeled_bytes: 0,
        };
        assert!(zero.within(0.1));
        let inf = CrossCheck {
            scope: Scope::KvCache,
            measured_bytes: 7,
            modeled_bytes: 0,
        };
        assert!(!inf.within(0.1));
    }

    #[test]
    fn report_renders_every_scope_and_round_trips_json() {
        let report = collect();
        let table = report.render_table();
        for s in Scope::ALL {
            assert!(table.contains(s.name()), "table missing {}", s.name());
        }
        let doc = Json::parse(&report.to_json().to_string()).expect("report json parses");
        let scopes = doc.get("scopes").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(scopes.len(), N_SCOPES);
        assert!(doc.get("total_live_bytes").and_then(|t| t.as_f64()).is_some());
    }
}
