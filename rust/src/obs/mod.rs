//! Live observability: runtime memory attribution + introspection server.
//!
//! PR 6 gave the stack *post-hoc* observability (trace/metrics files
//! written after a run ends).  This module makes the serving process
//! observable *while it serves*:
//!
//! - [`alloc`] — a `#[global_allocator]` tracking allocator (zero
//!   dependencies) that attributes every heap byte to a small fixed set
//!   of subsystem scopes (`kvcache`, `kernel_scratch`, `map_registry`,
//!   `batcher`, `trace`, plus `untagged` for everything else) via
//!   thread-local scope tags, maintaining per-scope live bytes,
//!   allocation counts and high-water marks.
//! - [`memreport`] — renders the scope table, cross-checks measured
//!   bytes against the [`crate::attention::memmodel`] formulas, and
//!   fits a growth exponent to `(N, measured peak)` samples so the
//!   paper's linear-memory claim is auditable against the *allocator*,
//!   not just the byte model.
//! - [`http`] — a hand-rolled HTTP/1.1 introspection server over
//!   `std::net::TcpListener` (`simulate --obs-addr 127.0.0.1:9464`)
//!   serving `/metrics`, `/metrics.json`, `/memory`, `/trace`,
//!   `/healthz` and `/vars?watch=N` from the live telemetry, tracer
//!   rings and allocator scope table.
//!
//! See DESIGN.md §16 for the attribution invariants and endpoint table.

pub mod alloc;
pub mod http;
pub mod memreport;
