//! Scope-tagged tracking `#[global_allocator]` — runtime memory
//! attribution with zero dependencies.
//!
//! Every heap allocation in the process is routed through
//! [`TrackingAlloc`] (a thin wrapper over [`std::alloc::System`]) and
//! charged to the [`Scope`] active on the allocating thread.  Subsystems
//! tag their allocation sites with a [`MemScope`] guard:
//!
//! ```
//! use se2attn::obs::alloc::{self, MemScope, Scope};
//!
//! let before = alloc::snapshot(Scope::KvCache).live_bytes;
//! let buf = {
//!     let _scope = MemScope::enter("kvcache");
//!     vec![0u8; 4096]
//! };
//! assert!(alloc::snapshot(Scope::KvCache).live_bytes >= before + 4096);
//! drop(buf); // frees are charged to the ORIGINAL scope, not the dropper's
//! assert!(alloc::snapshot(Scope::KvCache).live_bytes < before + 4096);
//! ```
//!
//! **Attribution invariants** (DESIGN.md §16):
//!
//! 1. A block is charged to the scope active *when it was allocated*;
//!    the owning scope id is stamped into a hidden header ahead of the
//!    returned pointer, so the matching `dealloc` credits the same scope
//!    no matter which thread or scope drops the block.  Per-scope
//!    `live_bytes` therefore never underflows and sums to the process'
//!    Rust-heap resident set ([`total_live_bytes`]).
//! 2. The allocator itself never allocates: the scope table is a fixed
//!    static array of atomics, the thread-local tag is a
//!    const-initialized `Cell` (no lazy init), and a thread whose TLS is
//!    already torn down falls back to [`Scope::Untagged`].
//! 3. Bookkeeping is relaxed atomics only — `fetch_add`/`fetch_max` per
//!    alloc, one saturating decrement per free.  `peak_bytes` is a
//!    monotonic high-water mark; [`reset_peak`] re-arms it to the
//!    current live value for region-scoped measurements (meaningful
//!    when the scope is otherwise quiescent).
//!
//! The header costs `max(align, 8)` bytes per allocation — noise for
//! the multi-KiB cache/scratch buffers this attributes, and the reason
//! the `memmodel` cross-check tolerance is 10%, not 0%.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of attribution scopes (including [`Scope::Untagged`]).
pub const N_SCOPES: usize = 6;

/// Subsystem attribution scopes.  A fixed enum, not a registry: the
/// allocator must never allocate, and the serving stack's memory story
/// is exactly these five subsystems plus "everything else".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Scope {
    /// Allocations made outside any tagged region.
    Untagged = 0,
    /// Per-session window caches ([`crate::coordinator::kvcache`]).
    KvCache = 1,
    /// Per-thread kernel scratch ([`crate::attention::kernel`]).
    KernelScratch = 2,
    /// Shared per-scene map rows ([`crate::coordinator::kvcache::MapRegistry`]).
    MapRegistry = 3,
    /// Shard queue envelopes: the serving admission queue + worker
    /// mailbox ([`crate::coordinator::admission`]) and the legacy fixed
    /// batcher ([`crate::coordinator::batcher`]).
    Batcher = 4,
    /// Span rings ([`crate::trace`]).
    Trace = 5,
}

impl Scope {
    /// Every scope, in id order (the order of exported metric rows).
    pub const ALL: [Scope; N_SCOPES] = [
        Scope::Untagged,
        Scope::KvCache,
        Scope::KernelScratch,
        Scope::MapRegistry,
        Scope::Batcher,
        Scope::Trace,
    ];

    /// Stable label used in metrics (`se2attn_mem_*{scope="..."}`),
    /// the `/memory` table, and [`MemScope::enter`] tags.
    pub fn name(self) -> &'static str {
        match self {
            Scope::Untagged => "untagged",
            Scope::KvCache => "kvcache",
            Scope::KernelScratch => "kernel_scratch",
            Scope::MapRegistry => "map_registry",
            Scope::Batcher => "batcher",
            Scope::Trace => "trace",
        }
    }

    /// Inverse of [`Scope::name`].
    pub fn from_tag(tag: &str) -> Option<Scope> {
        Scope::ALL.into_iter().find(|s| s.name() == tag)
    }

    fn from_id(id: u8) -> Scope {
        Scope::ALL
            .get(id as usize)
            .copied()
            .unwrap_or(Scope::Untagged)
    }
}

thread_local! {
    // Const-initialized so the first access from inside `alloc` cannot
    // itself allocate (plain ELF TLS slot, no lazy registration path
    // that touches the heap).
    static CURRENT: Cell<u8> = const { Cell::new(0) };
}

/// The scope active on the calling thread ([`Scope::Untagged`] when no
/// guard is live, or during thread teardown).
pub fn current_scope() -> Scope {
    Scope::from_id(CURRENT.try_with(Cell::get).unwrap_or(0))
}

/// RAII scope tag: allocations on this thread are charged to the given
/// scope until the guard drops (restoring the previous tag, so guards
/// nest).  Not `Send` — the tag is thread-local by construction.
pub struct MemScope {
    prev: u8,
    _not_send: PhantomData<*const ()>,
}

impl MemScope {
    /// Enter a scope by tag name.  Panics on an unknown tag — tags are
    /// source literals, so a typo should fail loudly in tests.
    pub fn enter(tag: &str) -> MemScope {
        match Scope::from_tag(tag) {
            Some(s) => MemScope::enter_scope(s),
            None => panic!("unknown memory scope tag {tag:?}"),
        }
    }

    /// Enter a scope by value (used for cross-thread propagation:
    /// [`crate::exec::ScopedPool`] re-enters the submitting thread's
    /// scope on every participating worker).
    pub fn enter_scope(scope: Scope) -> MemScope {
        let prev = CURRENT.try_with(|c| c.replace(scope as u8)).unwrap_or(0);
        MemScope {
            prev,
            _not_send: PhantomData,
        }
    }
}

impl Drop for MemScope {
    fn drop(&mut self) {
        let _ = CURRENT.try_with(|c| c.set(self.prev));
    }
}

struct ScopeCounters {
    live: AtomicU64,
    peak: AtomicU64,
    allocs: AtomicU64,
    frees: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const ZERO_COUNTERS: ScopeCounters = ScopeCounters {
    live: AtomicU64::new(0),
    peak: AtomicU64::new(0),
    allocs: AtomicU64::new(0),
    frees: AtomicU64::new(0),
};

static SCOPES: [ScopeCounters; N_SCOPES] = [ZERO_COUNTERS; N_SCOPES];

/// One scope's counters, read with relaxed loads (safe concurrent with
/// serving; values are eventually consistent across fields).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScopeSnapshot {
    pub scope: Scope,
    /// Bytes currently allocated and not yet freed under this scope.
    pub live_bytes: u64,
    /// High-water mark of `live_bytes` since start (or [`reset_peak`]).
    pub peak_bytes: u64,
    /// Total allocations charged to this scope.
    pub allocs: u64,
    /// Total frees credited to this scope.
    pub frees: u64,
}

/// Snapshot one scope.
pub fn snapshot(scope: Scope) -> ScopeSnapshot {
    let c = &SCOPES[scope as usize];
    ScopeSnapshot {
        scope,
        live_bytes: c.live.load(Ordering::Relaxed),
        peak_bytes: c.peak.load(Ordering::Relaxed),
        allocs: c.allocs.load(Ordering::Relaxed),
        frees: c.frees.load(Ordering::Relaxed),
    }
}

/// Snapshot every scope in id order.
pub fn snapshot_all() -> [ScopeSnapshot; N_SCOPES] {
    Scope::ALL.map(snapshot)
}

/// Re-arm a scope's high-water mark to its current live bytes, for
/// region-scoped peak measurements (the N-sweep linear-memory audit).
/// Racy against concurrent allocation in the same scope — callers own
/// the scope's quiescence.
pub fn reset_peak(scope: Scope) {
    let c = &SCOPES[scope as usize];
    c.peak.store(c.live.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Total live Rust-heap bytes across all scopes (the process' resident
/// heap as the allocator sees it — mmap'd stacks and C allocations are
/// out of scope).
pub fn total_live_bytes() -> u64 {
    SCOPES.iter().map(|c| c.live.load(Ordering::Relaxed)).sum()
}

/// Tracking allocator: `System` plus a scope header and per-scope
/// counters.  Installed process-wide below; never instantiate another.
pub struct TrackingAlloc;

// The returned pointer must satisfy `layout.align()`, and the 8-byte
// scope header must sit immediately below it.  `align.max(8)` is a
// multiple of `align` for every power-of-two align (8 is a multiple of
// 1/2/4/8; larger aligns use themselves), so `base + offset` keeps the
// caller's alignment and `base + offset - 8` is always inside the block.
#[inline]
fn tag_offset(align: usize) -> usize {
    align.max(8)
}

#[inline]
fn padded_layout(layout: Layout) -> Option<(Layout, usize)> {
    let off = tag_offset(layout.align());
    let size = layout.size().checked_add(off)?;
    Layout::from_size_align(size, layout.align())
        .ok()
        .map(|l| (l, off))
}

/// Stamp the owning scope into the header and charge the counters.
///
/// # Safety
/// `base` must be a live allocation of at least `off + size` bytes (or
/// null, which is passed through untouched).
unsafe fn finish_alloc(base: *mut u8, off: usize, size: usize) -> *mut u8 {
    if base.is_null() {
        return base;
    }
    let id = CURRENT.try_with(Cell::get).unwrap_or(0);
    // The header slot is 8-aligned only when the caller's align is >= 8;
    // write_unaligned keeps align-1 allocations sound.
    (base.add(off - 8) as *mut u64).write_unaligned(id as u64);
    let c = &SCOPES[id as usize];
    let now = c.live.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    c.peak.fetch_max(now, Ordering::Relaxed);
    c.allocs.fetch_add(1, Ordering::Relaxed);
    base.add(off)
}

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        match padded_layout(layout) {
            Some((padded, off)) => finish_alloc(System.alloc(padded), off, layout.size()),
            None => std::ptr::null_mut(),
        }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        match padded_layout(layout) {
            Some((padded, off)) => finish_alloc(System.alloc_zeroed(padded), off, layout.size()),
            None => std::ptr::null_mut(),
        }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        let off = tag_offset(layout.align());
        let id = (ptr.sub(8) as *const u64).read_unaligned();
        // A corrupted header (caller buffer underflow) degrades to
        // untagged attribution instead of indexing out of bounds.
        let id = if id < N_SCOPES as u64 { id as usize } else { 0 };
        let n = layout.size() as u64;
        let c = &SCOPES[id];
        // Saturating decrement: the header invariant makes underflow
        // impossible in correct programs, but a stomped header must not
        // wrap the gauge to 2^64.
        let _ = c
            .live
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
        c.frees.fetch_add(1, Ordering::Relaxed);
        let padded = Layout::from_size_align_unchecked(layout.size() + off, layout.align());
        System.dealloc(ptr.sub(off), padded);
    }

    // `realloc` uses the default alloc+copy+dealloc path: the old block
    // is credited to its original scope via its header, the new block is
    // charged to the reallocating thread's current scope.
}

/// The process-wide allocator.  Lives in the library so every consumer
/// (serving binary, benches, integration tests) gets attribution
/// without opting in.
#[global_allocator]
static GLOBAL: TrackingAlloc = TrackingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    // Attribution tests use large blocks and signed-delta assertions so
    // concurrent tests (which allocate KiBs, not MiBs, in these scopes)
    // cannot flake them.
    const BIG: usize = 8 << 20;
    const SLACK: i64 = 1 << 20;

    fn live(scope: Scope) -> i64 {
        snapshot(scope).live_bytes as i64
    }

    #[test]
    fn scoped_allocation_is_charged_and_credited() {
        let before = live(Scope::MapRegistry);
        let allocs_before = snapshot(Scope::MapRegistry).allocs;
        let buf = {
            let _g = MemScope::enter("map_registry");
            vec![0u8; BIG]
        };
        let mid = live(Scope::MapRegistry);
        assert!(
            mid - before >= BIG as i64 && mid - before <= BIG as i64 + SLACK,
            "live delta {} outside [{BIG}, {BIG}+slack]",
            mid - before
        );
        assert!(snapshot(Scope::MapRegistry).allocs > allocs_before);
        assert!(snapshot(Scope::MapRegistry).peak_bytes as i64 >= mid);
        // dropping OUTSIDE the scope still credits the owning scope
        drop(buf);
        let after = live(Scope::MapRegistry);
        assert!(
            mid - after >= BIG as i64 - SLACK,
            "free not credited: mid {mid} after {after}"
        );
    }

    #[test]
    fn scopes_nest_and_restore() {
        assert_eq!(current_scope(), Scope::Untagged);
        {
            let _a = MemScope::enter("kvcache");
            assert_eq!(current_scope(), Scope::KvCache);
            {
                let _b = MemScope::enter_scope(Scope::Trace);
                assert_eq!(current_scope(), Scope::Trace);
            }
            assert_eq!(current_scope(), Scope::KvCache);
        }
        assert_eq!(current_scope(), Scope::Untagged);
    }

    #[test]
    #[should_panic(expected = "unknown memory scope tag")]
    fn unknown_tag_panics() {
        let _ = MemScope::enter("no-such-scope");
    }

    #[test]
    fn tag_names_round_trip() {
        for s in Scope::ALL {
            assert_eq!(Scope::from_tag(s.name()), Some(s));
        }
        assert_eq!(Scope::from_tag("bogus"), None);
        // id order is stable — the metrics rows depend on it
        for (i, s) in Scope::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i);
        }
    }

    #[test]
    fn high_alignment_allocations_stay_aligned() {
        #[repr(align(256))]
        struct Page([u8; 256]);
        let _g = MemScope::enter_scope(Scope::Trace);
        let boxes: Vec<Box<Page>> = (0..8).map(|_| Box::new(Page([7u8; 256]))).collect();
        for b in &boxes {
            let p = b.as_ref() as *const Page as usize;
            assert_eq!(p % 256, 0, "tracking header broke alignment");
            assert_eq!(b.0[0], 7, "payload stomped by the scope header");
        }
    }

    #[test]
    fn grown_vec_keeps_books_balanced() {
        // realloc path: grow a Vec through several doublings, then drop;
        // the scope must return to (near) its starting live bytes.
        let before = live(Scope::Batcher);
        {
            let _g = MemScope::enter("batcher");
            let mut v: Vec<u64> = Vec::new();
            for i in 0..(1 << 18) {
                v.push(i);
            }
            assert!(live(Scope::Batcher) - before >= (1 << 21));
        }
        let after = live(Scope::Batcher);
        assert!(
            (after - before).abs() <= SLACK,
            "leaked {} bytes through realloc",
            after - before
        );
    }

    #[test]
    fn total_live_bytes_covers_all_scopes() {
        // untagged allocation on purpose: the total must cover scope 0
        // too (and staying off the tagged scopes keeps this test from
        // racing the per-scope peak assertions running in parallel)
        let before = total_live_bytes() as i64;
        let buf = vec![0u8; BIG];
        let after = total_live_bytes() as i64;
        assert!(after - before >= BIG as i64 - SLACK, "total missed a scope");
        drop(buf);
    }

    #[test]
    fn reset_peak_rearms_the_watermark() {
        let _g = MemScope::enter("kernel_scratch");
        // drive the watermark up, release, then re-arm: the new peak
        // must track the NEXT region, not the historical maximum
        let big = vec![0u8; BIG];
        drop(big);
        reset_peak(Scope::KernelScratch);
        let rearmed = snapshot(Scope::KernelScratch).peak_bytes as i64;
        let small = vec![0u8; 1024];
        let peak = snapshot(Scope::KernelScratch).peak_bytes as i64;
        assert!(
            peak - rearmed < SLACK,
            "re-armed peak {peak} still reflects the old {BIG}-byte region (base {rearmed})"
        );
        drop(small);
    }
}
