//! Structured tracing for the serving path (DESIGN.md §15).
//!
//! Every request admitted through [`crate::coordinator::Server::submit`] is
//! assigned a **trace id**, and each pipeline stage it passes through —
//! route → enqueue → batch → tokenize → decode → attend → respond — records
//! a [`Span`] into a lock-free per-thread ring buffer.  The rings are
//! pre-allocated ([`TraceConfig::ring_spans`] slots each), so the hot path
//! never allocates: recording a span is one `fetch_add` on the ring head
//! plus four relaxed atomic stores.  When a ring wraps, the oldest spans
//! are overwritten and a `dropped` counter is bumped — memory stays bounded
//! no matter how long the server runs.
//!
//! Exported traces use the Chrome `trace_event` JSON format (an object with
//! a `traceEvents` array of complete `"ph":"X"` events, timestamps in
//! microseconds), which loads directly into `chrome://tracing` or Perfetto:
//! each shard worker appears as one track (`tid` = shard + 1, `tid` 0 is
//! the front-end submit path), and the `args.trace` field on every slice
//! carries the request's trace id so a single request can be followed
//! across tracks.
//!
//! The whole subsystem is off by default.  Disabled cost is a single
//! relaxed atomic load + branch per potential span (the global [`enabled`]
//! gate); no thread-local is touched until tracing is actually on.
//!
//! Kernel profiling ([`ProfileConfig`], [`KernelProfile`]) lives here too:
//! the flash kernel and the KV cache flush per-call counters (blocks
//! skipped, rows dequantized, scratch bytes, per-thread work share,
//! evictions) into a global profile when profiling is enabled, again behind
//! one branch when it is not.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::jsonio::Json;

// --------------------------------------------------------------------------
// stages
// --------------------------------------------------------------------------

/// Pipeline stage a span belongs to.  The discriminant is packed into the
/// span's meta word, so variants must stay `< 256`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Front-end: shard selection + channel send in `Server::submit`.
    Route = 0,
    /// Queue residency: submit time → the shard worker picking the
    /// envelope out of its batch (recorded when the batch runs).
    Enqueue = 1,
    /// One `run_batch` invocation on a shard worker (all envelopes).
    Batch = 2,
    /// Per-step cache lookup + tokenization for a batch chunk.
    Tokenize = 3,
    /// One `ActionDecoder::decode` call for a batch chunk.
    Decode = 4,
    /// One `flash_sdpa_rows` kernel invocation.
    Attend = 5,
    /// Serialization + response channel send for one envelope.
    Respond = 6,
    /// Instant event: a KV-cache session or map eviction.
    CacheEvict = 7,
    /// Instant event: a session migration between worker processes
    /// (arg = KV blob bytes shipped).
    Migrate = 8,
}

impl Stage {
    /// All stages, in pipeline order (used by trace validation).
    pub const ALL: [Stage; 9] = [
        Stage::Route,
        Stage::Enqueue,
        Stage::Batch,
        Stage::Tokenize,
        Stage::Decode,
        Stage::Attend,
        Stage::Respond,
        Stage::CacheEvict,
        Stage::Migrate,
    ];

    /// Stages every traced `simulate` run must produce (CacheEvict only
    /// appears under cache pressure, so it is excluded).
    pub const PIPELINE: [Stage; 7] = [
        Stage::Route,
        Stage::Enqueue,
        Stage::Batch,
        Stage::Tokenize,
        Stage::Decode,
        Stage::Attend,
        Stage::Respond,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Route => "route",
            Stage::Enqueue => "enqueue",
            Stage::Batch => "batch",
            Stage::Tokenize => "tokenize",
            Stage::Decode => "decode",
            Stage::Attend => "attend",
            Stage::Respond => "respond",
            Stage::CacheEvict => "cache_evict",
            Stage::Migrate => "migrate",
        }
    }

    fn from_u8(v: u8) -> Option<Stage> {
        Stage::ALL.into_iter().find(|s| *s as u8 == v)
    }
}

// --------------------------------------------------------------------------
// span ring
// --------------------------------------------------------------------------

/// A decoded span, as returned by [`SpanRing::drain`] / [`Tracer::spans`].
#[derive(Clone, Debug)]
pub struct Span {
    pub stage: Stage,
    /// Trace id of the request this span belongs to (0 = not tied to a
    /// single request, e.g. a whole-batch span).
    pub trace_id: u64,
    /// Ring (track) the span was recorded on: 0 = front-end, `s + 1` =
    /// shard `s`.
    pub track: usize,
    /// Start offset from the tracer epoch, microseconds.
    pub start_us: u64,
    /// Duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// Stage-specific payload: batch size for `Batch`, rows for `Attend`,
    /// bytes for `CacheEvict`, 0 otherwise.
    pub arg: u64,
}

/// One pre-allocated slot: four atomics written with relaxed stores.  A
/// slot is published by the meta word (bit 63 set = occupied); a
/// torn read under wrap can at worst misattribute one span, never corrupt
/// memory — acceptable for a lossy diagnostic ring.
struct Slot {
    trace_id: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    /// `occupied<<63 | arg<<16 | stage` (arg truncated to 47 bits).
    meta: AtomicU64,
}

const META_OCCUPIED: u64 = 1 << 63;

/// Lock-free bounded span recorder.  Single-producer per shard ring (the
/// shard worker thread); the front-end ring is multi-producer, which the
/// `fetch_add` head makes safe (each producer claims a distinct slot).
pub struct SpanRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
    dropped: AtomicU64,
}

impl SpanRing {
    pub fn new(capacity: usize) -> SpanRing {
        let cap = capacity.max(1);
        let slots = (0..cap)
            .map(|_| Slot {
                trace_id: AtomicU64::new(0),
                start_us: AtomicU64::new(0),
                dur_us: AtomicU64::new(0),
                meta: AtomicU64::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        SpanRing {
            slots,
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record one span.  Allocation-free; overwrites the oldest slot once
    /// the ring has wrapped (counted in [`SpanRing::dropped`]).
    pub fn record(&self, stage: Stage, trace_id: u64, start_us: u64, dur_us: u64, arg: u64) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        if seq >= self.slots.len() as u64 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        slot.trace_id.store(trace_id, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        let meta = META_OCCUPIED | ((arg & ((1 << 47) - 1)) << 16) | stage as u64;
        slot.meta.store(meta, Ordering::Release);
    }

    /// Spans overwritten because the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total spans ever recorded on this ring (including overwritten).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Snapshot every occupied slot, oldest first.
    fn drain(&self, track: usize, out: &mut Vec<Span>) {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let start = head.saturating_sub(cap);
        for seq in start..head {
            let slot = &self.slots[(seq % cap) as usize];
            let meta = slot.meta.load(Ordering::Acquire);
            if meta & META_OCCUPIED == 0 {
                continue;
            }
            let Some(stage) = Stage::from_u8((meta & 0xff) as u8) else {
                continue;
            };
            out.push(Span {
                stage,
                trace_id: slot.trace_id.load(Ordering::Relaxed),
                track,
                start_us: slot.start_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
                arg: (meta >> 16) & ((1 << 47) - 1),
            });
        }
    }
}

// --------------------------------------------------------------------------
// global gate + thread context
// --------------------------------------------------------------------------

/// Number of live [`Tracer`]s.  The fast-path check for "is tracing on at
/// all" is a relaxed load of this counter — one branch when disabled, no
/// thread-local access.
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

/// Kernel/cache profiling gate (see [`ProfileConfig`]).
static PROFILING: AtomicUsize = AtomicUsize::new(0);

/// True when at least one tracer is live.  This is the one-branch disabled
/// path: callers must check it before touching the thread-local context.
#[inline]
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed) != 0
}

/// True when kernel profiling is on.
#[inline]
pub fn profiling() -> bool {
    PROFILING.load(Ordering::Relaxed) != 0
}

struct ThreadCtx {
    ring: Arc<SpanRing>,
    epoch: Instant,
    /// Trace id attributed to subsequently recorded spans (0 = none).
    trace_id: u64,
}

thread_local! {
    static CTX: std::cell::RefCell<Option<ThreadCtx>> =
        const { std::cell::RefCell::new(None) };
}

/// Bind the calling thread to `ring` for the lifetime of the returned
/// guard.  Shard workers call this once at startup; span helpers are
/// no-ops on threads with no installed context.
pub fn install(ring: Arc<SpanRing>, epoch: Instant) -> CtxGuard {
    CTX.with(|c| {
        *c.borrow_mut() = Some(ThreadCtx {
            ring,
            epoch,
            trace_id: 0,
        });
    });
    CtxGuard
}

/// Uninstalls the thread context on drop (keeps rings from outliving the
/// tracer through detached thread-locals).
pub struct CtxGuard;

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CTX.with(|c| *c.borrow_mut() = None);
    }
}

/// Set the trace id attributed to spans recorded by this thread until the
/// next call (0 clears).  Cheap; called per envelope inside a batch.
pub fn set_trace_id(trace_id: u64) {
    if !enabled() {
        return;
    }
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.trace_id = trace_id;
        }
    });
}

#[inline]
fn micros_since(epoch: Instant, t: Instant) -> u64 {
    t.saturating_duration_since(epoch).as_micros() as u64
}

/// Record a complete span covering `t0 → now` on the calling thread's
/// ring.  One branch + early return when tracing is disabled.
#[inline]
pub fn record_since(stage: Stage, t0: Instant, arg: u64) {
    if !enabled() {
        return;
    }
    record_since_slow(stage, t0, arg);
}

#[cold]
fn record_since_slow(stage: Stage, t0: Instant, arg: u64) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow().as_ref() {
            let start = micros_since(ctx.epoch, t0);
            let end = micros_since(ctx.epoch, Instant::now());
            ctx.ring
                .record(stage, ctx.trace_id, start, end.saturating_sub(start), arg);
        }
    });
}

/// Record a complete span with explicit endpoints (used for queue
/// residency, where the start predates the worker picking up the item).
pub fn record_between(stage: Stage, t0: Instant, t1: Instant, trace_id: u64, arg: u64) {
    if !enabled() {
        return;
    }
    CTX.with(|c| {
        if let Some(ctx) = c.borrow().as_ref() {
            let start = micros_since(ctx.epoch, t0);
            let end = micros_since(ctx.epoch, t1);
            ctx.ring
                .record(stage, trace_id, start, end.saturating_sub(start), arg);
        }
    });
}

/// Record an instant (zero-duration) event on the calling thread's ring.
#[inline]
pub fn instant(stage: Stage, arg: u64) {
    if !enabled() {
        return;
    }
    instant_slow(stage, arg);
}

#[cold]
fn instant_slow(stage: Stage, arg: u64) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow().as_ref() {
            let now = micros_since(ctx.epoch, Instant::now());
            ctx.ring.record(stage, ctx.trace_id, now, 0, arg);
        }
    });
}

// --------------------------------------------------------------------------
// tracer
// --------------------------------------------------------------------------

/// Tracing configuration carried by `ServeConfig`.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Master switch.  Off by default; when off the server allocates no
    /// rings and the per-span cost is one branch.
    pub enabled: bool,
    /// Slots per ring (one ring per shard + one front-end ring).  Each
    /// slot is 32 bytes, so the default 16384 costs 512 KiB per ring.
    pub ring_spans: usize,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig {
            enabled: false,
            ring_spans: 16_384,
        }
    }
}

/// Owns the span rings for one server: ring 0 is the front-end (submit
/// path, multi-producer), rings `1..=shards` belong to shard workers.
/// Construction bumps the global [`enabled`] gate; drop releases it.
pub struct Tracer {
    epoch: Instant,
    rings: Vec<Arc<SpanRing>>,
    next_trace: AtomicU64,
}

impl Tracer {
    pub fn new(shards: usize, cfg: TraceConfig) -> Arc<Tracer> {
        // span rings are the tracer's only resident allocation; charge
        // them to the trace scope in the memory attribution table
        let _mem = crate::obs::alloc::MemScope::enter("trace");
        let rings = (0..shards + 1)
            .map(|_| Arc::new(SpanRing::new(cfg.ring_spans)))
            .collect();
        ACTIVE.fetch_add(1, Ordering::Relaxed);
        Arc::new(Tracer {
            epoch: Instant::now(),
            rings,
            next_trace: AtomicU64::new(1),
        })
    }

    /// Mint a fresh per-request trace id.  This is the only atomic the
    /// submit path touches, and only when tracing is enabled — the
    /// `ShardRouter` itself stays stateless.
    pub fn mint(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// The front-end ring (track 0).
    pub fn frontend_ring(&self) -> Arc<SpanRing> {
        self.rings[0].clone()
    }

    /// Shard `s`'s ring (track `s + 1`).
    pub fn shard_ring(&self, shard: usize) -> Arc<SpanRing> {
        self.rings[shard + 1].clone()
    }

    /// Record a span on the front-end ring from an arbitrary caller
    /// thread (no thread-local context required).
    pub fn record_frontend(&self, stage: Stage, t0: Instant, trace_id: u64, arg: u64) {
        let start = micros_since(self.epoch, t0);
        let end = micros_since(self.epoch, Instant::now());
        self.rings[0].record(stage, trace_id, start, end.saturating_sub(start), arg);
    }

    /// Total spans recorded / dropped across all rings.
    pub fn totals(&self) -> (u64, u64) {
        let mut rec = 0;
        let mut drop = 0;
        for r in &self.rings {
            rec += r.recorded();
            drop += r.dropped();
        }
        (rec, drop)
    }

    /// Snapshot all retained spans, oldest-first per track.
    pub fn spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for (track, ring) in self.rings.iter().enumerate() {
            ring.drain(track, &mut out);
        }
        out
    }

    /// Export as a Chrome `trace_event` document (`chrome://tracing` /
    /// Perfetto).  Complete events (`"ph":"X"`), timestamps in µs, one
    /// `tid` per track; `args.trace` carries the request trace id.
    pub fn to_chrome_trace(&self) -> Json {
        let (recorded, dropped) = self.totals();
        let mut events: Vec<Json> = Vec::new();
        for (track, name) in self.track_names() {
            events.push(Json::obj(vec![
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(track as f64)),
                ("args", Json::obj(vec![("name", Json::Str(name))])),
            ]));
        }
        for s in self.spans() {
            let ph = if matches!(s.stage, Stage::CacheEvict | Stage::Migrate) {
                "i"
            } else {
                "X"
            };
            events.push(Json::obj(vec![
                ("name", Json::Str(s.stage.name().into())),
                ("cat", Json::Str("serve".into())),
                ("ph", Json::Str(ph.into())),
                ("ts", Json::Num(s.start_us as f64)),
                ("dur", Json::Num(s.dur_us as f64)),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(s.track as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("trace", Json::Num(s.trace_id as f64)),
                        ("arg", Json::Num(s.arg as f64)),
                    ]),
                ),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
            (
                "otherData",
                Json::obj(vec![
                    ("spans_recorded", Json::Num(recorded as f64)),
                    ("spans_dropped", Json::Num(dropped as f64)),
                ]),
            ),
        ])
    }

    fn track_names(&self) -> Vec<(usize, String)> {
        (0..self.rings.len())
            .map(|t| {
                let name = if t == 0 {
                    "frontend".to_string()
                } else {
                    format!("shard-{}", t - 1)
                };
                (t, name)
            })
            .collect()
    }

    /// Write the Chrome trace JSON to `path`.
    pub fn write_chrome_trace(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_trace().to_string())
    }
}

impl Drop for Tracer {
    fn drop(&mut self) {
        ACTIVE.fetch_sub(1, Ordering::Relaxed);
    }
}

// --------------------------------------------------------------------------
// kernel profiling
// --------------------------------------------------------------------------

/// Kernel/cache profiling switch carried by `ServeConfig` and the CLI.
/// When disabled, the kernel's per-call accounting costs one branch at
/// flush time (counters live in registers either way).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProfileConfig {
    pub enabled: bool,
}

/// RAII guard enabling the global profiling gate.
pub struct ProfileGuard;

impl ProfileGuard {
    pub fn enable() -> ProfileGuard {
        PROFILING.fetch_add(1, Ordering::Relaxed);
        ProfileGuard
    }
}

impl Drop for ProfileGuard {
    fn drop(&mut self) {
        PROFILING.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Global flash-kernel + cache profile.  All counters are cumulative since
/// process start; [`KernelProfile::snapshot`] copies them, and deltas
/// between snapshots isolate a measurement window.
#[derive(Default)]
pub struct KernelProfileAtomics {
    pub calls: AtomicU64,
    pub rows: AtomicU64,
    pub key_blocks_visited: AtomicU64,
    pub key_blocks_skipped: AtomicU64,
    pub rows_dequantized: AtomicU64,
    pub scratch_bytes: AtomicU64,
    /// Work chunks executed (per-thread work share = chunks / participants).
    pub chunks: AtomicU64,
    /// Threads that participated across all calls.
    pub participants: AtomicU64,
    pub cache_session_evictions: AtomicU64,
    pub cache_map_evictions: AtomicU64,
}

static KERNEL_PROFILE: KernelProfileAtomics = KernelProfileAtomics {
    calls: AtomicU64::new(0),
    rows: AtomicU64::new(0),
    key_blocks_visited: AtomicU64::new(0),
    key_blocks_skipped: AtomicU64::new(0),
    rows_dequantized: AtomicU64::new(0),
    scratch_bytes: AtomicU64::new(0),
    chunks: AtomicU64::new(0),
    participants: AtomicU64::new(0),
    cache_session_evictions: AtomicU64::new(0),
    cache_map_evictions: AtomicU64::new(0),
};

/// Access the global profile counters (kernel flush path).
pub fn kernel_profile() -> &'static KernelProfileAtomics {
    &KERNEL_PROFILE
}

/// A point-in-time copy of the global kernel profile.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelProfile {
    pub calls: u64,
    pub rows: u64,
    pub key_blocks_visited: u64,
    pub key_blocks_skipped: u64,
    pub rows_dequantized: u64,
    pub scratch_bytes: u64,
    pub chunks: u64,
    pub participants: u64,
    pub cache_session_evictions: u64,
    pub cache_map_evictions: u64,
}

impl KernelProfile {
    pub fn snapshot() -> KernelProfile {
        let p = &KERNEL_PROFILE;
        KernelProfile {
            calls: p.calls.load(Ordering::Relaxed),
            rows: p.rows.load(Ordering::Relaxed),
            key_blocks_visited: p.key_blocks_visited.load(Ordering::Relaxed),
            key_blocks_skipped: p.key_blocks_skipped.load(Ordering::Relaxed),
            rows_dequantized: p.rows_dequantized.load(Ordering::Relaxed),
            scratch_bytes: p.scratch_bytes.load(Ordering::Relaxed),
            chunks: p.chunks.load(Ordering::Relaxed),
            participants: p.participants.load(Ordering::Relaxed),
            cache_session_evictions: p.cache_session_evictions.load(Ordering::Relaxed),
            cache_map_evictions: p.cache_map_evictions.load(Ordering::Relaxed),
        }
    }

    /// Counter-wise difference (`self - earlier`), saturating at zero.
    pub fn delta(&self, earlier: &KernelProfile) -> KernelProfile {
        KernelProfile {
            calls: self.calls.saturating_sub(earlier.calls),
            rows: self.rows.saturating_sub(earlier.rows),
            key_blocks_visited: self
                .key_blocks_visited
                .saturating_sub(earlier.key_blocks_visited),
            key_blocks_skipped: self
                .key_blocks_skipped
                .saturating_sub(earlier.key_blocks_skipped),
            rows_dequantized: self
                .rows_dequantized
                .saturating_sub(earlier.rows_dequantized),
            scratch_bytes: self.scratch_bytes.saturating_sub(earlier.scratch_bytes),
            chunks: self.chunks.saturating_sub(earlier.chunks),
            participants: self.participants.saturating_sub(earlier.participants),
            cache_session_evictions: self
                .cache_session_evictions
                .saturating_sub(earlier.cache_session_evictions),
            cache_map_evictions: self
                .cache_map_evictions
                .saturating_sub(earlier.cache_map_evictions),
        }
    }

    /// `(name, value)` rows for export, stable order.
    pub fn rows(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("kernel_calls", self.calls),
            ("kernel_rows", self.rows),
            ("kernel_key_blocks_visited", self.key_blocks_visited),
            ("kernel_key_blocks_skipped", self.key_blocks_skipped),
            ("kernel_rows_dequantized", self.rows_dequantized),
            ("kernel_scratch_bytes", self.scratch_bytes),
            ("kernel_chunks", self.chunks),
            ("kernel_participants", self.participants),
            ("cache_session_evictions", self.cache_session_evictions),
            ("cache_map_evictions", self.cache_map_evictions),
        ]
    }
}

// --------------------------------------------------------------------------
// tests
// --------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use std::time::Duration;

    /// Tests share the process-global ACTIVE gate, so every test that
    /// needs tracing-on holds a tracer for its whole body; this lock
    /// keeps gate-sensitive tests from interleaving.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn ring_records_and_drains_in_order() {
        let ring = SpanRing::new(8);
        for i in 0..5u64 {
            ring.record(Stage::Decode, i, i * 10, 5, i);
        }
        let mut out = Vec::new();
        ring.drain(3, &mut out);
        assert_eq!(out.len(), 5);
        assert_eq!(ring.dropped(), 0);
        for (i, s) in out.iter().enumerate() {
            assert_eq!(s.stage, Stage::Decode);
            assert_eq!(s.trace_id, i as u64);
            assert_eq!(s.start_us, i as u64 * 10);
            assert_eq!(s.dur_us, 5);
            assert_eq!(s.arg, i as u64);
            assert_eq!(s.track, 3);
        }
    }

    #[test]
    fn ring_wraps_with_bounded_memory() {
        let ring = SpanRing::new(4);
        for i in 0..10u64 {
            ring.record(Stage::Batch, i, i, 1, 0);
        }
        let mut out = Vec::new();
        ring.drain(0, &mut out);
        assert_eq!(out.len(), 4, "ring retains exactly its capacity");
        assert_eq!(ring.dropped(), 6);
        assert_eq!(ring.recorded(), 10);
        // the retained spans are the newest four
        let ids: Vec<u64> = out.iter().map(|s| s.trace_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn stage_meta_roundtrip_includes_large_args() {
        let ring = SpanRing::new(2);
        let big_arg = (1u64 << 47) - 1;
        ring.record(Stage::CacheEvict, 7, 1, 0, big_arg);
        // args wider than 47 bits are truncated, not corrupted
        ring.record(Stage::Attend, 8, 2, 3, u64::MAX);
        let mut out = Vec::new();
        ring.drain(0, &mut out);
        assert_eq!(out[0].arg, big_arg);
        assert_eq!(out[1].arg, big_arg);
        assert_eq!(out[0].stage, Stage::CacheEvict);
        assert_eq!(out[1].stage, Stage::Attend);
    }

    #[test]
    fn tracer_gate_counts_live_tracers() {
        let _guard = GATE.lock().unwrap();
        let before = enabled();
        let t = Tracer::new(2, TraceConfig::default());
        assert!(enabled());
        drop(t);
        assert_eq!(enabled(), before);
    }

    #[test]
    fn thread_context_records_spans_with_trace_ids() {
        let _guard = GATE.lock().unwrap();
        let t = Tracer::new(1, TraceConfig::default());
        let _ctx = install(t.shard_ring(0), t.epoch());
        set_trace_id(42);
        let t0 = Instant::now();
        record_since(Stage::Decode, t0, 16);
        instant(Stage::CacheEvict, 128);
        set_trace_id(0);
        let spans = t.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].stage, Stage::Decode);
        assert_eq!(spans[0].trace_id, 42);
        assert_eq!(spans[0].track, 1);
        assert_eq!(spans[1].stage, Stage::CacheEvict);
        assert_eq!(spans[1].dur_us, 0);
        assert_eq!(spans[1].arg, 128);
    }

    #[test]
    fn record_between_uses_explicit_endpoints() {
        let _guard = GATE.lock().unwrap();
        let t = Tracer::new(1, TraceConfig::default());
        let _ctx = install(t.shard_ring(0), t.epoch());
        let t0 = t.epoch() + Duration::from_micros(100);
        let t1 = t.epoch() + Duration::from_micros(350);
        record_between(Stage::Enqueue, t0, t1, 9, 0);
        let spans = t.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].start_us, 100);
        assert_eq!(spans[0].dur_us, 250);
        assert_eq!(spans[0].trace_id, 9);
    }

    #[test]
    fn helpers_are_noops_when_disabled_or_uninstalled() {
        // No tracer live on this thread and (usually) none globally: the
        // helpers must not panic and must not record anywhere.
        let t0 = Instant::now();
        record_since(Stage::Decode, t0, 1);
        instant(Stage::CacheEvict, 1);
        set_trace_id(3);

        // Even with the global gate up, a thread without an installed
        // context records nothing.
        let _guard = GATE.lock().unwrap();
        let t = Tracer::new(1, TraceConfig::default());
        record_since(Stage::Decode, t0, 1);
        assert_eq!(t.spans().len(), 0);
    }

    #[test]
    fn mint_is_unique_and_nonzero() {
        let _guard = GATE.lock().unwrap();
        let t = Tracer::new(1, TraceConfig::default());
        let a = t.mint();
        let b = t.mint();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn chrome_trace_export_parses_and_covers_tracks() {
        let _guard = GATE.lock().unwrap();
        let t = Tracer::new(2, TraceConfig::default());
        t.record_frontend(Stage::Route, Instant::now(), 5, 0);
        {
            let _ctx = install(t.shard_ring(1), t.epoch());
            set_trace_id(5);
            record_since(Stage::Batch, Instant::now(), 4);
        }
        let doc = t.to_chrome_trace();
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 thread_name metadata events + 2 spans
        assert_eq!(events.len(), 5);
        let route = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("route"))
            .unwrap();
        assert_eq!(route.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(route.get("tid").unwrap().as_usize().unwrap(), 0);
        assert_eq!(
            route
                .get("args")
                .unwrap()
                .get("trace")
                .unwrap()
                .as_usize()
                .unwrap(),
            5
        );
        let batch = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("batch"))
            .unwrap();
        assert_eq!(batch.get("tid").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn concurrent_frontend_recording_loses_nothing_under_capacity() {
        let ring = Arc::new(SpanRing::new(4096));
        let threads = 8;
        let per = 128;
        std::thread::scope(|s| {
            for t in 0..threads {
                let ring = ring.clone();
                s.spawn(move || {
                    for i in 0..per {
                        ring.record(Stage::Route, (t * per + i) as u64 + 1, 0, 1, 0);
                    }
                });
            }
        });
        let mut out = Vec::new();
        ring.drain(0, &mut out);
        assert_eq!(out.len(), threads * per);
        assert_eq!(ring.dropped(), 0);
        let mut ids: Vec<u64> = out.iter().map(|s| s.trace_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), threads * per, "every span retained exactly once");
    }

    #[test]
    fn kernel_profile_snapshot_and_delta() {
        let p = kernel_profile();
        let before = KernelProfile::snapshot();
        p.calls.fetch_add(2, Ordering::Relaxed);
        p.rows.fetch_add(100, Ordering::Relaxed);
        p.key_blocks_skipped.fetch_add(7, Ordering::Relaxed);
        let after = KernelProfile::snapshot();
        let d = after.delta(&before);
        assert!(d.calls >= 2);
        assert!(d.rows >= 100);
        assert!(d.key_blocks_skipped >= 7);
        let rows = d.rows();
        assert_eq!(rows.len(), 10);
        assert!(rows.iter().any(|(n, _)| *n == "kernel_key_blocks_skipped"));
    }

    #[test]
    fn profile_guard_toggles_gate() {
        let was = profiling();
        {
            let _g = ProfileGuard::enable();
            assert!(profiling());
        }
        assert_eq!(profiling(), was);
    }

    #[test]
    fn pipeline_stage_names_are_unique() {
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Stage::ALL.len());
    }
}
