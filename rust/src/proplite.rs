//! Property-based testing mini-framework (substrate for the absent
//! `proptest` crate).
//!
//! A property is a closure over a seeded [`crate::prng::Rng`]; the runner
//! executes it for `cases` seeds and, on failure, reports the failing seed
//! so the case can be replayed deterministically:
//!
//! ```
//! use se2attn::proplite::check;
//! check("addition commutes", 256, |rng| {
//!     let (a, b) = (rng.range(-1e6, 1e6), rng.range(-1e6, 1e6));
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use crate::prng::Rng;

/// Run `cases` random trials of `prop`.  Panics (test failure) with the
/// seed and message of the first counterexample.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Allow targeted replay: SE2ATTN_PROP_SEED=<n> runs just that seed.
    if let Ok(s) = std::env::var("SE2ATTN_PROP_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!("property '{name}' failed at replayed seed {seed}: {msg}");
            }
            return;
        }
    }
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at seed {seed} \
                 (replay with SE2ATTN_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two f64s are within `tol`, with a useful message.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (|diff|={} > {tol})", (a - b).abs()))
    }
}

/// Assert every pair of corresponding slice elements is within `tol`.
pub fn all_close(a: &[f64], b: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!(
                "{what}[{i}]: {x} vs {y} (|diff|={} > {tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

/// f32 variant of [`all_close`].
pub fn all_close_f32(a: &[f32], b: &[f32], tol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!(
                "{what}[{i}]: {x} vs {y} (|diff|={} > {tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 32, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "replay with SE2ATTN_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("always fails", 8, |_| Err("nope".into()));
    }

    #[test]
    fn close_helpers() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-6, "x").is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, "v").is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 0.0, "v").is_err());
    }
}
