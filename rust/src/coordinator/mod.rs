//! L3 coordinator: the serving/training brain of the system.
//!
//! * [`model`] — `ModelHandle`: parameter state + fwd/train/decode calls
//!   against the AOT artifacts (manifest-driven parameter threading).
//! * [`batcher`] — dynamic batching of rollout requests into the fixed
//!   batch shape the artifacts were lowered at (deadline-based flush,
//!   pad-and-slice).
//! * [`router`] — routes requests across per-method model replicas.
//! * [`rollout`] — autoregressive simulation scheduler: decode -> action ->
//!   kinematic integration -> re-tokenize, for minADE evaluation and
//!   serving.
//! * [`trainer`] — training orchestrator over the dataset pipeline.
//! * [`server`] — thread-based serving loop wiring the above together.
//! * [`telemetry`] — lock-free counters/histograms for the hot path.

pub mod batcher;
pub mod model;
pub mod rollout;
pub mod router;
pub mod server;
pub mod telemetry;
pub mod trainer;

pub use batcher::{Batcher, BatcherConfig};
pub use model::ModelHandle;
pub use rollout::{RolloutEngine, RolloutRequest, RolloutResult};
pub use router::Router;
pub use server::Server;
pub use trainer::Trainer;
