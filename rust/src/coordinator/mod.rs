//! L3 coordinator: the serving/training brain of the system.
//!
//! * [`model`] — `ModelHandle`: parameter state + fwd/train/decode calls
//!   against the AOT artifacts (manifest-driven parameter threading).
//! * [`batcher`] — dynamic batching of rollout requests into the fixed
//!   batch shape the artifacts were lowered at (deadline-based flush,
//!   pad-and-slice).
//! * [`router`] — routes requests across per-method model replicas.
//! * [`kvcache`] — per-session incremental tokenization cache: shared map
//!   rows, sliding-window agent rows, exact pose re-anchoring, capacity
//!   eviction and hit/miss/bytes telemetry (DESIGN.md §10).
//! * [`rollout`] — autoregressive simulation scheduler: decode -> action ->
//!   kinematic integration -> advance the token cache, for minADE
//!   evaluation and serving.
//! * [`trainer`] — training orchestrator over the dataset pipeline.
//! * [`server`] — thread-based serving loop wiring the above together.
//! * [`telemetry`] — lock-free counters/histograms for the hot path.

pub mod batcher;
pub mod kvcache;
pub mod model;
pub mod rollout;
pub mod router;
pub mod server;
pub mod telemetry;
pub mod trainer;

pub use batcher::{Batcher, BatcherConfig};
pub use kvcache::{CacheConfig, KvCachePool, SessionKey, WindowCache};
pub use model::ModelHandle;
pub use rollout::{RolloutEngine, RolloutRequest, RolloutResult};
pub use router::Router;
pub use server::Server;
pub use trainer::Trainer;
