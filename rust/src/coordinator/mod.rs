//! L3 coordinator: the serving/training brain of the system.
//!
//! * [`model`] — `ModelHandle`: parameter state + fwd/train/decode calls
//!   against the AOT artifacts (manifest-driven parameter threading).
//! * [`admission`] — async admission control for the serving path
//!   (DESIGN.md §17): bounded wait queue with request deadlines
//!   (deadline-miss shedding), per-tenant token-bucket QoS, and typed
//!   [`admission::AdmissionError`]s replacing binary busy-bounces.
//! * [`batcher`] — dynamic batching of requests into a fixed batch shape
//!   (deadline-based flush, pad-and-slice).  Retained for the trainer
//!   path; the serving path now schedules continuously via [`admission`]
//!   + the step loop in [`server`].
//! * [`router`] — two routing layers: worker-shard selection with session
//!   affinity (`ShardRouter`) and per-method model-replica routing inside
//!   one shard (`Router`).
//! * [`kvcache`] — per-session incremental tokenization cache: shared map
//!   rows (`MapRegistry`, one registry across shards), sliding-window
//!   agent rows at a per-session storage precision (f32 exact, or
//!   quantized f16/bf16 — DESIGN.md §14), exact pose re-anchoring,
//!   precision-aware LRU byte eviction and hit/miss/bytes telemetry
//!   (DESIGN.md §10).
//! * [`rollout`] — autoregressive simulation scheduler: decode -> action ->
//!   kinematic integration -> advance the token cache, for minADE
//!   evaluation and serving; generic over the [`model::ActionDecoder`]
//!   boundary, with single-step session advancement
//!   ([`rollout::RolloutEngine::step_sessions`]) as a first-class
//!   operation for the continuous scheduler.
//! * [`trainer`] — training orchestrator over the dataset pipeline.
//! * [`server`] — sharded worker-pool serving front end wiring the above
//!   together (DESIGN.md §12): per-shard continuous-batching step loop
//!   behind an [`admission::AdmissionQueue`] (DESIGN.md §17), with
//!   optional span tracing and kernel profiling via [`crate::trace`]
//!   (DESIGN.md §15).
//! * [`telemetry`] — lock-free counters/histograms for the hot path,
//!   including per-shard and per-tenant breakdowns.
//! * [`wire`] — length-prefixed binary frame protocol for the process
//!   boundary (DESIGN.md §19): handshake, request/response envelopes,
//!   heartbeats, drain/transfer.
//! * [`session_codec`] — self-describing wire/disk codec for one KV
//!   session (key + `WindowCache`, f16/bf16 rows kept in their
//!   quantized form), so sessions migrate instead of rebuilding.
//! * [`proc`] — multi-process scale-out: a `ProcServer` coordinator
//!   supervising worker *processes* over [`wire`], with envelope replay
//!   on worker death and warm-session migration on drain.

pub mod admission;
pub mod batcher;
pub mod kvcache;
pub mod model;
pub mod proc;
pub mod rollout;
pub mod router;
pub mod server;
pub mod session_codec;
pub mod telemetry;
pub mod trainer;
pub mod wire;

pub use admission::{AdmissionConfig, AdmissionError, AdmissionQueue};
pub use batcher::{Batcher, BatcherConfig};
pub use kvcache::{CacheConfig, KvCachePool, MapRegistry, SessionKey, WindowCache};
pub use model::{ActionDecoder, ModelHandle, NativeSdpaDecoder, SyntheticDecoder};
pub use rollout::{RolloutEngine, RolloutRequest, RolloutResult};
pub use proc::{worker_serve, ProcServer, WorkerOptions};
pub use router::{shard_of, shard_of_excluding, Router, ShardRouter};
pub use server::{Backend, BackendFactory, ServeConfig, Server};
pub use trainer::Trainer;
