//! Autoregressive rollout scheduler: decode actions for the frontier
//! tokens, integrate the kinematic model, slide the history window,
//! advance the token cache, repeat — the serving-path core of the
//! agent-simulation task (paper Sec. IV-B) and the engine behind minADE
//! evaluation.
//!
//! Tokenization is incremental (DESIGN.md §10): each decode step tokenizes
//! only the frontier agent states and hits the per-session
//! [`KvCachePool`] for everything else — map rows are tokenized once per
//! scene and shared across samples, older window steps are reused verbatim
//! and evicted as the window slides, and poses are re-anchored exactly to
//! the moving robot frame at emit time.
//!
//! Batching: the decode artifact is lowered at batch size B, so up to B
//! scene-samples advance per PJRT call; a group of scenes with S samples
//! each is packed into ceil(scenes*S / B) slots per step.  Padding slots
//! replicate the last real scene's already-assembled rows in the batch
//! buffer instead of re-extending tokenizer output per slot.
//!
//! Single-step advancement is first-class (DESIGN.md §17): the
//! continuous-batching scheduler holds long-lived [`SessionState`]s and
//! drives [`RolloutEngine::step_sessions`] directly, packing sessions
//! from *different requests* into one step batch with per-slot
//! [`SlotParams`] (seed/temperature/trace).  Whole-request
//! [`RolloutEngine::rollout_with_cache`] is a thin loop over the same
//! primitive, so both paths decode bit-identically.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, SimConfig};
use crate::dataset::Batch;
use crate::metrics;
use crate::sim::agent::KinematicAction;
use crate::sim::{AgentState, MapElement, Scenario, TrajectoryClass};
use crate::tokenizer::{TokenizedScene, Tokenizer};

use super::kvcache::{CacheConfig, KvCachePool, SessionKey};
use super::model::{ActionDecoder, ModelHandle, SlotParams};
use super::telemetry::CacheStats;

/// A request to roll one scenario forward.
#[derive(Clone)]
pub struct RolloutRequest {
    /// The scene to roll forward (map + recorded agent history).
    pub scenario: Scenario,
    /// History window end (inclusive) in scenario steps.
    pub t0: usize,
    /// Joint trajectory samples to draw (the minADE "K").
    pub n_samples: usize,
    /// Decode softmax temperature.
    pub temperature: f32,
    /// Base seed for action sampling (combined with the step index).
    pub seed: i32,
}

/// World-frame sampled futures plus evaluation metrics.
#[derive(Clone, Debug)]
pub struct RolloutResult {
    /// trajectories[sample][agent][step] = world (x, y).
    pub trajectories: Vec<Vec<Vec<(f64, f64)>>>,
    /// Per-agent minADE vs the scenario's recorded future.
    pub min_ade: Vec<f64>,
    /// Per-agent ground-truth class.
    pub classes: Vec<TrajectoryClass>,
    /// Colliding agent pairs summed over samples (radius
    /// [`metrics::COLLISION_RADIUS_M`]), for per-family safety metrics.
    pub collisions: usize,
    /// Per-step mean decode latency (ms) observed for this request.
    pub decode_ms: f64,
}

/// One in-flight decode session: a scene-sample's mutable window state
/// plus its KV-cache identity.  Opaque outside the coordinator: the
/// continuous scheduler holds these across step batches and hands them
/// back to [`RolloutEngine::step_sessions`] each step and to
/// [`RolloutEngine::finish_request`] at retirement.
pub struct SessionState {
    map: Vec<MapElement>,
    window: Vec<Vec<AgentState>>,
    /// Recorded world positions per agent per emitted step.
    track: Vec<Vec<(f64, f64)>>,
    /// Session identity in the KV cache pool.
    key: SessionKey,
}

impl SessionState {
    /// Cache-pool identity — the scheduler ends the pool session with
    /// this key when the owning request retires.
    pub fn key(&self) -> SessionKey {
        self.key
    }

    /// The live history window (serialization surface of the migration
    /// codec): `window[step][agent]`, oldest step first.
    pub fn window(&self) -> &[Vec<AgentState>] {
        &self.window
    }

    /// Recorded world positions per agent per emitted step.
    pub fn track(&self) -> &[Vec<(f64, f64)>] {
        &self.track
    }

    /// Reassemble a session from migrated parts (the receive half of a
    /// worker-to-worker transfer).  The parts are installed verbatim, so
    /// the rebuilt session steps bit-identically to the one exported.
    pub fn from_parts(
        map: Vec<MapElement>,
        window: Vec<Vec<AgentState>>,
        track: Vec<Vec<(f64, f64)>>,
        key: SessionKey,
    ) -> SessionState {
        SessionState {
            map,
            window,
            track,
            key,
        }
    }
}

/// One scene slot of a continuous step batch: a live session plus the
/// decode parameters of the request that owns it.
pub struct StepSlot<'a> {
    pub session: &'a mut SessionState,
    pub params: SlotParams,
}

/// What one [`RolloutEngine::step_sessions`] call did, for telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReport {
    /// Summed decode wall time across this call's decode invocations (ms).
    pub decode_ms: f64,
    /// Decode invocations issued (chunks of the model batch size).
    pub decode_calls: usize,
    /// Real session slots advanced.
    pub real_slots: usize,
    /// Padding slots decoded alongside them.
    pub padded_slots: usize,
}

/// The autoregressive rollout scheduler (see module docs): generic over
/// [`ActionDecoder`] backends, cache-pooled via [`KvCachePool`].
pub struct RolloutEngine {
    /// Scene tokenizer (shared layout with training).
    pub tokenizer: Tokenizer,
    /// Model shape the decode artifacts were lowered at.
    pub model_cfg: ModelConfig,
    /// Simulator timing/shape knobs.
    pub sim: SimConfig,
}

impl RolloutEngine {
    pub fn new(model_cfg: ModelConfig, sim: SimConfig) -> RolloutEngine {
        RolloutEngine {
            tokenizer: Tokenizer::new(&model_cfg, &sim),
            model_cfg,
            sim,
        }
    }

    /// Open one decode session (sample `sample` of `req`): seed its
    /// history window from the scenario and mint its cache-pool key.
    /// The matching `pool.end_session` is the caller's responsibility.
    pub fn begin_session(&self, req: &RolloutRequest, sample: u32) -> SessionState {
        let h = self.sim.history_steps;
        let window: Vec<Vec<AgentState>> = (req.t0 + 1 - h..=req.t0)
            .map(|t| req.scenario.states[t].clone())
            .collect();
        let n_agents = window[0].len();
        SessionState {
            map: req.scenario.map_elements.clone(),
            window,
            track: vec![Vec::new(); n_agents],
            key: SessionKey {
                // family-aware scene id: same-seed scenarios from different
                // families must not share cached map rows (the pool's
                // element-count collision guard cannot tell them apart —
                // every family pads to the same sim.n_map_tokens)
                scene: req.scenario.scene_id(),
                t0: req.t0 as u32,
                sample,
            },
        }
    }

    /// The per-slot decode seed for sample `sample_index` of `req` at
    /// `step` — matches the legacy fixed-batch path bit for bit.  The
    /// base mixes the request seed with the step index exactly as
    /// before; the offset is the request-local chunk start the sample
    /// occupied when the request decoded alone (chunks of the model
    /// batch size).  Every slot of such a chunk shares one seed, so a
    /// single-request step batch takes the decoder's uniform fast path
    /// and reproduces the legacy actions; in a shared step batch the
    /// per-slot seeds keep each request's sampling stream independent
    /// of whoever else is in the batch.
    pub fn step_seed(&self, req: &RolloutRequest, step: usize, sample_index: usize) -> i32 {
        let chunk_start = (sample_index / self.model_cfg.batch_size) * self.model_cfg.batch_size;
        req.seed
            .wrapping_mul(7919)
            .wrapping_add(step as i32 * 104_729)
            .wrapping_add(chunk_start as i32)
    }

    /// Advance a set of live sessions one decode step — the single-step
    /// primitive of the continuous scheduler.  Slots may belong to
    /// different requests; each carries its own [`SlotParams`].  The
    /// decode boundary is the [`ActionDecoder`] trait, so any backend
    /// (PJRT artifacts or an artifact-free synthetic decoder) drives the
    /// same scheduler.
    ///
    /// Tracing: when any slot carries a nonzero trace id, tokenize and
    /// decode spans are recorded per request (slots of one request are
    /// expected to be packed contiguously), so a shared step batch still
    /// reconstructs into per-request timelines.
    pub fn step_sessions(
        &self,
        model: &dyn ActionDecoder,
        slots: &mut [StepSlot<'_>],
        pool: &KvCachePool,
    ) -> Result<StepReport> {
        let b = self.model_cfg.batch_size;
        let n_tokens = self.model_cfg.n_tokens;
        let feat_dim = self.model_cfg.feat_dim;
        let mut report = StepReport {
            real_slots: slots.len(),
            ..StepReport::default()
        };

        let total = slots.len();
        for chunk_start in (0..total).step_by(b) {
            let chunk = &mut slots[chunk_start..(chunk_start + b).min(total)];
            let traced = chunk.iter().any(|s| s.params.trace != 0);
            // tokenize only the frontier of each session; the pool supplies
            // cached map rows and the reusable older window steps
            let tok_t0 = std::time::Instant::now();
            let mut scenes: Vec<TokenizedScene> = Vec::with_capacity(chunk.len());
            for slot in chunk.iter() {
                let s = &slot.session;
                let slot_t0 = std::time::Instant::now();
                if traced {
                    crate::trace::set_trace_id(slot.params.trace);
                }
                let scene = pool.step(s.key, &self.tokenizer, &s.map, &s.window);
                if traced {
                    crate::trace::record_since(crate::trace::Stage::Tokenize, slot_t0, 1);
                    crate::trace::set_trace_id(0);
                }
                scenes.push(scene?);
            }
            if !traced {
                crate::trace::record_since(
                    crate::trace::Stage::Tokenize,
                    tok_t0,
                    chunk.len() as u64,
                );
            }
            let mut batch = Batch {
                feat: Vec::with_capacity(b * n_tokens * feat_dim),
                pose: Vec::with_capacity(b * n_tokens * 3),
                tq: Vec::with_capacity(b * n_tokens),
                target: Vec::with_capacity(b * n_tokens),
                batch_size: b,
            };
            for s in &scenes {
                batch.feat.extend_from_slice(&s.feat);
                batch.pose.extend_from_slice(&s.pose);
                batch.tq.extend_from_slice(&s.tq);
                batch.target.extend_from_slice(&s.target);
            }
            // pad unused slots by replicating the last real scene's rows
            // within the batch buffer (no redundant tokenizer walks)
            for _ in scenes.len()..b {
                let fb = batch.feat.len() - scenes.last().unwrap().feat.len();
                let pb = batch.pose.len() - scenes.last().unwrap().pose.len();
                let tb = batch.tq.len() - scenes.last().unwrap().tq.len();
                let gb = batch.target.len() - scenes.last().unwrap().target.len();
                batch.feat.extend_from_within(fb..);
                batch.pose.extend_from_within(pb..);
                batch.tq.extend_from_within(tb..);
                batch.target.extend_from_within(gb..);
            }
            report.padded_slots += b - scenes.len();
            let params: Vec<SlotParams> = chunk.iter().map(|s| s.params).collect();
            let t0 = std::time::Instant::now();
            let out = model.decode_slots(&batch, n_tokens, feat_dim, &params)?;
            let t1 = std::time::Instant::now();
            report.decode_ms += (t1 - t0).as_secs_f64() * 1e3;
            report.decode_calls += 1;
            if traced {
                // one Decode span per request sharing this chunk
                let mut last = 0u64;
                for slot in chunk.iter() {
                    let id = slot.params.trace;
                    if id != 0 && id != last {
                        crate::trace::record_between(
                            crate::trace::Stage::Decode,
                            t0,
                            t1,
                            id,
                            chunk.len() as u64,
                        );
                        last = id;
                    }
                }
            } else {
                crate::trace::record_since(crate::trace::Stage::Decode, t0, chunk.len() as u64);
            }

            // apply sampled frontier actions per (real) session
            for (si, slot) in chunk.iter_mut().enumerate() {
                let state = &mut *slot.session;
                let scene = &scenes[si];
                let n_agents = state.window[0].len();
                let latest = state.window.last().unwrap().clone();
                let mut next = Vec::with_capacity(n_agents);
                for a in 0..n_agents {
                    let tok = scene.agent_token(scene.history_steps - 1, a);
                    let id = out.actions[si * n_tokens + tok];
                    let action: KinematicAction =
                        self.tokenizer.codebook.decode(id.max(0) as usize);
                    let stepped = latest[a].step(action, self.sim.dt);
                    next.push(stepped);
                }
                // record world positions, slide the window
                for (a, st) in next.iter().enumerate() {
                    state.track[a].push((st.pose.x, st.pose.y));
                }
                state.window.remove(0);
                state.window.push(next);
            }
        }
        Ok(report)
    }

    /// Run a full rollout request with a private, request-local cache
    /// pool.  Serving goes through [`Self::rollout_with_cache`] so map
    /// rows and telemetry are shared server-wide.
    pub fn rollout(
        &self,
        model: &dyn ActionDecoder,
        req: &RolloutRequest,
    ) -> Result<RolloutResult> {
        let pool = KvCachePool::new(CacheConfig::default(), Arc::new(CacheStats::default()));
        self.rollout_with_cache(model, req, &pool)
    }

    /// Run a full rollout request: S samples x future_steps decode steps,
    /// tokenizing only frontier tokens against `pool`'s session caches.
    pub fn rollout_with_cache(
        &self,
        model: &dyn ActionDecoder,
        req: &RolloutRequest,
        pool: &KvCachePool,
    ) -> Result<RolloutResult> {
        // a zero-sample request is a recoverable caller error, not a
        // `sessions[0]` panic on the serving thread
        if req.n_samples == 0 {
            bail!("rollout request asks for zero samples — nothing to roll out");
        }
        let mut sessions: Vec<SessionState> = (0..req.n_samples)
            .map(|i| self.begin_session(req, i as u32))
            .collect();
        let stepped = (|| -> Result<StepReport> {
            let mut total = StepReport::default();
            for step in 0..self.sim.future_steps {
                let mut slots: Vec<StepSlot<'_>> = sessions
                    .iter_mut()
                    .enumerate()
                    .map(|(i, session)| StepSlot {
                        params: SlotParams {
                            seed: self.step_seed(req, step, i),
                            temperature: req.temperature,
                            trace: 0,
                        },
                        session,
                    })
                    .collect();
                let rep = self.step_sessions(model, &mut slots, pool)?;
                total.decode_ms += rep.decode_ms;
                total.decode_calls += rep.decode_calls;
                total.real_slots += rep.real_slots;
                total.padded_slots += rep.padded_slots;
            }
            Ok(total)
        })();
        // session lifecycle: release before propagating any decode error
        for s in &sessions {
            pool.end_session(s.key);
        }
        let rep = stepped?;
        let decode_ms = rep.decode_ms / rep.decode_calls.max(1) as f64;
        Ok(self.finish_request(req, &sessions, decode_ms))
    }

    /// Assemble the [`RolloutResult`] for a request whose sessions have
    /// all advanced `future_steps` steps.  Pure bookkeeping — the caller
    /// owns the session lifecycle (`pool.end_session` per key), which is
    /// what lets the continuous scheduler retire requests one at a time
    /// out of a shared step batch.
    pub fn finish_request(
        &self,
        req: &RolloutRequest,
        sessions: &[SessionState],
        decode_ms: f64,
    ) -> RolloutResult {
        let n_agents = sessions.first().map(|s| s.track.len()).unwrap_or(0);
        let trajectories: Vec<Vec<Vec<(f64, f64)>>> =
            sessions.iter().map(|s| s.track.clone()).collect();
        let collisions = trajectories
            .iter()
            .map(|s| metrics::sample_collisions(s, metrics::COLLISION_RADIUS_M))
            .sum();

        // minADE vs recorded ground-truth future
        let mut min_ade = Vec::with_capacity(n_agents);
        let mut classes = Vec::with_capacity(n_agents);
        for a in 0..n_agents {
            let truth: Vec<(f64, f64)> = req
                .scenario
                .future_positions(a, req.t0)
                .into_iter()
                .take(self.sim.future_steps)
                .collect();
            let per_sample: Vec<Vec<(f64, f64)>> = trajectories
                .iter()
                .map(|t| t[a].iter().take(truth.len()).cloned().collect())
                .collect();
            min_ade.push(metrics::min_ade(&per_sample, &truth));
            classes.push(req.scenario.classify_future(a, req.t0));
        }

        RolloutResult {
            trajectories,
            min_ade,
            classes,
            collisions,
            decode_ms,
        }
    }

    /// Evaluate a model over many scenarios, accumulating a Table-I row.
    pub fn evaluate(
        &self,
        model: &ModelHandle,
        scenario_seeds: &[u64],
        n_samples: usize,
        row: &mut metrics::TableOneRow,
    ) -> Result<()> {
        let gen = crate::sim::ScenarioGenerator::new(self.sim.clone());
        let t0 = self.sim.history_steps - 1;
        for &seed in scenario_seeds {
            let scenario = gen.generate(seed);
            // NLL on the recorded window
            let ts = self.tokenizer.tokenize_scenario(&scenario, t0);
            let mut batch_scenes = vec![&ts; self.model_cfg.batch_size];
            batch_scenes.truncate(self.model_cfg.batch_size);
            let mut batch = Batch {
                feat: Vec::new(),
                pose: Vec::new(),
                tq: Vec::new(),
                target: Vec::new(),
                batch_size: self.model_cfg.batch_size,
            };
            for s in &batch_scenes {
                batch.feat.extend_from_slice(&s.feat);
                batch.pose.extend_from_slice(&s.pose);
                batch.tq.extend_from_slice(&s.tq);
                batch.target.extend_from_slice(&s.target);
            }
            let logits = model.forward(&batch, self.model_cfg.n_tokens, self.model_cfg.feat_dim)?;
            let per_scene = self.model_cfg.n_tokens * self.model_cfg.n_actions;
            let n_labeled = ts.target.iter().filter(|&&t| t >= 0).count();
            row.add_nll(
                metrics::nll(&logits[..per_scene], &ts.target, self.model_cfg.n_actions),
                n_labeled,
            );

            // minADE rollout
            let req = RolloutRequest {
                scenario,
                t0,
                n_samples,
                temperature: 1.0,
                seed: seed as i32,
            };
            let res = self.rollout(model, &req).context("rollout")?;
            for (a, &ade) in res.min_ade.iter().enumerate() {
                row.add_min_ade(res.classes[a], ade);
            }
        }
        Ok(())
    }
}
