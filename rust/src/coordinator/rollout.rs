//! Autoregressive rollout scheduler: decode actions for the frontier
//! tokens, integrate the kinematic model, slide the history window,
//! advance the token cache, repeat — the serving-path core of the
//! agent-simulation task (paper Sec. IV-B) and the engine behind minADE
//! evaluation.
//!
//! Tokenization is incremental (DESIGN.md §10): each decode step tokenizes
//! only the frontier agent states and hits the per-session
//! [`KvCachePool`] for everything else — map rows are tokenized once per
//! scene and shared across samples, older window steps are reused verbatim
//! and evicted as the window slides, and poses are re-anchored exactly to
//! the moving robot frame at emit time.
//!
//! Batching: the decode artifact is lowered at batch size B, so up to B
//! scene-samples advance per PJRT call; a group of scenes with S samples
//! each is packed into ceil(scenes*S / B) slots per step.  Padding slots
//! replicate the last real scene's already-assembled rows in the batch
//! buffer instead of re-extending tokenizer output per slot.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, SimConfig};
use crate::dataset::Batch;
use crate::metrics;
use crate::sim::agent::KinematicAction;
use crate::sim::{AgentState, MapElement, Scenario, TrajectoryClass};
use crate::tokenizer::{TokenizedScene, Tokenizer};

use super::kvcache::{CacheConfig, KvCachePool, SessionKey};
use super::model::{ActionDecoder, ModelHandle};
use super::telemetry::CacheStats;

/// A request to roll one scenario forward.
#[derive(Clone)]
pub struct RolloutRequest {
    /// The scene to roll forward (map + recorded agent history).
    pub scenario: Scenario,
    /// History window end (inclusive) in scenario steps.
    pub t0: usize,
    /// Joint trajectory samples to draw (the minADE "K").
    pub n_samples: usize,
    /// Decode softmax temperature.
    pub temperature: f32,
    /// Base seed for action sampling (combined with the step index).
    pub seed: i32,
}

/// World-frame sampled futures plus evaluation metrics.
#[derive(Clone, Debug)]
pub struct RolloutResult {
    /// trajectories[sample][agent][step] = world (x, y).
    pub trajectories: Vec<Vec<Vec<(f64, f64)>>>,
    /// Per-agent minADE vs the scenario's recorded future.
    pub min_ade: Vec<f64>,
    /// Per-agent ground-truth class.
    pub classes: Vec<TrajectoryClass>,
    /// Colliding agent pairs summed over samples (radius
    /// [`metrics::COLLISION_RADIUS_M`]), for per-family safety metrics.
    pub collisions: usize,
    /// Per-step mean decode latency (ms) observed for this request.
    pub decode_ms: f64,
}

/// One in-flight scene-sample: mutable window state plus its cache key.
struct SampleState {
    map: Vec<MapElement>,
    window: Vec<Vec<AgentState>>,
    /// Recorded world positions per agent per emitted step.
    track: Vec<Vec<(f64, f64)>>,
    /// Session identity in the KV cache pool.
    key: SessionKey,
}

/// The autoregressive rollout scheduler (see module docs): generic over
/// [`ActionDecoder`] backends, cache-pooled via [`KvCachePool`].
pub struct RolloutEngine {
    /// Scene tokenizer (shared layout with training).
    pub tokenizer: Tokenizer,
    /// Model shape the decode artifacts were lowered at.
    pub model_cfg: ModelConfig,
    /// Simulator timing/shape knobs.
    pub sim: SimConfig,
}

impl RolloutEngine {
    pub fn new(model_cfg: ModelConfig, sim: SimConfig) -> RolloutEngine {
        RolloutEngine {
            tokenizer: Tokenizer::new(&model_cfg, &sim),
            model_cfg,
            sim,
        }
    }

    fn sample_state(&self, req: &RolloutRequest, sample: u32) -> SampleState {
        let h = self.sim.history_steps;
        let window: Vec<Vec<AgentState>> = (req.t0 + 1 - h..=req.t0)
            .map(|t| req.scenario.states[t].clone())
            .collect();
        let n_agents = window[0].len();
        SampleState {
            map: req.scenario.map_elements.clone(),
            window,
            track: vec![Vec::new(); n_agents],
            key: SessionKey {
                // family-aware scene id: same-seed scenarios from different
                // families must not share cached map rows (the pool's
                // element-count collision guard cannot tell them apart —
                // every family pads to the same sim.n_map_tokens)
                scene: req.scenario.scene_id(),
                t0: req.t0 as u32,
                sample,
            },
        }
    }

    /// Advance a group of samples one decode step.  The decode boundary
    /// is the [`ActionDecoder`] trait, so any backend (PJRT artifacts or
    /// an artifact-free synthetic decoder) drives the same scheduler.
    fn step_samples(
        &self,
        model: &dyn ActionDecoder,
        samples: &mut [SampleState],
        pool: &KvCachePool,
        seed: i32,
        temperature: f32,
    ) -> Result<f64> {
        let b = self.model_cfg.batch_size;
        let n_tokens = self.model_cfg.n_tokens;
        let feat_dim = self.model_cfg.feat_dim;
        let mut decode_ms = 0.0;
        let mut calls = 0usize;

        let total = samples.len();
        for chunk_start in (0..total).step_by(b) {
            let chunk = &mut samples[chunk_start..(chunk_start + b).min(total)];
            // tokenize only the frontier of each sample; the pool supplies
            // cached map rows and the reusable older window steps
            let tok_t0 = std::time::Instant::now();
            let scenes: Vec<TokenizedScene> = chunk
                .iter()
                .map(|s| pool.step(s.key, &self.tokenizer, &s.map, &s.window))
                .collect::<Result<_>>()?;
            crate::trace::record_since(crate::trace::Stage::Tokenize, tok_t0, chunk.len() as u64);
            let mut batch = Batch {
                feat: Vec::with_capacity(b * n_tokens * feat_dim),
                pose: Vec::with_capacity(b * n_tokens * 3),
                tq: Vec::with_capacity(b * n_tokens),
                target: Vec::with_capacity(b * n_tokens),
                batch_size: b,
            };
            for s in &scenes {
                batch.feat.extend_from_slice(&s.feat);
                batch.pose.extend_from_slice(&s.pose);
                batch.tq.extend_from_slice(&s.tq);
                batch.target.extend_from_slice(&s.target);
            }
            // pad unused slots by replicating the last real scene's rows
            // within the batch buffer (no redundant tokenizer walks)
            for _ in scenes.len()..b {
                let fb = batch.feat.len() - scenes.last().unwrap().feat.len();
                let pb = batch.pose.len() - scenes.last().unwrap().pose.len();
                let tb = batch.tq.len() - scenes.last().unwrap().tq.len();
                let gb = batch.target.len() - scenes.last().unwrap().target.len();
                batch.feat.extend_from_within(fb..);
                batch.pose.extend_from_within(pb..);
                batch.tq.extend_from_within(tb..);
                batch.target.extend_from_within(gb..);
            }
            let t0 = std::time::Instant::now();
            let out = model.decode(
                &batch,
                n_tokens,
                feat_dim,
                seed.wrapping_add(chunk_start as i32),
                temperature,
            )?;
            decode_ms += t0.elapsed().as_secs_f64() * 1e3;
            crate::trace::record_since(crate::trace::Stage::Decode, t0, chunk.len() as u64);
            calls += 1;

            // apply sampled frontier actions per (real) sample
            for (si, state) in chunk.iter_mut().enumerate() {
                let scene = &scenes[si];
                let n_agents = state.window[0].len();
                let latest = state.window.last().unwrap().clone();
                let mut next = Vec::with_capacity(n_agents);
                for a in 0..n_agents {
                    let tok = scene.agent_token(scene.history_steps - 1, a);
                    let id = out.actions[si * n_tokens + tok];
                    let action: KinematicAction =
                        self.tokenizer.codebook.decode(id.max(0) as usize);
                    let stepped = latest[a].step(action, self.sim.dt);
                    next.push(stepped);
                }
                // record world positions, slide the window
                for (a, st) in next.iter().enumerate() {
                    state.track[a].push((st.pose.x, st.pose.y));
                }
                state.window.remove(0);
                state.window.push(next);
            }
        }
        Ok(decode_ms / calls.max(1) as f64)
    }

    /// Run a full rollout request with a private, request-local cache
    /// pool.  Serving goes through [`Self::rollout_with_cache`] so map
    /// rows and telemetry are shared server-wide.
    pub fn rollout(
        &self,
        model: &dyn ActionDecoder,
        req: &RolloutRequest,
    ) -> Result<RolloutResult> {
        let pool = KvCachePool::new(CacheConfig::default(), Arc::new(CacheStats::default()));
        self.rollout_with_cache(model, req, &pool)
    }

    /// Run a full rollout request: S samples x future_steps decode steps,
    /// tokenizing only frontier tokens against `pool`'s session caches.
    pub fn rollout_with_cache(
        &self,
        model: &dyn ActionDecoder,
        req: &RolloutRequest,
        pool: &KvCachePool,
    ) -> Result<RolloutResult> {
        // a zero-sample request is a recoverable caller error, not a
        // `samples[0]` panic on the serving thread
        if req.n_samples == 0 {
            bail!("rollout request asks for zero samples — nothing to roll out");
        }
        let mut samples: Vec<SampleState> = (0..req.n_samples)
            .map(|i| self.sample_state(req, i as u32))
            .collect();
        let stepped = (|| -> Result<f64> {
            let mut decode_ms = 0.0;
            for step in 0..self.sim.future_steps {
                decode_ms += self.step_samples(
                    model,
                    &mut samples,
                    pool,
                    req.seed
                        .wrapping_mul(7919)
                        .wrapping_add(step as i32 * 104_729),
                    req.temperature,
                )?;
            }
            Ok(decode_ms)
        })();
        // session lifecycle: release before propagating any decode error
        for s in &samples {
            pool.end_session(s.key);
        }
        let decode_ms = stepped? / self.sim.future_steps as f64;

        let n_agents = samples[0].track.len();
        let trajectories: Vec<Vec<Vec<(f64, f64)>>> =
            samples.iter().map(|s| s.track.clone()).collect();
        let collisions = trajectories
            .iter()
            .map(|s| metrics::sample_collisions(s, metrics::COLLISION_RADIUS_M))
            .sum();

        // minADE vs recorded ground-truth future
        let mut min_ade = Vec::with_capacity(n_agents);
        let mut classes = Vec::with_capacity(n_agents);
        for a in 0..n_agents {
            let truth: Vec<(f64, f64)> = req
                .scenario
                .future_positions(a, req.t0)
                .into_iter()
                .take(self.sim.future_steps)
                .collect();
            let per_sample: Vec<Vec<(f64, f64)>> = trajectories
                .iter()
                .map(|t| t[a].iter().take(truth.len()).cloned().collect())
                .collect();
            min_ade.push(metrics::min_ade(&per_sample, &truth));
            classes.push(req.scenario.classify_future(a, req.t0));
        }

        Ok(RolloutResult {
            trajectories,
            min_ade,
            classes,
            collisions,
            decode_ms,
        })
    }

    /// Evaluate a model over many scenarios, accumulating a Table-I row.
    pub fn evaluate(
        &self,
        model: &ModelHandle,
        scenario_seeds: &[u64],
        n_samples: usize,
        row: &mut metrics::TableOneRow,
    ) -> Result<()> {
        let gen = crate::sim::ScenarioGenerator::new(self.sim.clone());
        let t0 = self.sim.history_steps - 1;
        for &seed in scenario_seeds {
            let scenario = gen.generate(seed);
            // NLL on the recorded window
            let ts = self.tokenizer.tokenize_scenario(&scenario, t0);
            let mut batch_scenes = vec![&ts; self.model_cfg.batch_size];
            batch_scenes.truncate(self.model_cfg.batch_size);
            let mut batch = Batch {
                feat: Vec::new(),
                pose: Vec::new(),
                tq: Vec::new(),
                target: Vec::new(),
                batch_size: self.model_cfg.batch_size,
            };
            for s in &batch_scenes {
                batch.feat.extend_from_slice(&s.feat);
                batch.pose.extend_from_slice(&s.pose);
                batch.tq.extend_from_slice(&s.tq);
                batch.target.extend_from_slice(&s.target);
            }
            let logits = model.forward(&batch, self.model_cfg.n_tokens, self.model_cfg.feat_dim)?;
            let per_scene = self.model_cfg.n_tokens * self.model_cfg.n_actions;
            let n_labeled = ts.target.iter().filter(|&&t| t >= 0).count();
            row.add_nll(
                metrics::nll(&logits[..per_scene], &ts.target, self.model_cfg.n_actions),
                n_labeled,
            );

            // minADE rollout
            let req = RolloutRequest {
                scenario,
                t0,
                n_samples,
                temperature: 1.0,
                seed: seed as i32,
            };
            let res = self.rollout(model, &req).context("rollout")?;
            for (a, &ade) in res.min_ade.iter().enumerate() {
                row.add_min_ade(res.classes[a], ade);
            }
        }
        Ok(())
    }
}
