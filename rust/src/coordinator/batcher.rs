//! Dynamic batcher: the AOT artifacts are lowered at a fixed batch size B,
//! so incoming requests are grouped into full batches, padding unused slots
//! by repeating the first scene (padded outputs are sliced away).
//!
//! Flush policy: a batch is emitted when full, or when the oldest queued
//! request has waited `max_wait`; `max_queue` bounds memory (backpressure:
//! callers get a typed [`AdmissionError::QueueFull`] instead of unbounded
//! queuing).
//!
//! The serving path no longer uses this type — shard workers schedule
//! continuously through [`super::admission::AdmissionQueue`] (DESIGN.md
//! §17).  The fixed batcher remains for trainer-style callers that need
//! deadline-flushed whole batches, and as the fixed-batch baseline in
//! `benches/serving_load.rs`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::admission::AdmissionError;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub batch_size: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> BatcherConfig {
        BatcherConfig {
            batch_size: 8,
            max_wait: Duration::from_millis(20),
            max_queue: 256,
        }
    }
}

struct Queued<T> {
    item: T,
    enqueued_at: Instant,
}

/// A batch handed to the execution stage: `items` are the real requests,
/// `padding` how many extra slots were filled by repetition.
pub struct ReadyBatch<T> {
    pub items: Vec<T>,
    pub padding: usize,
}

/// Order-preserving dynamic batcher (generic over request type).
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Queued<T>>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        assert!(cfg.batch_size > 0);
        Batcher {
            cfg,
            queue: VecDeque::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue a request; `Err` hands the item back with a typed
    /// [`AdmissionError::QueueFull`] (backpressure).  The `shard` label is
    /// 0: the fixed batcher serves non-sharded callers (trainer path,
    /// benches).
    pub fn push(&mut self, item: T) -> Result<(), (T, AdmissionError)> {
        if self.queue.len() >= self.cfg.max_queue {
            let err = AdmissionError::QueueFull {
                shard: 0,
                capacity: self.cfg.max_queue,
            };
            return Err((item, err));
        }
        // queue growth (VecDeque doublings up to max_queue slots) is
        // charged to the batcher scope in the memory attribution table
        let _mem = crate::obs::alloc::MemScope::enter("batcher");
        self.queue.push_back(Queued {
            item,
            enqueued_at: Instant::now(),
        });
        Ok(())
    }

    fn should_flush(&self, now: Instant) -> bool {
        if self.queue.len() >= self.cfg.batch_size {
            return true;
        }
        match self.queue.front() {
            Some(front) => now.duration_since(front.enqueued_at) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Pop a batch if the flush policy triggers.  FIFO order is preserved;
    /// never returns an empty batch.
    pub fn poll(&mut self, now: Instant) -> Option<ReadyBatch<T>> {
        if !self.should_flush(now) {
            return None;
        }
        let take = self.queue.len().min(self.cfg.batch_size);
        let items: Vec<T> = (0..take)
            .map(|_| self.queue.pop_front().unwrap().item)
            .collect();
        let padding = self.cfg.batch_size - items.len();
        Some(ReadyBatch { items, padding })
    }

    /// Flush everything immediately (shutdown path).
    pub fn drain(&mut self) -> Vec<ReadyBatch<T>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.batch_size);
            let items: Vec<T> = (0..take)
                .map(|_| self.queue.pop_front().unwrap().item)
                .collect();
            let padding = self.cfg.batch_size - items.len();
            out.push(ReadyBatch { items, padding });
        }
        out
    }

    /// Time until the oldest request would force a flush (for event-loop
    /// sleep calculation).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|f| {
            self.cfg
                .max_wait
                .saturating_sub(now.duration_since(f.enqueued_at))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proplite::check;

    fn cfg(bs: usize, wait_ms: u64, max_q: usize) -> BatcherConfig {
        BatcherConfig {
            batch_size: bs,
            max_wait: Duration::from_millis(wait_ms),
            max_queue: max_q,
        }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(cfg(4, 1000, 100));
        for i in 0..4 {
            b.push(i).unwrap();
        }
        let batch = b.poll(Instant::now()).expect("full batch");
        assert_eq!(batch.items, vec![0, 1, 2, 3]);
        assert_eq!(batch.padding, 0);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = Batcher::new(cfg(4, 50, 100));
        b.push(7).unwrap();
        let now = Instant::now();
        assert!(b.poll(now).is_none(), "should wait");
        let later = now + Duration::from_millis(60);
        let batch = b.poll(later).expect("deadline flush");
        assert_eq!(batch.items, vec![7]);
        assert_eq!(batch.padding, 3);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut b = Batcher::new(cfg(2, 10, 3));
        assert!(b.push(1).is_ok());
        assert!(b.push(2).is_ok());
        assert!(b.push(3).is_ok());
        let (item, err) = b.push(4).unwrap_err();
        assert_eq!(item, 4, "rejected item is handed back");
        assert!(
            matches!(err, AdmissionError::QueueFull { capacity: 3, .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("busy"), "{err}");
    }

    #[test]
    fn drain_empties_queue() {
        let mut b = Batcher::new(cfg(4, 1000, 100));
        for i in 0..10 {
            b.push(i).unwrap();
        }
        let batches = b.drain();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2].items, vec![8, 9]);
        assert_eq!(batches[2].padding, 2);
        assert!(b.is_empty());
    }

    #[test]
    fn property_no_loss_no_dup_fifo() {
        check("batcher conservation", 50, |rng| {
            let bs = 1 + rng.below(6);
            let mut b = Batcher::new(cfg(bs, 0, 10_000));
            let n = rng.below(200);
            for i in 0..n {
                b.push(i).map_err(|_| "rejected".to_string())?;
            }
            let mut seen = Vec::new();
            let far = Instant::now() + Duration::from_secs(10);
            while let Some(batch) = b.poll(far) {
                if batch.items.is_empty() {
                    return Err("empty batch".into());
                }
                seen.extend(batch.items);
            }
            if seen != (0..n).collect::<Vec<_>>() {
                return Err(format!("order/loss violation: {} items", seen.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn next_deadline_shrinks() {
        let mut b = Batcher::new(cfg(4, 100, 10));
        let t0 = Instant::now();
        b.push(0).unwrap();
        let d1 = b.next_deadline(t0).unwrap();
        let d2 = b.next_deadline(t0 + Duration::from_millis(50)).unwrap();
        assert!(d2 < d1);
    }
}
