//! Serialization of one KV session for cross-worker migration
//! (DESIGN.md §19).
//!
//! A migrating session ships its cached [`WindowCache`] rows verbatim —
//! f32 rows byte-for-byte, quantized rows as their **raw u16 codes plus
//! the per-row scale/offset pair** — so the destination worker resumes
//! with warm rows and no re-quantization: the rebuilt cache emits
//! bit-identically to the one exported.  Shipping raw f16/bf16 codes is
//! also what halves migration bytes for quantized sessions (DESIGN.md
//! §14): the wire size *is* the resident size.
//!
//! The format follows the [`crate::checkpoint`] discipline (magic,
//! version, little-endian, length-prefixed method string, actionable
//! errors on skew) but carries a session, not weights:
//!
//! ```text
//! [SESSION_MAGIC u32][SESSION_VERSION u32][method str]
//! [scene u64][t0 u32][sample u32][precision u8]
//! [feat_dim u32][n_agents u32][history_steps u32][n_map u32]
//! map rows:   n_map * feat_dim f32, then n_map world poses (3 x f64)
//! step rows:  per step — n_agents feature rows (raw f32, or
//!             scale f32 + offset f32 + feat_dim u16 codes per row),
//!             then n_agents world poses (3 x f64)
//! ```
//!
//! The header is exactly [`session_header_bytes`] bytes; the body is
//! exactly [`crate::attention::memmodel::map_tokens_bytes`] `+`
//! [`crate::attention::memmodel::window_cache_bytes`] — serialization
//! adds nothing beyond the documented header overhead, an invariant the
//! `session_codec_props` property suite pins against the memmodel.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::attention::memmodel::{map_tokens_bytes, window_cache_bytes};
use crate::attention::quant::FeatureRows;
use crate::config::CachePrecision;
use crate::geometry::Pose;

use super::kvcache::{MapTokens, SessionKey, WindowCache};
use super::wire::{
    put_f32, put_pose, put_str, put_u16, put_u32, put_u64, put_u8, take_pose, Cursor,
};

/// Session-blob magic (distinct from the checkpoint magic `0x5E2A_C4B7`
/// and the frame magic `0x5E2A_F8A3`).
pub const SESSION_MAGIC: u32 = 0x5E55_C0DE;
/// Bumped on any layout change; a mismatch fails loudly at decode.
pub const SESSION_VERSION: u32 = 1;

const MAX_MAP_ROWS: u64 = 1 << 20;
const MAX_AGENT_ROWS: u64 = 4096;
const MAX_STEPS: u64 = 1 << 16;
const MAX_FEAT_DIM: u64 = 1 << 16;
/// Wire bytes of one pose (3 x f64) — matches
/// [`crate::attention::memmodel::POSE_BYTES`].
const POSE_WIRE_BYTES: usize = 24;

/// Exact header size of an encoded session blob: every fixed field plus
/// the length-prefixed method string.  This is the codec's entire
/// overhead over the memmodel's resident-byte formulas.
pub fn session_header_bytes(method: &str) -> usize {
    // magic + version + method (len prefix + bytes) + scene + t0 +
    // sample + precision + feat_dim + n_agents + history_steps + n_map
    4 + 4 + (4 + method.len()) + 8 + 4 + 4 + 1 + 4 + 4 + 4 + 4
}

/// Exact size of the blob [`encode_session`] produces for a session of
/// this shape: header overhead plus the memmodel byte formulas.
pub fn session_blob_bytes(
    method: &str,
    n_map: usize,
    n_agents: usize,
    history_steps: usize,
    feat_dim: usize,
    precision: CachePrecision,
) -> usize {
    session_header_bytes(method)
        + map_tokens_bytes(n_map, feat_dim)
        + window_cache_bytes(n_agents, history_steps, feat_dim, precision)
}

fn precision_tag(p: CachePrecision) -> u8 {
    match p {
        CachePrecision::F32 => 0,
        CachePrecision::F16 => 1,
        CachePrecision::Bf16 => 2,
    }
}

fn precision_from(tag: u8) -> Result<CachePrecision> {
    match tag {
        0 => Ok(CachePrecision::F32),
        1 => Ok(CachePrecision::F16),
        2 => Ok(CachePrecision::Bf16),
        t => bail!("corrupt session blob: unknown precision tag {t}"),
    }
}

fn put_feature_rows(out: &mut Vec<u8>, rows: &FeatureRows) {
    if let Some(raw) = rows.raw_f32() {
        for &x in raw {
            put_f32(out, x);
        }
    } else {
        let q = rows.as_quant().expect("non-f32 rows are quantized");
        for j in 0..q.len() {
            let (scale, offset, codes) = q.row_raw(j);
            put_f32(out, scale);
            put_f32(out, offset);
            for &code in codes {
                put_u16(out, code);
            }
        }
    }
}

fn take_feature_rows(
    c: &mut Cursor<'_>,
    precision: CachePrecision,
    n_rows: usize,
    feat_dim: usize,
) -> Result<FeatureRows> {
    let mut rows = FeatureRows::new(precision, feat_dim);
    if precision.is_quantized() {
        let q = rows.as_quant_mut().expect("quantized store");
        let mut codes = Vec::with_capacity(feat_dim);
        for _ in 0..n_rows {
            let scale = c.f32("row scale")?;
            let offset = c.f32("row offset")?;
            codes.clear();
            for _ in 0..feat_dim {
                codes.push(c.u16("row code")?);
            }
            q.push_row_raw(scale, offset, &codes);
        }
    } else {
        let raw = c.bytes(n_rows * feat_dim * 4, "f32 rows")?;
        let mut data = Vec::with_capacity(n_rows * feat_dim);
        for chunk in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        rows.push_rows(&data);
    }
    Ok(rows)
}

/// Serialize one session's cached window for migration.  `method` is the
/// attention method the session was decoding under — the decode side
/// refuses to resume it under a different method.
pub fn encode_session(method: &str, key: SessionKey, cache: &WindowCache) -> Vec<u8> {
    let map = cache.map();
    let fd = cache.feat_dim();
    let mut out = Vec::with_capacity(session_blob_bytes(
        method,
        map.len(),
        cache.n_agents(),
        cache.history_steps(),
        fd,
        cache.precision(),
    ));
    put_u32(&mut out, SESSION_MAGIC);
    put_u32(&mut out, SESSION_VERSION);
    put_str(&mut out, method);
    put_u64(&mut out, key.scene);
    put_u32(&mut out, key.t0);
    put_u32(&mut out, key.sample);
    put_u8(&mut out, precision_tag(cache.precision()));
    put_u32(&mut out, fd as u32);
    put_u32(&mut out, cache.n_agents() as u32);
    put_u32(&mut out, cache.history_steps() as u32);
    put_u32(&mut out, map.len() as u32);

    for &x in &map.feat {
        put_f32(&mut out, x);
    }
    for p in &map.world_pose {
        put_pose(&mut out, p);
    }
    for (feat, poses) in cache.step_rows() {
        put_feature_rows(&mut out, feat);
        for p in poses {
            put_pose(&mut out, p);
        }
    }
    out
}

/// Decode a migrated session blob back into its cache-pool identity and
/// a ready-to-install [`WindowCache`].  Version or method skew fails
/// with an actionable message, mirroring [`crate::checkpoint::load`];
/// malformed bytes are recoverable errors, never a panic.
pub fn decode_session(bytes: &[u8], expected_method: &str) -> Result<(SessionKey, WindowCache)> {
    let mut c = Cursor::new(bytes);
    let magic = c.u32("session magic").context("decoding session blob")?;
    if magic != SESSION_MAGIC {
        bail!("not a se2attn session blob (bad magic {magic:#010x})");
    }
    let version = c.u32("session version")?;
    if version != SESSION_VERSION {
        bail!(
            "session codec version {version}, expected {SESSION_VERSION} — \
             re-export the session from a worker running this build"
        );
    }
    let method = c.str("session method")?;
    if method != expected_method {
        bail!(
            "session was exported for method '{method}', expected \
             '{expected_method}' — refusing to resume a KV cache under a \
             different attention method"
        );
    }
    let key = SessionKey {
        scene: c.u64("session scene")?,
        t0: c.u32("session t0")?,
        sample: c.u32("session sample")?,
    };
    let precision = precision_from(c.u8("session precision")?)?;
    let feat_dim = c.count("session feat_dim", MAX_FEAT_DIM)?;
    let n_agents = c.count("session agents", MAX_AGENT_ROWS)?;
    let h = c.count("session steps", MAX_STEPS)?;
    let n_map = c.count("session map rows", MAX_MAP_ROWS)?;

    // every count is validated against the bytes actually present before
    // any proportional allocation (the framing discipline of wire.rs)
    let map_raw = c.bytes(n_map * feat_dim * 4, "map features")?;
    let mut map_feat = Vec::with_capacity(n_map * feat_dim);
    for chunk in map_raw.chunks_exact(4) {
        map_feat.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    if c.remaining() < n_map * POSE_WIRE_BYTES {
        bail!("corrupt session blob: truncated inside map poses");
    }
    let mut map_pose = Vec::with_capacity(n_map);
    for _ in 0..n_map {
        map_pose.push(take_pose(&mut c)?);
    }
    let map = Arc::new(MapTokens {
        feat: map_feat,
        world_pose: map_pose,
    });

    let mut steps = Vec::with_capacity(h);
    for _ in 0..h {
        let feat = take_feature_rows(&mut c, precision, n_agents, feat_dim)?;
        let mut poses = Vec::with_capacity(n_agents);
        for _ in 0..n_agents {
            poses.push(take_pose(&mut c)?);
        }
        steps.push((feat, poses));
    }
    if !c.is_empty() {
        bail!(
            "corrupt session blob: {} trailing bytes after the last step",
            c.remaining()
        );
    }
    let cache = WindowCache::from_parts(map, steps, precision)?;
    Ok((key, cache))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, SimConfig};
    use crate::sim::ScenarioGenerator;
    use crate::tokenizer::Tokenizer;

    fn sample_cache(seed: u64, precision: CachePrecision) -> (Tokenizer, WindowCache) {
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&ModelConfig::synthetic(), &sim);
        let s = ScenarioGenerator::new(sim.clone()).generate(seed);
        let window: Vec<_> = (0..sim.history_steps).map(|t| s.states[t].clone()).collect();
        let map = Arc::new(MapTokens::tokenize(&tok, &s.map_elements));
        let cache = WindowCache::from_window_with(&tok, map, &window, precision).unwrap();
        (tok, cache)
    }

    #[test]
    fn roundtrip_emits_bit_identically_at_every_precision() {
        for p in CachePrecision::ALL {
            let (tok, cache) = sample_cache(11, p);
            let key = SessionKey { scene: 11, t0: 7, sample: 2 };
            let blob = encode_session("se2fourier", key, &cache);
            let (back_key, back) = decode_session(&blob, "se2fourier").unwrap();
            assert_eq!(back_key, key);
            assert_eq!(back.precision(), p);
            let (a, b) = (cache.emit(&tok).unwrap(), back.emit(&tok).unwrap());
            assert_eq!(a.feat, b.feat, "{p:?}: features must round-trip losslessly");
            assert_eq!(a.pose, b.pose, "{p:?}");
            assert_eq!(a.tq, b.tq, "{p:?}");
            assert_eq!(a.frame, b.frame, "{p:?}");
        }
    }

    #[test]
    fn blob_size_matches_memmodel_exactly() {
        let sim = SimConfig::default();
        for p in CachePrecision::ALL {
            let (_, cache) = sample_cache(3, p);
            let key = SessionKey { scene: 3, t0: 7, sample: 0 };
            let blob = encode_session("abs", key, &cache);
            assert_eq!(
                blob.len(),
                session_blob_bytes(
                    "abs",
                    cache.map().len(),
                    sim.n_agents,
                    sim.history_steps,
                    cache.feat_dim(),
                    p
                ),
                "{p:?}"
            );
        }
    }

    #[test]
    fn version_and_method_skew_fail_actionably() {
        let (_, cache) = sample_cache(5, CachePrecision::F32);
        let key = SessionKey { scene: 5, t0: 7, sample: 0 };
        let mut blob = encode_session("se2fourier", key, &cache);

        // wrong method: refuse to resume under a different attention method
        let err = decode_session(&blob, "abs").unwrap_err();
        assert!(format!("{err:#}").contains("exported for method 'se2fourier'"), "{err:#}");
        assert!(format!("{err:#}").contains("'abs'"), "{err:#}");

        // bumped version: actionable, names both versions
        blob[4..8].copy_from_slice(&2u32.to_le_bytes());
        let err = decode_session(&blob, "se2fourier").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("session codec version 2, expected 1"), "{msg}");

        // garbage magic: typed, names the blob kind
        blob[0..4].copy_from_slice(&0xAABB_CCDDu32.to_le_bytes());
        let err = decode_session(&blob, "se2fourier").unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"), "{err:#}");
    }

    #[test]
    fn truncation_and_trailing_bytes_are_recoverable_errors() {
        let (_, cache) = sample_cache(9, CachePrecision::F16);
        let key = SessionKey { scene: 9, t0: 7, sample: 1 };
        let blob = encode_session("se2fourier", key, &cache);
        for cut in [10usize, 40, 60, blob.len() / 2, blob.len() - 1] {
            assert!(
                decode_session(&blob[..cut], "se2fourier").is_err(),
                "cut at {cut} must fail, not panic"
            );
        }
        let mut padded = blob.clone();
        padded.extend_from_slice(&[0u8; 7]);
        let err = decode_session(&padded, "se2fourier").unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }
}
