//! Multi-process scale-out: worker shards as child **processes**.
//!
//! The in-process [`super::server::Server`] keeps every shard on a
//! thread inside one address space.  This module moves the shard
//! boundary to a process boundary: a [`ProcServer`] coordinator owns
//! admission, routing, and the pending-request table, while each worker
//! is a separate OS process speaking the length-prefixed binary
//! protocol of [`super::wire`] over a loopback TCP socket.
//!
//! What the process boundary buys (and what this module must therefore
//! guarantee):
//!
//! * **Scale-out** — workers step rollouts on their own cores with no
//!   shared allocator or `Arc` contention; `benches/shard_scaling.rs`
//!   measures the 1 -> 4 process curve.
//! * **Fault isolation** — a worker SIGKILL'd mid-rollout loses no
//!   sessions: the coordinator keeps the full request envelope in its
//!   pending table and **replays** it to a live worker (deterministic
//!   re-derivation; the rollout restarts from `t0` with the same seeds,
//!   so results stay bit-identical to the single-process path).
//! * **Migration, not cache misses** — a *cooperative* handoff (drain)
//!   ships each live session's KV cache through the
//!   [`super::session_codec`] blob inside a [`Frame::Transfer`], so the
//!   receiving worker resumes mid-rollout with warm rows instead of
//!   rebuilding them.
//!
//! Failure model (see DESIGN.md §19): a request is **replayed** when
//! its worker dies uncleanly (crash, SIGKILL, socket loss), **migrated**
//! when its worker drains cleanly, and **lost** only when every worker
//! is excluded — in which case the caller gets a typed error, never a
//! hang.  A drain that dies mid-way (SIGKILL'd after `Drain`, partition,
//! rejected `Transfer`) is both: envelopes whose `Transfer` landed were
//! migrated, the rest replay like any other death.  Liveness is
//! heartbeat + connection-loss based; respawn is supervised by the
//! coordinator with a generation counter so a stale reader thread can
//! never double-declare a death, and a respawn that fails to spawn
//! re-routes or typed-fails every envelope parked on it.

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Method, ProcConfig};
use crate::prng::SplitMix64;
use crate::trace::{self, Stage};

use super::admission::{AdmissionConfig, AdmissionError};
use super::kvcache::{CacheConfig, KvCachePool, SessionKey};
use super::model::SlotParams;
use super::rollout::{RolloutEngine, RolloutRequest, RolloutResult, SessionState, StepSlot};
use super::router::shard_of_excluding;
use super::server::Backend;
use super::session_codec::{decode_session, encode_session};
use super::telemetry::{CacheStats, ServerStats};
use super::wire::{Frame, SessionTransfer, WireError, MAX_FRAME_BYTES, WIRE_VERSION};

// ---------------------------------------------------------------------------
// Coordinator state
// ---------------------------------------------------------------------------

/// An admitted request the coordinator has not yet answered.  Keeps the
/// full [`RolloutRequest`] so the envelope can be **replayed** to
/// another worker if its current owner dies — the worker side holds no
/// state the coordinator cannot reconstruct.
struct Pending {
    worker: usize,
    tenant: u8,
    method: Method,
    request: RolloutRequest,
    submitted_at: Instant,
    respond: mpsc::Sender<Result<RolloutResult>>,
}

/// Per-worker connection slot.  `generation` increments on every death
/// so a stale reader thread (still blocked on the old socket) can never
/// re-trigger death handling for a slot that already reconnected.
struct SlotState {
    conn: Option<TcpStream>,
    last_seen: Instant,
    generation: u64,
    child: Option<Child>,
    /// Frames queued while the worker is between connections (spawned
    /// but not yet through its handshake).  Flushed on `HelloAck`.
    backlog: Vec<Vec<u8>>,
    /// A handshake is mid-flush for this slot (writing HelloAck + backlog
    /// with the lock *released*, so a stalled worker socket cannot block
    /// the submit path); refuses duplicate registrations meanwhile.
    registering: bool,
    draining: bool,
    dead: bool,
    /// Set when a respawn is launched; consumed by the handshake to
    /// record resurrect latency.
    respawn_started: Option<Instant>,
}

struct Shared {
    slots: Vec<Mutex<SlotState>>,
    pending: Mutex<HashMap<u64, Pending>>,
    stats: Arc<ServerStats>,
    cfg: ProcConfig,
    /// Shared secret each worker must echo in its `Hello`; a random
    /// local process cannot register as a worker by guessing the port.
    token: u64,
    addr: SocketAddr,
    shutting_down: AtomicBool,
    next_req: AtomicU64,
    /// argv prefix for spawning workers (`[program, fixed args...]`);
    /// the coordinator appends `--connect/--worker-id/--token/...`.
    worker_cmd: Vec<String>,
    max_queue: usize,
}

/// Coordinator for a fleet of worker processes.  Mirrors the submit
/// surface of [`super::server::Server`] (`submit`, `submit_for_tenant`,
/// `call`) so callers and tests can swap the two behind one shape.
pub struct ProcServer {
    shared: Arc<Shared>,
    threads: Vec<thread::JoinHandle<()>>,
}

/// Exclusion vector for routing: a worker takes no new traffic while
/// dead or draining.
fn exclusion(shared: &Shared) -> Vec<bool> {
    shared
        .slots
        .iter()
        .map(|s| {
            let s = s.lock().unwrap();
            s.dead || s.draining
        })
        .collect()
}

/// On the proc path queue depth *is* inflight depth (workers admit
/// immediately; there is no coordinator-side step queue).
fn sync_depth(stats: &ServerStats, w: usize) {
    stats.shards[w].queue_depth.set(stats.shards[w].inflight.get());
}

/// Deliver one encoded frame to worker `i`: write it if connected,
/// queue it if the worker is between connections, and fall through to
/// death handling if the write fails or the slot is already dead (the
/// latter closes the race where a request is routed to a worker that
/// dies between routing and send).
fn send_payload(shared: &Arc<Shared>, i: usize, payload: Vec<u8>) {
    let failed_gen = {
        let mut slot = shared.slots[i].lock().unwrap();
        match slot.conn.as_mut() {
            Some(conn) => match super::wire::write_frame(conn, &payload) {
                Ok(()) => return,
                Err(_) => Some(slot.generation),
            },
            None if !slot.dead => {
                slot.backlog.push(payload);
                return;
            }
            None => None,
        }
    };
    match failed_gen {
        Some(generation) => on_worker_down(shared, i, generation),
        // dead slot with no connection: whatever was pending here must
        // move now — nothing else will notice
        None => replay_pending(shared, i),
    }
}

/// Handle the death of worker `i`.  Idempotent per generation: the
/// caller passes the generation it observed, and only the first caller
/// for that generation does the work (reader thread, supervisor, and a
/// failed write can all race here).
fn on_worker_down(shared: &Arc<Shared>, i: usize, expected_gen: u64) {
    if shared.shutting_down.load(Ordering::SeqCst) {
        return;
    }
    let (planned, child) = {
        let mut slot = shared.slots[i].lock().unwrap();
        if slot.generation != expected_gen {
            return; // someone else already handled this death
        }
        slot.generation += 1;
        if let Some(conn) = slot.conn.take() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        let child = slot.child.take();
        let planned = slot.draining;
        let respawn = shared.cfg.respawn && !planned && !shared.cfg.manual_workers;
        slot.dead = !respawn;
        slot.respawn_started = respawn.then(Instant::now);
        if !respawn {
            slot.backlog.clear();
        }
        (planned, child)
    };
    shared.stats.shards[i].live.set(0);
    shared.stats.shards[i].queue_depth.set(0);
    if let Some(mut child) = child {
        let _ = child.kill();
        let _ = child.wait();
    }
    if planned {
        // Drain: `Transfer` handling already moved migrated envelopes off
        // this slot, so whatever it still owns was NOT migrated — the
        // worker was killed mid-drain, partitioned, or its Transfer was
        // rejected.  Those leftovers must replay (or fail typed) like any
        // other death; only the death counter and respawn are skipped,
        // because the exit itself was requested.
        replay_pending(shared, i);
        return;
    }
    shared.stats.migration.worker_deaths.inc();
    replay_pending(shared, i);
    let respawning = shared.slots[i].lock().unwrap().respawn_started.is_some();
    if respawning {
        shared.stats.migration.worker_respawns.inc();
        if let Err(e) = spawn_child(shared, i) {
            eprintln!("se2attn: respawn of worker {i} failed: {e:#}");
            {
                let mut slot = shared.slots[i].lock().unwrap();
                slot.dead = true;
                slot.respawn_started = None;
                slot.backlog.clear();
            }
            // replay_pending above parked this slot's envelopes on the
            // respawn that now cannot happen; with `respawn_started`
            // cleared they re-route to a live worker or fail typed
            // instead of waiting forever on a dead slot's backlog.
            replay_pending(shared, i);
        }
    }
}

/// Re-route every pending envelope owned by dead worker `from`.  A
/// respawning worker keeps envelopes whose scene has no live
/// alternative (they sit in the backlog until the respawn connects);
/// otherwise orphans fail with a typed error rather than hanging.
fn replay_pending(shared: &Arc<Shared>, from: usize) {
    // exclusion snapshot BEFORE the pending lock (lock order: slots,
    // then pending — send_payload below re-takes slot locks)
    let mut excluded = exclusion(shared);
    excluded[from] = true;
    let from_respawning = shared.slots[from].lock().unwrap().respawn_started.is_some();
    let mut sends: Vec<(usize, Vec<u8>)> = Vec::new();
    let mut fails: Vec<(mpsc::Sender<Result<RolloutResult>>, anyhow::Error)> = Vec::new();
    {
        let mut pending = shared.pending.lock().unwrap();
        let owned: Vec<u64> = pending
            .iter()
            .filter(|(_, p)| p.worker == from)
            .map(|(&id, _)| id)
            .collect();
        for req_id in owned {
            let target = shard_of_excluding(
                pending[&req_id].request.scenario.scene_id(),
                shared.slots.len(),
                &excluded,
            )
            .or(if from_respawning { Some(from) } else { None });
            match target {
                Some(t) => {
                    let p = pending.get_mut(&req_id).unwrap();
                    shared.stats.shards[p.worker].inflight.sub(1);
                    shared.stats.shards[t].inflight.add(1);
                    p.worker = t;
                    let frame = Frame::Request {
                        req_id,
                        tenant: p.tenant,
                        trace_id: 0,
                        method: p.method.name().to_string(),
                        rollout: p.request.clone(),
                    };
                    sends.push((t, frame.encode()));
                    shared.stats.migration.envelopes_replayed.inc();
                }
                None => {
                    let p = pending.remove(&req_id).unwrap();
                    shared.stats.shards[p.worker].inflight.sub(1);
                    shared.stats.requests_failed.inc();
                    shared.stats.shards[from].failed.inc();
                    fails.push((
                        p.respond,
                        anyhow!("worker {from} died with no live worker to replay to"),
                    ));
                }
            }
        }
    }
    for w in 0..shared.slots.len() {
        sync_depth(&shared.stats, w);
    }
    for (t, payload) in sends {
        send_payload(shared, t, payload);
    }
    for (respond, err) in fails {
        let _ = respond.send(Err(err));
    }
}

fn spawn_child(shared: &Arc<Shared>, i: usize) -> Result<u32> {
    let addr = shared.addr.to_string();
    spawn_child_via(shared, i, &addr)
}

/// Launch the worker process for slot `i`, telling it to connect to
/// `connect` (normally the coordinator's own listener; tests interpose
/// a chaos proxy here).
fn spawn_child_via(shared: &Arc<Shared>, i: usize, connect: &str) -> Result<u32> {
    let cmd = &shared.worker_cmd;
    if cmd.is_empty() {
        bail!("no worker command configured (manual_workers fleet?)");
    }
    let child = Command::new(&cmd[0])
        .args(&cmd[1..])
        .arg("--connect")
        .arg(connect)
        .arg("--worker-id")
        .arg(i.to_string())
        .arg("--token")
        .arg(shared.token.to_string())
        .arg("--heartbeat-ms")
        .arg(shared.cfg.heartbeat.as_millis().to_string())
        .spawn()
        .with_context(|| format!("spawning worker {i} via {:?}", cmd[0]))?;
    let pid = child.id();
    shared.slots[i].lock().unwrap().child = Some(child);
    Ok(pid)
}

// ---------------------------------------------------------------------------
// Coordinator threads
// ---------------------------------------------------------------------------

fn accept_loop(shared: Arc<Shared>, listener: TcpListener) {
    for conn in listener.incoming() {
        if shared.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        // a thread per handshake: a client that connects and stalls
        // (or feeds garbage byte-by-byte) must not block the accept
        // loop — the protocol-fuzz tests rely on this
        let shared = Arc::clone(&shared);
        thread::Builder::new()
            .name("se2-proc-handshake".into())
            .spawn(move || handshake(shared, stream))
            .expect("spawn handshake thread");
    }
}

/// Validate a freshly accepted connection: read `Hello`, check version
/// + token + worker id, flush the slot backlog, hand the socket to a
/// reader thread.  Every rejection counts in `wire_errors` and closes
/// the socket — malformed clients get silence, never a panic.
fn handshake(shared: Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.cfg.connect_timeout));
    let hello = match Frame::read_from(&mut stream) {
        Ok(f) => f,
        Err(_) => {
            shared.stats.migration.wire_errors.inc();
            return;
        }
    };
    let Frame::Hello { version, worker_id, pid: _, token } = hello else {
        shared.stats.migration.wire_errors.inc();
        return;
    };
    let worker = worker_id as usize;
    if version != WIRE_VERSION || token != shared.token || worker >= shared.slots.len() {
        shared.stats.migration.wire_errors.inc();
        return;
    }
    let _ = stream.set_read_timeout(None);
    let Ok(mut reader) = stream.try_clone() else { return };
    let gen = {
        let mut slot = shared.slots[worker].lock().unwrap();
        if slot.conn.is_some() || slot.registering {
            // duplicate registration for a live slot — refuse it rather
            // than hijacking the session
            shared.stats.migration.wire_errors.inc();
            return;
        }
        slot.registering = true;
        if let Some(t0) = slot.respawn_started.take() {
            shared.stats.migration.resurrect_latency.record(t0.elapsed());
        }
        slot.dead = false;
        slot.draining = false;
        slot.last_seen = Instant::now();
        slot.generation
    };
    // Flush HelloAck + queued backlog with the slot lock RELEASED: these
    // writes can block on a full TCP buffer, and holding the lock here
    // would stall `exclusion()` — i.e. admission for the whole fleet —
    // behind one stalled worker socket.  `registering` keeps concurrent
    // handshakes out, and `conn` is still `None`, so racing
    // `send_payload` calls park frames in the backlog; the loop re-takes
    // the lock and drains whatever accumulated until none remain.
    let mut ok = Frame::HelloAck.write_to(&mut stream).is_ok();
    loop {
        let batch = {
            let mut slot = shared.slots[worker].lock().unwrap();
            if slot.generation != gen || slot.dead || shared.shutting_down.load(Ordering::SeqCst) {
                // death handling or shutdown overtook the flush
                slot.registering = false;
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            if !ok {
                // connection died mid-flush: leave the backlog for the
                // supervisor's next pass to recover
                slot.registering = false;
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            if slot.backlog.is_empty() {
                slot.conn = Some(stream);
                slot.registering = false;
                break;
            }
            std::mem::take(&mut slot.backlog)
        };
        let mut unsent: Vec<Vec<u8>> = Vec::new();
        for payload in batch {
            if ok && super::wire::write_frame(&mut stream, &payload).is_err() {
                ok = false;
            }
            if !ok {
                unsent.push(payload);
            }
        }
        if !unsent.is_empty() {
            // restore what we could not send ahead of frames queued
            // meanwhile, preserving delivery order
            let mut slot = shared.slots[worker].lock().unwrap();
            unsent.append(&mut slot.backlog);
            slot.backlog = unsent;
        }
    }
    shared.stats.shards[worker].live.set(1);
    let rshared = Arc::clone(&shared);
    thread::Builder::new()
        .name(format!("se2-proc-read-{worker}"))
        .spawn(move || reader_loop(rshared, &mut reader, worker, gen))
        .expect("spawn reader thread");
}

fn reader_loop(shared: Arc<Shared>, reader: &mut TcpStream, i: usize, gen: u64) {
    loop {
        match Frame::read_from(reader) {
            Ok(frame) => {
                {
                    let mut slot = shared.slots[i].lock().unwrap();
                    if slot.generation != gen {
                        return; // stale reader for a reconnected slot
                    }
                    slot.last_seen = Instant::now();
                }
                handle_frame(&shared, i, frame);
            }
            Err(e) => {
                if !matches!(e, WireError::Io(_)) {
                    shared.stats.migration.wire_errors.inc();
                }
                on_worker_down(&shared, i, gen);
                return;
            }
        }
    }
}

/// Liveness sweep: declares a worker dead when its heartbeats stop
/// (`death_after` of silence) or its child process is reaped.
fn supervisor_loop(shared: Arc<Shared>) {
    let tick = (shared.cfg.heartbeat / 2).max(Duration::from_millis(10));
    while !shared.shutting_down.load(Ordering::SeqCst) {
        thread::sleep(tick);
        for i in 0..shared.slots.len() {
            let down_gen = {
                let mut slot = shared.slots[i].lock().unwrap();
                let stale =
                    slot.conn.is_some() && slot.last_seen.elapsed() > shared.cfg.death_after;
                let reaped = match slot.child.as_mut() {
                    Some(c) => matches!(c.try_wait(), Ok(Some(_))),
                    None => false,
                };
                (stale || reaped).then_some(slot.generation)
            };
            if let Some(gen) = down_gen {
                on_worker_down(&shared, i, gen);
            }
        }
    }
}

/// Dispatch one frame from worker `i`.
fn handle_frame(shared: &Arc<Shared>, i: usize, frame: Frame) {
    match frame {
        Frame::Heartbeat { .. } => {} // last_seen already refreshed
        Frame::Response { req_id, outcome } => {
            let p = shared.pending.lock().unwrap().remove(&req_id);
            let Some(p) = p else { return }; // replayed + answered twice
            shared.stats.shards[p.worker].inflight.sub(1);
            sync_depth(&shared.stats, p.worker);
            match outcome {
                Ok(res) => {
                    shared.stats.requests_done.inc();
                    shared.stats.shards[p.worker].done.inc();
                    shared.stats.e2e_latency.record(p.submitted_at.elapsed());
                    shared
                        .stats
                        .decode_latency
                        .record(Duration::from_secs_f64(res.decode_ms / 1e3));
                    shared.stats.families.record(
                        p.request.scenario.family,
                        &res.min_ade,
                        res.collisions as u64,
                        res.trajectories.len() as u64,
                    );
                    shared.stats.tenants.done(p.tenant);
                    let _ = p.respond.send(Ok(res));
                }
                Err(msg) => {
                    shared.stats.requests_failed.inc();
                    shared.stats.shards[p.worker].failed.inc();
                    shared.stats.e2e_latency.record(p.submitted_at.elapsed());
                    let _ = p.respond.send(Err(anyhow!(msg)));
                }
            }
        }
        Frame::Transfer {
            req_id,
            tenant,
            trace_id,
            method,
            rollout,
            steps_done,
            decode_ms,
            sessions,
        } => {
            let mut excluded = exclusion(shared);
            excluded[i] = true;
            let n_sessions = sessions.len() as u64;
            let kv_bytes: u64 = sessions.iter().map(|s| s.kv.len() as u64).sum();
            let target =
                shard_of_excluding(rollout.scenario.scene_id(), shared.slots.len(), &excluded);
            match target {
                Some(t) => {
                    {
                        let mut pending = shared.pending.lock().unwrap();
                        if let Some(p) = pending.get_mut(&req_id) {
                            shared.stats.shards[p.worker].inflight.sub(1);
                            shared.stats.shards[t].inflight.add(1);
                            p.worker = t;
                        }
                    }
                    sync_depth(&shared.stats, i);
                    sync_depth(&shared.stats, t);
                    let frame = Frame::Transfer {
                        req_id,
                        tenant,
                        trace_id,
                        method,
                        rollout,
                        steps_done,
                        decode_ms,
                        sessions,
                    };
                    send_payload(shared, t, frame.encode());
                    shared.stats.migration.sessions_migrated.add(n_sessions);
                    shared.stats.migration.migration_bytes.add(kv_bytes);
                    if trace::profiling() {
                        trace::instant(Stage::Migrate, kv_bytes);
                    }
                }
                None => {
                    let p = shared.pending.lock().unwrap().remove(&req_id);
                    if let Some(p) = p {
                        shared.stats.shards[p.worker].inflight.sub(1);
                        sync_depth(&shared.stats, p.worker);
                        shared.stats.requests_failed.inc();
                        shared.stats.shards[i].failed.inc();
                        let _ = p.respond.send(Err(anyhow!(
                            "worker {i} drained with no live worker to migrate its sessions to"
                        )));
                    }
                }
            }
        }
        Frame::DrainDone => {}
        _ => shared.stats.migration.wire_errors.inc(),
    }
}

// ---------------------------------------------------------------------------
// ProcServer
// ---------------------------------------------------------------------------

impl ProcServer {
    /// Start the coordinator: bind the loopback listener, start the
    /// accept + supervisor threads, and (unless
    /// [`ProcConfig::manual_workers`]) spawn one worker process per
    /// slot via `worker_cmd`.
    pub fn start(
        workers: usize,
        cfg: ProcConfig,
        admission: AdmissionConfig,
        worker_cmd: Vec<String>,
    ) -> Result<ProcServer> {
        if workers == 0 {
            bail!("a process fleet needs at least one worker");
        }
        if worker_cmd.is_empty() && !cfg.manual_workers {
            bail!("no worker command given (set manual_workers to connect workers yourself)");
        }
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding the coordinator socket")?;
        let addr = listener.local_addr().context("reading the bound address")?;
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0);
        let seed = ((std::process::id() as u64) << 32) | ((addr.port() as u64) ^ nanos);
        let token = SplitMix64::new(seed).next_u64();
        let shared = Arc::new(Shared {
            slots: (0..workers)
                .map(|_| {
                    Mutex::new(SlotState {
                        conn: None,
                        last_seen: Instant::now(),
                        generation: 0,
                        child: None,
                        backlog: Vec::new(),
                        registering: false,
                        draining: false,
                        dead: false,
                        respawn_started: None,
                    })
                })
                .collect(),
            pending: Mutex::new(HashMap::new()),
            stats: Arc::new(ServerStats::with_shards(workers)),
            cfg,
            token,
            addr,
            shutting_down: AtomicBool::new(false),
            next_req: AtomicU64::new(0),
            worker_cmd,
            max_queue: admission.max_queue,
        });
        let mut threads = Vec::new();
        let a = Arc::clone(&shared);
        threads.push(
            thread::Builder::new()
                .name("se2-proc-accept".into())
                .spawn(move || accept_loop(a, listener))
                .context("spawning the accept thread")?,
        );
        let s = Arc::clone(&shared);
        threads.push(
            thread::Builder::new()
                .name("se2-proc-supervise".into())
                .spawn(move || supervisor_loop(s))
                .context("spawning the supervisor thread")?,
        );
        if !shared.cfg.manual_workers {
            for i in 0..workers {
                spawn_child(&shared, i)?;
            }
        }
        Ok(ProcServer { shared, threads })
    }

    pub fn n_workers(&self) -> usize {
        self.shared.slots.len()
    }

    /// The coordinator's loopback listener address — workers (and the
    /// protocol-fuzz tests) connect here.
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Handshake token; exposed so tests can connect hand-rolled
    /// workers.
    pub fn token(&self) -> u64 {
        self.shared.token
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.shared.stats)
    }

    /// Sources for the introspection server (`/healthz` shows per-worker
    /// liveness via the shard `live` gauges).
    pub fn obs_sources(&self) -> crate::obs::http::ObsSources {
        crate::obs::http::ObsSources {
            stats: Arc::clone(&self.shared.stats),
            tracer: None,
            max_queue: self.shared.max_queue,
        }
    }

    /// OS pid of worker `i`'s child process, if the coordinator spawned
    /// one (fault-injection tests SIGKILL this).
    pub fn worker_pid(&self, i: usize) -> Option<u32> {
        self.shared.slots[i].lock().unwrap().child.as_ref().map(Child::id)
    }

    /// Spawn worker `i` told to connect through `connect` instead of the
    /// coordinator's own address — the hook the chaos-proxy tests use to
    /// interpose delays and partitions on the worker socket.
    pub fn spawn_worker_via(&self, i: usize, connect: &str) -> Result<u32> {
        {
            let mut slot = self.shared.slots[i].lock().unwrap();
            slot.dead = false;
            slot.draining = false;
        }
        spawn_child_via(&self.shared, i, connect)
    }

    /// Cooperative handoff: stop routing new work to worker `i` and ask
    /// it to export its live sessions ([`Frame::Transfer`]) and exit.
    pub fn drain_worker(&self, i: usize) {
        self.shared.slots[i].lock().unwrap().draining = true;
        send_payload(&self.shared, i, Frame::Drain.encode());
    }

    pub fn submit(
        &self,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        self.submit_for_tenant(0, method, request)
    }

    /// Admit + route a request to a live worker.  Mirrors
    /// [`super::server::Server::submit_for_tenant`]: the receiver always
    /// yields exactly one result, typed errors included.
    pub fn submit_for_tenant(
        &self,
        tenant: u8,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let (rtx, rrx) = mpsc::channel();
        self.enqueue(tenant, method, request, rtx);
        rrx
    }

    pub fn call(&self, method: Method, request: RolloutRequest) -> Result<RolloutResult> {
        self.submit(method, request)
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    fn enqueue(
        &self,
        tenant: u8,
        method: Method,
        request: RolloutRequest,
        respond: mpsc::Sender<Result<RolloutResult>>,
    ) {
        let shared = &self.shared;
        if shared.shutting_down.load(Ordering::SeqCst) {
            let _ = respond.send(Err(anyhow!("server is shut down — request not accepted")));
            return;
        }
        let excluded = exclusion(shared);
        let Some(worker) =
            shard_of_excluding(request.scenario.scene_id(), shared.slots.len(), &excluded)
        else {
            let _ = respond.send(Err(anyhow!("no live worker process to route to")));
            return;
        };
        let sh = &shared.stats.shards[worker];
        sh.requests.inc();
        if shared.max_queue > 0 && sh.inflight.get() >= shared.max_queue as u64 {
            shared.stats.queue_rejections.inc();
            sh.rejected.inc();
            shared.stats.tenants.rejected(tenant);
            let _ = respond.send(Err(anyhow::Error::new(AdmissionError::QueueFull {
                shard: worker,
                capacity: shared.max_queue,
            })));
            return;
        }
        let req_id = shared.next_req.fetch_add(1, Ordering::SeqCst) + 1;
        let frame = Frame::Request {
            req_id,
            tenant,
            trace_id: 0,
            method: method.name().to_string(),
            rollout: request.clone(),
        };
        let payload = frame.encode();
        if payload.len() > MAX_FRAME_BYTES as usize {
            // undeliverable to ANY worker — fail typed now rather than
            // letting the refused write masquerade as a worker death
            // (which would replay the same oversize frame forever)
            shared.stats.requests_failed.inc();
            sh.failed.inc();
            let _ = respond.send(Err(anyhow!(
                "request frame is {} bytes, over the {} byte wire cap",
                payload.len(),
                MAX_FRAME_BYTES
            )));
            return;
        }
        sh.inflight.add(1);
        sync_depth(&shared.stats, worker);
        shared.stats.requests_in.inc();
        shared.stats.tenants.admitted(tenant);
        // pending entry goes in BEFORE the send: if the worker dies
        // mid-write, death handling finds and replays the envelope
        shared.pending.lock().unwrap().insert(
            req_id,
            Pending {
                worker,
                tenant,
                method,
                request,
                submitted_at: Instant::now(),
                respond,
            },
        );
        send_payload(shared, worker, payload);
    }

    /// Stop the fleet: kill children, close sockets, fail anything
    /// still pending.  Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        shutdown_now(&self.shared);
    }
}

fn shutdown_now(shared: &Arc<Shared>) {
    if shared.shutting_down.swap(true, Ordering::SeqCst) {
        return;
    }
    for slot in &shared.slots {
        let mut slot = slot.lock().unwrap();
        slot.dead = true;
        slot.backlog.clear();
        if let Some(conn) = slot.conn.take() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        if let Some(mut child) = slot.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    // wake the accept loop so it observes shutting_down and exits
    let _ = TcpStream::connect(shared.addr);
    let drained: Vec<Pending> = {
        let mut pending = shared.pending.lock().unwrap();
        pending.drain().map(|(_, p)| p).collect()
    };
    for p in drained {
        let _ = p.respond.send(Err(anyhow!("server is shut down — request abandoned")));
    }
}

impl Drop for ProcServer {
    fn drop(&mut self) {
        shutdown_now(&self.shared);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Connection parameters for one worker process (parsed from the argv
/// the coordinator passed to it).
pub struct WorkerOptions {
    pub connect: String,
    pub worker_id: u32,
    pub token: u64,
    pub heartbeat: Duration,
}

enum WorkerEvent {
    Frame(Frame),
    Disconnected,
}

/// One admitted request on the worker: the stepping state the
/// continuous loop advances, plus everything needed to re-wrap it in a
/// [`Frame::Transfer`] on drain.
struct ActiveReq {
    req_id: u64,
    tenant: u8,
    trace_id: u64,
    method: Method,
    request: RolloutRequest,
    sessions: Vec<SessionState>,
    steps_done: usize,
    decode_ms: f64,
}

/// The coordinator may still be binding its listener when a freshly
/// spawned worker starts; retry briefly instead of dying on the first
/// refused connect.
fn connect_retry(addr: &str) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..200 {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
        thread::sleep(Duration::from_millis(50));
    }
    Err(anyhow!("connecting to coordinator {addr}: {}", last.unwrap()))
}

/// Run one worker process: connect, handshake, then loop stepping
/// admitted rollouts and answering frames until the coordinator goes
/// away (socket loss => clean exit — a worker never outlives its
/// coordinator as an orphan) or a `Drain` arrives.
pub fn worker_serve(
    engine: &RolloutEngine,
    backend: &mut Backend,
    cache: CacheConfig,
    opts: &WorkerOptions,
) -> Result<()> {
    let mut conn = connect_retry(&opts.connect)?;
    let _ = conn.set_nodelay(true);
    Frame::Hello {
        version: WIRE_VERSION,
        worker_id: opts.worker_id,
        pid: std::process::id(),
        token: opts.token,
    }
    .write_to(&mut conn)
    .context("sending Hello")?;
    match Frame::read_from(&mut conn).context("waiting for HelloAck")? {
        Frame::HelloAck => {}
        other => bail!("expected HelloAck, coordinator sent {other:?}"),
    }
    let (tx, rx) = mpsc::channel();
    let mut reader = conn.try_clone().context("cloning the socket for reads")?;
    thread::Builder::new()
        .name("se2-worker-read".into())
        .spawn(move || loop {
            match Frame::read_from(&mut reader) {
                Ok(f) => {
                    if tx.send(WorkerEvent::Frame(f)).is_err() {
                        return;
                    }
                }
                Err(_) => {
                    let _ = tx.send(WorkerEvent::Disconnected);
                    return;
                }
            }
        })
        .context("spawning the worker reader thread")?;

    let pool = KvCachePool::new(cache, Arc::new(CacheStats::default()));
    let mut active: Vec<ActiveReq> = Vec::new();
    let mut hb_seq: u64 = 0;
    let mut last_hb = Instant::now();
    loop {
        let mut events: Vec<WorkerEvent> = Vec::new();
        if active.is_empty() {
            // idle: block until traffic or the next heartbeat is due
            let wait = opts
                .heartbeat
                .saturating_sub(last_hb.elapsed())
                .max(Duration::from_millis(1));
            match rx.recv_timeout(wait) {
                Ok(ev) => events.push(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
        }
        while let Ok(ev) = rx.try_recv() {
            events.push(ev);
        }
        for ev in events {
            match ev {
                WorkerEvent::Disconnected => return Ok(()),
                WorkerEvent::Frame(Frame::Request {
                    req_id,
                    tenant,
                    trace_id,
                    method,
                    rollout,
                }) => {
                    let admitted =
                        admit_request(engine, backend, req_id, tenant, trace_id, &method, rollout);
                    match admitted {
                        Ok(a) => active.push(a),
                        Err(msg) => {
                            let resp = Frame::Response { req_id, outcome: Err(msg) };
                            if resp.write_to(&mut conn).is_err() {
                                return Ok(());
                            }
                        }
                    }
                }
                WorkerEvent::Frame(Frame::Transfer {
                    req_id,
                    tenant,
                    trace_id,
                    method,
                    rollout,
                    steps_done,
                    decode_ms,
                    sessions,
                }) => {
                    let admitted = admit_transfer(
                        engine, backend, &pool, req_id, tenant, trace_id, &method, rollout,
                        steps_done, decode_ms, sessions,
                    );
                    match admitted {
                        Ok(a) => active.push(a),
                        Err(msg) => {
                            let resp = Frame::Response { req_id, outcome: Err(msg) };
                            if resp.write_to(&mut conn).is_err() {
                                return Ok(());
                            }
                        }
                    }
                }
                WorkerEvent::Frame(Frame::Drain) => {
                    export_all(&mut conn, &pool, &mut active);
                    return Ok(());
                }
                WorkerEvent::Frame(_) => {} // coordinator never sends anything else
            }
        }
        if last_hb.elapsed() >= opts.heartbeat {
            hb_seq += 1;
            if (Frame::Heartbeat { seq: hb_seq }.write_to(&mut conn)).is_err() {
                return Ok(());
            }
            last_hb = Instant::now();
        }
        if !active.is_empty() {
            for (req_id, outcome) in step_active(engine, backend, &pool, &mut active) {
                if (Frame::Response { req_id, outcome }.write_to(&mut conn)).is_err() {
                    return Ok(());
                }
            }
        }
    }
}

/// Validate and admit a fresh request; errors go back as typed strings
/// in a `Response` frame, matching the in-process server's messages.
#[allow(clippy::too_many_arguments)]
fn admit_request(
    engine: &RolloutEngine,
    backend: &Backend,
    req_id: u64,
    tenant: u8,
    trace_id: u64,
    method: &str,
    rollout: RolloutRequest,
) -> Result<ActiveReq, String> {
    let m = Method::parse(method).map_err(|e| format!("{e:#}"))?;
    if backend.n_replicas(m) == 0 {
        return Err(format!("method '{method}' is not deployed on this worker"));
    }
    if rollout.n_samples == 0 {
        return Err("rollout requires at least one sample".into());
    }
    let h = engine.sim.history_steps;
    if rollout.t0 + 1 < h || rollout.t0 >= rollout.scenario.states.len() {
        return Err(format!(
            "t0 {} outside the scenario (history {h}, {} recorded steps)",
            rollout.t0,
            rollout.scenario.states.len()
        ));
    }
    let n_agents = rollout.scenario.states[rollout.t0].len();
    if n_agents == 0 {
        return Err("scenario has no agents at t0".into());
    }
    for t in rollout.t0 + 1 - h..=rollout.t0 {
        if rollout.scenario.states[t].len() != n_agents {
            return Err(format!("agent count varies across the history window at t={t}"));
        }
    }
    let sessions = (0..rollout.n_samples)
        .map(|s| engine.begin_session(&rollout, s as u32))
        .collect();
    Ok(ActiveReq {
        req_id,
        tenant,
        trace_id,
        method: m,
        request: rollout,
        sessions,
        steps_done: 0,
        decode_ms: 0.0,
    })
}

/// Resume a migrated request: install each session's KV blob into the
/// local pool (a corrupt blob silently degrades to a cache-miss
/// rebuild — correctness never depends on the cache) and rebuild the
/// stepping state from the transferred windows/tracks.
#[allow(clippy::too_many_arguments)]
fn admit_transfer(
    engine: &RolloutEngine,
    backend: &Backend,
    pool: &KvCachePool,
    req_id: u64,
    tenant: u8,
    trace_id: u64,
    method: &str,
    rollout: RolloutRequest,
    steps_done: u32,
    decode_ms: f64,
    transfers: Vec<SessionTransfer>,
) -> Result<ActiveReq, String> {
    let m = Method::parse(method).map_err(|e| format!("{e:#}"))?;
    if backend.n_replicas(m) == 0 {
        return Err(format!("method '{method}' is not deployed on this worker"));
    }
    if transfers.is_empty() {
        return Err("transfer carries no sessions".into());
    }
    let _ = engine; // session geometry is already baked into the transfer
    let mut sessions = Vec::with_capacity(transfers.len());
    for st in transfers {
        let key = SessionKey {
            scene: rollout.scenario.scene_id(),
            t0: rollout.t0 as u32,
            sample: st.sample,
        };
        if !st.kv.is_empty() {
            if let Ok((k, cache)) = decode_session(&st.kv, m.name()) {
                if k == key {
                    pool.install_session(k, cache);
                }
            }
        }
        sessions.push(SessionState::from_parts(
            rollout.scenario.map_elements.clone(),
            st.window,
            st.track,
            key,
        ));
    }
    Ok(ActiveReq {
        req_id,
        tenant,
        trace_id,
        method: m,
        request: rollout,
        sessions,
        steps_done: steps_done as usize,
        decode_ms,
    })
}

/// One continuous-scheduler pass over the active set: batch all
/// requests per method into one `step_sessions` call (per-request
/// slots stay contiguous so [`RolloutEngine::step_seed`]'s chunk math
/// matches the single-process path bit-for-bit), then retire finished
/// requests.  Returns `(req_id, outcome)` pairs ready to wire back.
fn step_active(
    engine: &RolloutEngine,
    backend: &mut Backend,
    pool: &KvCachePool,
    active: &mut Vec<ActiveReq>,
) -> Vec<(u64, Result<RolloutResult, String>)> {
    let mut out = Vec::new();
    for m in Method::ALL {
        if !active.iter().any(|a| a.method == m) {
            continue;
        }
        let Some(model) = backend.route(m) else { continue };
        let mut slots: Vec<StepSlot> = Vec::new();
        for a in active.iter_mut().filter(|a| a.method == m) {
            let req = &a.request;
            let done = a.steps_done;
            for (i, s) in a.sessions.iter_mut().enumerate() {
                slots.push(StepSlot {
                    session: s,
                    params: SlotParams {
                        seed: engine.step_seed(req, done, i),
                        temperature: req.temperature,
                        trace: 0,
                    },
                });
            }
        }
        let stepped = engine.step_sessions(&**model, &mut slots, pool);
        drop(slots);
        match stepped {
            Ok(rep) => {
                let per_slot = rep.decode_ms / rep.real_slots.max(1) as f64;
                for a in active.iter_mut().filter(|a| a.method == m) {
                    a.decode_ms += per_slot * a.sessions.len() as f64;
                    a.steps_done += 1;
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let mut i = 0;
                while i < active.len() {
                    if active[i].method == m {
                        let a = active.swap_remove(i);
                        for s in &a.sessions {
                            pool.end_session(s.key());
                        }
                        out.push((a.req_id, Err(msg.clone())));
                    } else {
                        i += 1;
                    }
                }
            }
        }
    }
    let mut i = 0;
    while i < active.len() {
        if active[i].steps_done >= engine.sim.future_steps {
            let a = active.swap_remove(i);
            for s in &a.sessions {
                pool.end_session(s.key());
            }
            let decode_ms = a.decode_ms / a.steps_done.max(1) as f64;
            let res = engine.finish_request(&a.request, &a.sessions, decode_ms);
            out.push((a.req_id, Ok(res)));
        } else {
            i += 1;
        }
    }
    out
}

/// Encode a [`Frame::Transfer`] under the wire cap, degrading
/// gracefully: a request's aggregated KV blobs (each up to
/// [`MAX_FRAME_BYTES`] on its own at decode) can push the frame over
/// the cap, and `write_frame` now refuses such payloads outright.  So:
/// try with KV; if oversize, drop the blobs (the destination rebuilds
/// them as cache misses — the blob is an optimization, the envelope is
/// the truth); if the bare scheduler state *still* cannot fit, return
/// `None` so the caller skips the frame and the coordinator replays the
/// envelope when the drained worker's socket closes.
fn encode_transfer_bounded(mut frame: Frame) -> Option<Vec<u8>> {
    let payload = frame.encode();
    if payload.len() <= MAX_FRAME_BYTES as usize {
        return Some(payload);
    }
    let Frame::Transfer { sessions, .. } = &mut frame else {
        return None;
    };
    for s in sessions.iter_mut() {
        s.kv = Vec::new();
    }
    let payload = frame.encode();
    (payload.len() <= MAX_FRAME_BYTES as usize).then_some(payload)
}

/// Drain: ship every active request back to the coordinator as a
/// [`Frame::Transfer`] — full request context, per-sample windows and
/// tracks, and each session's KV cache as a [`super::session_codec`]
/// blob — then signal `DrainDone`.
fn export_all(conn: &mut TcpStream, pool: &KvCachePool, active: &mut Vec<ActiveReq>) {
    for a in active.drain(..) {
        let sessions: Vec<SessionTransfer> = a
            .sessions
            .iter()
            .map(|s| {
                let kv = pool
                    .export_session(s.key())
                    .map(|c| encode_session(a.method.name(), s.key(), &c))
                    .unwrap_or_default();
                SessionTransfer {
                    sample: s.key().sample,
                    window: s.window().to_vec(),
                    track: s.track().to_vec(),
                    kv,
                }
            })
            .collect();
        for s in &a.sessions {
            pool.end_session(s.key());
        }
        let frame = Frame::Transfer {
            req_id: a.req_id,
            tenant: a.tenant,
            trace_id: a.trace_id,
            method: a.method.name().to_string(),
            rollout: a.request,
            steps_done: a.steps_done as u32,
            decode_ms: a.decode_ms,
            sessions,
        };
        // a request too large even without KV is not exported: the
        // coordinator replays its envelope once this socket closes
        let Some(payload) = encode_transfer_bounded(frame) else {
            continue;
        };
        if super::wire::write_frame(conn, &payload).is_err() {
            return;
        }
    }
    let _ = Frame::DrainDone.write_to(conn);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::coordinator::router::shard_of;
    use crate::sim::ScenarioGenerator;

    fn test_cfg() -> ProcConfig {
        ProcConfig {
            heartbeat: Duration::from_millis(25),
            death_after: Duration::from_secs(5),
            connect_timeout: Duration::from_secs(2),
            respawn: false,
            manual_workers: true,
        }
    }

    fn fleet(n: usize) -> ProcServer {
        ProcServer::start(n, test_cfg(), AdmissionConfig::default(), Vec::new()).unwrap()
    }

    /// Hand-rolled worker: registers over the real socket protocol but
    /// is driven frame-by-frame by the test.
    fn fake_worker(server: &ProcServer, id: u32) -> TcpStream {
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let hello = Frame::Hello {
            version: WIRE_VERSION,
            worker_id: id,
            pid: 4242,
            token: server.token(),
        };
        hello.write_to(&mut s).unwrap();
        match Frame::read_from(&mut s).unwrap() {
            Frame::HelloAck => s,
            f => panic!("expected HelloAck, got {f:?}"),
        }
    }

    /// A request whose scene hashes to worker `want` out of `n`.
    fn request_for_worker(want: usize, n: usize) -> RolloutRequest {
        let sim = SimConfig::default();
        let scenarios = ScenarioGenerator::new(sim.clone());
        for seed in 0..10_000u64 {
            let s = scenarios.generate(seed);
            if shard_of(s.scene_id(), n) == want {
                return RolloutRequest {
                    scenario: s,
                    t0: sim.history_steps - 1,
                    n_samples: 2,
                    temperature: 0.5,
                    seed: 7,
                };
            }
        }
        unreachable!("no scene routed to worker {want}");
    }

    fn dummy_result() -> RolloutResult {
        RolloutResult {
            trajectories: Vec::new(),
            min_ade: Vec::new(),
            classes: Vec::new(),
            collisions: 0,
            decode_ms: 0.25,
        }
    }

    fn wait_until(deadline_ms: u64, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(deadline_ms) {
            if cond() {
                return true;
            }
            thread::sleep(Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn bad_token_hello_is_refused_and_counted() {
        let server = fleet(1);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        let hello = Frame::Hello {
            version: WIRE_VERSION,
            worker_id: 0,
            pid: 1,
            token: server.token() ^ 1,
        };
        hello.write_to(&mut s).unwrap();
        // the coordinator hangs up without a HelloAck
        assert!(Frame::read_from(&mut s).is_err());
        let stats = server.stats();
        assert!(wait_until(2_000, || stats.migration.wire_errors.get() == 1));
        assert_eq!(stats.shards[0].live.get(), 0, "never registered as live");
    }

    #[test]
    fn fake_worker_serves_a_request_end_to_end() {
        let server = fleet(1);
        let mut w = fake_worker(&server, 0);
        let rx = server.submit(Method::Se2Fourier, request_for_worker(0, 1));
        // the worker sees the request frame with the envelope intact
        let (req_id, rollout) = match Frame::read_from(&mut w).unwrap() {
            Frame::Request { req_id, method, rollout, .. } => {
                assert_eq!(method, "se2fourier");
                (req_id, rollout)
            }
            f => panic!("expected Request, got {f:?}"),
        };
        assert_eq!(rollout.n_samples, 2);
        let resp = Frame::Response { req_id, outcome: Ok(dummy_result()) };
        resp.write_to(&mut w).unwrap();
        let res = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(res.decode_ms, 0.25);
        let stats = server.stats();
        assert_eq!(stats.requests_in.get(), 1);
        assert!(wait_until(2_000, || stats.requests_done.get() == 1));
        assert_eq!(stats.shards[0].inflight.get(), 0);
    }

    /// A worker killed mid-drain (after `Drain`, before exporting its
    /// sessions) must not strand its envelopes: whatever was not
    /// migrated replays to a survivor, while the planned exit still does
    /// not count as a worker death.
    #[test]
    fn drain_death_replays_unmigrated_envelopes() {
        let server = fleet(2);
        let mut w0 = fake_worker(&server, 0);
        let mut w1 = fake_worker(&server, 1);
        let rx = server.submit(Method::Abs, request_for_worker(0, 2));
        let died_req = match Frame::read_from(&mut w0).unwrap() {
            Frame::Request { req_id, .. } => req_id,
            f => panic!("expected Request, got {f:?}"),
        };
        server.drain_worker(0);
        assert!(matches!(Frame::read_from(&mut w0).unwrap(), Frame::Drain));
        // SIGKILL'd mid-drain: the socket closes with no Transfer sent
        drop(w0);
        let req_id = match Frame::read_from(&mut w1).unwrap() {
            Frame::Request { req_id, .. } => req_id,
            f => panic!("expected replayed Request, got {f:?}"),
        };
        assert_eq!(req_id, died_req, "the un-migrated envelope replays");
        let resp = Frame::Response { req_id, outcome: Ok(dummy_result()) };
        resp.write_to(&mut w1).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        let stats = server.stats();
        assert_eq!(stats.migration.worker_deaths.get(), 0, "a drain exit stays planned");
        assert_eq!(stats.migration.envelopes_replayed.get(), 1);
        assert_eq!(stats.requests_failed.get(), 0, "nothing lost");
    }

    /// Envelopes parked on a respawning worker must fail typed — not
    /// hang — when the respawn itself cannot be spawned.
    #[test]
    #[cfg(unix)]
    fn respawn_spawn_failure_fails_parked_envelopes() {
        use std::os::unix::fs::PermissionsExt;
        let script = std::env::temp_dir()
            .join(format!("se2attn-respawn-fail-{}.sh", std::process::id()));
        std::fs::write(&script, "#!/bin/sh\nsleep 2\n").unwrap();
        std::fs::set_permissions(&script, std::fs::Permissions::from_mode(0o755)).unwrap();
        let cfg = ProcConfig {
            heartbeat: Duration::from_millis(25),
            death_after: Duration::from_secs(5),
            connect_timeout: Duration::from_secs(2),
            respawn: true,
            manual_workers: false,
        };
        let server = ProcServer::start(
            1,
            cfg,
            AdmissionConfig::default(),
            vec![script.to_str().unwrap().to_string()],
        )
        .unwrap();
        // the "worker" never speaks the protocol, so the envelope parks
        // in the slot backlog waiting for a handshake that never comes
        let rx = server.submit(Method::Abs, request_for_worker(0, 1));
        // the script exits on its own in ~2s; deleting it first makes the
        // supervised respawn fail at spawn
        std::fs::remove_file(&script).unwrap();
        let res = rx
            .recv_timeout(Duration::from_secs(20))
            .expect("parked envelope hung after a failed respawn");
        let msg = format!("{:#}", res.unwrap_err());
        assert!(msg.contains("no live worker"), "unexpected error: {msg}");
        assert_eq!(server.stats().migration.worker_deaths.get(), 1);
    }

    /// A `Transfer` whose aggregated KV blobs exceed the frame cap is
    /// re-encoded kv-less (destination rebuilds as cache misses) instead
    /// of being refused by `write_frame` mid-drain.
    #[test]
    fn oversize_transfer_degrades_to_kv_less() {
        let req = request_for_worker(0, 1);
        let big = (MAX_FRAME_BYTES as usize / 2) + 1024;
        let frame = Frame::Transfer {
            req_id: 1,
            tenant: 0,
            trace_id: 0,
            method: "abs".into(),
            rollout: req.clone(),
            steps_done: 3,
            decode_ms: 0.5,
            sessions: vec![
                SessionTransfer { sample: 0, window: vec![], track: vec![], kv: vec![0u8; big] },
                SessionTransfer { sample: 1, window: vec![], track: vec![], kv: vec![0u8; big] },
            ],
        };
        let payload = encode_transfer_bounded(frame).expect("kv-less fallback must fit");
        assert!(payload.len() <= MAX_FRAME_BYTES as usize);
        match Frame::decode(&payload).unwrap() {
            Frame::Transfer { sessions, steps_done, .. } => {
                assert_eq!(steps_done, 3);
                assert_eq!(sessions.len(), 2);
                assert!(sessions.iter().all(|s| s.kv.is_empty()), "kv dropped to fit");
            }
            f => panic!("expected Transfer, got {f:?}"),
        }
        // under the cap, the kv rides along untouched
        let small = Frame::Transfer {
            req_id: 2,
            tenant: 0,
            trace_id: 0,
            method: "abs".into(),
            rollout: req,
            steps_done: 1,
            decode_ms: 0.1,
            sessions: vec![SessionTransfer {
                sample: 0,
                window: vec![],
                track: vec![],
                kv: vec![1, 2, 3],
            }],
        };
        let payload = encode_transfer_bounded(small).unwrap();
        match Frame::decode(&payload).unwrap() {
            Frame::Transfer { sessions, .. } => assert_eq!(sessions[0].kv, vec![1, 2, 3]),
            f => panic!("expected Transfer, got {f:?}"),
        }
    }

    /// Frames routed to a worker that has not yet connected park in the
    /// slot backlog and flush — outside the slot lock — on handshake.
    #[test]
    fn backlog_queued_before_connect_is_flushed_on_handshake() {
        let server = fleet(1);
        let rx = server.submit(Method::Abs, request_for_worker(0, 1));
        let mut w = fake_worker(&server, 0);
        let req_id = match Frame::read_from(&mut w).unwrap() {
            Frame::Request { req_id, .. } => req_id,
            f => panic!("expected the queued Request, got {f:?}"),
        };
        let resp = Frame::Response { req_id, outcome: Ok(dummy_result()) };
        resp.write_to(&mut w).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }

    #[test]
    fn dead_workers_envelope_replays_to_a_survivor() {
        let server = fleet(2);
        let mut w0 = fake_worker(&server, 0);
        let mut w1 = fake_worker(&server, 1);
        let rx = server.submit(Method::Abs, request_for_worker(0, 2));
        // worker 0 receives the envelope, then dies mid-rollout
        let died_req = match Frame::read_from(&mut w0).unwrap() {
            Frame::Request { req_id, .. } => req_id,
            f => panic!("expected Request, got {f:?}"),
        };
        drop(w0);
        // the coordinator replays the same envelope to the survivor
        let req_id = match Frame::read_from(&mut w1).unwrap() {
            Frame::Request { req_id, method, .. } => {
                assert_eq!(method, "abs");
                req_id
            }
            f => panic!("expected replayed Request, got {f:?}"),
        };
        assert_eq!(req_id, died_req, "replay reuses the envelope id");
        let resp = Frame::Response { req_id, outcome: Ok(dummy_result()) };
        resp.write_to(&mut w1).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        let stats = server.stats();
        assert_eq!(stats.migration.worker_deaths.get(), 1);
        assert_eq!(stats.migration.envelopes_replayed.get(), 1);
        assert_eq!(stats.requests_failed.get(), 0, "nothing lost");
    }
}

