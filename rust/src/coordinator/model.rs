//! `ModelHandle`: owns the parameter/optimizer state for one attention
//! method and drives the AOT artifacts (init / fwd / train_step / decode).
//!
//! Parameter threading is manifest-driven: the artifacts name their slots
//! `param:<name>` / `m:<name>` / `v:<name>` in sorted order, and the handle
//! slices its state vectors accordingly — no hard-coded parameter count
//! anywhere on the Rust side.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::Method;
use crate::dataset::Batch;
use crate::runtime::{Engine, HostTensor};

/// Decoded model outputs for one batch.
pub struct DecodeOutput {
    /// (B, N) sampled action ids.
    pub actions: Vec<i32>,
    /// (B, N) log-probability of each sampled action.
    pub logp: Vec<f32>,
    /// (B, N, A) full logits.
    pub logits: Vec<f32>,
}

/// Per-slot decode parameters for a continuous step batch
/// (DESIGN.md §17): each scene slot carries the seed/temperature of the
/// *request* it belongs to, so sessions from different requests can
/// share one decode call without perturbing each other's sampling
/// stream.  `trace` is the owning request's trace id (0 = untraced);
/// per-slot backends attribute their kernel spans to it.
#[derive(Clone, Copy, Debug)]
pub struct SlotParams {
    pub seed: i32,
    pub temperature: f32,
    pub trace: u64,
}

impl SlotParams {
    /// Whether two slots can share one [`ActionDecoder::decode`] call
    /// (trace attribution never splits a batch).
    fn same_decode(&self, other: &SlotParams) -> bool {
        self.seed == other.seed && self.temperature == other.temperature
    }
}

/// Anything that can sample per-token actions for a tokenized batch: the
/// PJRT-backed [`ModelHandle`] in production, or an artifact-free
/// [`SyntheticDecoder`] in tests and benches.  The rollout scheduler and
/// the sharded server are generic over this boundary, so the whole
/// serving stack (router -> admission -> KV-cache pool -> rollout) can
/// be exercised without compiled XLA artifacts.
pub trait ActionDecoder {
    fn decode(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        seed: i32,
        temperature: f32,
    ) -> Result<DecodeOutput>;

    /// Decode a batch whose slots carry individual [`SlotParams`] — the
    /// single-step primitive of the continuous scheduler, where one step
    /// batch mixes sessions from several requests.
    ///
    /// `slots[s]` parameterizes scene slot `s`; padding slots
    /// (`s >= slots.len()`) reuse the last real slot's parameters and
    /// their outputs are unspecified (the caller slices them away).
    ///
    /// The default implementation splits the batch into maximal runs of
    /// equal `(seed, temperature)`, re-packs each run into a full
    /// fixed-shape batch (replicating the run's last slot, exactly like
    /// the rollout scheduler pads) and decodes it through
    /// [`ActionDecoder::decode`] — correct for any backend whose decode
    /// artifact takes one scalar seed, at the cost of one call per run.
    /// Backends that sample per row ([`SyntheticDecoder`],
    /// [`NativeSdpaDecoder`]) override this with a single-pass
    /// implementation.  A uniform batch always takes the one-call fast
    /// path, so single-request chunks decode bit-identically to the
    /// legacy fixed-batch path.
    fn decode_slots(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        slots: &[SlotParams],
    ) -> Result<DecodeOutput> {
        let bs = b.batch_size;
        if slots.is_empty() || slots.len() > bs {
            bail!(
                "decode_slots: {} slot params for a batch of {}",
                slots.len(),
                bs
            );
        }
        if slots.iter().all(|s| s.same_decode(&slots[0])) {
            return self.decode(b, n_tokens, feat_dim, slots[0].seed, slots[0].temperature);
        }
        let mut actions = vec![0i32; bs * n_tokens];
        let mut logp: Vec<f32> = Vec::new();
        let mut logits: Vec<f32> = Vec::new();
        let mut i = 0;
        while i < slots.len() {
            let mut j = i + 1;
            while j < slots.len() && slots[j].same_decode(&slots[i]) {
                j += 1;
            }
            let sub = repack_run(b, i, j, n_tokens, feat_dim);
            let out = self.decode(&sub, n_tokens, feat_dim, slots[i].seed, slots[i].temperature)?;
            let n = (j - i) * n_tokens;
            if out.actions.len() < n {
                bail!(
                    "decode_slots: backend returned {} actions for a run of {}",
                    out.actions.len(),
                    n
                );
            }
            actions[i * n_tokens..i * n_tokens + n].copy_from_slice(&out.actions[..n]);
            if !out.logp.is_empty() && out.logp.len() >= n {
                logp.resize(bs * n_tokens, 0.0);
                logp[i * n_tokens..i * n_tokens + n].copy_from_slice(&out.logp[..n]);
            }
            let a_dim = out.logits.len() / (bs * n_tokens).max(1);
            if a_dim > 0 && out.logits.len() >= n * a_dim {
                logits.resize(bs * n_tokens * a_dim, 0.0);
                logits[i * n_tokens * a_dim..(i * n_tokens + n) * a_dim]
                    .copy_from_slice(&out.logits[..n * a_dim]);
            }
            i = j;
        }
        Ok(DecodeOutput {
            actions,
            logp,
            logits,
        })
    }
}

/// Re-pack slots `[i, j)` of `b` into a full fixed-shape batch, padding
/// the tail by replicating the run's last slot (the same
/// `extend_from_within` padding the rollout scheduler uses).
fn repack_run(b: &Batch, i: usize, j: usize, n_tokens: usize, feat_dim: usize) -> Batch {
    let bs = b.batch_size;
    let (fr, pr, tr) = (n_tokens * feat_dim, n_tokens * 3, n_tokens);
    let mut sub = Batch {
        feat: Vec::with_capacity(bs * fr),
        pose: Vec::with_capacity(bs * pr),
        tq: Vec::with_capacity(bs * tr),
        target: Vec::with_capacity(bs * tr),
        batch_size: bs,
    };
    sub.feat.extend_from_slice(&b.feat[i * fr..j * fr]);
    sub.pose.extend_from_slice(&b.pose[i * pr..j * pr]);
    sub.tq.extend_from_slice(&b.tq[i * tr..j * tr]);
    sub.target.extend_from_slice(&b.target[i * tr..j * tr]);
    for _ in j - i..bs {
        sub.feat.extend_from_within((sub.feat.len() - fr)..);
        sub.pose.extend_from_within((sub.pose.len() - pr)..);
        sub.tq.extend_from_within((sub.tq.len() - tr)..);
        sub.target.extend_from_within((sub.target.len() - tr)..);
    }
    sub
}

/// Deterministic artifact-free decoder: each token's action is a stateless
/// hash of that token's feature row and the decode seed.  Two properties
/// the serving tests rely on:
///
/// * **batch-packing independence** — a token's action depends only on its
///   own row, never on which other scenes share the batch or how much
///   padding was appended, so per-request results are identical no matter
///   how requests are sharded across workers;
/// * **determinism** — same request, same actions, every time.
///
/// `work_per_token` adds extra hash rounds per token to emulate real model
/// latency in throughput benchmarks.
pub struct SyntheticDecoder {
    pub n_actions: usize,
    pub work_per_token: usize,
}

impl SyntheticDecoder {
    pub fn new(n_actions: usize) -> SyntheticDecoder {
        SyntheticDecoder {
            n_actions,
            work_per_token: 0,
        }
    }

    pub fn with_work(n_actions: usize, work_per_token: usize) -> SyntheticDecoder {
        SyntheticDecoder {
            n_actions,
            work_per_token,
        }
    }
}

impl ActionDecoder for SyntheticDecoder {
    fn decode(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        seed: i32,
        _temperature: f32,
    ) -> Result<DecodeOutput> {
        use crate::prng::SplitMix64;
        let bs = b.batch_size;
        if b.feat.len() != bs * n_tokens * feat_dim {
            bail!(
                "synthetic decode: batch carries {} features, expected {}",
                b.feat.len(),
                bs * n_tokens * feat_dim
            );
        }
        let mut actions = Vec::with_capacity(bs * n_tokens);
        for s in 0..bs {
            for t in 0..n_tokens {
                let row = &b.feat[(s * n_tokens + t) * feat_dim..(s * n_tokens + t + 1) * feat_dim];
                let mut h = (seed as i64 as u64) ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                for &f in row {
                    h = SplitMix64::new(h ^ u64::from(f.to_bits())).next_u64();
                }
                for _ in 0..self.work_per_token {
                    h = SplitMix64::new(h).next_u64();
                }
                actions.push((h % self.n_actions.max(1) as u64) as i32);
            }
        }
        // diagnostics (logp/logits) are not produced on this path; the
        // rollout scheduler consumes actions only
        Ok(DecodeOutput {
            actions,
            logp: Vec::new(),
            logits: Vec::new(),
        })
    }

    /// Single-pass override: the hash is per row anyway, so a mixed-seed
    /// step batch costs exactly one pass — no re-packing.
    fn decode_slots(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        slots: &[SlotParams],
    ) -> Result<DecodeOutput> {
        use crate::prng::SplitMix64;
        let bs = b.batch_size;
        if slots.is_empty() || slots.len() > bs {
            bail!(
                "decode_slots: {} slot params for a batch of {}",
                slots.len(),
                bs
            );
        }
        if b.feat.len() != bs * n_tokens * feat_dim {
            bail!(
                "synthetic decode: batch carries {} features, expected {}",
                b.feat.len(),
                bs * n_tokens * feat_dim
            );
        }
        let mut actions = Vec::with_capacity(bs * n_tokens);
        for s in 0..bs {
            let seed = slots[s.min(slots.len() - 1)].seed;
            for t in 0..n_tokens {
                let row = &b.feat[(s * n_tokens + t) * feat_dim..(s * n_tokens + t + 1) * feat_dim];
                let mut h = (seed as i64 as u64) ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                for &f in row {
                    h = SplitMix64::new(h ^ u64::from(f.to_bits())).next_u64();
                }
                for _ in 0..self.work_per_token {
                    h = SplitMix64::new(h).next_u64();
                }
                actions.push((h % self.n_actions.max(1) as u64) as i32);
            }
        }
        Ok(DecodeOutput {
            actions,
            logp: Vec::new(),
            logits: Vec::new(),
        })
    }
}

/// Artifact-free decoder that drives the **native blocked flash kernel**
/// on every decode call: each scene slot self-attends its own feature
/// rows (q = k = v, visibility from the batch's `tq` timestamps) through
/// [`crate::attention::kernel::flash_sdpa_blocked`], and the action per
/// token is a stateless hash of the attended row.  This is what
/// `simulate --synthetic` and the observability CI smoke serve with, so a
/// traced run exercises the Attend stage (spans + profiling counters)
/// without compiled XLA artifacts.
///
/// The [`SyntheticDecoder`] properties carry over: attention never
/// crosses scene-slot boundaries, so actions are batch-packing
/// independent, and the kernel is bit-stable across thread counts, so
/// results are deterministic for a fixed kernel shape.
pub struct NativeSdpaDecoder {
    pub n_actions: usize,
    pub kernel: crate::attention::kernel::KernelConfig,
}

impl NativeSdpaDecoder {
    pub fn new(n_actions: usize, kernel: crate::attention::kernel::KernelConfig) -> Self {
        NativeSdpaDecoder { n_actions, kernel }
    }
}

impl ActionDecoder for NativeSdpaDecoder {
    fn decode(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        seed: i32,
        _temperature: f32,
    ) -> Result<DecodeOutput> {
        use crate::attention::kernel::flash_sdpa_blocked;
        use crate::prng::SplitMix64;
        let bs = b.batch_size;
        if b.feat.len() != bs * n_tokens * feat_dim {
            bail!(
                "native decode: batch carries {} features, expected {}",
                b.feat.len(),
                bs * n_tokens * feat_dim
            );
        }
        if b.tq.len() != bs * n_tokens {
            bail!(
                "native decode: batch carries {} timestamps, expected {}",
                b.tq.len(),
                bs * n_tokens
            );
        }
        let scale = 1.0 / (feat_dim.max(1) as f64).sqrt();
        let mut attended = vec![0.0f32; n_tokens * feat_dim];
        let mut actions = Vec::with_capacity(bs * n_tokens);
        for s in 0..bs {
            let rows = &b.feat[s * n_tokens * feat_dim..(s + 1) * n_tokens * feat_dim];
            let tq = &b.tq[s * n_tokens..(s + 1) * n_tokens];
            flash_sdpa_blocked(
                rows,
                rows,
                rows,
                tq,
                tq,
                feat_dim,
                scale,
                &mut attended,
                &self.kernel,
            );
            for t in 0..n_tokens {
                let row = &attended[t * feat_dim..(t + 1) * feat_dim];
                let mut h = (seed as i64 as u64) ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                for &f in row {
                    h = SplitMix64::new(h ^ u64::from(f.to_bits())).next_u64();
                }
                actions.push((h % self.n_actions.max(1) as u64) as i32);
            }
        }
        // diagnostics (logp/logits) are not produced on this path; the
        // rollout scheduler consumes actions only
        Ok(DecodeOutput {
            actions,
            logp: Vec::new(),
            logits: Vec::new(),
        })
    }

    /// Single-pass override: attention is per scene slot anyway, so a
    /// mixed-seed step batch costs exactly one kernel call per slot —
    /// same as the uniform path.  Each slot's kernel call runs under
    /// that slot's trace id, so the Attend spans of a shared step batch
    /// land on the timeline of the request that owns the slot.
    fn decode_slots(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        slots: &[SlotParams],
    ) -> Result<DecodeOutput> {
        use crate::attention::kernel::flash_sdpa_blocked;
        use crate::prng::SplitMix64;
        let bs = b.batch_size;
        if slots.is_empty() || slots.len() > bs {
            bail!(
                "decode_slots: {} slot params for a batch of {}",
                slots.len(),
                bs
            );
        }
        if b.feat.len() != bs * n_tokens * feat_dim {
            bail!(
                "native decode: batch carries {} features, expected {}",
                b.feat.len(),
                bs * n_tokens * feat_dim
            );
        }
        if b.tq.len() != bs * n_tokens {
            bail!(
                "native decode: batch carries {} timestamps, expected {}",
                b.tq.len(),
                bs * n_tokens
            );
        }
        let scale = 1.0 / (feat_dim.max(1) as f64).sqrt();
        let mut attended = vec![0.0f32; n_tokens * feat_dim];
        let mut actions = Vec::with_capacity(bs * n_tokens);
        let mut ambient = 0u64;
        for s in 0..bs {
            let p = slots[s.min(slots.len() - 1)];
            // padding slots attribute to nobody
            let want = if s < slots.len() { p.trace } else { 0 };
            if want != ambient {
                crate::trace::set_trace_id(want);
                ambient = want;
            }
            let rows = &b.feat[s * n_tokens * feat_dim..(s + 1) * n_tokens * feat_dim];
            let tq = &b.tq[s * n_tokens..(s + 1) * n_tokens];
            flash_sdpa_blocked(
                rows,
                rows,
                rows,
                tq,
                tq,
                feat_dim,
                scale,
                &mut attended,
                &self.kernel,
            );
            for t in 0..n_tokens {
                let row = &attended[t * feat_dim..(t + 1) * feat_dim];
                let mut h = (p.seed as i64 as u64) ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                for &f in row {
                    h = SplitMix64::new(h ^ u64::from(f.to_bits())).next_u64();
                }
                actions.push((h % self.n_actions.max(1) as u64) as i32);
            }
        }
        if ambient != 0 {
            crate::trace::set_trace_id(0);
        }
        Ok(DecodeOutput {
            actions,
            logp: Vec::new(),
            logits: Vec::new(),
        })
    }
}

/// Owns one attention variant's parameters + Adam state and drives its
/// AOT artifacts (`fwd_*` / `train_step_*` / `decode_*`) through the
/// PJRT [`Engine`].  The production [`ActionDecoder`]; see
/// [`SyntheticDecoder`] for the artifact-free test/bench counterpart.
pub struct ModelHandle {
    /// Attention method this handle's artifacts were lowered for.
    pub method: Method,
    engine: Arc<Engine>,
    /// Parameters, Adam first and second moments (manifest order).
    params: Vec<HostTensor>,
    opt_m: Vec<HostTensor>,
    opt_v: Vec<HostTensor>,
    /// Optimizer steps taken (checkpointed and restored).
    pub step: u64,
    n_params: usize,
}

impl ModelHandle {
    /// Initialize parameters on-device via the `init` artifact.
    pub fn init(engine: Arc<Engine>, method: Method, seed: i32) -> Result<ModelHandle> {
        let init = engine.load("init")?;
        let params = init.execute(&[HostTensor::scalar_i32(seed)])?;
        let n_params = params.len();
        let opt_m: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape.clone(), vec![0.0; p.numel()]))
            .collect();
        let opt_v = opt_m.clone();
        Ok(ModelHandle {
            method,
            engine,
            params,
            opt_m,
            opt_v,
            step: 0,
            n_params,
        })
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// Total scalar parameter count (for logging).
    pub fn n_weights(&self) -> usize {
        self.params.iter().map(HostTensor::numel).sum()
    }

    fn batch_tensors(&self, b: &Batch, n_tokens: usize, feat_dim: usize) -> Vec<HostTensor> {
        let bs = b.batch_size;
        vec![
            HostTensor::f32(vec![bs, n_tokens, feat_dim], b.feat.clone()),
            HostTensor::f32(vec![bs, n_tokens, 3], b.pose.clone()),
            HostTensor::i32(vec![bs, n_tokens], b.tq.clone()),
        ]
    }

    /// Forward pass: logits (B, N, A) flattened.
    pub fn forward(&self, b: &Batch, n_tokens: usize, feat_dim: usize) -> Result<Vec<f32>> {
        let name = format!("fwd_{}", self.method.name());
        let mut inputs = self.params.clone();
        inputs.extend(self.batch_tensors(b, n_tokens, feat_dim));
        let out = self.engine.run(&name, &inputs)?;
        Ok(out
            .into_iter()
            .next()
            .context("fwd returned nothing")?
            .as_f32()?
            .to_vec())
    }

    /// One optimizer step; returns the training loss.
    pub fn train_step(&mut self, b: &Batch, n_tokens: usize, feat_dim: usize) -> Result<f32> {
        let name = format!("train_step_{}", self.method.name());
        self.step += 1;
        let p = self.n_params;
        let mut inputs =
            Vec::with_capacity(3 * p + 5);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.opt_m.iter().cloned());
        inputs.extend(self.opt_v.iter().cloned());
        inputs.push(HostTensor::scalar_f32(self.step as f32));
        inputs.extend(self.batch_tensors(b, n_tokens, feat_dim));
        inputs.push(HostTensor::i32(
            vec![b.batch_size, n_tokens],
            b.target.clone(),
        ));
        let mut out = self.engine.run(&name, &inputs)?;
        if out.len() != 3 * p + 1 {
            bail!(
                "train_step returned {} outputs, expected {}",
                out.len(),
                3 * p + 1
            );
        }
        let loss = out.pop().unwrap().item_f32()?;
        self.opt_v = out.split_off(2 * p);
        self.opt_m = out.split_off(p);
        self.params = out;
        Ok(loss)
    }

    /// Sample actions for every token.
    pub fn decode(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        seed: i32,
        temperature: f32,
    ) -> Result<DecodeOutput> {
        let name = format!("decode_{}", self.method.name());
        let mut inputs = self.params.clone();
        inputs.extend(self.batch_tensors(b, n_tokens, feat_dim));
        inputs.push(HostTensor::scalar_i32(seed));
        inputs.push(HostTensor::scalar_f32(temperature));
        let out = self.engine.run(&name, &inputs)?;
        if out.len() != 3 {
            bail!("decode returned {} outputs, expected 3", out.len());
        }
        Ok(DecodeOutput {
            actions: out[0].as_i32()?.to_vec(),
            logp: out[1].as_f32()?.to_vec(),
            logits: out[2].as_f32()?.to_vec(),
        })
    }

    /// Snapshot parameters (for checkpoint writing / tests).
    pub fn params(&self) -> &[HostTensor] {
        &self.params
    }

    /// Restore parameters (e.g. from another handle / checkpoint).
    pub fn set_params(&mut self, params: Vec<HostTensor>) -> Result<()> {
        if params.len() != self.n_params {
            bail!("expected {} tensors, got {}", self.n_params, params.len());
        }
        self.params = params;
        Ok(())
    }

    /// Full training-state checkpoint (params + Adam moments + step).
    pub fn to_checkpoint(&self, param_names: &[String]) -> Result<crate::checkpoint::Checkpoint> {
        if param_names.len() != self.n_params {
            bail!(
                "param_names has {} entries, model has {}",
                param_names.len(),
                self.n_params
            );
        }
        let mut ck =
            crate::checkpoint::Checkpoint::new(self.step, self.method.name());
        for (name, t) in param_names.iter().zip(&self.params) {
            ck.push(&format!("param:{name}"), t.clone());
        }
        for (name, t) in param_names.iter().zip(&self.opt_m) {
            ck.push(&format!("m:{name}"), t.clone());
        }
        for (name, t) in param_names.iter().zip(&self.opt_v) {
            ck.push(&format!("v:{name}"), t.clone());
        }
        Ok(ck)
    }

    /// Restore full training state from a checkpoint.
    pub fn restore(
        &mut self,
        ck: &crate::checkpoint::Checkpoint,
        param_names: &[String],
    ) -> Result<()> {
        let params = ck.take_ordered("param:", param_names)?;
        let m = ck.take_ordered("m:", param_names)?;
        let v = ck.take_ordered("v:", param_names)?;
        for (t, spec) in params.iter().zip(&self.params) {
            if t.shape != spec.shape {
                bail!("checkpoint shape mismatch: {:?} vs {:?}", t.shape, spec.shape);
            }
        }
        self.params = params;
        self.opt_m = m;
        self.opt_v = v;
        self.step = ck.step;
        Ok(())
    }
}

impl ActionDecoder for ModelHandle {
    fn decode(
        &self,
        b: &Batch,
        n_tokens: usize,
        feat_dim: usize,
        seed: i32,
        temperature: f32,
    ) -> Result<DecodeOutput> {
        ModelHandle::decode(self, b, n_tokens, feat_dim, seed, temperature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(bs: usize, n_tokens: usize, feat_dim: usize, salt: f32) -> Batch {
        Batch {
            feat: (0..bs * n_tokens * feat_dim)
                .map(|i| (i % 13) as f32 * 0.25 + salt)
                .collect(),
            pose: vec![0.0; bs * n_tokens * 3],
            tq: vec![0; bs * n_tokens],
            target: vec![-100; bs * n_tokens],
            batch_size: bs,
        }
    }

    #[test]
    fn synthetic_decode_is_deterministic_and_in_range() {
        let d = SyntheticDecoder::new(64);
        let b = toy_batch(2, 8, 4, 0.0);
        let a1 = d.decode(&b, 8, 4, 7, 1.0).unwrap();
        let a2 = d.decode(&b, 8, 4, 7, 0.1).unwrap();
        assert_eq!(a1.actions, a2.actions, "temperature-independent");
        assert_eq!(a1.actions.len(), 16);
        assert!(a1.actions.iter().all(|&a| (0..64).contains(&a)));
        // the seed perturbs the sample
        let a3 = d.decode(&b, 8, 4, 8, 1.0).unwrap();
        assert_ne!(a1.actions, a3.actions);
    }

    /// The property the cross-shard equivalence test rests on: a token's
    /// action depends only on its own feature row (and the seed), not on
    /// which other scenes share the batch.
    #[test]
    fn synthetic_decode_is_batch_packing_independent() {
        let d = SyntheticDecoder::new(32);
        let (n_tokens, fd) = (4, 3);
        let alone = toy_batch(1, n_tokens, fd, 1.5);
        // same rows, packed behind a different leading scene
        let mut packed = toy_batch(2, n_tokens, fd, 9.0);
        packed.feat[n_tokens * fd..].copy_from_slice(&alone.feat);
        let a = d.decode(&alone, n_tokens, fd, 3, 1.0).unwrap();
        let p = d.decode(&packed, n_tokens, fd, 3, 1.0).unwrap();
        assert_eq!(
            a.actions,
            p.actions[n_tokens..],
            "actions must not depend on batch packing"
        );
    }

    #[test]
    fn synthetic_decode_rejects_shape_drift() {
        let d = SyntheticDecoder::new(8);
        let b = toy_batch(1, 4, 3, 0.0);
        assert!(d.decode(&b, 5, 3, 0, 1.0).is_err());
    }

    #[test]
    fn native_sdpa_decode_is_deterministic_and_in_range() {
        use crate::attention::kernel::KernelConfig;
        let d = NativeSdpaDecoder::new(64, KernelConfig::fixed(8, 8, 1));
        let b = toy_batch(2, 8, 4, 0.5);
        let a1 = d.decode(&b, 8, 4, 7, 1.0).unwrap();
        let a2 = d.decode(&b, 8, 4, 7, 0.1).unwrap();
        assert_eq!(a1.actions, a2.actions, "temperature-independent");
        assert_eq!(a1.actions.len(), 16);
        assert!(a1.actions.iter().all(|&a| (0..64).contains(&a)));
        let a3 = d.decode(&b, 8, 4, 8, 1.0).unwrap();
        assert_ne!(a1.actions, a3.actions, "seed perturbs the sample");
        // kernel bit-stability across threads => identical actions
        let d4 = NativeSdpaDecoder::new(64, KernelConfig::fixed(8, 8, 4));
        let a4 = d4.decode(&b, 8, 4, 7, 1.0).unwrap();
        assert_eq!(a1.actions, a4.actions, "thread count must not perturb");
    }

    #[test]
    fn native_sdpa_decode_is_batch_packing_independent() {
        use crate::attention::kernel::KernelConfig;
        let d = NativeSdpaDecoder::new(32, KernelConfig::fixed(4, 8, 2));
        let (n_tokens, fd) = (4, 3);
        let alone = toy_batch(1, n_tokens, fd, 1.5);
        let mut packed = toy_batch(2, n_tokens, fd, 9.0);
        packed.feat[n_tokens * fd..].copy_from_slice(&alone.feat);
        let a = d.decode(&alone, n_tokens, fd, 3, 1.0).unwrap();
        let p = d.decode(&packed, n_tokens, fd, 3, 1.0).unwrap();
        assert_eq!(
            a.actions,
            p.actions[n_tokens..],
            "self-attention never crosses scene-slot boundaries"
        );
    }

    #[test]
    fn native_sdpa_decode_rejects_shape_drift() {
        use crate::attention::kernel::KernelConfig;
        let d = NativeSdpaDecoder::new(8, KernelConfig::fixed(4, 8, 1));
        let b = toy_batch(1, 4, 3, 0.0);
        assert!(d.decode(&b, 5, 3, 0, 1.0).is_err());
    }

    fn uniform(n: usize, seed: i32) -> Vec<SlotParams> {
        vec![
            SlotParams {
                seed,
                temperature: 1.0,
                trace: 0,
            };
            n
        ]
    }

    /// A uniform slot batch must take the one-call fast path and decode
    /// bit-identically to plain `decode` — the property that keeps
    /// single-request chunks equal to the legacy fixed-batch path.
    #[test]
    fn decode_slots_uniform_matches_decode() {
        use crate::attention::kernel::KernelConfig;
        let (n_tokens, fd) = (6, 4);
        let b = toy_batch(3, n_tokens, fd, 0.75);
        let syn = SyntheticDecoder::new(64);
        let nat = NativeSdpaDecoder::new(64, KernelConfig::fixed(8, 8, 2));
        for seed in [0, 7, -3] {
            let s = uniform(3, seed);
            assert_eq!(
                syn.decode(&b, n_tokens, fd, seed, 1.0).unwrap().actions,
                syn.decode_slots(&b, n_tokens, fd, &s).unwrap().actions,
            );
            assert_eq!(
                nat.decode(&b, n_tokens, fd, seed, 1.0).unwrap().actions,
                nat.decode_slots(&b, n_tokens, fd, &s).unwrap().actions,
            );
        }
    }

    /// The continuous-scheduler property: a slot in a mixed-seed step
    /// batch decodes exactly what it would decode alone in its own
    /// batch under its own seed — per-request results cannot depend on
    /// which other requests happen to share the step.
    #[test]
    fn decode_slots_heterogeneous_equals_solo_decodes() {
        use crate::attention::kernel::KernelConfig;
        let (n_tokens, fd) = (4, 3);
        let b = toy_batch(3, n_tokens, fd, 2.25);
        let seeds = [11, -5, 11];
        let slots: Vec<SlotParams> = seeds
            .iter()
            .map(|&seed| SlotParams {
                seed,
                temperature: 1.0,
                trace: 0,
            })
            .collect();
        let syn = SyntheticDecoder::new(32);
        let nat = NativeSdpaDecoder::new(32, KernelConfig::fixed(4, 8, 1));
        let got_syn = syn.decode_slots(&b, n_tokens, fd, &slots).unwrap();
        let got_nat = nat.decode_slots(&b, n_tokens, fd, &slots).unwrap();
        for (s, &seed) in seeds.iter().enumerate() {
            let mut solo = toy_batch(1, n_tokens, fd, 0.0);
            solo.feat
                .copy_from_slice(&b.feat[s * n_tokens * fd..(s + 1) * n_tokens * fd]);
            let want_syn = syn.decode(&solo, n_tokens, fd, seed, 1.0).unwrap();
            let want_nat = nat.decode(&solo, n_tokens, fd, seed, 1.0).unwrap();
            assert_eq!(
                want_syn.actions,
                got_syn.actions[s * n_tokens..(s + 1) * n_tokens],
                "synthetic slot {s}"
            );
            assert_eq!(
                want_nat.actions,
                got_nat.actions[s * n_tokens..(s + 1) * n_tokens],
                "native slot {s}"
            );
        }
    }

    /// Exercise the default run-grouping implementation (re-pack each
    /// equal-(seed,temp) run, decode, stitch) through a backend that
    /// does NOT override `decode_slots`, and check it agrees with the
    /// single-pass override on the same input.
    #[test]
    fn default_decode_slots_grouping_matches_override() {
        struct DefaultOnly(SyntheticDecoder);
        impl ActionDecoder for DefaultOnly {
            fn decode(
                &self,
                b: &Batch,
                n_tokens: usize,
                feat_dim: usize,
                seed: i32,
                temperature: f32,
            ) -> Result<DecodeOutput> {
                self.0.decode(b, n_tokens, feat_dim, seed, temperature)
            }
        }
        let (n_tokens, fd) = (4, 3);
        let b = toy_batch(4, n_tokens, fd, 1.25);
        let seeds = [2, 2, 9, -1];
        let slots: Vec<SlotParams> = seeds
            .iter()
            .map(|&seed| SlotParams {
                seed,
                temperature: 1.0,
                trace: 0,
            })
            .collect();
        let wrapped = DefaultOnly(SyntheticDecoder::new(32));
        let plain = SyntheticDecoder::new(32);
        assert_eq!(
            wrapped.decode_slots(&b, n_tokens, fd, &slots).unwrap().actions,
            plain.decode_slots(&b, n_tokens, fd, &slots).unwrap().actions,
        );
    }

    /// Fewer slot params than scene slots = the tail is padding; the
    /// real prefix must still decode per-slot correctly.
    #[test]
    fn decode_slots_tolerates_padding_slots() {
        let (n_tokens, fd) = (4, 3);
        let b = toy_batch(4, n_tokens, fd, 0.5);
        let slots = [
            SlotParams {
                seed: 1,
                temperature: 1.0,
                trace: 0,
            },
            SlotParams {
                seed: 8,
                temperature: 1.0,
                trace: 0,
            },
        ];
        let d = SyntheticDecoder::new(32);
        let got = d.decode_slots(&b, n_tokens, fd, &slots).unwrap();
        for (s, p) in slots.iter().enumerate() {
            let mut solo = toy_batch(1, n_tokens, fd, 0.0);
            solo.feat
                .copy_from_slice(&b.feat[s * n_tokens * fd..(s + 1) * n_tokens * fd]);
            let want = d.decode(&solo, n_tokens, fd, p.seed, 1.0).unwrap();
            assert_eq!(want.actions, got.actions[s * n_tokens..(s + 1) * n_tokens]);
        }
        // no params at all is a caller bug, not silent misdecoding
        assert!(d.decode_slots(&b, n_tokens, fd, &[]).is_err());
    }
}
