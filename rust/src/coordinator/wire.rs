//! Coordinator <-> worker-process wire protocol (DESIGN.md §19).
//!
//! Worker shards run as child **processes** speaking a length-prefixed
//! binary protocol over a local TCP socket.  The framing extends the
//! discipline already used by [`crate::checkpoint`] (little-endian
//! integers, length-prefixed strings, magic + version header, implausible
//! counts rejected before allocation) — but unlike checkpoints, which
//! parse trusted local files, frames arrive from a socket, so every
//! decode error is **typed** ([`WireError`]) and recoverable: the
//! coordinator counts it, drops the connection, and keeps serving.
//! Nothing on this path panics or hangs on malformed input
//! (`tests/failure_injection.rs` fuzzes exactly that).
//!
//! Layout of one frame on the wire:
//!
//! ```text
//! [WIRE_MAGIC u32][payload_len u32][payload: tag u8 + body]
//! ```
//!
//! `payload_len` is capped at [`MAX_FRAME_BYTES`]; a larger prefix is
//! rejected before any allocation.  The payload body is a [`Frame`]:
//! handshake (`Hello`/`HelloAck`), request/response, liveness
//! (`Heartbeat`), and session migration (`Drain`/`Transfer`/`DrainDone`).
//!
//! Scenario payloads serialize only what the worker consumes — seed,
//! family, map elements and recorded agent states.  The derived lane
//! graph and recorded actions stay coordinator-side: workers tokenize
//! from `map_elements`/`states` and score against `future_positions`,
//! never the raw `LaneGraph`, so the decoded [`Scenario`] carries an
//! empty graph and reproduces rollouts bit-for-bit.

use std::io::{Read, Write};

use crate::geometry::Pose;
use crate::sim::{
    AgentKind, AgentState, FamilyId, KinematicAction, LaneGraph, MapElement, MapElementKind,
    Scenario, TrajectoryClass,
};

use super::rollout::{RolloutRequest, RolloutResult};

/// Frame magic (distinct from the checkpoint magic `0x5E2A_C4B7`).
pub const WIRE_MAGIC: u32 = 0x5E2A_F8A3;
/// Protocol version carried in `Hello`; a mismatch is a typed error, not
/// a silent best-effort parse.
pub const WIRE_VERSION: u32 = 1;
/// Hard cap on one frame's payload.  A length prefix above this is
/// rejected *before* allocating, so a hostile/corrupt 4 GiB prefix
/// cannot OOM the coordinator.
pub const MAX_FRAME_BYTES: u32 = 64 << 20;

const MAX_STR: usize = 4096;
const MAX_AGENTS: u64 = 4096;
const MAX_STEPS: u64 = 1 << 16;
const MAX_MAP: u64 = 1 << 20;
const MAX_SAMPLES: u64 = 1 << 16;
const MAX_TRACK: u64 = 1 << 20;

/// Typed decode/transport errors.  Every malformed input maps onto one
/// of these — the coordinator's fuzz tests match on the variants.
#[derive(Debug)]
pub enum WireError {
    /// The 4-byte frame prefix was not [`WIRE_MAGIC`].
    BadMagic(u32),
    /// A `Hello` carried an unsupported protocol version.
    BadVersion(u32),
    /// A length prefix exceeded its documented cap.
    Oversize {
        what: &'static str,
        len: u64,
        cap: u64,
    },
    /// The payload ended before the field being decoded.
    Truncated(&'static str),
    /// An enum tag had no defined meaning.
    BadTag { what: &'static str, tag: u32 },
    /// A length-prefixed string was not UTF-8.
    BadUtf8(&'static str),
    /// Socket-level failure (includes mid-frame disconnects, which
    /// surface as `UnexpectedEof`).
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => {
                write!(f, "not a se2attn wire frame (bad magic {m:#010x})")
            }
            WireError::BadVersion(v) => {
                write!(f, "wire protocol version {v}, expected {WIRE_VERSION}")
            }
            WireError::Oversize { what, len, cap } => {
                write!(f, "corrupt frame: {what} length {len} exceeds cap {cap}")
            }
            WireError::Truncated(what) => {
                write!(f, "corrupt frame: truncated while reading {what}")
            }
            WireError::BadTag { what, tag } => {
                write!(f, "corrupt frame: unknown {what} tag {tag}")
            }
            WireError::BadUtf8(what) => write!(f, "corrupt frame: {what} is not utf-8"),
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

// ---------------------------------------------------------------------
// primitive writers (little-endian, matching checkpoint.rs)

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed string, truncated to [`MAX_STR`] bytes on a char
/// boundary (long anyhow chains in error responses must not make the
/// frame undecodable).
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    let mut n = s.len().min(MAX_STR);
    while n > 0 && !s.is_char_boundary(n) {
        n -= 1;
    }
    put_u32(out, n as u32);
    out.extend_from_slice(&s.as_bytes()[..n]);
}

// ---------------------------------------------------------------------
// primitive reader

/// Bounds-checked reader over one frame payload.  Every accessor returns
/// a typed [`WireError`] instead of panicking on short input.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated(what));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u16(&mut self, what: &'static str) -> Result<u16, WireError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn i32(&mut self, what: &'static str) -> Result<i32, WireError> {
        Ok(self.u32(what)? as i32)
    }

    pub fn f32(&mut self, what: &'static str) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    pub fn f64(&mut self, what: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Length-prefixed count, validated against `cap` before the caller
    /// allocates anything proportional to it.
    pub fn count(&mut self, what: &'static str, cap: u64) -> Result<usize, WireError> {
        let n = self.u32(what)? as u64;
        if n > cap {
            return Err(WireError::Oversize { what, len: n, cap });
        }
        Ok(n as usize)
    }

    pub fn str(&mut self, what: &'static str) -> Result<String, WireError> {
        let n = self.count(what, MAX_STR as u64)?;
        let b = self.take(n, what)?;
        String::from_utf8(b.to_vec()).map_err(|_| WireError::BadUtf8(what))
    }

    pub fn bytes(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        self.take(n, what)
    }
}

// ---------------------------------------------------------------------
// stream framing

/// Write one `[magic][len][payload]` frame and flush.  A payload over
/// [`MAX_FRAME_BYTES`] is refused *before* any bytes hit the wire (the
/// receiver would reject the length prefix and drop the connection, so
/// sending it could only destroy the stream); callers that can build
/// such payloads — `export_all`'s aggregated `Transfer` — must degrade
/// (drop KV blobs) instead of sending.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), WireError> {
    if payload.len() > MAX_FRAME_BYTES as usize {
        return Err(WireError::Oversize {
            what: "frame",
            len: payload.len() as u64,
            cap: MAX_FRAME_BYTES as u64,
        });
    }
    w.write_all(&WIRE_MAGIC.to_le_bytes())?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame payload.  Validates the magic and the length prefix
/// (against [`MAX_FRAME_BYTES`]) before allocating; a peer that
/// disconnects mid-frame yields `WireError::Io(UnexpectedEof)`.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, WireError> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head)?;
    let magic = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let len = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversize {
            what: "frame",
            len: len as u64,
            cap: MAX_FRAME_BYTES as u64,
        });
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

// ---------------------------------------------------------------------
// domain codecs

fn kind_tag(k: AgentKind) -> u8 {
    match k {
        AgentKind::Vehicle => 0,
        AgentKind::Pedestrian => 1,
        AgentKind::Cyclist => 2,
    }
}

fn kind_from(tag: u8) -> Result<AgentKind, WireError> {
    match tag {
        0 => Ok(AgentKind::Vehicle),
        1 => Ok(AgentKind::Pedestrian),
        2 => Ok(AgentKind::Cyclist),
        t => Err(WireError::BadTag {
            what: "agent kind",
            tag: t as u32,
        }),
    }
}

fn map_kind_tag(k: MapElementKind) -> u8 {
    match k {
        MapElementKind::Lane => 0,
        MapElementKind::Crosswalk => 1,
        MapElementKind::Signal => 2,
    }
}

fn map_kind_from(tag: u8) -> Result<MapElementKind, WireError> {
    match tag {
        0 => Ok(MapElementKind::Lane),
        1 => Ok(MapElementKind::Crosswalk),
        2 => Ok(MapElementKind::Signal),
        t => Err(WireError::BadTag {
            what: "map element kind",
            tag: t as u32,
        }),
    }
}

fn class_tag(c: TrajectoryClass) -> u8 {
    match c {
        TrajectoryClass::Stationary => 0,
        TrajectoryClass::Straight => 1,
        TrajectoryClass::Turning => 2,
    }
}

fn class_from(tag: u8) -> Result<TrajectoryClass, WireError> {
    match tag {
        0 => Ok(TrajectoryClass::Stationary),
        1 => Ok(TrajectoryClass::Straight),
        2 => Ok(TrajectoryClass::Turning),
        t => Err(WireError::BadTag {
            what: "trajectory class",
            tag: t as u32,
        }),
    }
}

pub fn put_pose(out: &mut Vec<u8>, p: &Pose) {
    put_f64(out, p.x);
    put_f64(out, p.y);
    put_f64(out, p.theta);
}

pub fn take_pose(c: &mut Cursor<'_>) -> Result<Pose, WireError> {
    // construct the literal (not Pose::new) so decoded angles round-trip
    // bit-for-bit instead of passing through the wrap
    Ok(Pose {
        x: c.f64("pose.x")?,
        y: c.f64("pose.y")?,
        theta: c.f64("pose.theta")?,
    })
}

fn put_agent(out: &mut Vec<u8>, a: &AgentState) {
    put_pose(out, &a.pose);
    put_f64(out, a.speed);
    put_u8(out, kind_tag(a.kind));
    put_f64(out, a.length);
    put_f64(out, a.width);
    put_f64(out, a.last_action.accel);
    put_f64(out, a.last_action.yaw_rate);
}

fn take_agent(c: &mut Cursor<'_>) -> Result<AgentState, WireError> {
    Ok(AgentState {
        pose: take_pose(c)?,
        speed: c.f64("agent.speed")?,
        kind: kind_from(c.u8("agent.kind")?)?,
        length: c.f64("agent.length")?,
        width: c.f64("agent.width")?,
        last_action: KinematicAction {
            accel: c.f64("agent.accel")?,
            yaw_rate: c.f64("agent.yaw_rate")?,
        },
    })
}

fn put_agent_step(out: &mut Vec<u8>, step: &[AgentState]) {
    put_u32(out, step.len() as u32);
    for a in step {
        put_agent(out, a);
    }
}

fn take_agent_step(c: &mut Cursor<'_>) -> Result<Vec<AgentState>, WireError> {
    let n = c.count("agent step", MAX_AGENTS)?;
    (0..n).map(|_| take_agent(c)).collect()
}

fn put_map_element(out: &mut Vec<u8>, e: &MapElement) {
    put_u8(out, map_kind_tag(e.kind));
    put_pose(out, &e.pose);
    put_f64(out, e.curvature);
    put_f64(out, e.speed_limit);
    put_f64(out, e.signal_state);
}

fn take_map_element(c: &mut Cursor<'_>) -> Result<MapElement, WireError> {
    Ok(MapElement {
        kind: map_kind_from(c.u8("map.kind")?)?,
        pose: take_pose(c)?,
        curvature: c.f64("map.curvature")?,
        speed_limit: c.f64("map.speed_limit")?,
        signal_state: c.f64("map.signal_state")?,
    })
}

fn put_scenario(out: &mut Vec<u8>, s: &Scenario) {
    put_u64(out, s.seed);
    put_u8(out, s.family.index() as u8);
    put_u32(out, s.map_elements.len() as u32);
    for e in &s.map_elements {
        put_map_element(out, e);
    }
    put_u32(out, s.states.len() as u32);
    for step in &s.states {
        put_agent_step(out, step);
    }
}

fn take_scenario(c: &mut Cursor<'_>) -> Result<Scenario, WireError> {
    let seed = c.u64("scenario.seed")?;
    let fam = c.u8("scenario.family")? as usize;
    let family = *FamilyId::ALL
        .get(fam)
        .ok_or(WireError::BadTag {
            what: "scenario family",
            tag: fam as u32,
        })?;
    let n_map = c.count("scenario map elements", MAX_MAP)?;
    let map_elements = (0..n_map)
        .map(|_| take_map_element(c))
        .collect::<Result<Vec<_>, _>>()?;
    let n_steps = c.count("scenario steps", MAX_STEPS)?;
    let states = (0..n_steps)
        .map(|_| take_agent_step(c))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(Scenario {
        // lane graph and recorded actions are coordinator-side detail
        // (see module docs) — workers never read them
        map: LaneGraph::empty(),
        map_elements,
        states,
        actions: Vec::new(),
        seed,
        family,
    })
}

pub fn put_request(out: &mut Vec<u8>, r: &RolloutRequest) {
    put_scenario(out, &r.scenario);
    put_u32(out, r.t0 as u32);
    put_u32(out, r.n_samples as u32);
    put_f32(out, r.temperature);
    put_i32(out, r.seed);
}

pub fn take_request(c: &mut Cursor<'_>) -> Result<RolloutRequest, WireError> {
    let scenario = take_scenario(c)?;
    let t0 = c.u32("request.t0")? as usize;
    let n_samples = c.count("request samples", MAX_SAMPLES)?;
    Ok(RolloutRequest {
        scenario,
        t0,
        n_samples,
        temperature: c.f32("request.temperature")?,
        seed: c.i32("request.seed")?,
    })
}

fn put_track(out: &mut Vec<u8>, track: &[Vec<(f64, f64)>]) {
    put_u32(out, track.len() as u32);
    for per_agent in track {
        put_u32(out, per_agent.len() as u32);
        for &(x, y) in per_agent {
            put_f64(out, x);
            put_f64(out, y);
        }
    }
}

fn take_track(c: &mut Cursor<'_>) -> Result<Vec<Vec<(f64, f64)>>, WireError> {
    let n_agents = c.count("track agents", MAX_AGENTS)?;
    let mut track = Vec::with_capacity(n_agents);
    for _ in 0..n_agents {
        let n = c.count("track points", MAX_TRACK)?;
        let mut pts = Vec::with_capacity(n);
        for _ in 0..n {
            pts.push((c.f64("track.x")?, c.f64("track.y")?));
        }
        track.push(pts);
    }
    Ok(track)
}

pub fn put_result(out: &mut Vec<u8>, r: &RolloutResult) {
    put_u32(out, r.trajectories.len() as u32);
    for sample in &r.trajectories {
        put_track(out, sample);
    }
    put_u32(out, r.min_ade.len() as u32);
    for &a in &r.min_ade {
        put_f64(out, a);
    }
    put_u32(out, r.classes.len() as u32);
    for &cl in &r.classes {
        put_u8(out, class_tag(cl));
    }
    put_u64(out, r.collisions as u64);
    put_f64(out, r.decode_ms);
}

pub fn take_result(c: &mut Cursor<'_>) -> Result<RolloutResult, WireError> {
    let n_samples = c.count("result samples", MAX_SAMPLES)?;
    let trajectories = (0..n_samples)
        .map(|_| take_track(c))
        .collect::<Result<Vec<_>, _>>()?;
    let n_ade = c.count("result min_ade", MAX_AGENTS)?;
    let mut min_ade = Vec::with_capacity(n_ade);
    for _ in 0..n_ade {
        min_ade.push(c.f64("result.min_ade")?);
    }
    let n_cls = c.count("result classes", MAX_AGENTS)?;
    let mut classes = Vec::with_capacity(n_cls);
    for _ in 0..n_cls {
        classes.push(class_from(c.u8("result.class")?)?);
    }
    Ok(RolloutResult {
        trajectories,
        min_ade,
        classes,
        collisions: c.u64("result.collisions")? as usize,
        decode_ms: c.f64("result.decode_ms")?,
    })
}

// ---------------------------------------------------------------------
// frames

/// One migrating session: scheduler state (window + recorded track) plus
/// the serialized KV window cache
/// ([`super::session_codec::encode_session`]), so the destination worker
/// resumes with warm cached rows instead of a rebuild miss.
#[derive(Clone, Debug)]
pub struct SessionTransfer {
    /// Sample index within the owning request.
    pub sample: u32,
    /// Sliding history window at export time.
    pub window: Vec<Vec<AgentState>>,
    /// World positions emitted so far, per agent.
    pub track: Vec<Vec<(f64, f64)>>,
    /// Session-codec blob of the cached KV rows; empty when the source
    /// held no cached rows for this session.
    pub kv: Vec<u8>,
}

fn put_session_transfer(out: &mut Vec<u8>, s: &SessionTransfer) {
    put_u32(out, s.sample);
    put_u32(out, s.window.len() as u32);
    for step in &s.window {
        put_agent_step(out, step);
    }
    put_track(out, &s.track);
    put_u32(out, s.kv.len() as u32);
    out.extend_from_slice(&s.kv);
}

fn take_session_transfer(c: &mut Cursor<'_>) -> Result<SessionTransfer, WireError> {
    let sample = c.u32("session.sample")?;
    let h = c.count("session window", MAX_STEPS)?;
    let window = (0..h)
        .map(|_| take_agent_step(c))
        .collect::<Result<Vec<_>, _>>()?;
    let track = take_track(c)?;
    let kv_len = c.count("session kv blob", MAX_FRAME_BYTES as u64)?;
    let kv = c.bytes(kv_len, "session kv blob")?.to_vec();
    Ok(SessionTransfer {
        sample,
        window,
        track,
        kv,
    })
}

/// One protocol message (the payload of a frame).
#[derive(Debug)]
pub enum Frame {
    /// Worker -> coordinator, first frame after connect.
    Hello {
        version: u32,
        worker_id: u32,
        pid: u32,
        token: u64,
    },
    /// Coordinator -> worker handshake acknowledgement.
    HelloAck,
    /// Coordinator -> worker: one rollout request.
    Request {
        req_id: u64,
        tenant: u8,
        trace_id: u64,
        method: String,
        rollout: RolloutRequest,
    },
    /// Worker -> coordinator: terminal answer for `req_id`.
    Response {
        req_id: u64,
        outcome: Result<RolloutResult, String>,
    },
    /// Worker -> coordinator liveness beacon.
    Heartbeat { seq: u64 },
    /// Coordinator -> worker: export all live sessions and exit.
    Drain,
    /// A mid-rollout request changing workers: full request context plus
    /// per-sample session state.  Worker -> coordinator on drain;
    /// coordinator -> (another) worker to resume.
    Transfer {
        req_id: u64,
        tenant: u8,
        trace_id: u64,
        method: String,
        rollout: RolloutRequest,
        steps_done: u32,
        decode_ms: f64,
        sessions: Vec<SessionTransfer>,
    },
    /// Worker -> coordinator: drain complete, the process is exiting.
    DrainDone,
}

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_REQUEST: u8 = 3;
const TAG_RESPONSE: u8 = 4;
const TAG_HEARTBEAT: u8 = 5;
const TAG_DRAIN: u8 = 6;
const TAG_TRANSFER: u8 = 7;
const TAG_DRAIN_DONE: u8 = 8;

impl Frame {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Frame::Hello {
                version,
                worker_id,
                pid,
                token,
            } => {
                put_u8(&mut out, TAG_HELLO);
                put_u32(&mut out, *version);
                put_u32(&mut out, *worker_id);
                put_u32(&mut out, *pid);
                put_u64(&mut out, *token);
            }
            Frame::HelloAck => put_u8(&mut out, TAG_HELLO_ACK),
            Frame::Request {
                req_id,
                tenant,
                trace_id,
                method,
                rollout,
            } => {
                put_u8(&mut out, TAG_REQUEST);
                put_u64(&mut out, *req_id);
                put_u8(&mut out, *tenant);
                put_u64(&mut out, *trace_id);
                put_str(&mut out, method);
                put_request(&mut out, rollout);
            }
            Frame::Response { req_id, outcome } => {
                put_u8(&mut out, TAG_RESPONSE);
                put_u64(&mut out, *req_id);
                match outcome {
                    Ok(res) => {
                        put_u8(&mut out, 0);
                        put_result(&mut out, res);
                    }
                    Err(msg) => {
                        put_u8(&mut out, 1);
                        put_str(&mut out, msg);
                    }
                }
            }
            Frame::Heartbeat { seq } => {
                put_u8(&mut out, TAG_HEARTBEAT);
                put_u64(&mut out, *seq);
            }
            Frame::Drain => put_u8(&mut out, TAG_DRAIN),
            Frame::Transfer {
                req_id,
                tenant,
                trace_id,
                method,
                rollout,
                steps_done,
                decode_ms,
                sessions,
            } => {
                put_u8(&mut out, TAG_TRANSFER);
                put_u64(&mut out, *req_id);
                put_u8(&mut out, *tenant);
                put_u64(&mut out, *trace_id);
                put_str(&mut out, method);
                put_request(&mut out, rollout);
                put_u32(&mut out, *steps_done);
                put_f64(&mut out, *decode_ms);
                put_u32(&mut out, sessions.len() as u32);
                for s in sessions {
                    put_session_transfer(&mut out, s);
                }
            }
            Frame::DrainDone => put_u8(&mut out, TAG_DRAIN_DONE),
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Frame, WireError> {
        let mut c = Cursor::new(payload);
        let tag = c.u8("frame tag")?;
        match tag {
            TAG_HELLO => Ok(Frame::Hello {
                version: c.u32("hello.version")?,
                worker_id: c.u32("hello.worker_id")?,
                pid: c.u32("hello.pid")?,
                token: c.u64("hello.token")?,
            }),
            TAG_HELLO_ACK => Ok(Frame::HelloAck),
            TAG_REQUEST => Ok(Frame::Request {
                req_id: c.u64("request.req_id")?,
                tenant: c.u8("request.tenant")?,
                trace_id: c.u64("request.trace_id")?,
                method: c.str("request.method")?,
                rollout: take_request(&mut c)?,
            }),
            TAG_RESPONSE => {
                let req_id = c.u64("response.req_id")?;
                let outcome = match c.u8("response.outcome")? {
                    0 => Ok(take_result(&mut c)?),
                    1 => Err(c.str("response.error")?),
                    t => {
                        return Err(WireError::BadTag {
                            what: "response outcome",
                            tag: t as u32,
                        })
                    }
                };
                Ok(Frame::Response { req_id, outcome })
            }
            TAG_HEARTBEAT => Ok(Frame::Heartbeat {
                seq: c.u64("heartbeat.seq")?,
            }),
            TAG_DRAIN => Ok(Frame::Drain),
            TAG_TRANSFER => {
                let req_id = c.u64("transfer.req_id")?;
                let tenant = c.u8("transfer.tenant")?;
                let trace_id = c.u64("transfer.trace_id")?;
                let method = c.str("transfer.method")?;
                let rollout = take_request(&mut c)?;
                let steps_done = c.u32("transfer.steps_done")?;
                let decode_ms = c.f64("transfer.decode_ms")?;
                let n = c.count("transfer sessions", MAX_SAMPLES)?;
                let sessions = (0..n)
                    .map(|_| take_session_transfer(&mut c))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(Frame::Transfer {
                    req_id,
                    tenant,
                    trace_id,
                    method,
                    rollout,
                    steps_done,
                    decode_ms,
                    sessions,
                })
            }
            TAG_DRAIN_DONE => Ok(Frame::DrainDone),
            t => Err(WireError::BadTag {
                what: "frame",
                tag: t as u32,
            }),
        }
    }

    /// Encode and write as one frame.
    pub fn write_to(&self, w: &mut impl Write) -> Result<(), WireError> {
        write_frame(w, &self.encode())
    }

    /// Read and decode one frame.
    pub fn read_from(r: &mut impl Read) -> Result<Frame, WireError> {
        Frame::decode(&read_frame(r)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::ScenarioGenerator;

    fn sample_request(seed: u64) -> RolloutRequest {
        let sim = SimConfig::default();
        let scenario = ScenarioGenerator::new(sim.clone()).generate(seed);
        RolloutRequest {
            scenario,
            t0: sim.history_steps - 1,
            n_samples: 2,
            temperature: 0.8,
            seed: 41,
        }
    }

    /// decode(encode(x)) re-encodes to the same bytes — the codec is a
    /// bijection on its own image, which is what migration/replay needs.
    fn assert_roundtrip(f: &Frame) {
        let bytes = f.encode();
        let back = Frame::decode(&bytes).unwrap();
        assert_eq!(bytes, back.encode(), "{f:?}");
    }

    #[test]
    fn frames_roundtrip() {
        assert_roundtrip(&Frame::Hello {
            version: WIRE_VERSION,
            worker_id: 3,
            pid: 4242,
            token: 0xDEAD_BEEF,
        });
        assert_roundtrip(&Frame::HelloAck);
        assert_roundtrip(&Frame::Request {
            req_id: 7,
            tenant: 2,
            trace_id: 99,
            method: "se2fourier".into(),
            rollout: sample_request(11),
        });
        assert_roundtrip(&Frame::Heartbeat { seq: 123 });
        assert_roundtrip(&Frame::Drain);
        assert_roundtrip(&Frame::DrainDone);
        assert_roundtrip(&Frame::Response {
            req_id: 9,
            outcome: Err("decode step failed".into()),
        });
    }

    #[test]
    fn result_and_transfer_roundtrip() {
        let res = RolloutResult {
            trajectories: vec![vec![vec![(1.5, -2.5), (0.0, 0.25)], vec![(3.0, 4.0)]]],
            min_ade: vec![0.5, 1.25],
            classes: vec![TrajectoryClass::Straight, TrajectoryClass::Turning],
            collisions: 3,
            decode_ms: 1.75,
        };
        assert_roundtrip(&Frame::Response {
            req_id: 12,
            outcome: Ok(res),
        });
        let req = sample_request(5);
        let window = vec![req.scenario.states[0].clone(), req.scenario.states[1].clone()];
        assert_roundtrip(&Frame::Transfer {
            req_id: 13,
            tenant: 0,
            trace_id: 4,
            method: "abs".into(),
            rollout: req,
            steps_done: 6,
            decode_ms: 0.25,
            sessions: vec![SessionTransfer {
                sample: 1,
                window,
                track: vec![vec![(9.0, 9.5)], vec![]],
                kv: vec![1, 2, 3, 4],
            }],
        });
    }

    #[test]
    fn decoded_request_replays_identically() {
        // the decoded scenario must drive the rollout engine bit-for-bit:
        // every field the engine reads survives, and the scene id (cache
        // affinity + routing key) is preserved exactly
        let req = sample_request(17);
        let mut buf = Vec::new();
        put_request(&mut buf, &req);
        let back = take_request(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(back.scenario.scene_id(), req.scenario.scene_id());
        assert_eq!(back.scenario.states.len(), req.scenario.states.len());
        for (a, b) in req
            .scenario
            .states
            .iter()
            .flatten()
            .zip(back.scenario.states.iter().flatten())
        {
            assert_eq!(a.pose, b.pose);
            assert_eq!(a.speed.to_bits(), b.speed.to_bits());
            assert_eq!(a.kind, b.kind);
        }
        assert_eq!(back.scenario.map_elements.len(), req.scenario.map_elements.len());
        assert_eq!(back.t0, req.t0);
        assert_eq!(back.n_samples, req.n_samples);
        assert_eq!(back.seed, req.seed);
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut buf: &[u8] = &[0xAA, 0xBB, 0xCC, 0xDD, 0, 0, 0, 0];
        match read_frame(&mut buf) {
            Err(WireError::BadMagic(_)) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_typed_and_never_allocates() {
        let mut head = Vec::new();
        put_u32(&mut head, WIRE_MAGIC);
        put_u32(&mut head, u32::MAX); // 4 GiB claim
        match read_frame(&mut head.as_slice()) {
            Err(WireError::Oversize { what: "frame", len, .. }) => {
                assert_eq!(len, u32::MAX as u64)
            }
            other => panic!("expected Oversize, got {other:?}"),
        }
    }

    #[test]
    fn oversize_payload_is_refused_before_writing() {
        let payload = vec![0u8; MAX_FRAME_BYTES as usize + 1];
        let mut sink = Vec::new();
        match write_frame(&mut sink, &payload) {
            Err(WireError::Oversize { what: "frame", len, cap }) => {
                assert_eq!(len, MAX_FRAME_BYTES as u64 + 1);
                assert_eq!(cap, MAX_FRAME_BYTES as u64);
            }
            other => panic!("expected Oversize, got {other:?}"),
        }
        assert!(sink.is_empty(), "nothing written for a refused frame");
    }

    #[test]
    fn mid_frame_disconnect_is_typed_eof() {
        let mut buf = Vec::new();
        put_u32(&mut buf, WIRE_MAGIC);
        put_u32(&mut buf, 100); // promises 100 bytes,
        buf.extend_from_slice(&[0u8; 10]); // delivers 10, then "disconnects"
        match read_frame(&mut buf.as_slice()) {
            Err(WireError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof)
            }
            other => panic!("expected Io(UnexpectedEof), got {other:?}"),
        }
    }

    #[test]
    fn truncated_payload_and_bad_tags_are_typed() {
        // a Request frame cut short inside the scenario
        let full = Frame::Request {
            req_id: 1,
            tenant: 0,
            trace_id: 0,
            method: "abs".into(),
            rollout: sample_request(3),
        }
        .encode();
        for cut in [1usize, 5, 20, full.len() - 1] {
            match Frame::decode(&full[..cut]) {
                Err(WireError::Truncated(_)) => {}
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
        match Frame::decode(&[99]) {
            Err(WireError::BadTag { what: "frame", tag: 99 }) => {}
            other => panic!("expected BadTag, got {other:?}"),
        }
        // an implausible agent-count prefix must be rejected before any
        // allocation happens
        let mut buf = vec![TAG_HEARTBEAT];
        buf.truncate(0);
        put_u8(&mut buf, TAG_REQUEST);
        put_u64(&mut buf, 1); // req_id
        put_u8(&mut buf, 0); // tenant
        put_u64(&mut buf, 0); // trace
        put_str(&mut buf, "abs");
        put_u64(&mut buf, 7); // scenario.seed
        put_u8(&mut buf, 0); // family
        put_u32(&mut buf, u32::MAX); // map element count: implausible
        match Frame::decode(&buf) {
            Err(WireError::Oversize { .. }) => {}
            other => panic!("expected Oversize, got {other:?}"),
        }
    }

    #[test]
    fn long_error_strings_truncate_on_char_boundary() {
        let msg = "é".repeat(4096); // 2 bytes per char — must split cleanly
        let f = Frame::Response {
            req_id: 1,
            outcome: Err(msg),
        };
        match Frame::decode(&f.encode()).unwrap() {
            Frame::Response { outcome: Err(m), .. } => {
                assert!(m.len() <= 4096);
                assert!(m.chars().all(|ch| ch == 'é'));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stream_framing_roundtrips_multiple_frames() {
        let mut buf = Vec::new();
        Frame::Heartbeat { seq: 1 }.write_to(&mut buf).unwrap();
        Frame::Drain.write_to(&mut buf).unwrap();
        let mut r = buf.as_slice();
        assert!(matches!(
            Frame::read_from(&mut r).unwrap(),
            Frame::Heartbeat { seq: 1 }
        ));
        assert!(matches!(Frame::read_from(&mut r).unwrap(), Frame::Drain));
        // clean EOF between frames is an Io error the reader loop maps to
        // connection-closed
        assert!(matches!(
            Frame::read_from(&mut r),
            Err(WireError::Io(_))
        ));
    }
}
