//! Lock-free telemetry for the serving hot path: counters and fixed-bucket
//! latency histograms (atomics only, no allocation after construction).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sim::suite::FamilyId;

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating add: sticks at `u64::MAX` instead of wrapping.  Used for
    /// accumulators fed by unbounded external values (e.g. minADE sums),
    /// where a single pathological sample must not reset the counter.
    pub fn saturating_add(&self, n: u64) {
        let mut cur = self.v.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(n);
            if next == cur {
                return;
            }
            match self
                .v
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (resident bytes, live sessions, ...).
#[derive(Default, Debug)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement (never wraps below zero).
    pub fn sub(&self, n: u64) {
        let mut cur = self.v.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self
                .v
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// KV/tokenization cache counters for the incremental decode engine
/// (DESIGN.md §10): session and map-row hit rates, sliding-window and
/// capacity evictions, and resident bytes across all live caches.
///
/// `resident_bytes` is fed exclusively from the caches' own
/// `resident_bytes()` accessors, which price rows at their **true
/// storage precision** (f16/bf16 codes + per-row scale/offset, or raw
/// f32) using the closed-form byte model in
/// [`crate::attention::memmodel`] — one byte model for the gauge, the
/// eviction budget and the capacity-planning formulas, so the stats
/// line, `max_bytes` enforcement and DESIGN.md §14 arithmetic can never
/// drift apart (regression-tested in `tests/quantized_cache.rs`).  The
/// hit/miss/eviction counters are precision-independent: the same
/// workload produces the same counts at any [`crate::config::CachePrecision`].
#[derive(Default, Debug)]
pub struct CacheStats {
    pub hits: Counter,
    pub misses: Counter,
    pub evictions: Counter,
    pub map_hits: Counter,
    pub map_misses: Counter,
    pub resident_bytes: Gauge,
}

impl CacheStats {
    /// Session hit rate in [0, 1]; 0 when no lookups happened yet.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get();
        let total = h + self.misses.get();
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "cache hits={} misses={} ({:.0}%) evict={} map_hits={} map_misses={} resident={}B",
            self.hits.get(),
            self.misses.get(),
            self.hit_rate() * 100.0,
            self.evictions.get(),
            self.map_hits.get(),
            self.map_misses.get(),
            self.resident_bytes.get(),
        )
    }
}

/// Per-family serving counters: requests, minADE accumulation (micrometer
/// integer atomics — no float CAS on the hot path) and collision counts,
/// one fixed slot per registered [`FamilyId`] so recording stays
/// allocation-free after construction.
#[derive(Debug)]
pub struct FamilyTelemetry {
    requests: Vec<Counter>,
    /// Sum of per-agent minADE in micrometers.
    ade_um: Vec<Counter>,
    ade_n: Vec<Counter>,
    collisions: Vec<Counter>,
    /// Joint trajectory samples served (collision-rate denominator, so
    /// the reported rate is comparable across `--samples` settings).
    samples: Vec<Counter>,
}

impl Default for FamilyTelemetry {
    fn default() -> Self {
        let slots = || (0..FamilyId::ALL.len()).map(|_| Counter::default()).collect();
        FamilyTelemetry {
            requests: slots(),
            ade_um: slots(),
            ade_n: slots(),
            collisions: slots(),
            samples: slots(),
        }
    }
}

impl FamilyTelemetry {
    /// Fold one completed rollout into the family's slot.
    pub fn record(&self, family: FamilyId, min_ade: &[f64], collisions: u64, samples: u64) {
        let i = family.index();
        self.requests[i].inc();
        for &a in min_ade {
            if a.is_finite() && a >= 0.0 {
                // The f64→u64 cast saturates at u64::MAX for pathological
                // minADE values; the accumulator must saturate too, or one
                // such sample wraps the sum and corrupts every later mean.
                self.ade_um[i].saturating_add((a * 1e6) as u64);
                self.ade_n[i].inc();
            }
        }
        self.collisions[i].add(collisions);
        self.samples[i].add(samples);
    }

    pub fn requests(&self, family: FamilyId) -> u64 {
        self.requests[family.index()].get()
    }

    /// Raw accumulated minADE in micrometers (saturates at `u64::MAX`).
    pub fn ade_micrometers(&self, family: FamilyId) -> u64 {
        self.ade_um[family.index()].get()
    }

    /// Samples folded into the minADE accumulator.
    pub fn ade_samples(&self, family: FamilyId) -> u64 {
        self.ade_n[family.index()].get()
    }

    /// Joint trajectory samples served for `family`.
    pub fn samples(&self, family: FamilyId) -> u64 {
        self.samples[family.index()].get()
    }

    pub fn collisions(&self, family: FamilyId) -> u64 {
        self.collisions[family.index()].get()
    }

    /// Mean colliding pairs per joint sample (0 until something was
    /// recorded).
    pub fn collision_rate(&self, family: FamilyId) -> f64 {
        let i = family.index();
        let n = self.samples[i].get();
        if n == 0 {
            return 0.0;
        }
        self.collisions[i].get() as f64 / n as f64
    }

    /// Mean per-agent minADE in meters (0 until something was recorded).
    pub fn mean_min_ade_m(&self, family: FamilyId) -> f64 {
        let i = family.index();
        let n = self.ade_n[i].get();
        if n == 0 {
            return 0.0;
        }
        self.ade_um[i].get() as f64 / 1e6 / n as f64
    }

    /// Compact per-family block for the stats line; only families that
    /// actually served traffic appear.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = FamilyId::ALL
            .iter()
            .filter(|f| self.requests(**f) > 0)
            .map(|f| {
                format!(
                    "{}:req={} minADE={:.2}m col/smp={:.2}",
                    f.name(),
                    self.requests(*f),
                    self.mean_min_ade_m(*f),
                    self.collision_rate(*f),
                )
            })
            .collect();
        if parts.is_empty() {
            "families[-]".to_string()
        } else {
            format!("families[{}]", parts.join(" "))
        }
    }
}

/// Per-tenant admission counters (DESIGN.md §17): one fixed slot per
/// QoS class ([`super::admission::TENANT_CLASSES`] of them — tenant ids
/// wrap), so recording stays allocation-free on the admission path.
/// Admitted / rejected (queue full) / shed (deadline missed) / done are
/// deliberately separate: under overload the shed:rejected ratio is the
/// signal that distinguishes "queue too short" from "deadline too tight".
#[derive(Debug)]
pub struct TenantTelemetry {
    admitted: Vec<Counter>,
    rejected: Vec<Counter>,
    shed: Vec<Counter>,
    done: Vec<Counter>,
}

impl Default for TenantTelemetry {
    fn default() -> Self {
        let n = super::admission::TENANT_CLASSES;
        let slots = || (0..n).map(|_| Counter::default()).collect();
        TenantTelemetry {
            admitted: slots(),
            rejected: slots(),
            shed: slots(),
            done: slots(),
        }
    }
}

impl TenantTelemetry {
    fn slot(&self, tenant: u8) -> usize {
        tenant as usize % self.admitted.len()
    }

    /// The tenant's request joined a step batch.
    pub fn admitted(&self, tenant: u8) {
        self.admitted[self.slot(tenant)].inc();
    }

    /// The tenant's request bounced off a full admission queue.
    pub fn rejected(&self, tenant: u8) {
        self.rejected[self.slot(tenant)].inc();
    }

    /// The tenant's request was shed after missing its deadline.
    pub fn shed(&self, tenant: u8) {
        self.shed[self.slot(tenant)].inc();
    }

    /// The tenant's request completed with a real result.
    pub fn done(&self, tenant: u8) {
        self.done[self.slot(tenant)].inc();
    }

    pub fn admitted_count(&self, tenant: u8) -> u64 {
        self.admitted[self.slot(tenant)].get()
    }

    pub fn rejected_count(&self, tenant: u8) -> u64 {
        self.rejected[self.slot(tenant)].get()
    }

    pub fn shed_count(&self, tenant: u8) -> u64 {
        self.shed[self.slot(tenant)].get()
    }

    pub fn done_count(&self, tenant: u8) -> u64 {
        self.done[self.slot(tenant)].get()
    }

    /// Number of QoS class slots.
    pub fn classes(&self) -> usize {
        self.admitted.len()
    }

    /// Compact block for the stats line; only classes that saw traffic
    /// appear, and an all-idle bundle contributes nothing.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = (0..self.classes())
            .filter(|&t| {
                self.admitted[t].get() + self.rejected[t].get() + self.shed[t].get() > 0
            })
            .map(|t| {
                format!(
                    "t{t}:adm={} rej={} shed={} done={}",
                    self.admitted[t].get(),
                    self.rejected[t].get(),
                    self.shed[t].get(),
                    self.done[t].get(),
                )
            })
            .collect();
        if parts.is_empty() {
            String::new()
        } else {
            format!(" tenants[{}]", parts.join(" "))
        }
    }
}

/// Log-spaced latency histogram: bucket i covers [2^i, 2^(i+1)) microseconds,
/// plus exact observed min/max atomics so the extreme percentiles report
/// real values rather than power-of-two bucket bounds.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
    /// Exact smallest recorded value (`u64::MAX` until first record).
    min_us: AtomicU64,
    /// Exact largest recorded value.
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
            min_us: AtomicU64::new(u64::MAX),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record_us(&self, us: u64) {
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.min_us.fetch_min(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Exact smallest recorded value (0 before anything was recorded).
    pub fn min_us(&self) -> u64 {
        let v = self.min_us.load(Ordering::Relaxed);
        if v == u64::MAX {
            0
        } else {
            v
        }
    }

    /// Exact largest recorded value (0 before anything was recorded).
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Snapshot of the per-bucket counts (bucket i covers
    /// `[2^i, 2^(i+1))` µs), for exporters.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us() as f64 / n as f64
    }

    /// Approximate percentile.  Interior percentiles use the bucket upper
    /// bound clamped to the exact observed maximum (a power-of-two bound
    /// can overshoot the true value by ~2x); p ≤ 0 returns the exact
    /// observed minimum and p ≥ 100 the exact observed maximum.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        if p <= 0.0 {
            return self.min_us();
        }
        let max = self.max_us();
        if p >= 100.0 {
            return max;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)).min(max);
            }
        }
        max
    }
}

/// Counters of one serving shard (worker thread).  The cross-shard sums
/// live in [`ServerStats`] (every shard also increments the shared global
/// atomics); these slots expose the per-shard breakdown so a hot or
/// starved shard is visible in the stats line.
#[derive(Default, Debug)]
pub struct ShardStats {
    /// Requests received by this shard (counted at routing time, before
    /// the queue-capacity check — a Busy-bounced request still counts
    /// here and additionally in `rejected`).
    pub requests: Counter,
    /// Requests answered with a real rollout result.
    pub done: Counter,
    /// Requests answered with an error (decode failure, undeployed method).
    pub failed: Counter,
    /// Per-shard backpressure rejections (this shard's queue was full).
    pub rejected: Counter,
    /// Requests shed by this shard's admission controller after missing
    /// their deadline (counted separately from `rejected`: a shed request
    /// was accepted into the queue first).
    pub shed: Counter,
    /// Step batches this shard executed (one per decode step per method
    /// under the continuous scheduler).
    pub batches: Counter,
    /// Requests submitted but not yet answered (the least-loaded routing
    /// signal for stateless traffic).
    pub inflight: Gauge,
    /// Envelopes currently waiting in this shard's batcher queues
    /// (refreshed by the worker loop; the `/healthz` saturation signal
    /// and the `/vars` sampler read it without touching the queues).
    pub queue_depth: Gauge,
    /// 1 while the shard worker thread is running, 0 once it exits
    /// (normally or by panic — maintained by a drop guard, so
    /// `/healthz` sees dead shards either way).
    pub live: Gauge,
    /// Decode sessions currently admitted to this shard's continuous
    /// step batch (step-batch occupancy; refreshed by the worker loop).
    pub live_sessions: Gauge,
}

impl ShardStats {
    /// Compact `s<i>:` fragment for the stats line.
    pub fn summary_fragment(&self, shard: usize) -> String {
        format!(
            "s{shard}:req={} done={} rej={} inflight={} q={} shed={} live={}",
            self.requests.get(),
            self.done.get(),
            self.rejected.get(),
            self.inflight.get(),
            self.queue_depth.get(),
            self.shed.get(),
            self.live_sessions.get(),
        )
    }
}

/// Multi-process fleet counters (DESIGN.md §19): worker liveness churn,
/// session migration volume and the wire-protocol error budget.  All
/// zero on the in-process serving path — the summary fragment only
/// appears once a process boundary exists.
#[derive(Default, Debug)]
pub struct MigrationStats {
    /// Workers declared dead (heartbeat timeout, connection loss, or a
    /// reaped child process).
    pub worker_deaths: Counter,
    /// Dead workers the coordinator respawned.
    pub worker_respawns: Counter,
    /// KV sessions that moved between worker processes via the session
    /// codec (drain/rebalance Transfer frames), instead of rebuilding
    /// as cache misses.
    pub sessions_migrated: Counter,
    /// Encoded session-blob bytes shipped across the process boundary.
    pub migration_bytes: Counter,
    /// In-flight request envelopes replayed to a live worker after
    /// their original worker died.
    pub envelopes_replayed: Counter,
    /// Frames rejected by the wire codec (bad magic, oversized length
    /// prefix, truncation, unknown tag) — each one cost a connection,
    /// never a coordinator panic.
    pub wire_errors: Counter,
    /// Time from deciding to respawn a worker to its Hello completing.
    pub resurrect_latency: LatencyHistogram,
}

impl MigrationStats {
    fn is_idle(&self) -> bool {
        self.worker_deaths.get() == 0
            && self.sessions_migrated.get() == 0
            && self.envelopes_replayed.get() == 0
            && self.wire_errors.get() == 0
    }

    /// Compact block for the stats line; empty until the fleet sees its
    /// first death, migration or wire error.
    pub fn summary(&self) -> String {
        if self.is_idle() {
            return String::new();
        }
        format!(
            " fleet[deaths={} respawns={} migrated={} mig_bytes={} replayed={} \
             wire_err={} resurrect_p95={:.1}ms]",
            self.worker_deaths.get(),
            self.worker_respawns.get(),
            self.sessions_migrated.get(),
            self.migration_bytes.get(),
            self.envelopes_replayed.get(),
            self.wire_errors.get(),
            self.resurrect_latency.percentile_us(95.0) as f64 / 1e3,
        )
    }
}

/// Serving metrics bundle.
#[derive(Default, Debug)]
pub struct ServerStats {
    pub requests_in: Counter,
    pub requests_done: Counter,
    pub requests_failed: Counter,
    pub batches: Counter,
    pub padded_slots: Counter,
    pub queue_rejections: Counter,
    /// Requests shed after missing their admission deadline (counted
    /// separately from `queue_rejections`: sheds were accepted first —
    /// under overload the ratio distinguishes a too-short queue from a
    /// too-tight deadline).
    pub queue_sheds: Counter,
    /// Real (non-padding) session-slots decoded across all step batches;
    /// divided by `batches` this is the mean step-batch occupancy of the
    /// continuous scheduler.
    pub step_sessions: Counter,
    pub e2e_latency: LatencyHistogram,
    pub decode_latency: LatencyHistogram,
    /// Time requests spent in the admission queue before joining a step
    /// batch (sheds and rejections never record here).
    pub queue_age: LatencyHistogram,
    /// Per-tenant QoS class admission counters.
    pub tenants: TenantTelemetry,
    /// Shared with every shard's [`crate::coordinator::kvcache::KvCachePool`]
    /// (one gauge/counter set aggregated across shards).
    pub cache: std::sync::Arc<CacheStats>,
    /// Per-scenario-family request/minADE/collision counters.
    pub families: FamilyTelemetry,
    /// Per-shard counters (empty for a non-sharded bundle, e.g. in unit
    /// tests that only exercise the global counters).
    pub shards: Vec<std::sync::Arc<ShardStats>>,
    /// Multi-process fleet counters (all zero on the in-process path).
    pub migration: MigrationStats,
}

impl ServerStats {
    /// Stats bundle for a server with `n` shards.
    pub fn with_shards(n: usize) -> ServerStats {
        ServerStats {
            shards: (0..n).map(|_| std::sync::Arc::default()).collect(),
            ..ServerStats::default()
        }
    }

    /// Per-shard breakdown block, empty when no shards are registered.
    fn shard_summary(&self) -> String {
        if self.shards.is_empty() {
            return String::new();
        }
        let parts: Vec<String> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| s.summary_fragment(i))
            .collect();
        format!(" shards[{}]", parts.join(" "))
    }

    pub fn summary(&self) -> String {
        format!(
            "in={} done={} failed={} batches={} pad={} rej={} shed={} \
             steps={} qage_p95={:.1}ms e2e_mean={:.1}ms e2e_p95={:.1}ms \
             decode_mean={:.1}ms decode_p95={:.1}ms decode_p99={:.1}ms \
             {} {}{}{}",
            self.requests_in.get(),
            self.requests_done.get(),
            self.requests_failed.get(),
            self.batches.get(),
            self.padded_slots.get(),
            self.queue_rejections.get(),
            self.queue_sheds.get(),
            self.step_sessions.get(),
            self.queue_age.percentile_us(95.0) as f64 / 1e3,
            self.e2e_latency.mean_us() / 1e3,
            self.e2e_latency.percentile_us(95.0) as f64 / 1e3,
            self.decode_latency.mean_us() / 1e3,
            self.decode_latency.percentile_us(95.0) as f64 / 1e3,
            self.decode_latency.percentile_us(99.0) as f64 / 1e3,
            self.cache.summary(),
            self.families.summary(),
            self.tenants.summary(),
            self.shard_summary(),
        ) + &self.migration.summary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = std::sync::Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn histogram_percentiles_are_monotone() {
        let h = LatencyHistogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn gauge_saturates_at_zero() {
        let g = Gauge::default();
        g.add(10);
        g.sub(3);
        assert_eq!(g.get(), 7);
        g.sub(100);
        assert_eq!(g.get(), 0);
        g.set(42);
        assert_eq!(g.get(), 42);
    }

    #[test]
    fn cache_stats_hit_rate_and_summary() {
        let c = CacheStats::default();
        assert_eq!(c.hit_rate(), 0.0);
        c.hits.add(3);
        c.misses.inc();
        c.resident_bytes.add(1024);
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        let s = c.summary();
        assert!(s.contains("hits=3") && s.contains("resident=1024B"), "{s}");
    }

    #[test]
    fn family_telemetry_records_and_summarizes() {
        let t = FamilyTelemetry::default();
        assert_eq!(t.summary(), "families[-]");
        t.record(FamilyId::Roundabout, &[1.5, 2.5], 1, 4);
        t.record(FamilyId::Roundabout, &[f64::NAN], 0, 4);
        t.record(FamilyId::ParkingLot, &[0.5], 2, 1);
        assert_eq!(t.requests(FamilyId::Roundabout), 2);
        assert_eq!(t.requests(FamilyId::ParkingLot), 1);
        assert_eq!(t.requests(FamilyId::Corridor), 0);
        assert!((t.mean_min_ade_m(FamilyId::Roundabout) - 2.0).abs() < 1e-6);
        assert_eq!(t.collisions(FamilyId::ParkingLot), 2);
        // per-sample collision rate: 1 pair over 8 samples
        assert!((t.collision_rate(FamilyId::Roundabout) - 0.125).abs() < 1e-12);
        assert_eq!(t.collision_rate(FamilyId::Corridor), 0.0);
        let s = t.summary();
        assert!(s.contains("roundabout:req=2"), "{s}");
        assert!(s.contains("parking-lot:req=1"), "{s}");
        assert!(!s.contains("corridor"), "{s}");
        // the server stats line carries the per-family block
        let stats = ServerStats::default();
        stats.families.record(FamilyId::HighwayMerge, &[3.0], 0, 2);
        assert!(stats.summary().contains("highway-merge:req=1"));
    }

    #[test]
    fn shard_stats_appear_in_summary() {
        let stats = ServerStats::with_shards(2);
        stats.shards[0].requests.add(3);
        stats.shards[0].done.add(2);
        stats.shards[0].inflight.add(1);
        stats.shards[1].rejected.inc();
        stats.shards[0].queue_depth.set(5);
        let s = stats.summary();
        assert!(s.contains("s0:req=3 done=2 rej=0 inflight=1 q=5"), "{s}");
        assert!(s.contains("s1:req=0 done=0 rej=1 inflight=0 q=0"), "{s}");
        // a shard-less bundle keeps the legacy line shape
        assert!(!ServerStats::default().summary().contains("shards["));
    }

    #[test]
    fn histogram_bucket_bounds() {
        let h = LatencyHistogram::default();
        h.record_us(1000);
        // p100 upper bound must be >= the recorded value
        assert!(h.percentile_us(100.0) >= 1000);
    }

    #[test]
    fn histogram_extremes_report_observed_values() {
        let h = LatencyHistogram::default();
        assert_eq!(h.min_us(), 0);
        assert_eq!(h.max_us(), 0);
        h.record_us(700);
        h.record_us(900);
        h.record_us(1000);
        // 1000 lands in bucket [512, 1024): the old upper-bound answer was
        // 1024 for p100 (and 2048 for a 1025 µs sample — ~2x overshoot).
        assert_eq!(h.min_us(), 700);
        assert_eq!(h.max_us(), 1000);
        assert_eq!(h.percentile_us(0.0), 700);
        assert_eq!(h.percentile_us(100.0), 1000);
        // interior percentiles are clamped to the observed max
        assert!(h.percentile_us(99.0) <= 1000);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
    }

    #[test]
    fn histogram_interior_percentile_clamps_to_max() {
        let h = LatencyHistogram::default();
        h.record_us(1025); // bucket [1024, 2048) — upper bound 2048
        assert_eq!(h.percentile_us(95.0), 1025);
        assert_eq!(h.percentile_us(100.0), 1025);
        assert_eq!(h.percentile_us(0.0), 1025);
    }

    #[test]
    fn family_ade_accumulation_saturates_on_pathological_values() {
        let t = FamilyTelemetry::default();
        // f64::MAX * 1e6 saturates to u64::MAX at the cast; a second such
        // sample must stick there rather than wrap the accumulator.
        t.record(FamilyId::Roundabout, &[f64::MAX], 0, 1);
        assert_eq!(t.ade_micrometers(FamilyId::Roundabout), u64::MAX);
        t.record(FamilyId::Roundabout, &[f64::MAX], 0, 1);
        assert_eq!(t.ade_micrometers(FamilyId::Roundabout), u64::MAX);
        assert_eq!(t.ade_samples(FamilyId::Roundabout), 2);
        assert!(t.mean_min_ade_m(FamilyId::Roundabout).is_finite());
    }

    #[test]
    fn tenant_telemetry_wraps_and_summarizes() {
        let t = TenantTelemetry::default();
        assert_eq!(t.summary(), "");
        t.admitted(1);
        t.admitted(1);
        t.done(1);
        t.shed(2);
        // tenant ids wrap onto the fixed class slots
        let wrapped = (t.classes() + 1) as u8;
        t.rejected(wrapped);
        assert_eq!(t.admitted_count(1), 2);
        assert_eq!(t.done_count(1), 1);
        assert_eq!(t.shed_count(2), 1);
        assert_eq!(t.rejected_count(1), 1);
        let s = t.summary();
        assert!(s.contains("t1:adm=2 rej=1 shed=0 done=1"), "{s}");
        assert!(s.contains("t2:adm=0 rej=0 shed=1 done=0"), "{s}");
        assert!(!s.contains("t0:"), "{s}");
    }

    #[test]
    fn summary_line_reports_sheds_and_queue_age() {
        let stats = ServerStats::with_shards(1);
        stats.queue_sheds.add(4);
        stats.step_sessions.add(12);
        stats.queue_age.record_us(2000);
        stats.shards[0].shed.add(4);
        stats.shards[0].live_sessions.set(3);
        let s = stats.summary();
        assert!(s.contains("shed=4"), "{s}");
        assert!(s.contains("steps=12"), "{s}");
        assert!(s.contains("qage_p95=2.0ms"), "{s}");
        assert!(s.contains("s0:req=0 done=0 rej=0 inflight=0 q=0 shed=4 live=3"), "{s}");
    }

    #[test]
    fn migration_stats_stay_silent_until_fleet_activity() {
        let stats = ServerStats::default();
        assert!(!stats.summary().contains("fleet["), "idle fleet adds nothing");
        stats.migration.worker_deaths.inc();
        stats.migration.worker_respawns.inc();
        stats.migration.sessions_migrated.add(12);
        stats.migration.migration_bytes.add(4096);
        stats.migration.envelopes_replayed.add(3);
        stats.migration.resurrect_latency.record_us(2000);
        let s = stats.summary();
        assert!(s.contains("fleet[deaths=1 respawns=1 migrated=12"), "{s}");
        assert!(s.contains("mig_bytes=4096 replayed=3"), "{s}");
        assert!(s.contains("resurrect_p95=2.0ms"), "{s}");
        // wire errors alone also surface the block
        let quiet = ServerStats::default();
        quiet.migration.wire_errors.inc();
        assert!(quiet.migration.summary().contains("wire_err=1"));
    }

    #[test]
    fn summary_line_reports_decode_percentiles() {
        let stats = ServerStats::default();
        stats.e2e_latency.record_us(2000);
        stats.decode_latency.record_us(1500);
        let s = stats.summary();
        assert!(s.contains("e2e_p95="), "{s}");
        assert!(!s.contains("p95<="), "{s}");
        assert!(s.contains("decode_p95=1.5ms"), "{s}");
        assert!(s.contains("decode_p99=1.5ms"), "{s}");
    }
}
