//! Lock-free telemetry for the serving hot path: counters and fixed-bucket
//! latency histograms (atomics only, no allocation after construction).

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (resident bytes, live sessions, ...).
#[derive(Default, Debug)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement (never wraps below zero).
    pub fn sub(&self, n: u64) {
        let mut cur = self.v.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self
                .v
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// KV/tokenization cache counters for the incremental decode engine
/// (DESIGN.md §10): session and map-row hit rates, sliding-window and
/// capacity evictions, and resident bytes across all live caches.
#[derive(Default, Debug)]
pub struct CacheStats {
    pub hits: Counter,
    pub misses: Counter,
    pub evictions: Counter,
    pub map_hits: Counter,
    pub map_misses: Counter,
    pub resident_bytes: Gauge,
}

impl CacheStats {
    /// Session hit rate in [0, 1]; 0 when no lookups happened yet.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get();
        let total = h + self.misses.get();
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "cache hits={} misses={} ({:.0}%) evict={} map_hits={} map_misses={} resident={}B",
            self.hits.get(),
            self.misses.get(),
            self.hit_rate() * 100.0,
            self.evictions.get(),
            self.map_hits.get(),
            self.map_misses.get(),
            self.resident_bytes.get(),
        )
    }
}

/// Log-spaced latency histogram: bucket i covers [2^i, 2^(i+1)) microseconds.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record_us(&self, us: u64) {
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// Serving metrics bundle.
#[derive(Default, Debug)]
pub struct ServerStats {
    pub requests_in: Counter,
    pub requests_done: Counter,
    pub requests_failed: Counter,
    pub batches: Counter,
    pub padded_slots: Counter,
    pub queue_rejections: Counter,
    pub e2e_latency: LatencyHistogram,
    pub decode_latency: LatencyHistogram,
    /// Shared with the server's [`crate::coordinator::kvcache::KvCachePool`].
    pub cache: std::sync::Arc<CacheStats>,
}

impl ServerStats {
    pub fn summary(&self) -> String {
        format!(
            "in={} done={} failed={} batches={} pad={} rej={} \
             e2e_mean={:.1}ms e2e_p95<={:.1}ms decode_mean={:.1}ms {}",
            self.requests_in.get(),
            self.requests_done.get(),
            self.requests_failed.get(),
            self.batches.get(),
            self.padded_slots.get(),
            self.queue_rejections.get(),
            self.e2e_latency.mean_us() / 1e3,
            self.e2e_latency.percentile_us(95.0) as f64 / 1e3,
            self.decode_latency.mean_us() / 1e3,
            self.cache.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = std::sync::Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn histogram_percentiles_are_monotone() {
        let h = LatencyHistogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn gauge_saturates_at_zero() {
        let g = Gauge::default();
        g.add(10);
        g.sub(3);
        assert_eq!(g.get(), 7);
        g.sub(100);
        assert_eq!(g.get(), 0);
        g.set(42);
        assert_eq!(g.get(), 42);
    }

    #[test]
    fn cache_stats_hit_rate_and_summary() {
        let c = CacheStats::default();
        assert_eq!(c.hit_rate(), 0.0);
        c.hits.add(3);
        c.misses.inc();
        c.resident_bytes.add(1024);
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        let s = c.summary();
        assert!(s.contains("hits=3") && s.contains("resident=1024B"), "{s}");
    }

    #[test]
    fn histogram_bucket_bounds() {
        let h = LatencyHistogram::default();
        h.record_us(1000);
        // p100 upper bound must be >= the recorded value
        assert!(h.percentile_us(100.0) >= 1000);
    }
}
