//! Per-session KV/tokenization cache for streaming rollout (DESIGN.md §10).
//!
//! The rollout scheduler used to re-tokenize the whole history window on
//! every decode step — O(window) work for O(new-tokens) of new
//! information.  This module caches, per scene-sample session:
//!
//! * the **static map rows** (feature vectors + world poses), tokenized
//!   once per *scene* and shared across all samples of that scene through
//!   an [`Arc`] registry inside the pool;
//! * the **agent-step rows** of the sliding history window: the
//!   frame-invariant feature vectors are tokenized only for the frontier
//!   step of each decode step, older steps are reused verbatim and evicted
//!   as the window slides.
//!
//! Poses are cached in the *world* frame and re-anchored to the current
//! robot frame at [`WindowCache::emit`] time — an exact 9-flop SE(2)
//! compose per token, so the emitted batch is bit-identical to a full
//! [`Tokenizer::tokenize_window`] while skipping all per-token feature
//! work except the frontier.  (The approximate feature-space re-anchor for
//! *projected attention rows* lives in
//! [`crate::attention::incremental::IncrementalAttention`]; here nothing
//! is approximated.)
//!
//! [`KvCachePool`] owns the session map: allocation by scene-sample key,
//! LRU capacity eviction by per-session resident bytes (closed-form model
//! in [`crate::attention::memmodel::window_cache_bytes`] /
//! [`crate::attention::memmodel::map_tokens_bytes`]), and hit / miss /
//! eviction / resident-byte counters exported through
//! [`crate::coordinator::telemetry::CacheStats`].
//!
//! Sessions can store their cached agent-step feature rows at a reduced
//! [`CachePrecision`] (f16/bf16 with per-row scale/offset — DESIGN.md
//! §14): [`WindowCache::emit`] dequantizes features on read while poses
//! stay exact f64, so the emit-time re-anchor is **exact at every
//! precision** and only feature mantissas round.  The pool's LRU byte
//! eviction prices each session at its true stored bytes, so a mixed
//! f32/f16 population shares one byte budget fairly (bytes, not rows).
//! Shared map rows stay f32: they are counted once per scene and shared
//! across sessions of every precision.
//!
//! Sharded serving (DESIGN.md §12) runs one pool per worker shard —
//! sessions are pinned to their shard by the front end's affinity router
//! and never migrate — while the static map rows live in a
//! [`MapRegistry`] that the shards *share*, so one scene's map is
//! tokenized once server-wide no matter which shard first touches it.
//! Lock order is always pool -> registry; the registry never calls back
//! into a pool.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::attention::quant::FeatureRows;
use crate::config::CachePrecision;
use crate::geometry::Pose;
use crate::sim::{AgentState, MapElement};
use crate::tokenizer::{TokenizedScene, Tokenizer, MAP_T, NO_TARGET};

use super::telemetry::CacheStats;

/// Tokenized static map rows of one scene, shared across its samples.
#[derive(Debug)]
pub struct MapTokens {
    /// Row-major (n_map, feat_dim) frame-invariant features.
    pub feat: Vec<f32>,
    /// World-frame poses, re-anchored per emit.
    pub world_pose: Vec<Pose>,
}

impl MapTokens {
    pub fn tokenize(tok: &Tokenizer, elements: &[MapElement]) -> MapTokens {
        let fd = tok.feat_dim;
        let mut feat = vec![0.0f32; elements.len() * fd];
        let mut world_pose = Vec::with_capacity(elements.len());
        for (i, e) in elements.iter().enumerate() {
            tok.map_features(e, &mut feat[i * fd..(i + 1) * fd]);
            world_pose.push(e.pose);
        }
        MapTokens { feat, world_pose }
    }

    pub fn len(&self) -> usize {
        self.world_pose.len()
    }

    pub fn is_empty(&self) -> bool {
        self.world_pose.is_empty()
    }

    pub fn resident_bytes(&self) -> usize {
        self.feat.len() * std::mem::size_of::<f32>()
            + self.world_pose.len() * std::mem::size_of::<Pose>()
    }
}

/// One history step's agent rows, stored at the session's precision.
#[derive(Debug)]
struct AgentStepRows {
    feat: FeatureRows,
    world_pose: Vec<Pose>,
}

fn tokenize_step(
    tok: &Tokenizer,
    n_agents: usize,
    agents: &[AgentState],
    precision: CachePrecision,
) -> AgentStepRows {
    assert_eq!(agents.len(), n_agents, "agent count changed mid-session");
    let fd = tok.feat_dim;
    let mut rows = vec![0.0f32; agents.len() * fd];
    let mut world_pose = Vec::with_capacity(agents.len());
    for (a, st) in agents.iter().enumerate() {
        tok.agent_features(st, &mut rows[a * fd..(a + 1) * fd]);
        world_pose.push(st.pose);
    }
    let mut feat = FeatureRows::new(precision, fd);
    feat.push_rows(&rows);
    AgentStepRows { feat, world_pose }
}

/// The cached sliding window of one scene-sample session.
#[derive(Debug)]
pub struct WindowCache {
    map: Arc<MapTokens>,
    steps: VecDeque<AgentStepRows>,
    n_agents: usize,
    feat_dim: usize,
    precision: CachePrecision,
}

impl WindowCache {
    /// Build from a full window (the miss path) at f32 — bit-exact cache
    /// round-trips, the seed behavior.  See [`Self::from_window_with`]
    /// for the quantized tier.
    pub fn from_window(
        tok: &Tokenizer,
        map: Arc<MapTokens>,
        window: &[Vec<AgentState>],
    ) -> Result<WindowCache> {
        WindowCache::from_window_with(tok, map, window, CachePrecision::F32)
    }

    /// Build from a full window (the miss path): tokenizes every step,
    /// storing feature rows at `precision`.  An empty window (no steps,
    /// or steps with no agents) is a recoverable request error, not a
    /// panic — the serving path surfaces it to the caller instead of
    /// taking the worker down.
    pub fn from_window_with(
        tok: &Tokenizer,
        map: Arc<MapTokens>,
        window: &[Vec<AgentState>],
        precision: CachePrecision,
    ) -> Result<WindowCache> {
        if window.is_empty() || window[0].is_empty() {
            bail!("cannot build a session window cache from an empty window");
        }
        let n_agents = window[0].len();
        let mut steps = VecDeque::with_capacity(window.len());
        for step in window {
            steps.push_back(tokenize_step(tok, n_agents, step, precision));
        }
        Ok(WindowCache {
            map,
            steps,
            n_agents,
            feat_dim: tok.feat_dim,
            precision,
        })
    }

    /// Storage precision of this session's cached feature rows.
    pub fn precision(&self) -> CachePrecision {
        self.precision
    }

    /// Shared map rows this session emits against.
    pub fn map(&self) -> &Arc<MapTokens> {
        &self.map
    }

    /// Feature width of the cached rows.
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    /// Cached step rows, oldest first: `(feature rows, world poses)` per
    /// window step — the serialization surface of the session codec
    /// (`coordinator::session_codec`).
    pub fn step_rows(&self) -> impl Iterator<Item = (&FeatureRows, &[Pose])> {
        self.steps.iter().map(|s| (&s.feat, s.world_pose.as_slice()))
    }

    /// Rebuild a cache from serialized step rows (the deserialization
    /// half of the session codec).  Rows are installed verbatim — no
    /// re-tokenization and no re-quantization — so a migrated session
    /// emits bit-identically to the one exported on the source worker.
    pub fn from_parts(
        map: Arc<MapTokens>,
        steps: Vec<(FeatureRows, Vec<Pose>)>,
        precision: CachePrecision,
    ) -> Result<WindowCache> {
        if steps.is_empty() || steps[0].1.is_empty() {
            bail!("cannot rebuild a session window cache from an empty window");
        }
        let n_agents = steps[0].1.len();
        let feat_dim = steps[0].0.width();
        for (feat, poses) in &steps {
            if feat.len() != n_agents || poses.len() != n_agents || feat.width() != feat_dim {
                bail!("corrupt migrated session: ragged step rows");
            }
            if feat.precision() != precision {
                bail!("corrupt migrated session: row precision does not match header");
            }
        }
        Ok(WindowCache {
            map,
            steps: steps
                .into_iter()
                .map(|(feat, world_pose)| AgentStepRows { feat, world_pose })
                .collect(),
            n_agents,
            feat_dim,
            precision,
        })
    }

    /// Slide the window one decode step: evict the oldest step's rows and
    /// tokenize *only* the new frontier — the O(new) hot path.
    pub fn advance(&mut self, tok: &Tokenizer, frontier: &[AgentState]) {
        let rows = tokenize_step(tok, self.n_agents, frontier, self.precision);
        self.steps.pop_front();
        self.steps.push_back(rows);
    }

    /// Number of cached window steps.
    pub fn history_steps(&self) -> usize {
        self.steps.len()
    }

    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Assemble the model-ready tokenized scene: cached features are
    /// copied verbatim (f32) or dequantized (f16/bf16, within the
    /// per-row rounding bound), and poses are re-anchored — **exactly,
    /// at every precision** (poses are never quantized) — to the current
    /// robot frame (agent 0 at the latest step).  At f32, bit-identical
    /// to [`Tokenizer::tokenize_window`] on the same window, with no
    /// targets.
    ///
    /// An empty cached window (a corrupted or stale session) is a
    /// recoverable error: [`KvCachePool::step`] treats it as a cache miss
    /// and rebuilds from the caller's full window instead of panicking on
    /// the serving path.
    pub fn emit(&self, tok: &Tokenizer) -> Result<TokenizedScene> {
        let Some(latest) = self.steps.back() else {
            bail!("session window cache is empty — a cache-miss rebuild is required");
        };
        let Some(&frame) = latest.world_pose.first() else {
            bail!("session window cache has no agents — a cache-miss rebuild is required");
        };
        let h = self.steps.len();
        let n_map = self.map.len();
        let n_agents = self.n_agents;
        let n_tokens = n_map + h * n_agents;
        let fd = self.feat_dim;

        let mut feat = vec![0.0f32; n_tokens * fd];
        let mut pose = vec![0.0f32; n_tokens * 3];
        let mut tq = vec![0i32; n_tokens];
        let target = vec![NO_TARGET; n_tokens];

        feat[..n_map * fd].copy_from_slice(&self.map.feat);
        for (i, wp) in self.map.world_pose.iter().enumerate() {
            let mp = tok.to_model_frame(&frame, wp);
            pose[i * 3] = mp.x as f32;
            pose[i * 3 + 1] = mp.y as f32;
            pose[i * 3 + 2] = mp.theta as f32;
            tq[i] = MAP_T;
        }
        for (t, step) in self.steps.iter().enumerate() {
            let base = n_map + t * n_agents;
            step.feat
                .read_all_into(&mut feat[base * fd..(base + n_agents) * fd]);
            for (a, wp) in step.world_pose.iter().enumerate() {
                let idx = base + a;
                let mp = tok.to_model_frame(&frame, wp);
                pose[idx * 3] = mp.x as f32;
                pose[idx * 3 + 1] = mp.y as f32;
                pose[idx * 3 + 2] = mp.theta as f32;
                tq[idx] = t as i32;
            }
        }

        Ok(TokenizedScene {
            feat,
            pose,
            tq,
            target,
            frame,
            n_map,
            n_agents,
            history_steps: h,
        })
    }

    /// Resident bytes at this session's true storage precision (shared
    /// map rows are counted by the pool, once per scene, not per
    /// session).  Equal to
    /// [`crate::attention::memmodel::window_cache_bytes`] — the one byte
    /// model the telemetry gauge reports (regression-tested in
    /// `tests/quantized_cache.rs`).
    pub fn resident_bytes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| {
                s.feat.resident_bytes() + s.world_pose.len() * std::mem::size_of::<Pose>()
            })
            .sum()
    }
}

/// Identity of one scene-sample rollout session.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionKey {
    /// Scene identity (scenario seed).
    pub scene: u64,
    /// History window end at request time.
    pub t0: u32,
    /// Rollout sample index within the request.
    pub sample: u32,
}

/// Capacity limits for a [`KvCachePool`].
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Max live sessions before LRU eviction.
    pub max_sessions: usize,
    /// Max resident bytes across sessions + shared map rows.  Sessions
    /// are priced at their true stored bytes, so quantized sessions fit
    /// roughly twice as many under the same budget.
    pub max_bytes: usize,
    /// Max scenes whose map rows are kept for sharing.
    pub max_map_scenes: usize,
    /// Storage precision for sessions built by [`KvCachePool::step`]
    /// (per-session overrides go through
    /// [`KvCachePool::step_with_precision`]).
    pub precision: CachePrecision,
}

impl Default for CacheConfig {
    fn default() -> CacheConfig {
        CacheConfig {
            max_sessions: 4096,
            max_bytes: 256 << 20,
            max_map_scenes: 1024,
            precision: CachePrecision::F32,
        }
    }
}

struct SessionEntry {
    cache: WindowCache,
    bytes: usize,
    tick: u64,
}

struct MapRegistryInner {
    maps: HashMap<u64, Arc<MapTokens>>,
    /// FIFO of map-scene ids for capacity eviction.
    order: VecDeque<u64>,
    /// Shared map-row bytes, counted once per scene.
    bytes: usize,
}

/// Shared static-map row registry: tokenized once per scene, handed out by
/// `Arc` to every session.  In a sharded server one registry is shared by
/// all shard pools (map rows are immutable, so cross-shard sharing is
/// safe), bounded by `max_scenes` with FIFO eviction.
pub struct MapRegistry {
    max_scenes: usize,
    stats: Arc<CacheStats>,
    inner: Mutex<MapRegistryInner>,
}

impl MapRegistry {
    pub fn new(max_scenes: usize, stats: Arc<CacheStats>) -> MapRegistry {
        MapRegistry {
            max_scenes,
            stats,
            inner: Mutex::new(MapRegistryInner {
                maps: HashMap::new(),
                order: VecDeque::new(),
                bytes: 0,
            }),
        }
    }

    /// Shared map rows for a scene: tokenized once, handed out by Arc to
    /// every sample (and every later request) of the same scene.
    pub fn get_or_tokenize(
        &self,
        scene: u64,
        tok: &Tokenizer,
        elements: &[MapElement],
    ) -> Arc<MapTokens> {
        let mut inner = self.inner.lock().unwrap();
        // A seed collision (same scene id, different map) must not
        // silently substitute stale rows: validate the cheap invariant
        // and re-tokenize on mismatch.
        let already_known = match inner.maps.get(&scene) {
            Some(m) if m.len() == elements.len() => {
                self.stats.map_hits.inc();
                return Arc::clone(m);
            }
            Some(_) => true,
            None => false,
        };
        self.stats.map_misses.inc();
        // shared map rows are charged to the map_registry scope in the
        // memory attribution table (DESIGN.md §16)
        let _mem = crate::obs::alloc::MemScope::enter("map_registry");
        let m = Arc::new(MapTokens::tokenize(tok, elements));
        inner.bytes += m.resident_bytes();
        self.stats.resident_bytes.add(m.resident_bytes() as u64);
        if let Some(stale) = inner.maps.insert(scene, Arc::clone(&m)) {
            inner.bytes = inner.bytes.saturating_sub(stale.resident_bytes());
            self.stats.resident_bytes.sub(stale.resident_bytes() as u64);
        }
        if !already_known {
            inner.order.push_back(scene);
        }
        self.enforce_scene_capacity(&mut inner);
        m
    }

    /// Register migrated map rows for `scene`, returning the shared `Arc`
    /// to use: rows the registry already holds (same shape) win — the
    /// replicated-registry fast path, where a migrated session re-points
    /// at the destination's existing copy — otherwise the migrated rows
    /// are installed and handed back.
    pub fn install(&self, scene: u64, m: Arc<MapTokens>) -> Arc<MapTokens> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(have) = inner.maps.get(&scene) {
            if have.len() == m.len() {
                self.stats.map_hits.inc();
                return Arc::clone(have);
            }
        }
        self.stats.map_misses.inc();
        let _mem = crate::obs::alloc::MemScope::enter("map_registry");
        inner.bytes += m.resident_bytes();
        self.stats.resident_bytes.add(m.resident_bytes() as u64);
        if let Some(stale) = inner.maps.insert(scene, Arc::clone(&m)) {
            inner.bytes = inner.bytes.saturating_sub(stale.resident_bytes());
            self.stats.resident_bytes.sub(stale.resident_bytes() as u64);
        } else {
            inner.order.push_back(scene);
        }
        self.enforce_scene_capacity(&mut inner);
        m
    }

    fn enforce_scene_capacity(&self, inner: &mut MapRegistryInner) {
        while inner.maps.len() > self.max_scenes {
            if let Some(old) = inner.order.pop_front() {
                if let Some(gone) = inner.maps.remove(&old) {
                    inner.bytes = inner.bytes.saturating_sub(gone.resident_bytes());
                    self.stats.resident_bytes.sub(gone.resident_bytes() as u64);
                    self.stats.evictions.inc();
                    if crate::trace::profiling() {
                        crate::trace::kernel_profile()
                            .cache_map_evictions
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        crate::trace::instant(
                            crate::trace::Stage::CacheEvict,
                            gone.resident_bytes() as u64,
                        );
                    }
                }
            } else {
                break;
            }
        }
    }

    /// Bytes held by the shared map rows.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Number of scenes with registered map rows.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().maps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

struct PoolInner {
    sessions: HashMap<SessionKey, SessionEntry>,
    tick: u64,
    /// Per-session window bytes — the pool can only reclaim these, so
    /// `max_bytes` is enforced against this count alone (shared map
    /// bytes are bounded separately by `max_map_scenes`; folding them
    /// into one budget would make an unsatisfiable config thrash every
    /// insert).
    session_bytes: usize,
}

/// A shard-owned pool of per-session window caches over a (possibly
/// shared) map-row registry.
///
/// The serving hot path is [`KvCachePool::step`]: a cache hit tokenizes
/// only the frontier agent states and re-anchors cached poses exactly;
/// the result is bit-identical (at f32) to a full
/// [`Tokenizer::tokenize_window`]:
///
/// ```
/// use std::sync::Arc;
/// use se2attn::config::{ModelConfig, SimConfig};
/// use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
/// use se2attn::coordinator::telemetry::CacheStats;
/// use se2attn::sim::ScenarioGenerator;
/// use se2attn::tokenizer::Tokenizer;
///
/// let sim = SimConfig::default();
/// let tok = Tokenizer::new(&ModelConfig::synthetic(), &sim);
/// let scenario = ScenarioGenerator::new(sim.clone()).generate(7);
/// let window: Vec<_> = (0..sim.history_steps)
///     .map(|t| scenario.states[t].clone())
///     .collect();
///
/// let stats = Arc::new(CacheStats::default());
/// let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
/// let key = SessionKey { scene: scenario.seed, t0: 7, sample: 0 };
///
/// let scene = pool.step(key, &tok, &scenario.map_elements, &window).unwrap();
/// let full = tok.tokenize_window(&scenario.map_elements, &window, None);
/// assert_eq!(scene.feat, full.feat); // f32 sessions are bit-identical
/// assert_eq!(stats.misses.get(), 1); // first touch is a miss
/// pool.end_session(key);
/// ```
pub struct KvCachePool {
    cfg: CacheConfig,
    pub stats: Arc<CacheStats>,
    maps: Arc<MapRegistry>,
    inner: Mutex<PoolInner>,
}

impl KvCachePool {
    /// Standalone pool with a private map registry (single-shard servers,
    /// request-local pools, tests).
    pub fn new(cfg: CacheConfig, stats: Arc<CacheStats>) -> KvCachePool {
        let maps = Arc::new(MapRegistry::new(cfg.max_map_scenes, Arc::clone(&stats)));
        KvCachePool::with_map_registry(cfg, stats, maps)
    }

    /// Shard pool over a registry shared with the other shards.
    pub fn with_map_registry(
        cfg: CacheConfig,
        stats: Arc<CacheStats>,
        maps: Arc<MapRegistry>,
    ) -> KvCachePool {
        KvCachePool {
            cfg,
            stats,
            maps,
            inner: Mutex::new(PoolInner {
                sessions: HashMap::new(),
                tick: 0,
                session_bytes: 0,
            }),
        }
    }

    /// This pool's map registry (for sharing with sibling shard pools).
    pub fn map_registry(&self) -> Arc<MapRegistry> {
        Arc::clone(&self.maps)
    }

    /// Shared map rows for a scene (delegates to the registry).
    pub fn map_tokens(
        &self,
        scene: u64,
        tok: &Tokenizer,
        elements: &[MapElement],
    ) -> Arc<MapTokens> {
        self.maps.get_or_tokenize(scene, tok, elements)
    }

    /// One decode step for a session at the pool's configured precision
    /// (`CacheConfig::precision`).  Hit: slide the cached window by the
    /// frontier (`window.last()`) and emit — O(new) tokenization.  Miss
    /// (first step, evicted under pressure, or a corrupt/stale cached
    /// window): rebuild from the caller's full window.  At f32 the
    /// result is bit-identical to
    /// `tok.tokenize_window(map_elements, window, None)`; quantized
    /// sessions dequantize features within the per-row rounding bound
    /// while poses stay exact.  An empty caller window is a recoverable
    /// `Err`, never a panic on the serving path.
    pub fn step(
        &self,
        key: SessionKey,
        tok: &Tokenizer,
        map_elements: &[MapElement],
        window: &[Vec<AgentState>],
    ) -> Result<TokenizedScene> {
        self.step_with_precision(key, self.cfg.precision, tok, map_elements, window)
    }

    /// [`Self::step`] with an explicit per-session storage precision —
    /// sessions of different precisions coexist in one pool under one
    /// LRU byte budget.  A cached session whose stored precision differs
    /// from the requested one is rebuilt (counted as a miss), so the
    /// requested precision always wins.
    pub fn step_with_precision(
        &self,
        key: SessionKey,
        precision: CachePrecision,
        tok: &Tokenizer,
        map_elements: &[MapElement],
        window: &[Vec<AgentState>],
    ) -> Result<TokenizedScene> {
        if window.is_empty() || window[0].is_empty() {
            bail!(
                "session {key:?}: the request carries an empty history window — \
                 nothing to tokenize"
            );
        }
        // a ragged window would trip tokenize_step's agent-count invariant
        // further down; reject it here as a caller error instead
        if window.iter().any(|step| step.len() != window[0].len()) {
            bail!(
                "session {key:?}: ragged history window — agent count varies \
                 across steps"
            );
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;

        let mut entry = match inner.sessions.remove(&key) {
            // only a healthy cached window advances in O(new); a corrupt
            // (empty), shape-mismatched or precision-mismatched entry
            // falls through to the miss arm and is rebuilt —
            // recoverable, never a panic
            Some(mut e)
                if e.cache.n_agents() == window[0].len()
                    && e.cache.history_steps() > 0
                    && e.cache.precision() == precision =>
            {
                self.stats.hits.inc();
                // frontier rows are charged to the kvcache scope
                let _mem = crate::obs::alloc::MemScope::enter("kvcache");
                e.cache.advance(tok, window.last().unwrap());
                e
            }
            stale => {
                // a shape-mismatched or corrupt leftover is released
                if let Some(gone) = stale {
                    inner.session_bytes = inner.session_bytes.saturating_sub(gone.bytes);
                    self.stats.resident_bytes.sub(gone.bytes as u64);
                }
                self.stats.misses.inc();
                // map rows enter their own map_registry scope inside
                // get_or_tokenize; only the per-session window rows built
                // below are charged to kvcache
                let map = self.maps.get_or_tokenize(key.scene, tok, map_elements);
                let _mem = crate::obs::alloc::MemScope::enter("kvcache");
                let cache = WindowCache::from_window_with(tok, map, window, precision)?;
                let bytes = cache.resident_bytes();
                inner.session_bytes += bytes;
                self.stats.resident_bytes.add(bytes as u64);
                SessionEntry {
                    cache,
                    bytes,
                    tick: 0,
                }
            }
        };
        entry.tick = tick;
        let scene = match entry.cache.emit(tok) {
            Ok(scene) => scene,
            Err(e) => {
                // drop the entry but keep the byte accounting honest
                inner.session_bytes = inner.session_bytes.saturating_sub(entry.bytes);
                self.stats.resident_bytes.sub(entry.bytes as u64);
                return Err(e);
            }
        };
        inner.sessions.insert(key, entry);
        self.enforce_capacity(&mut inner, Some(key));
        Ok(scene)
    }

    fn enforce_capacity(&self, inner: &mut PoolInner, keep: Option<SessionKey>) {
        while inner.sessions.len() > self.cfg.max_sessions
            || inner.session_bytes > self.cfg.max_bytes
        {
            let victim = inner
                .sessions
                .iter()
                .filter(|(k, _)| Some(**k) != keep)
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            if let Some(gone) = inner.sessions.remove(&victim) {
                inner.session_bytes = inner.session_bytes.saturating_sub(gone.bytes);
                self.stats.resident_bytes.sub(gone.bytes as u64);
                self.stats.evictions.inc();
                if crate::trace::profiling() {
                    crate::trace::kernel_profile()
                        .cache_session_evictions
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    crate::trace::instant(crate::trace::Stage::CacheEvict, gone.bytes as u64);
                }
            }
        }
    }

    /// Remove and return a session's cached window for migration (drain,
    /// rebalance, or worker death with a live connection).  The pool's
    /// byte accounting is released; the caller owns serialization.
    /// `None` when the session is unknown (e.g. already LRU-evicted) —
    /// callers treat that as "nothing to migrate" and the destination
    /// rebuilds it as an ordinary cache miss.
    pub fn export_session(&self, key: SessionKey) -> Option<WindowCache> {
        let mut inner = self.inner.lock().unwrap();
        let gone = inner.sessions.remove(&key)?;
        inner.session_bytes = inner.session_bytes.saturating_sub(gone.bytes);
        self.stats.resident_bytes.sub(gone.bytes as u64);
        Some(gone.cache)
    }

    /// Install a migrated session (the receive half of
    /// [`Self::export_session`]).  The cache's map rows are re-pointed at
    /// this pool's registry copy when one of the same shape exists, so a
    /// scene's map stays tokenized once per destination no matter how
    /// many sessions migrate in.  The session enters at a fresh LRU tick
    /// under the normal byte budget.
    pub fn install_session(&self, key: SessionKey, mut cache: WindowCache) {
        cache.map = self.maps.install(key.scene, Arc::clone(&cache.map));
        let _mem = crate::obs::alloc::MemScope::enter("kvcache");
        let bytes = cache.resident_bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(stale) = inner.sessions.insert(key, SessionEntry { cache, bytes, tick }) {
            inner.session_bytes = inner.session_bytes.saturating_sub(stale.bytes);
            self.stats.resident_bytes.sub(stale.bytes as u64);
        }
        inner.session_bytes += bytes;
        self.stats.resident_bytes.add(bytes as u64);
        self.enforce_capacity(&mut inner, Some(key));
    }

    /// Drop a finished session (end of rollout).
    pub fn end_session(&self, key: SessionKey) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(gone) = inner.sessions.remove(&key) {
            inner.session_bytes = inner.session_bytes.saturating_sub(gone.bytes);
            self.stats.resident_bytes.sub(gone.bytes as u64);
        }
    }

    /// Live session count (tests / stats).
    pub fn live_sessions(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }

    /// Total resident bytes tracked by the pool (sessions + shared maps;
    /// the map bytes cover the registry, which may be shared with other
    /// shard pools).
    pub fn resident_bytes(&self) -> usize {
        let session_bytes = self.inner.lock().unwrap().session_bytes;
        session_bytes + self.maps.resident_bytes()
    }

    /// This pool's session-window bytes alone (per-shard capacity view).
    pub fn session_bytes(&self) -> usize {
        self.inner.lock().unwrap().session_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, SimConfig};
    use crate::sim::ScenarioGenerator;

    fn setup() -> (SimConfig, Tokenizer) {
        let sim = SimConfig::default();
        let tok = Tokenizer::new(&ModelConfig::synthetic(), &sim);
        (sim, tok)
    }

    /// The cached emit must be bit-identical to a full re-tokenization at
    /// every step of a sliding window walked across a real scenario.
    #[test]
    fn cached_emit_equals_full_tokenize_across_steps() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(17);
        let h = sim.history_steps;
        let mut window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();

        let map = Arc::new(MapTokens::tokenize(&tok, &s.map_elements));
        let mut cache = WindowCache::from_window(&tok, map, &window).unwrap();
        for t in h..s.n_steps() {
            let want = tok.tokenize_window(&s.map_elements, &window, None);
            let got = cache.emit(&tok).unwrap();
            assert_eq!(got.feat, want.feat, "step {t}: features");
            assert_eq!(got.pose, want.pose, "step {t}: poses");
            assert_eq!(got.tq, want.tq, "step {t}: timesteps");
            assert_eq!(got.target, want.target, "step {t}: targets");
            assert_eq!(got.frame, want.frame, "step {t}: frame");
            // slide
            window.remove(0);
            window.push(s.states[t].clone());
            cache.advance(&tok, &s.states[t]);
        }
    }

    /// Re-anchoring at emit time is exact: shifting the whole world by a
    /// rigid transform changes neither features nor emitted poses.
    #[test]
    fn cached_emit_invariant_under_world_shift() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(23);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let z = Pose::new(250.0, -80.0, 2.1);
        let mut s2 = s.clone();
        for step in s2.states.iter_mut() {
            for a in step.iter_mut() {
                a.pose = z.compose(&a.pose);
            }
        }
        for e in s2.map_elements.iter_mut() {
            e.pose = z.compose(&e.pose);
        }
        let window2: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s2.states[t].clone()).collect();

        let c1 = WindowCache::from_window(
            &tok,
            Arc::new(MapTokens::tokenize(&tok, &s.map_elements)),
            &window,
        )
        .unwrap();
        let c2 = WindowCache::from_window(
            &tok,
            Arc::new(MapTokens::tokenize(&tok, &s2.map_elements)),
            &window2,
        )
        .unwrap();
        let (e1, e2) = (c1.emit(&tok).unwrap(), c2.emit(&tok).unwrap());
        assert_eq!(e1.feat, e2.feat, "features must not leak absolute pose");
        for (a, b) in e1.pose.iter().zip(e2.pose.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn pool_hits_misses_and_map_sharing() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(5);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();

        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));

        let key_a = SessionKey { scene: 5, t0: 7, sample: 0 };
        let key_b = SessionKey { scene: 5, t0: 7, sample: 1 };
        // first touch of each session: miss; map tokenized once, shared
        pool.step(key_a, &tok, &s.map_elements, &window).unwrap();
        pool.step(key_b, &tok, &s.map_elements, &window).unwrap();
        assert_eq!(stats.misses.get(), 2);
        assert_eq!(stats.map_misses.get(), 1);
        assert_eq!(stats.map_hits.get(), 1);
        let m1 = pool.map_tokens(5, &tok, &s.map_elements);
        let m2 = pool.map_tokens(5, &tok, &s.map_elements);
        assert!(Arc::ptr_eq(&m1, &m2), "map rows must be shared");

        // steady state: hits
        let mut w = window.clone();
        w.remove(0);
        w.push(s.states[h].clone());
        pool.step(key_a, &tok, &s.map_elements, &w).unwrap();
        assert_eq!(stats.hits.get(), 1);
        assert!(stats.resident_bytes.get() > 0);
        assert_eq!(pool.live_sessions(), 2);

        pool.end_session(key_a);
        pool.end_session(key_b);
        assert_eq!(pool.live_sessions(), 0);
    }

    #[test]
    fn pool_evicts_lru_under_session_pressure() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(9);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();

        let stats = Arc::new(CacheStats::default());
        let cfg = CacheConfig {
            max_sessions: 2,
            ..CacheConfig::default()
        };
        let pool = KvCachePool::new(cfg, Arc::clone(&stats));
        for i in 0..4u32 {
            pool.step(
                SessionKey { scene: 9, t0: 7, sample: i },
                &tok,
                &s.map_elements,
                &window,
            )
            .unwrap();
        }
        assert_eq!(pool.live_sessions(), 2);
        assert_eq!(stats.evictions.get(), 2);
        // the evicted session re-misses and still produces a valid scene
        let scene = pool.step(
            SessionKey { scene: 9, t0: 7, sample: 0 },
            &tok,
            &s.map_elements,
            &window,
        )
        .unwrap();
        let want = tok.tokenize_window(&s.map_elements, &window, None);
        assert_eq!(scene.feat, want.feat);
        assert_eq!(stats.misses.get(), 5);
    }

    #[test]
    fn map_registry_revalidates_on_scene_id_collision() {
        let (sim, tok) = setup();
        let gen = ScenarioGenerator::new(sim.clone());
        let s1 = gen.generate(40);
        let mut s2 = gen.generate(41);
        // same claimed scene id, different (shorter) map
        s2.map_elements.truncate(s1.map_elements.len() - 3);
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let m1 = pool.map_tokens(7, &tok, &s1.map_elements);
        let m2 = pool.map_tokens(7, &tok, &s2.map_elements);
        assert_eq!(stats.map_misses.get(), 2, "collision must re-tokenize");
        assert_eq!(m2.len(), s2.map_elements.len());
        assert!(!Arc::ptr_eq(&m1, &m2));
        // byte gauge reflects the replacement, not the sum of both
        assert_eq!(pool.resident_bytes(), m2.resident_bytes());
    }

    #[test]
    fn tiny_byte_budget_does_not_thrash_on_map_bytes() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(12);
        let h = sim.history_steps;
        let mut window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let stats = Arc::new(CacheStats::default());
        // budget below even one session: sessions churn, but map bytes
        // alone must never trigger evict-everything loops
        let cfg = CacheConfig {
            max_bytes: 1,
            ..CacheConfig::default()
        };
        let pool = KvCachePool::new(cfg, Arc::clone(&stats));
        let key = SessionKey { scene: 12, t0: 7, sample: 0 };
        for t in h..h + 3 {
            let got = pool.step(key, &tok, &s.map_elements, &window).unwrap();
            let want = tok.tokenize_window(&s.map_elements, &window, None);
            assert_eq!(got.feat, want.feat, "output stays correct under churn");
            window.remove(0);
            window.push(s.states[t].clone());
        }
        // the just-inserted session is protected, so at most the previous
        // one is evicted per step — never an unbounded loop
        assert!(stats.evictions.get() <= 3);
    }

    #[test]
    fn resident_bytes_match_memmodel() {
        use crate::attention::memmodel::{map_tokens_bytes, window_cache_bytes};
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(2);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let map = Arc::new(MapTokens::tokenize(&tok, &s.map_elements));
        assert_eq!(
            map.resident_bytes(),
            map_tokens_bytes(s.map_elements.len(), tok.feat_dim)
        );
        for p in CachePrecision::ALL {
            let cache =
                WindowCache::from_window_with(&tok, Arc::clone(&map), &window, p).unwrap();
            assert_eq!(cache.precision(), p);
            assert_eq!(
                cache.resident_bytes(),
                window_cache_bytes(sim.n_agents, h, tok.feat_dim, p),
                "{p:?}"
            );
        }
    }

    /// A quantized session's emit keeps poses/timesteps/frame bit-exact
    /// and features within the per-row rounding bound; a per-session
    /// precision override rebuilds a cached session of the wrong
    /// precision instead of silently serving it.
    #[test]
    fn quantized_emit_is_close_and_precision_mismatch_rebuilds() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(19);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let key = SessionKey { scene: 19, t0: 7, sample: 0 };

        let want = tok.tokenize_window(&s.map_elements, &window, None);
        let got = pool
            .step_with_precision(key, CachePrecision::F16, &tok, &s.map_elements, &window)
            .unwrap();
        assert_eq!(got.pose, want.pose, "poses are never quantized");
        assert_eq!(got.tq, want.tq);
        assert_eq!(got.frame, want.frame);
        // map rows stay f32-exact; agent rows are within the f16 bound
        assert!(
            got.feat
                .iter()
                .zip(want.feat.iter())
                .all(|(a, b)| (a - b).abs() < 5e-2),
            "quantized features must stay close"
        );
        assert_eq!(got.feat[..want.n_map * tok.feat_dim], want.feat[..want.n_map * tok.feat_dim]);
        assert_eq!(stats.misses.get(), 1);

        // same key at f32: the f16 entry must not serve — rebuild as miss
        let exact = pool.step(key, &tok, &s.map_elements, &window).unwrap();
        assert_eq!(exact.feat, want.feat, "f32 emit stays bit-identical");
        assert_eq!(stats.misses.get(), 2, "precision mismatch is a miss");
        assert_eq!(stats.hits.get(), 0);
    }

    /// Regression (serving-path panic): an empty request window used to
    /// hit `expect("empty window")` / out-of-range indexing inside the
    /// pool; it must now surface as a recoverable error.
    #[test]
    fn empty_window_is_a_recoverable_error_not_a_panic() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(31);
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let key = SessionKey { scene: 31, t0: 7, sample: 0 };

        // no steps at all
        let err = pool.step(key, &tok, &s.map_elements, &[]).unwrap_err();
        assert!(format!("{err:#}").contains("empty"), "{err:#}");
        // steps but no agents
        let err = pool
            .step(key, &tok, &s.map_elements, &[Vec::new()])
            .unwrap_err();
        assert!(format!("{err:#}").contains("empty"), "{err:#}");
        // ragged window (agent count varies across steps)
        let ragged = vec![s.states[0].clone(), s.states[1][..2].to_vec()];
        let err = pool
            .step(key, &tok, &s.map_elements, &ragged)
            .unwrap_err();
        assert!(format!("{err:#}").contains("ragged"), "{err:#}");
        // the pool stays clean and usable for real traffic afterwards
        assert_eq!(pool.live_sessions(), 0);
        assert_eq!(stats.resident_bytes.get(), 0);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        pool.step(key, &tok, &s.map_elements, &window).unwrap();

        // the building blocks are recoverable too
        let map = Arc::new(MapTokens::tokenize(&tok, &s.map_elements));
        assert!(WindowCache::from_window(&tok, Arc::clone(&map), &[]).is_err());
        assert!(WindowCache::from_window(&tok, map, &[Vec::new()]).is_err());
    }

    /// Regression: a corrupted cached session (empty window) must force a
    /// cache-miss rebuild from the caller's full window, not panic in
    /// `emit`/`advance`.
    #[test]
    fn corrupt_cached_session_forces_miss_rebuild() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(37);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let key = SessionKey { scene: 37, t0: 7, sample: 0 };
        pool.step(key, &tok, &s.map_elements, &window).unwrap();
        assert_eq!(stats.misses.get(), 1);

        // corrupt the cached window behind the pool's back
        pool.inner
            .lock()
            .unwrap()
            .sessions
            .get_mut(&key)
            .unwrap()
            .cache
            .steps
            .clear();

        let got = pool.step(key, &tok, &s.map_elements, &window).unwrap();
        let want = tok.tokenize_window(&s.map_elements, &window, None);
        assert_eq!(got.feat, want.feat, "rebuilt output must be exact");
        assert_eq!(stats.misses.get(), 2, "corruption must count as a miss");
        assert_eq!(stats.hits.get(), 0);
    }

    /// Regression: eviction paths subtract raw byte counts from the
    /// shared `resident_bytes` gauge.  If the gauge under-counts (e.g.
    /// another shard's pool already drained it), releasing more bytes
    /// than recorded must saturate at zero — never wrap to ~u64::MAX in
    /// the stats line.
    #[test]
    fn resident_bytes_gauge_saturates_on_over_release() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(41);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let key = SessionKey { scene: 41, t0: 7, sample: 0 };
        pool.step(key, &tok, &s.map_elements, &window).unwrap();
        let recorded = stats.resident_bytes.get();
        assert!(recorded > 0);
        // drain the gauge below what the pool will release
        stats.resident_bytes.sub(recorded - 1);
        pool.end_session(key); // releases far more bytes than the gauge holds
        assert_eq!(
            stats.resident_bytes.get(),
            0,
            "gauge must saturate at zero, not wrap"
        );
        assert!(
            stats.summary().contains("resident=0B"),
            "{}",
            stats.summary()
        );
    }

    #[test]
    fn pool_byte_accounting_returns_to_map_only_after_release() {
        let (sim, tok) = setup();
        let s = ScenarioGenerator::new(sim.clone()).generate(3);
        let h = sim.history_steps;
        let window: Vec<Vec<crate::sim::AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let key = SessionKey { scene: 3, t0: 7, sample: 0 };
        pool.step(key, &tok, &s.map_elements, &window).unwrap();
        let map_bytes = pool.map_tokens(3, &tok, &s.map_elements).resident_bytes();
        assert!(pool.resident_bytes() > map_bytes);
        pool.end_session(key);
        assert_eq!(pool.resident_bytes(), map_bytes, "only shared map rows remain");
        assert_eq!(stats.resident_bytes.get() as usize, map_bytes);
    }
}
