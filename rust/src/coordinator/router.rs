//! Request routing, two layers:
//!
//! * [`ShardRouter`] — the serving front end's worker-shard picker:
//!   session-affinity traffic is hashed by family-aware scene id so every
//!   request touching one scene's cached KV rows lands on the shard that
//!   owns them; stateless traffic goes to the least-loaded shard.
//! * [`Router`] — inside one shard: maps each request to the model
//!   replica serving its attention method, tracking routed/rejected
//!   counts (vLLM-router-style, scaled to this system).

use std::collections::BTreeMap;

use crate::config::Method;
use crate::prng::SplitMix64;

use super::telemetry::Counter;

/// Stable shard assignment for a scene: a SplitMix64 finalizer over the
/// (already family-aware) scene id, mod the shard count.  Pure function —
/// the cross-shard equivalence test relies on the same scene mapping to
/// the same shard on every submit, so a session's cached KV rows never
/// migrate mid-rollout.
pub fn shard_of(scene_id: u64, n_shards: usize) -> usize {
    debug_assert!(n_shards > 0);
    (SplitMix64::new(scene_id).next_u64() % n_shards.max(1) as u64) as usize
}

/// [`shard_of`] with a set of excluded (dead/draining) shards: draws
/// from the same SplitMix64 stream until it lands on a live shard, so
/// reassignment is a pure function of the scene id and the exclusion
/// set — every coordinator replays the same choice.  Returns `None`
/// when every shard is excluded.
pub fn shard_of_excluding(scene_id: u64, n_shards: usize, excluded: &[bool]) -> Option<usize> {
    debug_assert!(n_shards > 0);
    debug_assert_eq!(excluded.len(), n_shards);
    if excluded.iter().all(|&e| e) {
        return None;
    }
    let mut rng = SplitMix64::new(scene_id);
    // bounded probe on the hash stream keeps the common case (few dead
    // shards) O(1); the deterministic linear fallback guarantees termination
    for _ in 0..n_shards.max(1) * 4 {
        let s = (rng.next_u64() % n_shards.max(1) as u64) as usize;
        if !excluded[s] {
            return Some(s);
        }
    }
    let first = shard_of(scene_id, n_shards);
    (0..n_shards).map(|off| (first + off) % n_shards).find(|&s| !excluded[s])
}

/// Front-end router over worker shards.  Stateless by design: routing
/// must stay a pure function of the request (plus the live load snapshot
/// for stateless traffic), so no atomics are touched on the submit path.
/// Per-shard acceptance counts live in
/// [`crate::coordinator::telemetry::ShardStats`] instead.
pub struct ShardRouter {
    n_shards: usize,
}

impl ShardRouter {
    pub fn new(n_shards: usize) -> ShardRouter {
        assert!(n_shards > 0, "a server needs at least one shard");
        ShardRouter { n_shards }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Session-affinity route: every request for `scene_id` lands on the
    /// same shard, so its cached map rows and window sessions stay local.
    pub fn shard_for_scene(&self, scene_id: u64) -> usize {
        shard_of(scene_id, self.n_shards)
    }

    /// Least-loaded route for stateless requests; `loads` is the current
    /// per-shard inflight depth in shard order.
    ///
    /// Tie-break contract (pinned by `least_loaded_ties_are_positional`):
    /// the **first** shard at the minimum load wins — strictly-lower load
    /// is the only thing that moves the pick.  Spelled as an explicit
    /// fold rather than `min_by_key` so the contract is in the code, not
    /// in an iterator adaptor's documented-but-easy-to-miss stability.
    pub fn least_loaded(&self, loads: impl IntoIterator<Item = u64>) -> usize {
        let mut best = 0usize;
        let mut best_load = u64::MAX;
        for (i, load) in loads.into_iter().enumerate() {
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }
}

/// Routing table over per-method replicas of `T` (model handles on the
/// inference thread; anything in tests).
pub struct Router<T> {
    replicas: BTreeMap<&'static str, Vec<T>>,
    next: BTreeMap<&'static str, usize>,
    pub routed: Counter,
    pub rejected: Counter,
}

impl<T> Router<T> {
    pub fn new() -> Router<T> {
        Router {
            replicas: BTreeMap::new(),
            next: BTreeMap::new(),
            routed: Counter::default(),
            rejected: Counter::default(),
        }
    }

    pub fn deploy(&mut self, method: Method, replica: T) {
        self.replicas.entry(method.name()).or_default().push(replica);
        self.next.entry(method.name()).or_insert(0);
    }

    pub fn methods(&self) -> Vec<&'static str> {
        self.replicas.keys().cloned().collect()
    }

    pub fn n_replicas(&self, method: Method) -> usize {
        self.replicas.get(method.name()).map_or(0, Vec::len)
    }

    /// Round-robin pick of a replica for `method`.
    pub fn route(&mut self, method: Method) -> Option<&mut T> {
        let name = method.name();
        let Some(replicas) = self.replicas.get_mut(name) else {
            self.rejected.inc();
            return None;
        };
        if replicas.is_empty() {
            self.rejected.inc();
            return None;
        }
        let idx = {
            let counter = self.next.get_mut(name).unwrap();
            let idx = *counter % replicas.len();
            *counter += 1;
            idx
        };
        self.routed.inc();
        Some(&mut replicas[idx])
    }
}

impl<T> Default for Router<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_round_robin() {
        let mut r: Router<u32> = Router::new();
        r.deploy(Method::Se2Fourier, 1);
        r.deploy(Method::Se2Fourier, 2);
        let picks: Vec<u32> = (0..4).map(|_| *r.route(Method::Se2Fourier).unwrap()).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
        assert_eq!(r.routed.get(), 4);
    }

    #[test]
    fn unknown_method_is_rejected() {
        let mut r: Router<u32> = Router::new();
        r.deploy(Method::Abs, 9);
        assert!(r.route(Method::Rope2d).is_none());
        assert_eq!(r.n_replicas(Method::Abs), 1);
        assert_eq!(r.n_replicas(Method::Rope2d), 0);
    }

    #[test]
    fn methods_lists_deployments() {
        let mut r: Router<u32> = Router::new();
        r.deploy(Method::Abs, 1);
        r.deploy(Method::Se2Fourier, 2);
        assert_eq!(r.methods(), vec!["abs", "se2fourier"]);
    }

    #[test]
    fn shard_assignment_is_deterministic_and_spread() {
        let r = ShardRouter::new(4);
        let mut counts = [0usize; 4];
        for scene in 0..256u64 {
            let s = r.shard_for_scene(scene);
            assert_eq!(s, r.shard_for_scene(scene), "stable per scene");
            assert_eq!(s, shard_of(scene, 4), "matches the pure function");
            counts[s] += 1;
        }
        // the SplitMix64 finalizer must not collapse sequential ids onto
        // one shard: every shard serves a healthy share of 256 scenes
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 24, "shard {i} got only {c}/256 scenes");
        }
    }

    #[test]
    fn single_shard_takes_everything() {
        let r = ShardRouter::new(1);
        for scene in [0u64, 7, u64::MAX] {
            assert_eq!(r.shard_for_scene(scene), 0);
        }
    }

    #[test]
    fn least_loaded_picks_min_with_stable_ties() {
        let r = ShardRouter::new(3);
        assert_eq!(r.least_loaded([5u64, 1, 3]), 1);
        assert_eq!(r.least_loaded([2u64, 2, 2]), 0, "ties break low");
        assert_eq!(r.least_loaded([4u64, 0, 0]), 1, "first minimum wins");
    }

    /// Pin the tie-break contract: the winner is the first index at the
    /// minimum, for every rotation of a tied load vector.  Would have
    /// caught any rewrite whose ties depend on iteration internals.
    #[test]
    fn least_loaded_ties_are_positional() {
        let r = ShardRouter::new(4);
        let base = [3u64, 1, 1, 1];
        for rot in 0..4 {
            let loads: Vec<u64> = (0..4).map(|i| base[(i + rot) % 4]).collect();
            let want = loads.iter().position(|&l| l == 1).unwrap();
            assert_eq!(r.least_loaded(loads.clone()), want, "loads {loads:?}");
        }
        // empty input degrades to shard 0, never panics
        assert_eq!(r.least_loaded(std::iter::empty()), 0);
    }

    #[test]
    fn excluding_reroutes_deterministically_off_dead_shards() {
        let n = 4;
        for scene in 0..512u64 {
            let home = shard_of(scene, n);
            // nothing excluded: identical to the plain assignment
            assert_eq!(shard_of_excluding(scene, n, &[false; 4]), Some(home));
            // home shard dead: lands elsewhere, and the same elsewhere
            // on every call (replayable reassignment)
            let mut dead = [false; 4];
            dead[home] = true;
            let moved = shard_of_excluding(scene, n, &dead).unwrap();
            assert_ne!(moved, home);
            assert_eq!(shard_of_excluding(scene, n, &dead), Some(moved));
            // one survivor: always found, even if the probe is unlucky
            let mut all_but = [true; 4];
            all_but[(home + 1) % n] = false;
            assert_eq!(shard_of_excluding(scene, n, &all_but), Some((home + 1) % n));
        }
        assert_eq!(shard_of_excluding(7, 4, &[true; 4]), None);
    }
}
