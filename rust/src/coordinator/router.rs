//! Request router: maps each request to the model replica serving its
//! attention method, tracking in-flight counts and rejecting methods that
//! are not deployed (vLLM-router-style, scaled to this system).

use std::collections::BTreeMap;

use crate::config::Method;

use super::telemetry::Counter;

/// Routing table over per-method replicas of `T` (model handles on the
/// inference thread; anything in tests).
pub struct Router<T> {
    replicas: BTreeMap<&'static str, Vec<T>>,
    next: BTreeMap<&'static str, usize>,
    pub routed: Counter,
    pub rejected: Counter,
}

impl<T> Router<T> {
    pub fn new() -> Router<T> {
        Router {
            replicas: BTreeMap::new(),
            next: BTreeMap::new(),
            routed: Counter::default(),
            rejected: Counter::default(),
        }
    }

    pub fn deploy(&mut self, method: Method, replica: T) {
        self.replicas.entry(method.name()).or_default().push(replica);
        self.next.entry(method.name()).or_insert(0);
    }

    pub fn methods(&self) -> Vec<&'static str> {
        self.replicas.keys().cloned().collect()
    }

    pub fn n_replicas(&self, method: Method) -> usize {
        self.replicas.get(method.name()).map_or(0, Vec::len)
    }

    /// Round-robin pick of a replica for `method`.
    pub fn route(&mut self, method: Method) -> Option<&mut T> {
        let name = method.name();
        let Some(replicas) = self.replicas.get_mut(name) else {
            self.rejected.inc();
            return None;
        };
        if replicas.is_empty() {
            self.rejected.inc();
            return None;
        }
        let idx = {
            let counter = self.next.get_mut(name).unwrap();
            let idx = *counter % replicas.len();
            *counter += 1;
            idx
        };
        self.routed.inc();
        Some(&mut replicas[idx])
    }
}

impl<T> Default for Router<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_round_robin() {
        let mut r: Router<u32> = Router::new();
        r.deploy(Method::Se2Fourier, 1);
        r.deploy(Method::Se2Fourier, 2);
        let picks: Vec<u32> = (0..4).map(|_| *r.route(Method::Se2Fourier).unwrap()).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
        assert_eq!(r.routed.get(), 4);
    }

    #[test]
    fn unknown_method_is_rejected() {
        let mut r: Router<u32> = Router::new();
        r.deploy(Method::Abs, 9);
        assert!(r.route(Method::Rope2d).is_none());
        assert_eq!(r.n_replicas(Method::Abs), 1);
        assert_eq!(r.n_replicas(Method::Rope2d), 0);
    }

    #[test]
    fn methods_lists_deployments() {
        let mut r: Router<u32> = Router::new();
        r.deploy(Method::Abs, 1);
        r.deploy(Method::Se2Fourier, 2);
        assert_eq!(r.methods(), vec!["abs", "se2fourier"]);
    }
}
