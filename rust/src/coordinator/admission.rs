//! Async admission control for the continuous-batching scheduler
//! (DESIGN.md §17): a bounded per-shard wait queue with request
//! deadlines, per-tenant token-bucket pacing, and deadline-miss
//! shedding.
//!
//! The fixed-batch serving path used to answer overload with a binary
//! `Busy` bounce the moment a queue filled.  The admission controller
//! splits that into two distinct, separately counted outcomes:
//!
//! * **rejection** ([`AdmissionError::QueueFull`]) — the bounded wait
//!   queue is at capacity, the request never enters the system;
//! * **shed** ([`AdmissionError::DeadlineExceeded`]) — the request was
//!   queued but waited past its deadline before a step-batch slot opened,
//!   so serving it would only produce a stale answer.  Shedding keeps
//!   the in-flight batch full of requests that can still meet their
//!   latency target, which is what holds goodput up under overload
//!   (`benches/serving_load.rs`).
//!
//! Tenant QoS: requests carry a tenant class (`0..TENANT_CLASSES`), and
//! each class is paced by a token bucket (`tenant_rate` tokens/s,
//! `tenant_burst` depth).  [`AdmissionQueue::admit`] scans past
//! rate-limited waiters, so a flooding tenant queues behind its own
//! bucket without head-of-line-blocking compliant tenants.  Shutdown
//! drain uses [`AdmissionQueue::admit_unpaced`]: every accepted caller
//! still gets a real result, regardless of pacing or deadline state.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Tenant QoS classes (fixed so per-tenant telemetry and the bucket
/// array stay allocation-free).  Tenant ids map onto classes modulo
/// this count.
pub const TENANT_CLASSES: usize = 8;

/// Typed admission outcome for a request that will not be served.
/// Propagated through `Server::submit` on the response channel, so
/// callers can `downcast_ref::<AdmissionError>()` instead of parsing
/// message text.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// The shard's bounded wait queue is at capacity; the request was
    /// never accepted into the system.
    QueueFull {
        shard: usize,
        capacity: usize,
    },
    /// The request waited in the admission queue past its deadline and
    /// was shed instead of served stale.
    DeadlineExceeded {
        shard: usize,
        waited: Duration,
        deadline: Duration,
    },
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // "busy" is load-bearing: callers (and the serving tests)
            // have matched on it since the fixed-batch Busy bounce
            AdmissionError::QueueFull { shard, capacity } => write!(
                f,
                "server busy (shard {shard} queue full at {capacity})"
            ),
            AdmissionError::DeadlineExceeded {
                shard,
                waited,
                deadline,
            } => write!(
                f,
                "request shed on shard {shard}: waited {:.1} ms past its {:.1} ms deadline",
                waited.as_secs_f64() * 1e3,
                deadline.as_secs_f64() * 1e3,
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Admission-controller knobs, applied per shard.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Bounded wait-queue depth; a push beyond this is a
    /// [`AdmissionError::QueueFull`] rejection.
    pub max_queue: usize,
    /// Max time a request may wait for admission before it is shed with
    /// [`AdmissionError::DeadlineExceeded`].  `Duration::ZERO` disables
    /// deadline shedding (requests wait indefinitely).
    pub deadline: Duration,
    /// Cap on decode sessions resident in one shard's in-flight step
    /// batch.  A single request whose `n_samples` exceeds the remaining
    /// headroom is still admitted alone (the cap bounds concurrency, it
    /// must not deadlock large requests).
    pub max_live_sessions: usize,
    /// Token-bucket refill rate per tenant class, requests/second.
    /// `<= 0` disables pacing (every tenant is unlimited).
    pub tenant_rate: f64,
    /// Token-bucket depth (burst allowance) per tenant class.
    pub tenant_burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            max_queue: 256,
            deadline: Duration::ZERO,
            // 4x the default model batch shape: enough concurrency to keep
            // padding negligible without unbounded resident session state
            max_live_sessions: 32,
            tenant_rate: 0.0,
            tenant_burst: 8.0,
        }
    }
}

/// A queued request awaiting admission to the step batch.
pub struct Waiting<T> {
    pub item: T,
    /// Tenant class (`0..TENANT_CLASSES`, pre-wrapped by [`AdmissionQueue::push`]).
    pub tenant: u8,
    pub enqueued_at: Instant,
}

/// Classic token bucket over `Instant` time; level refills lazily on
/// observation so no timer thread is needed.
#[derive(Clone, Copy, Debug)]
struct TokenBucket {
    level: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(cfg: &AdmissionConfig, now: Instant) -> TokenBucket {
        TokenBucket {
            level: cfg.tenant_burst.max(0.0),
            last: now,
        }
    }

    fn refill(&mut self, cfg: &AdmissionConfig, now: Instant) {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.level = (self.level + dt * cfg.tenant_rate).min(cfg.tenant_burst.max(0.0));
        self.last = now;
    }

    fn try_take(&mut self) -> bool {
        if self.level >= 1.0 {
            self.level -= 1.0;
            true
        } else {
            false
        }
    }

    /// Time until this bucket holds one token (`None` = never at the
    /// current rate).
    fn eta_to_token(&self, cfg: &AdmissionConfig) -> Option<Duration> {
        if self.level >= 1.0 {
            return Some(Duration::ZERO);
        }
        if cfg.tenant_rate <= 0.0 || cfg.tenant_burst < 1.0 {
            return None;
        }
        let secs = (1.0 - self.level) / cfg.tenant_rate;
        // clamp: a pathological rate must not overflow Duration
        Some(Duration::from_secs_f64(secs.min(3600.0)))
    }
}

/// Bounded admission queue with deadline shedding and per-tenant pacing
/// (generic over the queued request type, like the legacy [`super::batcher::Batcher`],
/// so the policy is unit-testable without a server).
pub struct AdmissionQueue<T> {
    cfg: AdmissionConfig,
    shard: usize,
    queue: VecDeque<Waiting<T>>,
    buckets: [TokenBucket; TENANT_CLASSES],
}

impl<T> AdmissionQueue<T> {
    pub fn new(cfg: AdmissionConfig, shard: usize, now: Instant) -> AdmissionQueue<T> {
        let buckets = [TokenBucket::new(&cfg, now); TENANT_CLASSES];
        AdmissionQueue {
            cfg,
            shard,
            queue: VecDeque::new(),
            buckets,
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue a request for admission.  `Err` hands the item back with
    /// the typed rejection so the caller can answer its response channel.
    pub fn push(&mut self, item: T, tenant: u8, now: Instant) -> Result<(), (T, AdmissionError)> {
        if self.queue.len() >= self.cfg.max_queue {
            return Err((
                item,
                AdmissionError::QueueFull {
                    shard: self.shard,
                    capacity: self.cfg.max_queue,
                },
            ));
        }
        // queue growth is charged to the batcher scope in the memory
        // attribution table (the admission queue replaced the per-method
        // batcher queues on the serving path)
        let _mem = crate::obs::alloc::MemScope::enter("batcher");
        self.queue.push_back(Waiting {
            item,
            tenant: (tenant as usize % TENANT_CLASSES) as u8,
            enqueued_at: now,
        });
        Ok(())
    }

    /// Remove and return every waiter whose deadline has passed, paired
    /// with its typed shed error.  No-op when deadlines are disabled.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<(Waiting<T>, AdmissionError)> {
        if self.cfg.deadline.is_zero() {
            return Vec::new();
        }
        let mut shed = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let waited = now.saturating_duration_since(self.queue[i].enqueued_at);
            if waited > self.cfg.deadline {
                let w = self.queue.remove(i).unwrap();
                let err = AdmissionError::DeadlineExceeded {
                    shard: self.shard,
                    waited,
                    deadline: self.cfg.deadline,
                };
                shed.push((w, err));
            } else {
                i += 1;
            }
        }
        shed
    }

    /// Admit the first waiter whose tenant bucket has a token (FIFO
    /// within a tenant; rate-limited waiters are skipped, not blocking).
    /// `None` = queue empty or every queued tenant is out of tokens.
    pub fn admit(&mut self, now: Instant) -> Option<Waiting<T>> {
        if self.cfg.tenant_rate <= 0.0 {
            return self.queue.pop_front();
        }
        for b in &mut self.buckets {
            b.refill(&self.cfg, now);
        }
        let pos = self
            .queue
            .iter()
            .position(|w| self.buckets[w.tenant as usize].level >= 1.0)?;
        let w = self.queue.remove(pos).unwrap();
        let took = self.buckets[w.tenant as usize].try_take();
        debug_assert!(took, "position() guaranteed a token");
        Some(w)
    }

    /// FIFO admission ignoring pacing and deadlines — the shutdown-drain
    /// path, where every already-accepted caller must still be served.
    pub fn admit_unpaced(&mut self) -> Option<Waiting<T>> {
        self.queue.pop_front()
    }

    /// How long the oldest waiter has been queued.
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queue
            .front()
            .map(|w| now.saturating_duration_since(w.enqueued_at))
    }

    /// Time until the earliest queued deadline expires (`None` when
    /// deadlines are off or the queue is empty).  Drives the worker's
    /// sleep so sheds happen on time without idle-tick polling.
    pub fn next_shed_in(&self, now: Instant) -> Option<Duration> {
        if self.cfg.deadline.is_zero() {
            return None;
        }
        self.queue
            .iter()
            .map(|w| {
                self.cfg
                    .deadline
                    .saturating_sub(now.saturating_duration_since(w.enqueued_at))
            })
            .min()
    }

    /// Time until some queued tenant's bucket refills to a whole token
    /// (`None` when the queue is empty or no queued tenant can ever
    /// refill).  Drives the worker's sleep when everything queued is
    /// rate-limited.
    pub fn refill_wait(&self, now: Instant) -> Option<Duration> {
        if self.cfg.tenant_rate <= 0.0 {
            return self.queue.front().map(|_| Duration::ZERO);
        }
        self.queue
            .iter()
            .filter_map(|w| {
                let mut b = self.buckets[w.tenant as usize];
                b.refill(&self.cfg, now);
                b.eta_to_token(&self.cfg)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdmissionConfig {
        AdmissionConfig::default()
    }

    #[test]
    fn queue_full_is_a_typed_rejection() {
        let now = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(
            AdmissionConfig {
                max_queue: 2,
                ..cfg()
            },
            3,
            now,
        );
        assert!(q.push(1, 0, now).is_ok());
        assert!(q.push(2, 0, now).is_ok());
        let (item, err) = q.push(3, 0, now).unwrap_err();
        assert_eq!(item, 3, "the rejected item comes back to answer its caller");
        assert_eq!(
            err,
            AdmissionError::QueueFull {
                shard: 3,
                capacity: 2
            }
        );
        // the Display keeps the historical "busy" marker
        assert!(err.to_string().contains("busy"), "{err}");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn deadline_expiry_sheds_in_fifo_order() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(
            AdmissionConfig {
                deadline: Duration::from_millis(10),
                ..cfg()
            },
            0,
            t0,
        );
        q.push(1, 0, t0).unwrap();
        q.push(2, 0, t0 + Duration::from_millis(8)).unwrap();
        // at t0+11ms only the first waiter is past its deadline
        let shed = q.shed_expired(t0 + Duration::from_millis(11));
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].0.item, 1);
        match &shed[0].1 {
            AdmissionError::DeadlineExceeded { waited, .. } => {
                assert!(*waited >= Duration::from_millis(11));
            }
            other => panic!("wrong shed error: {other:?}"),
        }
        assert_eq!(q.len(), 1);
        assert_eq!(q.admit(t0 + Duration::from_millis(12)).unwrap().item, 2);
    }

    #[test]
    fn no_deadline_means_no_shedding() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(), 0, t0);
        q.push(1, 0, t0).unwrap();
        assert!(q.shed_expired(t0 + Duration::from_secs(3600)).is_empty());
        assert!(q.next_shed_in(t0).is_none());
    }

    #[test]
    fn token_bucket_paces_admissions() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(
            AdmissionConfig {
                tenant_rate: 10.0, // one token per 100ms
                tenant_burst: 1.0,
                ..cfg()
            },
            0,
            t0,
        );
        q.push(1, 0, t0).unwrap();
        q.push(2, 0, t0).unwrap();
        // burst of 1: the first admit drains the bucket
        assert_eq!(q.admit(t0).unwrap().item, 1);
        assert!(q.admit(t0).is_none(), "bucket empty, second must wait");
        let eta = q.refill_wait(t0).expect("refill eta");
        assert!(eta > Duration::ZERO && eta <= Duration::from_millis(101), "{eta:?}");
        // after a refill interval the second waiter admits
        assert_eq!(q.admit(t0 + Duration::from_millis(150)).unwrap().item, 2);
    }

    #[test]
    fn rate_limited_tenant_does_not_block_compliant_tenants() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(
            AdmissionConfig {
                tenant_rate: 10.0,
                tenant_burst: 1.0,
                ..cfg()
            },
            0,
            t0,
        );
        // tenant 0 floods the head of the queue, tenant 1 queues behind
        q.push(10, 0, t0).unwrap();
        q.push(11, 0, t0).unwrap();
        q.push(20, 1, t0).unwrap();
        assert_eq!(q.admit(t0).unwrap().item, 10, "tenant 0 spends its burst");
        // tenant 0 is out of tokens: admission skips to tenant 1 instead
        // of head-of-line blocking on the flooding tenant
        assert_eq!(q.admit(t0).unwrap().item, 20);
        assert!(q.admit(t0).is_none());
        // drain ignores pacing entirely
        assert_eq!(q.admit_unpaced().unwrap().item, 11);
        assert!(q.is_empty());
    }

    #[test]
    fn zero_burst_never_admits_paced_but_drains_unpaced() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(
            AdmissionConfig {
                tenant_rate: 1e-9,
                tenant_burst: 0.0,
                ..cfg()
            },
            0,
            t0,
        );
        q.push(7, 0, t0).unwrap();
        assert!(q.admit(t0 + Duration::from_secs(3600)).is_none());
        assert!(q.refill_wait(t0).is_none(), "no refill eta at zero burst");
        assert_eq!(q.admit_unpaced().unwrap().item, 7);
    }

    #[test]
    fn unlimited_rate_is_strict_fifo() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(), 0, t0);
        for (i, tenant) in [(0u32, 0u8), (1, 3), (2, 1), (3, 3)] {
            q.push(i, tenant, t0).unwrap();
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.admit(t0).map(|w| w.item)).collect();
        assert_eq!(order, vec![0, 1, 2, 3], "deterministic submit-order admission");
    }

    #[test]
    fn tenant_ids_wrap_onto_classes() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(cfg(), 0, t0);
        q.push(1, (TENANT_CLASSES + 2) as u8, t0).unwrap();
        assert_eq!(q.admit(t0).unwrap().tenant, 2);
    }

    #[test]
    fn next_shed_in_tracks_the_earliest_deadline() {
        let t0 = Instant::now();
        let mut q: AdmissionQueue<u32> = AdmissionQueue::new(
            AdmissionConfig {
                deadline: Duration::from_millis(100),
                ..cfg()
            },
            0,
            t0,
        );
        q.push(1, 0, t0).unwrap();
        q.push(2, 0, t0 + Duration::from_millis(50)).unwrap();
        let eta = q.next_shed_in(t0 + Duration::from_millis(30)).unwrap();
        assert_eq!(eta, Duration::from_millis(70), "oldest waiter drives the sleep");
        assert!(q.oldest_wait(t0 + Duration::from_millis(30)).unwrap() >= Duration::from_millis(30));
    }
}
