//! Training orchestrator: dataset pipeline -> ModelHandle train steps, with
//! loss-curve recording and periodic validation — the loop behind the
//! `train_agents` end-to-end example and the Table-I bench.

use anyhow::Result;

use crate::config::{ModelConfig, SimConfig};
use crate::dataset::{generate_examples, Loader};
use crate::metrics;
use crate::tokenizer::Tokenizer;

use super::model::ModelHandle;

pub struct TrainReport {
    /// (step, train loss) samples.
    pub loss_curve: Vec<(u64, f32)>,
    /// Validation NLL after training (model-loss definition).
    pub final_val_loss: f64,
    pub steps: u64,
    pub wall_secs: f64,
    pub examples_seen: u64,
}

pub struct Trainer {
    pub model_cfg: ModelConfig,
    pub sim: SimConfig,
    pub loader: Loader,
    /// Record a loss sample every `log_every` steps.
    pub log_every: u64,
    /// If set, apply SE(2) frame-jitter augmentation with this max shift
    /// (model units) to every training batch — the data-augmentation
    /// baseline the paper names as future ablation work.
    pub augment: Option<f64>,
}

impl Trainer {
    /// Build a trainer with a freshly generated dataset.
    pub fn new(
        model_cfg: ModelConfig,
        sim: SimConfig,
        n_examples: usize,
        data_seed: u64,
    ) -> Trainer {
        let tokenizer = Tokenizer::new(&model_cfg, &sim);
        let examples = generate_examples(&sim, &tokenizer, data_seed, n_examples);
        // hold out at least one full batch for validation (Loader drops
        // ragged val tails, so a tiny fraction would validate on nothing)
        let val_frac = if examples.len() >= 2 * model_cfg.batch_size {
            (model_cfg.batch_size as f64 / examples.len() as f64).max(0.1)
        } else {
            0.0
        };
        let loader = Loader::new(examples, model_cfg.batch_size, val_frac, data_seed ^ 0xDA7A);
        Trainer {
            model_cfg,
            sim,
            loader,
            log_every: 10,
            augment: None,
        }
    }

    /// Build a trainer over pre-generated examples (e.g. from a dataset
    /// shard written by `gen-data`).
    pub fn from_examples(
        model_cfg: ModelConfig,
        sim: SimConfig,
        examples: Vec<crate::dataset::Example>,
        seed: u64,
    ) -> Trainer {
        let val_frac = if examples.len() >= 2 * model_cfg.batch_size {
            (model_cfg.batch_size as f64 / examples.len() as f64).max(0.1)
        } else {
            0.0
        };
        let loader = Loader::new(examples, model_cfg.batch_size, val_frac, seed ^ 0xDA7A);
        Trainer {
            model_cfg,
            sim,
            loader,
            log_every: 10,
            augment: None,
        }
    }

    /// Run `steps` optimizer steps on `model`.
    pub fn run(&mut self, model: &mut ModelHandle, steps: u64) -> Result<TrainReport> {
        let n_tokens = self.model_cfg.n_tokens;
        let feat_dim = self.model_cfg.feat_dim;
        let t0 = std::time::Instant::now();
        let mut loss_curve = Vec::new();
        let mut examples_seen = 0u64;
        for s in 0..steps {
            let batch = match self.augment {
                Some(shift) => self.loader.next_batch_augmented(shift),
                None => self.loader.next_batch(),
            };
            examples_seen += batch.batch_size as u64;
            let loss = model.train_step(&batch, n_tokens, feat_dim)?;
            if s % self.log_every == 0 || s + 1 == steps {
                loss_curve.push((model.step, loss));
            }
        }
        let final_val_loss = self.validate(model)?;
        Ok(TrainReport {
            loss_curve,
            final_val_loss,
            steps,
            wall_secs: t0.elapsed().as_secs_f64(),
            examples_seen,
        })
    }

    /// Mean NLL over the validation split.
    pub fn validate(&self, model: &ModelHandle) -> Result<f64> {
        let n_tokens = self.model_cfg.n_tokens;
        let feat_dim = self.model_cfg.feat_dim;
        let n_actions = self.model_cfg.n_actions;
        let mut total = 0.0;
        let mut n = 0usize;
        for batch in self.loader.val_batches() {
            let logits = model.forward(&batch, n_tokens, feat_dim)?;
            let v = metrics::nll(&logits, &batch.target, n_actions);
            let labeled = batch.target.iter().filter(|&&t| t >= 0).count();
            total += v * labeled as f64;
            n += labeled;
        }
        Ok(if n == 0 { f64::NAN } else { total / n as f64 })
    }
}
