//! Serving loop: a dedicated inference thread owns the PJRT engine (the
//! `xla` crate's client is `Rc`-based and must not cross threads) and all
//! model replicas; request producers on any thread submit through an mpsc
//! channel and receive results on per-request channels.
//!
//! Flow: submit -> router (per-method batcher) -> deadline/size flush ->
//! rollout engine -> respond.  Backpressure surfaces to callers as
//! `Busy` rejections instead of unbounded queues.  Shutdown is graceful:
//! partially filled batches drain *through the rollout engine*, so every
//! already-accepted caller gets a real result rather than a drop.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{Method, SystemConfig};
use crate::runtime::Engine;

use super::batcher::{Batcher, BatcherConfig, ReadyBatch};
use super::kvcache::{CacheConfig, KvCachePool};
use super::model::ModelHandle;
use super::rollout::{RolloutEngine, RolloutRequest, RolloutResult};
use super::telemetry::ServerStats;

/// A rollout request plus its response channel.
struct Envelope {
    method: Method,
    request: RolloutRequest,
    submitted_at: Instant,
    respond: mpsc::Sender<Result<RolloutResult>>,
}

enum Message {
    Request(Envelope),
    Shutdown,
}

/// Client-side handle to the serving thread.
pub struct Server {
    tx: mpsc::Sender<Message>,
    thread: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
}

impl Server {
    /// Start the inference thread: loads artifacts for `methods`, each
    /// initialized from `param_seed` (examples train them first via the
    /// Trainer; serving freshly initialized weights is allowed for
    /// latency benchmarking).
    pub fn start(
        cfg: SystemConfig,
        methods: Vec<Method>,
        param_seed: i32,
        batcher_cfg: BatcherConfig,
    ) -> Result<Server> {
        let stats = Arc::new(ServerStats::default());
        let stats_thread = Arc::clone(&stats);
        let (tx, rx) = mpsc::channel::<Message>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let thread = std::thread::Builder::new()
            .name("se2attn-inference".into())
            .spawn(move || {
                inference_thread(cfg, methods, param_seed, batcher_cfg, rx, ready_tx, stats_thread)
            })?;

        // wait for model load/compile before accepting traffic
        ready_rx
            .recv()
            .map_err(|_| anyhow!("inference thread died during startup"))??;

        Ok(Server {
            tx,
            thread: Some(thread),
            stats,
        })
    }

    /// Submit a rollout; returns the channel the result will arrive on.
    pub fn submit(
        &self,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let (rtx, rrx) = mpsc::channel();
        self.stats.requests_in.inc();
        let env = Envelope {
            method,
            request,
            submitted_at: Instant::now(),
            respond: rtx,
        };
        if self.tx.send(Message::Request(env)).is_err() {
            // inference thread gone; the receiver will see a disconnect
        }
        rrx
    }

    /// Blocking convenience call.
    pub fn call(&self, method: Method, request: RolloutRequest) -> Result<RolloutResult> {
        self.submit(method, request)
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn inference_thread(
    cfg: SystemConfig,
    methods: Vec<Method>,
    param_seed: i32,
    batcher_cfg: BatcherConfig,
    rx: mpsc::Receiver<Message>,
    ready_tx: mpsc::Sender<Result<()>>,
    stats: Arc<ServerStats>,
) {
    // build engine + models on THIS thread (PjRtClient is thread-local)
    let setup = (|| -> Result<(BTreeMap<&'static str, ModelHandle>, RolloutEngine)> {
        let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
        let mut models = BTreeMap::new();
        for m in &methods {
            // touch the decode artifact so compilation happens at startup
            engine.load(&format!("decode_{}", m.name()))?;
            models.insert(m.name(), ModelHandle::init(Arc::clone(&engine), *m, param_seed)?);
        }
        let rollout = RolloutEngine::new(cfg.model.clone(), cfg.sim.clone());
        Ok((models, rollout))
    })();

    let (mut models, rollout) = match setup {
        Ok(v) => {
            let _ = ready_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };

    let mut batchers: BTreeMap<&'static str, Batcher<Envelope>> = methods
        .iter()
        .map(|m| (m.name(), Batcher::new(batcher_cfg.clone())))
        .collect();

    // The server owns the KV/tokenization cache pool: sessions are
    // allocated per scene-sample as rollouts run, map rows are shared
    // across requests for the same scene, and the pool's counters feed the
    // ServerStats summary (hits/misses/evictions/resident bytes).
    let kv_pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats.cache));

    let mut running = true;
    while running {
        // sleep until the nearest batcher deadline (or a short idle tick)
        let now = Instant::now();
        let timeout = batchers
            .values()
            .filter_map(|b| b.next_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(50));

        match rx.recv_timeout(timeout) {
            Ok(Message::Request(env)) => match batchers.get_mut(env.method.name()) {
                Some(b) => {
                    if let Err(rejected) = b.push(env) {
                        stats.queue_rejections.inc();
                        let _ = rejected
                            .respond
                            .send(Err(anyhow!("server busy (queue full)")));
                    }
                }
                None => {
                    stats.queue_rejections.inc();
                    let _ = env.respond.send(Err(anyhow!(
                        "method '{}' is not deployed on this server",
                        env.method.name()
                    )));
                }
            },
            Ok(Message::Shutdown) => running = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
        }

        // flush any ready batches
        let now = Instant::now();
        for (name, b) in batchers.iter_mut() {
            while let Some(ready) = b.poll(now) {
                run_batch(name, ready, &mut models, &rollout, &kv_pool, &stats);
            }
        }
    }

    // graceful shutdown: drain queued requests through the rollout engine
    // so every already-accepted caller still gets a real result
    for (name, b) in batchers.iter_mut() {
        for mut ready in b.drain() {
            // drained batches never hit the fixed-shape inference path, so
            // their (large) padding must not skew the batching metric
            ready.padding = 0;
            run_batch(name, ready, &mut models, &rollout, &kv_pool, &stats);
        }
    }
}

/// Execute one ready batch and respond to each request (shared by the
/// steady-state flush and the shutdown drain).
fn run_batch(
    name: &str,
    ready: ReadyBatch<Envelope>,
    models: &mut BTreeMap<&'static str, ModelHandle>,
    rollout: &RolloutEngine,
    kv_pool: &KvCachePool,
    stats: &ServerStats,
) {
    stats.batches.inc();
    stats.padded_slots.add(ready.padding as u64);
    let model = models.get_mut(name).unwrap();
    for env in ready.items {
        let t0 = Instant::now();
        let result = rollout.rollout_with_cache(model, &env.request, kv_pool);
        stats.decode_latency.record(t0.elapsed());
        match &result {
            Ok(res) => {
                stats.requests_done.inc();
                stats.families.record(
                    env.request.scenario.family,
                    &res.min_ade,
                    res.collisions as u64,
                    res.trajectories.len() as u64,
                );
            }
            Err(_) => stats.requests_failed.inc(),
        }
        stats.e2e_latency.record(env.submitted_at.elapsed());
        let _ = env.respond.send(result);
    }
}
