//! Sharded serving front end (DESIGN.md §12): N worker threads, each
//! owning its own inference backend (the PJRT engine and the `xla`
//! crate's client are `Rc`-based and must not cross threads, so every
//! worker builds its replicas on its own thread), its own per-method
//! `Batcher` set, and its own `KvCachePool` shard over a *shared* map-row
//! registry.
//!
//! Routing: session traffic is hashed by family-aware
//! `Scenario::scene_id()` so every request touching one scene's cached KV
//! rows lands on the shard that owns them — sessions never migrate
//! mid-rollout.  Stateless traffic (`submit_stateless`) goes to the
//! least-loaded shard by inflight depth.
//!
//! Flow per shard: submit -> shard router -> per-method batcher ->
//! deadline/size flush -> replica router -> rollout engine -> respond.
//! Backpressure is **per shard**: a hot scene family fills only its own
//! shard's queues and surfaces `Busy` to its own callers; the other
//! shards keep serving.  Shutdown is graceful on every shard: partially
//! filled batches drain *through the rollout engine*, so every
//! already-accepted caller gets a real result rather than a drop, and a
//! submit after shutdown gets an explicit "server is shut down" error.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{Method, SystemConfig};
use crate::runtime::Engine;
use crate::sim::Scenario;

use super::batcher::{Batcher, BatcherConfig, ReadyBatch};
use super::kvcache::{CacheConfig, KvCachePool, MapRegistry};
use super::model::{ActionDecoder, ModelHandle};
use super::rollout::{RolloutEngine, RolloutRequest, RolloutResult};
use super::router::{shard_of, Router, ShardRouter};
use super::telemetry::{ServerStats, ShardStats};
use crate::trace::{self, ProfileConfig, ProfileGuard, Stage, TraceConfig, Tracer};

/// Per-worker inference backend: a replica router over boxed decoders,
/// built on the worker's own thread by a [`BackendFactory`].
pub type Backend = Router<Box<dyn ActionDecoder>>;

/// Builds one shard's backend *on that shard's thread* (argument: shard
/// id).  The default factory loads PJRT artifacts; tests and benches
/// inject artifact-free synthetic decoders through
/// [`Server::start_with_backend`].
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Backend> + Send + Sync>;

/// Serving-layer configuration: worker shard count plus the per-shard
/// batching and KV-cache budgets.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker shards (each its own thread + model replicas + batchers +
    /// cache pool).  `Default` derives this from the host's parallelism.
    pub workers: usize,
    /// Batcher knobs, applied per shard per method — `max_queue` is a
    /// per-shard bound, so backpressure isolates hot shards.
    pub batcher: BatcherConfig,
    /// KV/tokenization cache budget, applied per shard pool (the shared
    /// map-row registry is bounded by `max_map_scenes` once, server-wide).
    /// Its `precision` field (CLI `simulate --cache-precision`) selects
    /// the storage tier of every session cache on this server — f16/bf16
    /// roughly halve resident bytes per session, so the same per-shard
    /// `max_bytes` holds about twice the sessions (DESIGN.md §14) — and
    /// is copied into each shard's `ModelConfig.cache_precision` at
    /// startup so incremental engines derived from it agree.
    pub cache: CacheConfig,
    /// Blocked flash-kernel shape for *native CPU* attention derived
    /// from this server's model config — normalized into each shard's
    /// `ModelConfig.kernel` at startup and consumed through
    /// [`crate::attention::incremental::IncrementalConfig::for_model`]
    /// (the incremental feature-cache engines; PJRT artifact decode is
    /// internally threaded by XLA and unaffected).  The kernel is
    /// bit-stable across `threads`, so this knob trades latency for CPU
    /// without perturbing results; all shard threads share one scoped
    /// pool, and each attention call's transient state stays O(c) per
    /// participating worker.
    pub kernel: crate::attention::kernel::KernelConfig,
    /// Request tracing (DESIGN.md §15).  Off by default: no rings are
    /// allocated and every span site costs one branch.
    pub trace: TraceConfig,
    /// Kernel/cache profiling counters (DESIGN.md §15).  Off by default.
    pub profile: ProfileConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: crate::config::default_workers(),
            batcher: BatcherConfig::default(),
            cache: CacheConfig::default(),
            kernel: crate::attention::kernel::KernelConfig::default(),
            trace: TraceConfig::default(),
            profile: ProfileConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Config with an explicit worker count (`0` = keep the default).
    pub fn with_workers(workers: usize) -> ServeConfig {
        let mut cfg = ServeConfig::default();
        if workers > 0 {
            cfg.workers = workers;
        }
        cfg
    }
}

/// A rollout request plus its response channel.
struct Envelope {
    method: Method,
    request: RolloutRequest,
    submitted_at: Instant,
    /// Tracing id minted at submit (0 when tracing is off).
    trace_id: u64,
    respond: mpsc::Sender<Result<RolloutResult>>,
}

enum Message {
    Request(Envelope),
    Shutdown,
}

struct Shard {
    tx: mpsc::Sender<Message>,
    thread: Option<std::thread::JoinHandle<()>>,
    stats: Arc<ShardStats>,
}

/// Client-side handle to the sharded serving pool.
pub struct Server {
    shards: Vec<Shard>,
    router: ShardRouter,
    pub stats: Arc<ServerStats>,
    /// Span recorder, present when `ServeConfig::trace.enabled`.
    tracer: Option<Arc<Tracer>>,
    /// Per-shard queue capacity, retained for the introspection
    /// server's saturation check ([`Server::obs_sources`]).
    max_queue: usize,
    /// Holds the global profiling gate up while the server lives.
    _profile: Option<ProfileGuard>,
}

impl Server {
    /// Start the worker pool on the PJRT backend: each shard loads the
    /// artifacts for `methods` on its own thread, with replicas
    /// initialized from `param_seed` (examples train them first via the
    /// Trainer; serving freshly initialized weights is allowed for
    /// latency benchmarking).
    pub fn start(
        cfg: SystemConfig,
        methods: Vec<Method>,
        param_seed: i32,
        serve: ServeConfig,
    ) -> Result<Server> {
        // apply the serving-layer kernel + cache-precision overrides
        // BEFORE the factory captures its clone, so backends built from
        // this config (and any `IncrementalConfig::for_model` engine
        // derived from it) see the ServeConfig/CLI knobs
        let mut cfg = cfg;
        cfg.model.kernel = serve.kernel.normalized();
        cfg.model.cache_precision = serve.cache.precision;
        let factory: BackendFactory = {
            let cfg = cfg.clone();
            let methods = methods.clone();
            Arc::new(move |_shard| {
                // engine + models on the calling (worker) thread: the
                // PjRtClient is thread-local by construction
                let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
                let mut backend = Router::new();
                for m in &methods {
                    // touch the decode artifact so compilation happens at
                    // startup, not on the first request
                    engine.load(&format!("decode_{}", m.name()))?;
                    let handle = ModelHandle::init(Arc::clone(&engine), *m, param_seed)?;
                    backend.deploy(*m, Box::new(handle) as Box<dyn ActionDecoder>);
                }
                Ok(backend)
            })
        };
        Server::start_with_backend(cfg, methods, serve, factory)
    }

    /// Start the worker pool on an injected backend factory (called once
    /// per shard, on that shard's thread).  This is how tests and benches
    /// serve real traffic through the full shard/batch/cache machinery
    /// without compiled artifacts.
    pub fn start_with_backend(
        cfg: SystemConfig,
        methods: Vec<Method>,
        serve: ServeConfig,
        factory: BackendFactory,
    ) -> Result<Server> {
        // the serving-layer kernel and cache-precision knobs win over
        // whatever the model config carried in, so every shard agrees
        // with the CLI/ServeConfig
        let mut cfg = cfg;
        cfg.model.kernel = serve.kernel.normalized();
        cfg.model.cache_precision = serve.cache.precision;
        let workers = serve.workers.max(1);
        let stats = Arc::new(ServerStats::with_shards(workers));
        let tracer = serve.trace.enabled.then(|| Tracer::new(workers, serve.trace));
        let profile = serve.profile.enabled.then(ProfileGuard::enable);
        let maps = Arc::new(MapRegistry::new(
            serve.cache.max_map_scenes,
            Arc::clone(&stats.cache),
        ));

        let mut shards = Vec::with_capacity(workers);
        let mut ready_rxs = Vec::with_capacity(workers);
        for shard_id in 0..workers {
            let (tx, rx) = mpsc::channel::<Message>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let ctx = ShardCtx {
                id: shard_id,
                cfg: cfg.clone(),
                methods: methods.clone(),
                batcher_cfg: serve.batcher.clone(),
                cache_cfg: serve.cache.clone(),
                maps: Arc::clone(&maps),
                stats: Arc::clone(&stats),
                shard: Arc::clone(&stats.shards[shard_id]),
                factory: Arc::clone(&factory),
                tracer: tracer.clone(),
            };
            let thread = std::thread::Builder::new()
                .name(format!("se2attn-shard-{shard_id}"))
                .spawn(move || shard_worker(ctx, rx, ready_tx))?;
            shards.push(Shard {
                tx,
                thread: Some(thread),
                stats: Arc::clone(&stats.shards[shard_id]),
            });
            ready_rxs.push(ready_rx);
        }

        let server = Server {
            shards,
            router: ShardRouter::new(workers),
            stats,
            tracer,
            max_queue: serve.batcher.max_queue,
            _profile: profile,
        };
        // wait for every shard's model load/compile before accepting
        // traffic; on any failure the early return drops `server`, whose
        // Drop shuts the healthy shards down cleanly
        for (i, ready) in ready_rxs.into_iter().enumerate() {
            ready
                .recv()
                .map_err(|_| anyhow!("shard {i} died during startup"))??;
        }
        Ok(server)
    }

    /// Worker shard count.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The span recorder, when this server was started with
    /// `ServeConfig::trace.enabled` (export via
    /// [`Tracer::write_chrome_trace`] / [`Tracer::to_chrome_trace`]).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// The shard that session-affinity routing pins `scenario` to (pure
    /// function of the family-aware scene id — exposed for tests).
    pub fn shard_for(&self, scenario: &Scenario) -> usize {
        shard_of(scenario.scene_id(), self.shards.len())
    }

    /// Data sources for a live introspection server
    /// ([`crate::obs::http::ObsServer::start`]): shared stats, the span
    /// rings, and this server's per-shard queue capacity for the
    /// `/healthz` saturation check.  Everything is `Arc`-shared, so the
    /// introspection server may outlive this handle.
    pub fn obs_sources(&self) -> crate::obs::http::ObsSources {
        crate::obs::http::ObsSources {
            stats: Arc::clone(&self.stats),
            tracer: self.tracer.clone(),
            max_queue: self.max_queue,
        }
    }

    /// Submit a rollout with session affinity: requests for the same
    /// scene always land on the shard owning that scene's cached KV rows.
    /// Returns the channel the result will arrive on.
    pub fn submit(
        &self,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let shard = self.router.shard_for_scene(request.scenario.scene_id());
        self.submit_to(shard, method, request)
    }

    /// Submit a rollout with no cache affinity (one-shot evaluation
    /// traffic): routed to the least-loaded shard by inflight depth.
    pub fn submit_stateless(
        &self,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let shard = self
            .router
            .least_loaded(self.shards.iter().map(|s| s.stats.inflight.get()));
        self.submit_to(shard, method, request)
    }

    fn submit_to(
        &self,
        shard: usize,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let (rtx, rrx) = mpsc::channel();
        let submitted_at = Instant::now();
        // Trace-id minting is the only atomic the submit path touches,
        // and only when tracing is on — the ShardRouter's "no atomics on
        // the submit path" contract still holds for untraced servers.
        let trace_id = self.tracer.as_ref().map_or(0, |t| t.mint());
        let env = Envelope {
            method,
            request,
            submitted_at,
            trace_id,
            respond: rtx,
        };
        // inflight goes up BEFORE the send: the worker decrements when it
        // answers, and its (saturating) sub must never be able to run
        // ahead of this add or the gauge would stick one too high
        let sh = &self.shards[shard].stats;
        sh.inflight.add(1);
        match self.shards[shard].tx.send(Message::Request(env)) {
            Ok(()) => {
                // count the request only once the shard has accepted it
                self.stats.requests_in.inc();
                sh.requests.inc();
                if let Some(t) = &self.tracer {
                    // front-end ring (track 0); arg = target shard
                    t.record_frontend(Stage::Route, submitted_at, trace_id, shard as u64);
                }
            }
            Err(mpsc::SendError(msg)) => {
                // the shard has exited (shutdown): answer explicitly
                // instead of silently dropping the channel, and do NOT
                // count the request as accepted.  The worker never saw
                // the envelope, so undoing the add here cannot race a
                // worker-side decrement for it.
                sh.inflight.sub(1);
                if let Message::Request(env) = msg {
                    let _ = env
                        .respond
                        .send(Err(anyhow!("server is shut down — request not accepted")));
                }
            }
        }
        rrx
    }

    /// Blocking convenience call.
    pub fn call(&self, method: Method, request: RolloutRequest) -> Result<RolloutResult> {
        self.submit(method, request)
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    /// Graceful shutdown: every shard drains its partially filled batches
    /// through its rollout engine before the worker exits, so every
    /// accepted caller still gets a real result.  Idempotent; also runs
    /// on Drop.  After shutdown, `submit` answers "server is shut down".
    pub fn shutdown(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(Message::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(t) = s.thread.take() {
                let _ = t.join();
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Everything one worker shard owns or shares, bundled for the spawn.
struct ShardCtx {
    id: usize,
    cfg: SystemConfig,
    methods: Vec<Method>,
    batcher_cfg: BatcherConfig,
    cache_cfg: CacheConfig,
    /// Map-row registry shared across shards (immutable rows, scene-keyed).
    maps: Arc<MapRegistry>,
    /// Global counters (shared atomics — every shard increments the same
    /// bundle, so the stats line aggregates for free).
    stats: Arc<ServerStats>,
    /// This shard's breakdown slot.
    shard: Arc<ShardStats>,
    factory: BackendFactory,
    /// Present when tracing is on; the worker installs its ring as the
    /// thread-local span sink at startup.
    tracer: Option<Arc<Tracer>>,
}

/// Clears a shard's liveness gauge when its worker exits — by returning
/// *or by panicking* (Drop runs on unwind), so `/healthz` reports dead
/// shards either way.
struct LiveGuard(Arc<ShardStats>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.live.set(0);
        self.0.queue_depth.set(0);
    }
}

fn shard_worker(ctx: ShardCtx, rx: mpsc::Receiver<Message>, ready_tx: mpsc::Sender<Result<()>>) {
    ctx.shard.live.set(1);
    let _live = LiveGuard(Arc::clone(&ctx.shard));
    // bind this thread to its span ring for the worker's whole lifetime
    let _trace_ctx = ctx
        .tracer
        .as_ref()
        .map(|t| trace::install(t.shard_ring(ctx.id), t.epoch()));
    // build the backend on THIS thread (PJRT clients are thread-local)
    let mut backend = match (ctx.factory)(ctx.id) {
        Ok(b) => {
            let _ = ready_tx.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let rollout = RolloutEngine::new(ctx.cfg.model.clone(), ctx.cfg.sim.clone());
    let mut batchers: BTreeMap<Method, Batcher<Envelope>> = ctx
        .methods
        .iter()
        .map(|m| (*m, Batcher::new(ctx.batcher_cfg.clone())))
        .collect();

    // This shard's slice of the KV/tokenization cache: private sessions
    // (the affinity router guarantees a session only ever lands here),
    // shared map rows, counters aggregated into the server-wide bundle.
    let kv_pool = KvCachePool::with_map_registry(
        ctx.cache_cfg.clone(),
        Arc::clone(&ctx.stats.cache),
        Arc::clone(&ctx.maps),
    );

    let mut running = true;
    while running {
        // sleep until the nearest batcher deadline (or a short idle tick)
        let now = Instant::now();
        let timeout = batchers
            .values()
            .filter_map(|b| b.next_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(50));

        match rx.recv_timeout(timeout) {
            Ok(Message::Request(env)) => match batchers.get_mut(&env.method) {
                Some(b) => {
                    if let Err(rejected) = b.push(env) {
                        // per-shard backpressure: only this shard's
                        // callers see Busy; siblings keep serving
                        ctx.stats.queue_rejections.inc();
                        ctx.shard.rejected.inc();
                        ctx.shard.inflight.sub(1);
                        let _ = rejected
                            .respond
                            .send(Err(anyhow!("server busy (shard {} queue full)", ctx.id)));
                    }
                }
                None => {
                    ctx.stats.queue_rejections.inc();
                    ctx.shard.rejected.inc();
                    ctx.shard.inflight.sub(1);
                    let _ = env.respond.send(Err(anyhow!(
                        "method '{}' is not deployed on this server",
                        env.method.name()
                    )));
                }
            },
            Ok(Message::Shutdown) => running = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
        }
        // saturation is visible to /healthz the moment the queues fill,
        // not only after the next flush completes
        refresh_queue_depth(&ctx, &batchers);

        // flush any ready batches
        let now = Instant::now();
        for (method, b) in batchers.iter_mut() {
            while let Some(ready) = b.poll(now) {
                run_batch(*method, ready, &mut backend, &rollout, &kv_pool, &ctx);
            }
        }
        refresh_queue_depth(&ctx, &batchers);
    }

    // graceful shutdown: drain queued requests through the rollout engine
    // so every already-accepted caller still gets a real result
    for (method, b) in batchers.iter_mut() {
        for mut ready in b.drain() {
            // drained batches never hit the fixed-shape inference path, so
            // their (large) padding must not skew the batching metric
            ready.padding = 0;
            run_batch(*method, ready, &mut backend, &rollout, &kv_pool, &ctx);
        }
    }
}

/// Publish the shard's total queued-envelope count to its gauge (the
/// batchers live on the worker thread; the gauge is how `/healthz` and
/// the `/vars` sampler observe queue depth without touching them).
fn refresh_queue_depth(ctx: &ShardCtx, batchers: &BTreeMap<Method, Batcher<Envelope>>) {
    ctx.shard
        .queue_depth
        .set(batchers.values().map(|b| b.len() as u64).sum());
}

/// Execute one ready batch and respond to each request (shared by the
/// steady-state flush and the shutdown drain).
fn run_batch(
    method: Method,
    ready: ReadyBatch<Envelope>,
    backend: &mut Backend,
    rollout: &RolloutEngine,
    kv_pool: &KvCachePool,
    ctx: &ShardCtx,
) {
    let stats = &*ctx.stats;
    let batch_t0 = Instant::now();
    let batch_size = ready.items.len();
    stats.batches.inc();
    ctx.shard.batches.inc();
    stats.padded_slots.add(ready.padding as u64);
    let Some(model) = backend.route(method) else {
        // deployed method with no live replica on this shard: answer
        // every caller instead of wedging the batch
        for env in ready.items {
            stats.requests_failed.inc();
            ctx.shard.failed.inc();
            ctx.shard.inflight.sub(1);
            let _ = env.respond.send(Err(anyhow!(
                "method '{}' has no replica on shard {}",
                method.name(),
                ctx.id
            )));
        }
        return;
    };
    for env in ready.items {
        // queue residency: submit time -> this batch starting to run
        trace::record_between(Stage::Enqueue, env.submitted_at, batch_t0, env.trace_id, 0);
        // spans recorded below (tokenize/decode/attend, in the rollout
        // and kernel layers) attribute to this request
        trace::set_trace_id(env.trace_id);
        let t0 = Instant::now();
        let result = rollout.rollout_with_cache(model.as_ref(), &env.request, kv_pool);
        stats.decode_latency.record(t0.elapsed());
        match &result {
            Ok(res) => {
                stats.requests_done.inc();
                ctx.shard.done.inc();
                stats.families.record(
                    env.request.scenario.family,
                    &res.min_ade,
                    res.collisions as u64,
                    res.trajectories.len() as u64,
                );
            }
            Err(_) => {
                stats.requests_failed.inc();
                ctx.shard.failed.inc();
            }
        }
        stats.e2e_latency.record(env.submitted_at.elapsed());
        ctx.shard.inflight.sub(1);
        let respond_t0 = Instant::now();
        let _ = env.respond.send(result);
        trace::record_since(Stage::Respond, respond_t0, 0);
    }
    trace::set_trace_id(0);
    trace::record_since(Stage::Batch, batch_t0, batch_size as u64);
}
