//! Sharded serving front end (DESIGN.md §12, §17): N worker threads,
//! each owning its own inference backend (the PJRT engine and the `xla`
//! crate's client are `Rc`-based and must not cross threads, so every
//! worker builds its replicas on its own thread), its own admission
//! queue, and its own `KvCachePool` shard over a *shared* map-row
//! registry.
//!
//! Routing: session traffic is hashed by family-aware
//! `Scenario::scene_id()` so every request touching one scene's cached KV
//! rows lands on the shard that owns them — sessions never migrate
//! mid-rollout.  Stateless traffic (`submit_stateless`) goes to the
//! least-loaded shard by inflight depth.
//!
//! Scheduling is **continuous batching** (DESIGN.md §17): each worker
//! runs a step loop — admit waiting requests into the live set, pack
//! every live session into one step batch, decode *one* step through
//! the incremental engine, retire requests that reached their horizon,
//! respond — so sessions join and leave the in-flight batch at step
//! granularity instead of waiting for fixed-size batch flushes.  An
//! [`AdmissionQueue`] fronts the loop: a bounded wait queue with
//! optional request deadlines (stale waiters are *shed* with a typed
//! [`DeadlineExceeded`](super::admission::AdmissionError::DeadlineExceeded))
//! and per-tenant token-bucket pacing, replacing the old binary `Busy`
//! bounce.  Backpressure is still **per shard**: a hot scene family
//! fills only its own shard's queue, and only its own callers see
//! [`QueueFull`](super::admission::AdmissionError::QueueFull).
//!
//! The worker sleeps on its mailbox condvar when idle — a submit wakes
//! it immediately, so a quiet shard adds no idle-tick latency.  Shutdown
//! is graceful on every shard: the admission queue drains *through the
//! step loop* (pacing and deadlines ignored), so every already-accepted
//! caller gets a real result rather than a drop, and a submit after
//! shutdown gets an explicit "server is shut down" error.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{Method, SystemConfig};
use crate::runtime::Engine;
use crate::sim::Scenario;

use super::admission::{AdmissionConfig, AdmissionQueue};
use super::kvcache::{CacheConfig, KvCachePool, MapRegistry};
use super::model::{ActionDecoder, ModelHandle, SlotParams};
use super::rollout::{RolloutEngine, RolloutRequest, RolloutResult, SessionState, StepSlot};
use super::router::{shard_of, Router, ShardRouter};
use super::telemetry::{ServerStats, ShardStats};
use crate::trace::{self, ProfileConfig, ProfileGuard, Stage, TraceConfig, Tracer};

/// Per-worker inference backend: a replica router over boxed decoders,
/// built on the worker's own thread by a [`BackendFactory`].
pub type Backend = Router<Box<dyn ActionDecoder>>;

/// Builds one shard's backend *on that shard's thread* (argument: shard
/// id).  The default factory loads PJRT artifacts; tests and benches
/// inject artifact-free synthetic decoders through
/// [`Server::start_with_backend`].
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Backend> + Send + Sync>;

/// Serving-layer configuration: worker shard count plus the per-shard
/// admission and KV-cache budgets.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker shards (each its own thread + model replicas + admission
    /// queue + cache pool).  `Default` derives this from the host's
    /// parallelism.
    pub workers: usize,
    /// Admission-controller knobs, applied per shard — `max_queue` is a
    /// per-shard bound, so backpressure isolates hot shards; `deadline`
    /// and the tenant token buckets shape load under overload
    /// (DESIGN.md §17).
    pub admission: AdmissionConfig,
    /// KV/tokenization cache budget, applied per shard pool (the shared
    /// map-row registry is bounded by `max_map_scenes` once, server-wide).
    /// Its `precision` field (CLI `simulate --cache-precision`) selects
    /// the storage tier of every session cache on this server — f16/bf16
    /// roughly halve resident bytes per session, so the same per-shard
    /// `max_bytes` holds about twice the sessions (DESIGN.md §14) — and
    /// is copied into each shard's `ModelConfig.cache_precision` at
    /// startup so incremental engines derived from it agree.
    pub cache: CacheConfig,
    /// Blocked flash-kernel shape for *native CPU* attention derived
    /// from this server's model config — normalized into each shard's
    /// `ModelConfig.kernel` at startup and consumed through
    /// [`crate::attention::incremental::IncrementalConfig::for_model`]
    /// (the incremental feature-cache engines; PJRT artifact decode is
    /// internally threaded by XLA and unaffected).  The kernel is
    /// bit-stable across `threads`, so this knob trades latency for CPU
    /// without perturbing results; all shard threads share one scoped
    /// pool, and each attention call's transient state stays O(c) per
    /// participating worker.
    pub kernel: crate::attention::kernel::KernelConfig,
    /// Replace `kernel` with the one-shot startup microbenchmark's pick
    /// ([`crate::attention::kernel::KernelConfig::autotune`]) before the
    /// shards capture it (CLI `simulate --kernel-autotune`).  Env
    /// `SE2ATTN_KERNEL_*` pins still win inside the autotuner, and the
    /// tuned shape is process-cached, so every shard — and the PJRT
    /// tiling contract ([`crate::runtime::kernel_tiling`]) — sees one
    /// kernel shape.  Off by default: autotuning costs a few hundred ms
    /// of microbenchmark at startup.
    pub autotune_kernel: bool,
    /// Request tracing (DESIGN.md §15).  Off by default: no rings are
    /// allocated and every span site costs one branch.
    pub trace: TraceConfig,
    /// Kernel/cache profiling counters (DESIGN.md §15).  Off by default.
    pub profile: ProfileConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: crate::config::default_workers(),
            admission: AdmissionConfig::default(),
            cache: CacheConfig::default(),
            kernel: crate::attention::kernel::KernelConfig::default(),
            autotune_kernel: false,
            trace: TraceConfig::default(),
            profile: ProfileConfig::default(),
        }
    }
}

impl ServeConfig {
    /// The kernel shape the serving pool will actually run: the autotuned
    /// pick when `autotune_kernel` is set, otherwise the explicit
    /// `kernel` field — normalized either way.
    fn resolved_kernel(&self) -> crate::attention::kernel::KernelConfig {
        if self.autotune_kernel {
            crate::attention::kernel::KernelConfig::autotune().normalized()
        } else {
            self.kernel.normalized()
        }
    }
}

impl ServeConfig {
    /// Config with an explicit worker count (`0` = keep the default).
    pub fn with_workers(workers: usize) -> ServeConfig {
        let mut cfg = ServeConfig::default();
        if workers > 0 {
            cfg.workers = workers;
        }
        cfg
    }
}

/// A rollout request plus its response channel.
struct Envelope {
    method: Method,
    request: RolloutRequest,
    submitted_at: Instant,
    /// Tracing id minted at submit (0 when tracing is off).
    trace_id: u64,
    /// Tenant QoS class (wrapped onto the admission token buckets).
    tenant: u8,
    respond: mpsc::Sender<Result<RolloutResult>>,
}

enum Message {
    Request(Envelope),
    Shutdown,
}

/// Condvar-backed worker inbox: submitters push and wake the worker
/// immediately (no idle-tick polling), the worker drains FIFO.  `close`
/// seals the box so post-shutdown submits fail fast with an explicit
/// error instead of queueing into a dead shard.
struct Mailbox {
    state: Mutex<MailboxState>,
    ready: Condvar,
}

struct MailboxState {
    queue: VecDeque<Message>,
    closed: bool,
}

impl Mailbox {
    fn new() -> Mailbox {
        Mailbox {
            state: Mutex::new(MailboxState {
                queue: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue and wake the worker; `Err` hands the message back when
    /// the box is closed (worker exited or shutting down).
    fn push(&self, msg: Message) -> std::result::Result<(), Message> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(msg);
        }
        {
            // inbox growth is charged to the batcher scope alongside the
            // admission queue it feeds
            let _mem = crate::obs::alloc::MemScope::enter("batcher");
            st.queue.push_back(msg);
        }
        drop(st);
        self.ready.notify_one();
        Ok(())
    }

    /// Take everything queued.  When empty, sleep up to `timeout`
    /// (`None` = until work arrives or the box closes) — the condvar
    /// wake is what lets an idle shard pick up a submit with zero
    /// polling latency.
    fn recv(&self, timeout: Option<Duration>) -> Vec<Message> {
        let mut st = self.state.lock().unwrap();
        match timeout {
            Some(d) => {
                if st.queue.is_empty() && !st.closed {
                    st = self.ready.wait_timeout(st, d).unwrap().0;
                }
            }
            None => {
                while st.queue.is_empty() && !st.closed {
                    st = self.ready.wait(st).unwrap();
                }
            }
        }
        st.queue.drain(..).collect()
    }

    /// Non-blocking drain (the step loop must keep stepping live work).
    fn try_drain(&self) -> Vec<Message> {
        self.state.lock().unwrap().queue.drain(..).collect()
    }

    /// Seal against further pushes and hand back whatever was still
    /// queued.  Idempotent.
    fn close(&self) -> Vec<Message> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        st.queue.drain(..).collect()
    }
}

struct Shard {
    mailbox: Arc<Mailbox>,
    thread: Option<std::thread::JoinHandle<()>>,
    stats: Arc<ShardStats>,
}

/// Client-side handle to the sharded serving pool.
pub struct Server {
    shards: Vec<Shard>,
    router: ShardRouter,
    pub stats: Arc<ServerStats>,
    /// Span recorder, present when `ServeConfig::trace.enabled`.
    tracer: Option<Arc<Tracer>>,
    /// Per-shard admission-queue capacity, retained for the introspection
    /// server's saturation check ([`Server::obs_sources`]).
    max_queue: usize,
    /// Holds the global profiling gate up while the server lives.
    _profile: Option<ProfileGuard>,
}

impl Server {
    /// Start the worker pool on the PJRT backend: each shard loads the
    /// artifacts for `methods` on its own thread, with replicas
    /// initialized from `param_seed` (examples train them first via the
    /// Trainer; serving freshly initialized weights is allowed for
    /// latency benchmarking).
    pub fn start(
        cfg: SystemConfig,
        methods: Vec<Method>,
        param_seed: i32,
        serve: ServeConfig,
    ) -> Result<Server> {
        // apply the serving-layer kernel + cache-precision overrides
        // BEFORE the factory captures its clone, so backends built from
        // this config (and any `IncrementalConfig::for_model` engine
        // derived from it) see the ServeConfig/CLI knobs
        let mut cfg = cfg;
        cfg.model.kernel = serve.resolved_kernel();
        cfg.model.cache_precision = serve.cache.precision;
        let factory: BackendFactory = {
            let cfg = cfg.clone();
            let methods = methods.clone();
            Arc::new(move |_shard| {
                // engine + models on the calling (worker) thread: the
                // PjRtClient is thread-local by construction
                let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
                let mut backend = Router::new();
                for m in &methods {
                    // touch the decode artifact so compilation happens at
                    // startup, not on the first request
                    engine.load(&format!("decode_{}", m.name()))?;
                    let handle = ModelHandle::init(Arc::clone(&engine), *m, param_seed)?;
                    backend.deploy(*m, Box::new(handle) as Box<dyn ActionDecoder>);
                }
                Ok(backend)
            })
        };
        Server::start_with_backend(cfg, methods, serve, factory)
    }

    /// Start the worker pool on an injected backend factory (called once
    /// per shard, on that shard's thread).  This is how tests and benches
    /// serve real traffic through the full shard/admission/cache machinery
    /// without compiled artifacts.
    pub fn start_with_backend(
        cfg: SystemConfig,
        methods: Vec<Method>,
        serve: ServeConfig,
        factory: BackendFactory,
    ) -> Result<Server> {
        // the serving-layer kernel and cache-precision knobs win over
        // whatever the model config carried in, so every shard agrees
        // with the CLI/ServeConfig
        let mut cfg = cfg;
        cfg.model.kernel = serve.resolved_kernel();
        cfg.model.cache_precision = serve.cache.precision;
        let workers = serve.workers.max(1);
        let stats = Arc::new(ServerStats::with_shards(workers));
        let tracer = serve.trace.enabled.then(|| Tracer::new(workers, serve.trace));
        let profile = serve.profile.enabled.then(ProfileGuard::enable);
        let maps = Arc::new(MapRegistry::new(
            serve.cache.max_map_scenes,
            Arc::clone(&stats.cache),
        ));

        let mut shards = Vec::with_capacity(workers);
        let mut ready_rxs = Vec::with_capacity(workers);
        for shard_id in 0..workers {
            let mailbox = Arc::new(Mailbox::new());
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let ctx = ShardCtx {
                id: shard_id,
                cfg: cfg.clone(),
                methods: methods.clone(),
                admission: serve.admission.clone(),
                cache_cfg: serve.cache.clone(),
                maps: Arc::clone(&maps),
                stats: Arc::clone(&stats),
                shard: Arc::clone(&stats.shards[shard_id]),
                factory: Arc::clone(&factory),
                tracer: tracer.clone(),
            };
            let worker_mailbox = Arc::clone(&mailbox);
            let thread = std::thread::Builder::new()
                .name(format!("se2attn-shard-{shard_id}"))
                .spawn(move || shard_worker(ctx, worker_mailbox, ready_tx))?;
            shards.push(Shard {
                mailbox,
                thread: Some(thread),
                stats: Arc::clone(&stats.shards[shard_id]),
            });
            ready_rxs.push(ready_rx);
        }

        let server = Server {
            shards,
            router: ShardRouter::new(workers),
            stats,
            tracer,
            max_queue: serve.admission.max_queue,
            _profile: profile,
        };
        // wait for every shard's model load/compile before accepting
        // traffic; on any failure the early return drops `server`, whose
        // Drop shuts the healthy shards down cleanly
        for (i, ready) in ready_rxs.into_iter().enumerate() {
            ready
                .recv()
                .map_err(|_| anyhow!("shard {i} died during startup"))??;
        }
        Ok(server)
    }

    /// Worker shard count.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The span recorder, when this server was started with
    /// `ServeConfig::trace.enabled` (export via
    /// [`Tracer::write_chrome_trace`] / [`Tracer::to_chrome_trace`]).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// The shard that session-affinity routing pins `scenario` to (pure
    /// function of the family-aware scene id — exposed for tests).
    pub fn shard_for(&self, scenario: &Scenario) -> usize {
        shard_of(scenario.scene_id(), self.shards.len())
    }

    /// Data sources for a live introspection server
    /// ([`crate::obs::http::ObsServer::start`]): shared stats, the span
    /// rings, and this server's per-shard queue capacity for the
    /// `/healthz` saturation check.  Everything is `Arc`-shared, so the
    /// introspection server may outlive this handle.
    pub fn obs_sources(&self) -> crate::obs::http::ObsSources {
        crate::obs::http::ObsSources {
            stats: Arc::clone(&self.stats),
            tracer: self.tracer.clone(),
            max_queue: self.max_queue,
        }
    }

    /// Submit a rollout with session affinity: requests for the same
    /// scene always land on the shard owning that scene's cached KV rows.
    /// Returns the channel the result will arrive on.
    pub fn submit(
        &self,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        self.submit_for_tenant(0, method, request)
    }

    /// [`Server::submit`] on behalf of tenant QoS class `tenant`: the
    /// admission controller paces each class through its own token
    /// bucket (`AdmissionConfig::tenant_rate`/`tenant_burst`), so one
    /// flooding tenant queues behind its own bucket instead of starving
    /// the others.  Ids wrap onto
    /// [`super::admission::TENANT_CLASSES`] classes.
    pub fn submit_for_tenant(
        &self,
        tenant: u8,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let shard = self.router.shard_for_scene(request.scenario.scene_id());
        self.submit_to(shard, tenant, method, request)
    }

    /// Submit a rollout with no cache affinity (one-shot evaluation
    /// traffic): routed to the least-loaded shard by inflight depth.
    pub fn submit_stateless(
        &self,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let shard = self
            .router
            .least_loaded(self.shards.iter().map(|s| s.stats.inflight.get()));
        self.submit_to(shard, 0, method, request)
    }

    fn submit_to(
        &self,
        shard: usize,
        tenant: u8,
        method: Method,
        request: RolloutRequest,
    ) -> mpsc::Receiver<Result<RolloutResult>> {
        let (rtx, rrx) = mpsc::channel();
        let submitted_at = Instant::now();
        // Trace-id minting is the only atomic the submit path touches,
        // and only when tracing is on — the ShardRouter's "no atomics on
        // the submit path" contract still holds for untraced servers.
        let trace_id = self.tracer.as_ref().map_or(0, |t| t.mint());
        let env = Envelope {
            method,
            request,
            submitted_at,
            trace_id,
            tenant,
            respond: rtx,
        };
        // inflight goes up BEFORE the push: the worker decrements when it
        // answers, and its (saturating) sub must never be able to run
        // ahead of this add or the gauge would stick one too high
        let sh = &self.shards[shard].stats;
        sh.inflight.add(1);
        match self.shards[shard].mailbox.push(Message::Request(env)) {
            Ok(()) => {
                // count the request only once the shard has accepted it
                self.stats.requests_in.inc();
                sh.requests.inc();
                if let Some(t) = &self.tracer {
                    // front-end ring (track 0); arg = target shard
                    t.record_frontend(Stage::Route, submitted_at, trace_id, shard as u64);
                }
            }
            Err(msg) => {
                // the shard's mailbox is sealed (shutdown or worker
                // death): answer explicitly instead of silently dropping
                // the channel, and do NOT count the request as accepted.
                // The worker never saw the envelope, so undoing the add
                // here cannot race a worker-side decrement for it.
                sh.inflight.sub(1);
                if let Message::Request(env) = msg {
                    let _ = env
                        .respond
                        .send(Err(anyhow!("server is shut down — request not accepted")));
                }
            }
        }
        rrx
    }

    /// Blocking convenience call.
    pub fn call(&self, method: Method, request: RolloutRequest) -> Result<RolloutResult> {
        self.submit(method, request)
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    /// Graceful shutdown: every shard drains its admission queue through
    /// the continuous step loop (pacing and deadlines ignored) before
    /// the worker exits, so every accepted caller still gets a real
    /// result.  Idempotent; also runs on Drop.  After shutdown, `submit`
    /// answers "server is shut down".
    pub fn shutdown(&mut self) {
        for s in &self.shards {
            let _ = s.mailbox.push(Message::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(t) = s.thread.take() {
                let _ = t.join();
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Everything one worker shard owns or shares, bundled for the spawn.
struct ShardCtx {
    id: usize,
    cfg: SystemConfig,
    methods: Vec<Method>,
    admission: AdmissionConfig,
    cache_cfg: CacheConfig,
    /// Map-row registry shared across shards (immutable rows, scene-keyed).
    maps: Arc<MapRegistry>,
    /// Global counters (shared atomics — every shard increments the same
    /// bundle, so the stats line aggregates for free).
    stats: Arc<ServerStats>,
    /// This shard's breakdown slot.
    shard: Arc<ShardStats>,
    factory: BackendFactory,
    /// Present when tracing is on; the worker installs its ring as the
    /// thread-local span sink at startup.
    tracer: Option<Arc<Tracer>>,
}

/// Clears a shard's liveness gauges and seals its mailbox when its
/// worker exits — by returning *or by panicking* (Drop runs on unwind) —
/// so `/healthz` reports dead shards and later submits get an explicit
/// "server is shut down" answer instead of a dropped channel.
struct WorkerGuard {
    stats: Arc<ShardStats>,
    mailbox: Arc<Mailbox>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let _ = self.mailbox.close();
        self.stats.live.set(0);
        self.stats.queue_depth.set(0);
        self.stats.live_sessions.set(0);
    }
}

/// One admitted request being advanced through the continuous step loop.
struct ActiveRequest {
    env: Envelope,
    /// One decode session per requested sample, stepped in lockstep.
    sessions: Vec<SessionState>,
    steps_done: usize,
    /// Decode wall time attributed to this request (its slots' share of
    /// every shared step batch it participated in), ms.
    decode_ms: f64,
}

fn shard_worker(ctx: ShardCtx, mailbox: Arc<Mailbox>, ready_tx: mpsc::Sender<Result<()>>) {
    ctx.shard.live.set(1);
    let _guard = WorkerGuard {
        stats: Arc::clone(&ctx.shard),
        mailbox: Arc::clone(&mailbox),
    };
    // bind this thread to its span ring for the worker's whole lifetime
    let _trace_ctx = ctx
        .tracer
        .as_ref()
        .map(|t| trace::install(t.shard_ring(ctx.id), t.epoch()));
    // build the backend on THIS thread (PJRT clients are thread-local)
    let mut backend = match (ctx.factory)(ctx.id) {
        Ok(b) => {
            let _ = ready_tx.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let rollout = RolloutEngine::new(ctx.cfg.model.clone(), ctx.cfg.sim.clone());
    let future_steps = ctx.cfg.sim.future_steps;
    let max_live = ctx.admission.max_live_sessions.max(1);
    let mut adm: AdmissionQueue<Envelope> =
        AdmissionQueue::new(ctx.admission.clone(), ctx.id, Instant::now());
    let mut live: Vec<ActiveRequest> = Vec::new();
    let mut draining = false;

    // This shard's slice of the KV/tokenization cache: private sessions
    // (the affinity router guarantees a session only ever lands here),
    // shared map rows, counters aggregated into the server-wide bundle.
    let kv_pool = KvCachePool::with_map_registry(
        ctx.cache_cfg.clone(),
        Arc::clone(&ctx.stats.cache),
        Arc::clone(&ctx.maps),
    );

    loop {
        // 1. receive: never block while there is live work to step or a
        // drain to finish; otherwise sleep on the mailbox condvar, bounded
        // by the earliest deadline expiry / token-bucket refill when the
        // admission queue is waiting on time rather than on new messages
        let msgs = if !live.is_empty() || draining {
            mailbox.try_drain()
        } else if adm.is_empty() {
            mailbox.recv(None)
        } else {
            let now = Instant::now();
            let wake = [adm.next_shed_in(now), adm.refill_wait(now)]
                .into_iter()
                .flatten()
                .min();
            match wake {
                Some(d) => mailbox.recv(Some(d.max(Duration::from_millis(1)))),
                // queued but permanently unadmittable (zero-burst bucket):
                // nothing to time against, so block until new work or
                // shutdown — the drain will serve these waiters
                None => mailbox.recv(None),
            }
        };
        for msg in msgs {
            match msg {
                Message::Request(env) => enqueue(env, &mut adm, &ctx),
                Message::Shutdown => draining = true,
            }
        }
        if draining {
            // seal the inbox so post-shutdown submits fail fast; whatever
            // raced in before the seal still gets served below
            for msg in mailbox.close() {
                if let Message::Request(env) = msg {
                    enqueue(env, &mut adm, &ctx);
                }
            }
        }

        // 2. shed waiters past their deadline (never during drain — the
        // shutdown contract is that every accepted caller is served)
        let now = Instant::now();
        if !draining {
            for (w, err) in adm.shed_expired(now) {
                let env = w.item;
                ctx.stats.queue_sheds.inc();
                ctx.stats.tenants.shed(env.tenant);
                ctx.shard.shed.inc();
                ctx.shard.inflight.sub(1);
                let _ = env.respond.send(Err(anyhow::Error::new(err)));
            }
        }

        // 3. admit up to the live-session cap.  The admission unit is the
        // whole request; one whose n_samples exceeds the remaining
        // headroom is still admitted alone (the cap bounds concurrency,
        // it must not deadlock large requests).
        while live.iter().map(|a| a.sessions.len()).sum::<usize>() < max_live {
            let w = if draining {
                adm.admit_unpaced()
            } else {
                adm.admit(Instant::now())
            };
            let Some(w) = w else { break };
            let env = w.item;
            let admitted_at = Instant::now();
            ctx.stats
                .queue_age
                .record(admitted_at.saturating_duration_since(env.submitted_at));
            // queue residency span: submit -> joining the step batch
            trace::record_between(Stage::Enqueue, env.submitted_at, admitted_at, env.trace_id, 0);
            ctx.stats.tenants.admitted(env.tenant);
            let sessions: Vec<SessionState> = (0..env.request.n_samples)
                .map(|i| rollout.begin_session(&env.request, i as u32))
                .collect();
            live.push(ActiveRequest {
                env,
                sessions,
                steps_done: 0,
                decode_ms: 0.0,
            });
        }

        // 4. advance every live session one decode step, then retire the
        // requests that reached their horizon
        if !live.is_empty() {
            step_live(&mut live, &mut backend, &rollout, &kv_pool, &ctx);
            let mut rest = Vec::with_capacity(live.len());
            for a in live.drain(..) {
                if a.steps_done >= future_steps {
                    retire_request(a, &rollout, &kv_pool, &ctx);
                } else {
                    rest.push(a);
                }
            }
            live = rest;
        }

        // 5. publish load gauges (how /healthz and /vars see this shard)
        ctx.shard.queue_depth.set(adm.len() as u64);
        ctx.shard
            .live_sessions
            .set(live.iter().map(|a| a.sessions.len()).sum::<usize>() as u64);

        if draining && live.is_empty() && adm.is_empty() {
            break;
        }
    }
}

/// Move one incoming envelope into the admission queue, answering
/// immediately-rejectable requests (unknown method, zero samples, queue
/// full) on the spot with their typed error.
fn enqueue(env: Envelope, adm: &mut AdmissionQueue<Envelope>, ctx: &ShardCtx) {
    if !ctx.methods.contains(&env.method) {
        ctx.stats.queue_rejections.inc();
        ctx.shard.rejected.inc();
        ctx.shard.inflight.sub(1);
        let _ = env.respond.send(Err(anyhow!(
            "method '{}' is not deployed on this server",
            env.method.name()
        )));
        return;
    }
    if env.request.n_samples == 0 {
        // a recoverable caller error, failed before it ever queues
        ctx.stats.requests_failed.inc();
        ctx.shard.failed.inc();
        ctx.stats.e2e_latency.record(env.submitted_at.elapsed());
        ctx.shard.inflight.sub(1);
        let _ = env.respond.send(Err(anyhow!(
            "rollout request asks for zero samples — nothing to roll out"
        )));
        return;
    }
    let tenant = env.tenant;
    if let Err((env, err)) = adm.push(env, tenant, Instant::now()) {
        // per-shard backpressure: only this shard's callers see the
        // typed QueueFull; siblings keep serving
        ctx.stats.queue_rejections.inc();
        ctx.stats.tenants.rejected(tenant);
        ctx.shard.rejected.inc();
        ctx.shard.inflight.sub(1);
        let _ = env.respond.send(Err(anyhow::Error::new(err)));
    }
}

/// Advance every live request one decode step: one shared step batch per
/// method, sessions from different requests packed together with
/// per-slot seeds (see [`RolloutEngine::step_seed`]) so results are
/// bit-identical to each request running alone.
fn step_live(
    live: &mut Vec<ActiveRequest>,
    backend: &mut Backend,
    rollout: &RolloutEngine,
    kv_pool: &KvCachePool,
    ctx: &ShardCtx,
) {
    let mut methods: Vec<Method> = live.iter().map(|a| a.env.method).collect();
    methods.sort();
    methods.dedup();
    for method in methods {
        let round_t0 = Instant::now();
        let Some(model) = backend.route(method) else {
            // deployed method with no live replica on this shard: answer
            // every caller instead of wedging the step loop
            let (dead, rest): (Vec<_>, Vec<_>) =
                live.drain(..).partition(|a| a.env.method == method);
            *live = rest;
            for a in dead {
                fail_request(
                    a,
                    anyhow!("method '{}' has no replica on shard {}", method.name(), ctx.id),
                    kv_pool,
                    ctx,
                );
            }
            continue;
        };
        // pack the step batch: every live session of this method, slots
        // of one request contiguous, each slot carrying its request's
        // seed/temperature/trace
        let mut slots: Vec<StepSlot<'_>> = Vec::new();
        for a in live.iter_mut().filter(|a| a.env.method == method) {
            let req = &a.env.request;
            let step = a.steps_done;
            let trace_id = a.env.trace_id;
            for (i, session) in a.sessions.iter_mut().enumerate() {
                slots.push(StepSlot {
                    params: SlotParams {
                        seed: rollout.step_seed(req, step, i),
                        temperature: req.temperature,
                        trace: trace_id,
                    },
                    session,
                });
            }
        }
        if slots.is_empty() {
            continue;
        }
        let real = slots.len();
        match rollout.step_sessions(model.as_ref(), &mut slots, kv_pool) {
            Ok(rep) => {
                drop(slots);
                ctx.stats.batches.inc();
                ctx.shard.batches.inc();
                ctx.stats.padded_slots.add(rep.padded_slots as u64);
                ctx.stats.step_sessions.add(rep.real_slots as u64);
                // attribute decode wall time by slot share so retired
                // requests report a meaningful per-step decode latency
                let per_slot_ms = rep.decode_ms / rep.real_slots.max(1) as f64;
                for a in live.iter_mut().filter(|a| a.env.method == method) {
                    a.steps_done += 1;
                    a.decode_ms += per_slot_ms * a.sessions.len() as f64;
                }
                trace::record_since(Stage::Batch, round_t0, real as u64);
            }
            Err(e) => {
                drop(slots);
                // a step failure poisons every request sharing the batch:
                // fail them all rather than serve half-advanced sessions
                let msg = format!("decode step failed on shard {}: {e:#}", ctx.id);
                let (dead, rest): (Vec<_>, Vec<_>) =
                    live.drain(..).partition(|a| a.env.method == method);
                *live = rest;
                for a in dead {
                    fail_request(a, anyhow!("{msg}"), kv_pool, ctx);
                }
            }
        }
    }
}

/// Retire a request that has advanced all its steps: end its cache
/// sessions, assemble the result, respond.
fn retire_request(a: ActiveRequest, rollout: &RolloutEngine, kv_pool: &KvCachePool, ctx: &ShardCtx) {
    for s in &a.sessions {
        kv_pool.end_session(s.key());
    }
    let decode_ms = a.decode_ms / a.steps_done.max(1) as f64;
    let res = rollout.finish_request(&a.env.request, &a.sessions, decode_ms);
    ctx.stats
        .decode_latency
        .record(Duration::from_secs_f64(a.decode_ms / 1e3));
    ctx.stats.requests_done.inc();
    ctx.shard.done.inc();
    ctx.stats.tenants.done(a.env.tenant);
    ctx.stats.families.record(
        a.env.request.scenario.family,
        &res.min_ade,
        res.collisions as u64,
        res.trajectories.len() as u64,
    );
    ctx.stats.e2e_latency.record(a.env.submitted_at.elapsed());
    ctx.shard.inflight.sub(1);
    let respond_t0 = Instant::now();
    trace::set_trace_id(a.env.trace_id);
    let _ = a.env.respond.send(Ok(res));
    trace::record_since(Stage::Respond, respond_t0, 0);
    trace::set_trace_id(0);
}

/// Fail an admitted request (step error / missing replica): end its
/// cache sessions and answer its caller.
fn fail_request(a: ActiveRequest, err: anyhow::Error, kv_pool: &KvCachePool, ctx: &ShardCtx) {
    for s in &a.sessions {
        kv_pool.end_session(s.key());
    }
    ctx.stats.requests_failed.inc();
    ctx.shard.failed.inc();
    ctx.stats.e2e_latency.record(a.env.submitted_at.elapsed());
    ctx.shard.inflight.sub(1);
    let respond_t0 = Instant::now();
    trace::set_trace_id(a.env.trace_id);
    let _ = a.env.respond.send(Err(err));
    trace::record_since(Stage::Respond, respond_t0, 0);
    trace::set_trace_id(0);
}
