//! SE(2) geometry: poses, composition, relative transforms (paper Sec. II).
//!
//! Mirrors `python/compile/geometry.py` exactly — the Rust attention
//! baselines and the JAX kernels must agree on the group operations, and the
//! integration tests check them against each other through the artifacts.

use crate::linalg::Mat;

/// An SE(2) pose (x, y, theta).  theta is kept wrapped to (-pi, pi].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pose {
    pub x: f64,
    pub y: f64,
    pub theta: f64,
}

/// Wrap an angle to (-pi, pi].
pub fn wrap_angle(t: f64) -> f64 {
    t.sin().atan2(t.cos())
}

impl Pose {
    pub const IDENTITY: Pose = Pose { x: 0.0, y: 0.0, theta: 0.0 };

    pub fn new(x: f64, y: f64, theta: f64) -> Pose {
        Pose { x, y, theta: wrap_angle(theta) }
    }

    /// Group product self * other.
    pub fn compose(&self, other: &Pose) -> Pose {
        let (s, c) = self.theta.sin_cos();
        Pose::new(
            self.x + c * other.x - s * other.y,
            self.y + s * other.x + c * other.y,
            self.theta + other.theta,
        )
    }

    /// Group inverse.
    pub fn inverse(&self) -> Pose {
        let (s, c) = self.theta.sin_cos();
        Pose::new(-c * self.x - s * self.y, s * self.x - c * self.y, -self.theta)
    }

    /// Relative pose self^{-1} * other (paper: p_{n->m}).
    pub fn relative_to(&self, other: &Pose) -> Pose {
        self.inverse().compose(other)
    }

    /// Euclidean distance between positions.
    pub fn dist(&self, other: &Pose) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }

    pub fn radius(&self) -> f64 {
        (self.x * self.x + self.y * self.y).sqrt()
    }

    /// Homogeneous 3x3 representation psi (paper Eq. 8).
    pub fn matrix(&self) -> Mat {
        let (s, c) = self.theta.sin_cos();
        Mat::from_rows(&[
            &[c, -s, self.x],
            &[s, c, self.y],
            &[0.0, 0.0, 1.0],
        ])
    }

    /// Scale x/y by `a`, keep theta — the per-block spatial scaling.
    pub fn scaled(&self, a: f64) -> Pose {
        Pose { x: a * self.x, y: a * self.y, theta: self.theta }
    }

    /// Transform a point expressed in this pose's frame into the parent
    /// frame.
    pub fn transform_point(&self, px: f64, py: f64) -> (f64, f64) {
        let (s, c) = self.theta.sin_cos();
        (self.x + c * px - s * py, self.y + s * px + c * py)
    }
}

/// 2D rotation matrix rho(theta) (paper Eq. 5).
pub fn rot2(theta: f64) -> Mat {
    let (s, c) = theta.sin_cos();
    Mat::from_rows(&[&[c, -s], &[s, c]])
}

/// Rotate a feature pair in place by `theta` (the RoPE primitive).
#[inline]
pub fn rotate_pair(x0: f64, x1: f64, theta: f64) -> (f64, f64) {
    let (s, c) = theta.sin_cos();
    (c * x0 - s * x1, s * x0 + c * x1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_pose(rng: &mut Rng) -> Pose {
        Pose::new(
            rng.range(-3.0, 3.0),
            rng.range(-3.0, 3.0),
            rng.range(-std::f64::consts::PI, std::f64::consts::PI),
        )
    }

    fn assert_pose_close(a: &Pose, b: &Pose, tol: f64) {
        assert!((a.x - b.x).abs() < tol, "{a:?} vs {b:?}");
        assert!((a.y - b.y).abs() < tol, "{a:?} vs {b:?}");
        assert!(wrap_angle(a.theta - b.theta).abs() < tol, "{a:?} vs {b:?}");
    }

    #[test]
    fn identity_laws() {
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let p = rand_pose(&mut rng);
            assert_pose_close(&p.compose(&Pose::IDENTITY), &p, 1e-12);
            assert_pose_close(&Pose::IDENTITY.compose(&p), &p, 1e-12);
        }
    }

    #[test]
    fn inverse_law() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let p = rand_pose(&mut rng);
            assert_pose_close(&p.compose(&p.inverse()), &Pose::IDENTITY, 1e-9);
            assert_pose_close(&p.inverse().compose(&p), &Pose::IDENTITY, 1e-9);
        }
    }

    #[test]
    fn associativity() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let (a, b, c) =
                (rand_pose(&mut rng), rand_pose(&mut rng), rand_pose(&mut rng));
            assert_pose_close(
                &a.compose(&b).compose(&c),
                &a.compose(&b.compose(&c)),
                1e-9,
            );
        }
    }

    #[test]
    fn matrix_is_homomorphism() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let (a, b) = (rand_pose(&mut rng), rand_pose(&mut rng));
            let lhs = a.compose(&b).matrix();
            let rhs = a.matrix().matmul(&b.matrix());
            assert!(lhs.sub(&rhs).max_abs() < 1e-9);
        }
    }

    #[test]
    fn relative_pose_invariance() {
        // p_{n->m} is unchanged under a global frame shift (Fig. 1c).
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let (n, m, z) =
                (rand_pose(&mut rng), rand_pose(&mut rng), rand_pose(&mut rng));
            let rel = n.relative_to(&m);
            let zi = z.inverse();
            let rel_shifted = zi.compose(&n).relative_to(&zi.compose(&m));
            assert_pose_close(&rel, &rel_shifted, 1e-9);
        }
    }

    #[test]
    fn paper_relative_x_formula() {
        // x_{n->m} = (x_m - x_n) cos t_n + (y_m - y_n) sin t_n  (Sec. III-B)
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let (n, m) = (rand_pose(&mut rng), rand_pose(&mut rng));
            let rel = n.relative_to(&m);
            let expect_x = (m.x - n.x) * n.theta.cos() + (m.y - n.y) * n.theta.sin();
            let expect_y = -(m.x - n.x) * n.theta.sin() + (m.y - n.y) * n.theta.cos();
            assert!((rel.x - expect_x).abs() < 1e-9);
            assert!((rel.y - expect_y).abs() < 1e-9);
        }
    }

    #[test]
    fn rotate_pair_matches_matrix() {
        let m = rot2(0.3);
        let (a, b) = rotate_pair(1.0, 2.0, 0.3);
        let v = m.matvec(&[1.0, 2.0]);
        assert!((v[0] - a).abs() < 1e-12 && (v[1] - b).abs() < 1e-12);
    }
}
