//! Incremental decode engine: an append-only cache of pre-projected
//! `phi_k k` / `phi_k v` feature rows for streaming autoregressive rollout
//! (DESIGN.md §10).
//!
//! The paper's factorization (Eq. 19) anchors every projected key/value row
//! to a *single global frame*: unlike pairwise architectures, the rows stay
//! valid as the scene grows, so a decode step only has to
//!
//! 1. [`IncrementalAttention::append`] the newly tokenized frontier tokens
//!    (O(new) projection work),
//! 2. [`IncrementalAttention::attend`] the new queries against the cached
//!    rows through the same flash/online-softmax path as Algorithm 2
//!    ([`super::linear::flash_sdpa`]), and
//! 3. [`IncrementalAttention::evict_front`] rows that slid out of the
//!    history window,
//!
//! instead of re-projecting all `m` context tokens — O(window) → O(new)
//! per step.
//!
//! ## Re-anchoring
//!
//! The cache's reference frame is fixed at construction.  As the rollout
//! advances, token positions drift away from the anchor and eventually
//! leave the |p| <= ~4 band where the Fourier truncation is accurate
//! (paper Fig. 3).  [`IncrementalAttention::re_anchor`] re-centers the
//! *cached features themselves* under a global SE(2) transform `g`
//! (every cached key pose p becomes g∘p) without touching raw k/v:
//!
//! * **se2rep** — exact: psi is a homomorphism, so each 3-block is
//!   left-multiplied by psi(g) (scaled per block).
//! * **rope2d** — exact for translations (the method is not
//!   rotation-equivariant; rotating re-anchors are rejected).
//! * **se2fourier** — the theta pair rotates exactly by rho(g_theta); each
//!   frequency bank is a truncated Fourier series in the quadrature angle
//!   z, and the new bank is `e^{i u_g(z)} * psi(z - g_theta)` — an
//!   argument shift (exact on the truncated series, which is bandlimited
//!   below the 2F-point grid's Nyquist rate) followed by modulation with
//!   the anchor shift's own phase function and re-projection through the
//!   same 2F-point quadrature.  Error is bounded by the series tail beyond
//!   frequency F/2, i.e. the same O(J_{F/2}(r)) envelope as the
//!   factorization itself: negligible (< 1e-6) for |p| <= 2 at F >= 24,
//!   and within the paper's fp16 working band at the production F = 12.
//!
//! Derivation for the frequency banks: a cached X bank stores coefficients
//! A, B of Re/Im of psi(z) = (k0 + i k1) e^{i u_p(z)} with
//! u_p(z) = x cos z + y sin z.  For p' = g∘p,
//! u_{p'}(z) = u_g(z) + u_p(z - g_theta), hence
//! psi'(z) = e^{i u_g(z)} psi(z - g_theta).  The Y bank is the same with
//! u^Y(z) = u^X(z + pi/2).
//!
//! ## Quantized storage tier
//!
//! The cached rows can be stored at a reduced
//! [`crate::config::CachePrecision`] (f16/bf16 codes with per-row
//! scale/offset, [`super::quant`]): [`IncrementalAttention::attend`]
//! dequantizes visible rows on the fly inside the blocked kernel's
//! key-block loop, so no full-width f32 copy of the cache ever exists
//! and resident bytes drop to ~51% of f32 at the paper head (DESIGN.md
//! §14).  Re-anchoring is **quantization-safe**: the transform runs on
//! dequantized values at full f64 table precision and the result is
//! re-encoded once with a freshly computed row scale
//! ([`super::quant::FeatureRows::for_each_row_mut`]), so each re-anchor
//! adds at most one storage rounding (errors accumulate additively, never
//! multiplicatively), and the pose/timestamp bookkeeping that defines the
//! frame is plain f64 — the re-anchor *geometry* is exactly as accurate
//! as on the f32 path no matter how compressed the features are.

use anyhow::{bail, Result};

use crate::config::{CachePrecision, Method, ModelConfig};
use crate::fourier::{basis_fn, quadrature_grid, QuadratureTable};
use crate::geometry::Pose;

use super::kernel::{flash_sdpa_rows, KernelConfig};
use super::linear::proj_dim;
use super::projections as proj;
use super::quant::FeatureRows;
use super::AttnOutput;

/// Static description of one incremental attention head.
#[derive(Clone, Debug)]
pub struct IncrementalConfig {
    pub method: Method,
    /// Per-head feature width d (multiple of 6 for se2fourier, 4 for
    /// rope2d, 3 for se2rep) — same convention as [`super::AttnProblem`].
    pub d: usize,
    /// Fourier basis size F (se2fourier only).
    pub fourier_f: usize,
    /// Spatial scale ladder, cycled across blocks.
    pub scales: Vec<f64>,
    /// Blocked flash-kernel shape for [`IncrementalAttention::attend`]
    /// (bit-stable across `threads`, so cached-decode results do not
    /// depend on the serving host's core count).
    pub kernel: KernelConfig,
    /// Storage precision of the cached `phi_k k` / `phi_k v` rows
    /// (f16/bf16 halve resident bytes; f32 keeps the seed's bit-exact
    /// behavior).  See the module docs for the accuracy argument.
    pub precision: CachePrecision,
}

impl IncrementalConfig {
    /// One per-head incremental engine config derived from a model's
    /// configuration — the consumer of `ModelConfig.kernel`, so the
    /// serving-layer kernel knob (`ServeConfig.kernel`, CLI
    /// `--kernel-threads`, which `Server::start*` copy into each shard's
    /// `ModelConfig`) reaches every cached-row attend built this way.
    pub fn for_model(m: &ModelConfig, method: Method) -> IncrementalConfig {
        IncrementalConfig {
            method,
            d: m.head_dim,
            fourier_f: m.fourier_f,
            scales: m.spatial_scales.clone(),
            kernel: m.kernel,
            precision: m.cache_precision,
        }
    }

    fn validate(&self) {
        assert!(!self.scales.is_empty(), "empty scale ladder");
        match self.method {
            Method::Se2Fourier => assert_eq!(self.d % 6, 0, "d % 6 for se2fourier"),
            Method::Rope2d => assert_eq!(self.d % 4, 0, "d % 4 for rope2d"),
            Method::Se2Rep => assert_eq!(self.d % 3, 0, "d % 3 for se2rep"),
            Method::Abs => {}
        }
    }
}

/// The engine: cached projected rows (at the configured storage
/// precision) plus the poses they were anchored at.
pub struct IncrementalAttention {
    cfg: IncrementalConfig,
    /// Projected per-head width c.
    c: usize,
    /// Algorithm 2 prefactor (c/d)^(1/4), baked into q~ and k~.
    pref: f32,
    /// Cached `phi_k k` rows, row-major (m, c), possibly quantized.
    kt: FeatureRows,
    /// Cached `phi_k v` rows, row-major (m, c), possibly quantized.
    vt: FeatureRows,
    /// Visibility timesteps of the cached rows (never quantized).
    tk: Vec<i32>,
    /// Anchor-frame poses of the cached rows (for drift policy and
    /// re-anchor bookkeeping; raw k/v are *not* retained; never
    /// quantized, so the frame stays exact at any storage precision).
    poses: Vec<Pose>,
    key_scratch: Option<proj::Se2fKeyScratch>,
}

impl IncrementalAttention {
    pub fn new(cfg: IncrementalConfig) -> IncrementalAttention {
        cfg.validate();
        let c = proj_dim(cfg.method, cfg.d, cfg.fourier_f);
        let pref = ((c as f64) / (cfg.d as f64)).powf(0.25) as f32;
        let key_scratch = match cfg.method {
            Method::Se2Fourier => Some(proj::Se2fKeyScratch::new(cfg.fourier_f)),
            _ => None,
        };
        IncrementalAttention {
            kt: FeatureRows::new(cfg.precision, c),
            vt: FeatureRows::new(cfg.precision, c),
            cfg,
            c,
            pref,
            tk: Vec::new(),
            poses: Vec::new(),
            key_scratch,
        }
    }

    /// Storage precision of the cached rows.
    pub fn precision(&self) -> CachePrecision {
        self.cfg.precision
    }

    /// Number of cached context rows.
    pub fn len(&self) -> usize {
        self.tk.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tk.is_empty()
    }

    /// Projected per-head width c of the cached rows.
    pub fn proj_width(&self) -> usize {
        self.c
    }

    /// Resident bytes of the cache (projected rows at their storage
    /// precision, incl. per-row scale/offset when quantized, + timesteps
    /// + poses); equal to
    /// [`crate::attention::memmodel::incremental_cache_bytes`] at this
    /// engine's precision — the one byte model the telemetry gauges
    /// report (regression-tested in `tests/quantized_cache.rs`).
    pub fn resident_bytes(&self) -> usize {
        self.kt.resident_bytes()
            + self.vt.resident_bytes()
            + self.tk.len() * std::mem::size_of::<i32>()
            + self.poses.len() * std::mem::size_of::<Pose>()
    }

    /// Largest |scale * position| over cached rows — the quantity that
    /// must stay inside the paper's |p| <= ~4 accuracy band.  Callers
    /// trigger [`Self::re_anchor`] when this drifts too far.
    pub fn max_scaled_radius(&self) -> f64 {
        let amax = self
            .cfg
            .scales
            .iter()
            .fold(0.0f64, |m, a| m.max(a.abs()));
        self.poses
            .iter()
            .fold(0.0f64, |m, p| m.max(p.radius() * amax))
    }

    /// Project and append `len(t)` new context tokens (Alg. 2 line 2,
    /// restricted to the frontier).  `k`/`v` are row-major (n_new, d).
    /// Rows are projected in f32 and then handed to the storage tier —
    /// a verbatim extend at f32, one row-wise quantization otherwise.
    pub fn append(&mut self, k: &[f32], v: &[f32], poses: &[Pose], t: &[i32]) {
        let (d, c) = (self.cfg.d, self.c);
        let n_new = t.len();
        assert_eq!(k.len(), n_new * d, "k shape");
        assert_eq!(v.len(), n_new * d, "v shape");
        assert_eq!(poses.len(), n_new, "poses shape");
        let scales = &self.cfg.scales;
        let (k_rows, v_rows) = match self.cfg.method {
            Method::Abs => (k.to_vec(), v.to_vec()),
            Method::Rope2d => {
                let mut kr = k.to_vec();
                let mut vr = v.to_vec();
                for (j, p) in poses.iter().enumerate() {
                    proj::rope2d_project(&mut kr[j * c..(j + 1) * c], p, scales);
                    proj::rope2d_project(&mut vr[j * c..(j + 1) * c], p, scales);
                }
                (kr, vr)
            }
            Method::Se2Rep => {
                let mut kr = k.to_vec();
                let mut vr = v.to_vec();
                for (j, p) in poses.iter().enumerate() {
                    proj::se2rep_project_k(&mut kr[j * c..(j + 1) * c], p, scales);
                    proj::se2rep_project_k(&mut vr[j * c..(j + 1) * c], p, scales);
                }
                (kr, vr)
            }
            Method::Se2Fourier => {
                let scratch = self.key_scratch.as_mut().expect("se2f scratch");
                let mut k_row: Vec<f32> = Vec::with_capacity(c);
                let mut v_row: Vec<f32> = Vec::with_capacity(c);
                let mut kr = Vec::with_capacity(n_new * c);
                let mut vr = Vec::with_capacity(n_new * c);
                for (j, p) in poses.iter().enumerate() {
                    proj::se2f_project_kv_with(
                        scratch,
                        &k[j * d..(j + 1) * d],
                        &v[j * d..(j + 1) * d],
                        p,
                        scales,
                        self.pref,
                        &mut k_row,
                        &mut v_row,
                    );
                    kr.extend_from_slice(&k_row);
                    vr.extend_from_slice(&v_row);
                }
                (kr, vr)
            }
        };
        self.kt.push_rows(&k_rows);
        self.vt.push_rows(&v_rows);
        self.tk.extend_from_slice(t);
        self.poses.extend_from_slice(poses);
    }

    /// Drop the `n` oldest cached rows (sliding-window eviction).
    pub fn evict_front(&mut self, n: usize) {
        let n = n.min(self.len());
        self.kt.drain_front(n);
        self.vt.drain_front(n);
        self.tk.drain(..n);
        self.poses.drain(..n);
    }

    /// Attend `len(tq)` new queries (row-major (n, d), poses in the
    /// cache's anchor frame) against every cached row, through the same
    /// flash/online-softmax path as Algorithm 2.
    pub fn attend(&self, q: &[f32], pose_q: &[Pose], tq: &[i32]) -> AttnOutput {
        let (d, c, f) = (self.cfg.d, self.c, self.cfg.fourier_f);
        let n = tq.len();
        assert_eq!(q.len(), n * d, "q shape");
        assert_eq!(pose_q.len(), n, "pose_q shape");
        let scales = &self.cfg.scales;

        // ---- query pre-projection (mirrors linear::attention) ----------
        let mut qt = vec![0.0f32; n * c];
        match self.cfg.method {
            Method::Abs => qt.copy_from_slice(q),
            Method::Rope2d => {
                qt.copy_from_slice(q);
                for i in 0..n {
                    proj::rope2d_project(&mut qt[i * c..(i + 1) * c], &pose_q[i], scales);
                }
            }
            Method::Se2Rep => {
                qt.copy_from_slice(q);
                for i in 0..n {
                    proj::se2rep_project_q(&mut qt[i * c..(i + 1) * c], &pose_q[i], scales);
                }
            }
            Method::Se2Fourier => {
                let mut row: Vec<f32> = Vec::with_capacity(c);
                for i in 0..n {
                    proj::se2f_project_q(
                        &q[i * d..(i + 1) * d],
                        &pose_q[i],
                        scales,
                        f,
                        self.pref,
                        &mut row,
                    );
                    qt[i * c..(i + 1) * c].copy_from_slice(&row);
                }
            }
        }

        // ---- flash SDPA against the cached rows (blocked kernel; rows
        // dequantized on the fly inside the key-block loop when the
        // storage tier is f16/bf16) ---------------------------------------
        let eff_scale = match self.cfg.method {
            Method::Se2Fourier => 1.0 / (c as f64).sqrt(),
            _ => 1.0 / (d as f64).sqrt(),
        };
        let mut ot = vec![0.0f32; n * c];
        let kernel_scratch = flash_sdpa_rows(
            &qt,
            self.kt.as_kv(),
            self.vt.as_kv(),
            tq,
            &self.tk,
            c,
            eff_scale,
            &mut ot,
            &self.cfg.kernel,
        );

        // ---- post-projection (Alg. 2 line 4) ----------------------------
        let mut out = vec![0.0f32; n * d];
        match self.cfg.method {
            Method::Abs => out.copy_from_slice(&ot),
            Method::Rope2d => {
                out.copy_from_slice(&ot);
                for i in 0..n {
                    let neg = Pose {
                        x: -pose_q[i].x,
                        y: -pose_q[i].y,
                        theta: 0.0,
                    };
                    proj::rope2d_project(&mut out[i * d..(i + 1) * d], &neg, scales);
                }
            }
            Method::Se2Rep => {
                out.copy_from_slice(&ot);
                for i in 0..n {
                    proj::se2rep_unproject_o(&mut out[i * d..(i + 1) * d], &pose_q[i], scales);
                }
            }
            Method::Se2Fourier => {
                let mut row: Vec<f32> = Vec::with_capacity(d);
                for i in 0..n {
                    proj::se2f_unproject_o(&ot[i * c..(i + 1) * c], &pose_q[i], scales, f, &mut row);
                    out[i * d..(i + 1) * d].copy_from_slice(&row);
                }
            }
        }

        AttnOutput {
            out,
            // transients only: projected queries + projected outputs +
            // per-thread kernel scratch; the cache itself is resident
            // state, reported by resident_bytes().
            peak_temp_bytes: (qt.len() + ot.len()) * std::mem::size_of::<f32>()
                + kernel_scratch,
        }
    }

    /// Re-center the cache under a global SE(2) transform: every cached
    /// key pose p becomes g∘p, and the cached feature rows are rewritten
    /// to what projecting at g∘p would have produced — without raw k/v.
    /// Queries must subsequently be expressed in the new frame.
    ///
    /// On quantized storage the rewrite is quantization-safe (module
    /// docs): rows are dequantized, transformed at full precision, and
    /// re-encoded once against a fresh per-row scale, so repeated
    /// re-anchors add at most one storage rounding each — they never
    /// compound multiplicatively — and the pose update below is exact
    /// f64 at every precision.
    pub fn re_anchor(&mut self, g: &Pose) -> Result<()> {
        match self.cfg.method {
            Method::Abs => {}
            Method::Rope2d => {
                if g.theta.abs() > 1e-12 {
                    bail!(
                        "rope2d caches support translation-only re-anchoring \
                         (got rotation {:.3} rad): the method is not \
                         rotation-equivariant",
                        g.theta
                    );
                }
                let scales = self.cfg.scales.clone();
                self.kt
                    .for_each_row_mut(|row| proj::rope2d_project(row, g, &scales));
                self.vt
                    .for_each_row_mut(|row| proj::rope2d_project(row, g, &scales));
            }
            Method::Se2Rep => {
                // psi(g∘p) = psi(g) psi(p): exact left multiplication,
                // which is precisely the key projection applied at g.
                let scales = self.cfg.scales.clone();
                self.kt
                    .for_each_row_mut(|row| proj::se2rep_project_k(row, g, &scales));
                self.vt
                    .for_each_row_mut(|row| proj::se2rep_project_k(row, g, &scales));
            }
            Method::Se2Fourier => self.re_anchor_se2f(g),
        }
        for p in self.poses.iter_mut() {
            *p = g.compose(p);
        }
        Ok(())
    }

    /// The se2fourier feature-space re-anchor (see module docs): exact
    /// rotation of the theta pair; per frequency bank, argument shift by
    /// -g_theta, modulation by the anchor shift's phase, and re-projection
    /// through the 2F-point quadrature.
    fn re_anchor_se2f(&mut self, g: &Pose) {
        let f = self.cfg.fourier_f;
        let w = proj::se2f_block_width(f);
        let nb = self.cfg.d / 6;
        let scales = &self.cfg.scales;
        let table = QuadratureTable::new(f);
        let grid = quadrature_grid(f);
        let (st, ct) = g.theta.sin_cos();

        // Token-independent tables: the basis evaluated on the shifted
        // grid, and the modulation phase per (scale, axis, grid point).
        let mut gshift = vec![0.0f64; 2 * f * f];
        for (j, &z) in grid.iter().enumerate() {
            for i in 0..f {
                gshift[j * f + i] = basis_fn(i, z - g.theta);
            }
        }
        let ns = scales.len();
        // modulation[(s * 2 + axis) * 2F + j] = (sin, cos) of u_g at z_j
        let mut mod_sin = vec![0.0f64; ns * 2 * 2 * f];
        let mut mod_cos = vec![0.0f64; ns * 2 * 2 * f];
        for (s, &a) in scales.iter().enumerate() {
            let (gx, gy) = (a * g.x, a * g.y);
            for (j, &z) in grid.iter().enumerate() {
                let (sz, cz) = z.sin_cos();
                let ux = gx * cz + gy * sz;
                let uy = -gx * sz + gy * cz;
                let (sx, cx) = ux.sin_cos();
                let (sy, cy) = uy.sin_cos();
                mod_sin[(s * 2) * 2 * f + j] = sx;
                mod_cos[(s * 2) * 2 * f + j] = cx;
                mod_sin[(s * 2 + 1) * 2 * f + j] = sy;
                mod_cos[(s * 2 + 1) * 2 * f + j] = cy;
            }
        }

        let mut na = vec![0.0f64; f];
        let mut nb_acc = vec![0.0f64; f];
        // One row-wise transform applied through the storage tier: on
        // quantized rows this dequantizes, runs the f64 table math below,
        // and re-encodes once with a fresh per-row scale — the
        // quantization-safe formulation (module docs).
        let mut transform = |row: &mut [f32]| {
            for jb in 0..nb {
                let s = jb % ns;
                let blk = &mut row[jb * w..(jb + 1) * w];
                // the two frequency banks: X at offset 0, Y at 2F
                for (axis, off) in [(0usize, 0usize), (1, 2 * f)] {
                    let msin = &mod_sin[(s * 2 + axis) * 2 * f..(s * 2 + axis + 1) * 2 * f];
                    let mcos = &mod_cos[(s * 2 + axis) * 2 * f..(s * 2 + axis + 1) * 2 * f];
                    na.iter_mut().for_each(|x| *x = 0.0);
                    nb_acc.iter_mut().for_each(|x| *x = 0.0);
                    for j in 0..2 * f {
                        let gs = &gshift[j * f..(j + 1) * f];
                        let mut re = 0.0f64;
                        let mut im = 0.0f64;
                        for i in 0..f {
                            re += blk[off + i] as f64 * gs[i];
                            im += blk[off + f + i] as f64 * gs[i];
                        }
                        let (su, cu) = (msin[j], mcos[j]);
                        let re2 = cu * re - su * im;
                        let im2 = su * re + cu * im;
                        let wrow = &table.weights[j * f..(j + 1) * f];
                        for i in 0..f {
                            na[i] += re2 * wrow[i];
                            nb_acc[i] += im2 * wrow[i];
                        }
                    }
                    for i in 0..f {
                        blk[off + i] = na[i] as f32;
                        blk[off + f + i] = nb_acc[i] as f32;
                    }
                }
                // theta pair: rho(g_theta + theta_p) = rho(g_theta) rho(theta_p)
                let (x0, x1) = (blk[4 * f] as f64, blk[4 * f + 1] as f64);
                blk[4 * f] = (ct * x0 - st * x1) as f32;
                blk[4 * f + 1] = (st * x0 + ct * x1) as f32;
            }
        };
        self.kt.for_each_row_mut(&mut transform);
        self.vt.for_each_row_mut(&mut transform);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{linear, AttnProblem};
    use crate::prng::Rng;
    use crate::proplite::{all_close_f32, check};

    fn rand_pose(rng: &mut Rng, r: f64) -> Pose {
        Pose::new(
            rng.range(-r, r),
            rng.range(-r, r),
            rng.range(-3.1, 3.1),
        )
    }

    /// Materialize a store's rows as f32 (tests compare row contents
    /// across engines regardless of the storage representation).
    fn dump(rows: &FeatureRows) -> Vec<f32> {
        let mut out = vec![0.0f32; rows.len() * rows.width()];
        rows.read_all_into(&mut out);
        out
    }

    fn rand_data(rng: &mut Rng, n: usize, d: usize, r: f64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<Pose>, Vec<i32>) {
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..n).map(|_| rand_pose(rng, r)).collect();
        let t: Vec<i32> = (0..n).map(|_| rng.int_range(0, 3) as i32).collect();
        (q, k, v, poses, t)
    }

    /// `ModelConfig.kernel` (the ServeConfig/CLI plumbing target) must
    /// reach the engine built from it.
    #[test]
    fn for_model_threads_the_kernel_config_through() {
        let mut m = ModelConfig {
            spatial_scales: vec![1.0, 0.5],
            ..ModelConfig::synthetic()
        };
        m.kernel = KernelConfig::fixed(16, 4, 2);
        let cfg = IncrementalConfig::for_model(&m, Method::Se2Fourier);
        assert_eq!(cfg.kernel, KernelConfig::fixed(16, 4, 2));
        assert_eq!(cfg.d, 48);
        assert_eq!(cfg.fourier_f, 12);
        assert_eq!(cfg.scales, vec![1.0, 0.5]);
        // and the engine accepts it
        let eng = IncrementalAttention::new(cfg);
        assert_eq!(eng.proj_width(), (4 * 12 + 2) * 8);
    }

    /// Chunked append + attend reproduces Algorithm 2 on the same inputs
    /// for every method (the ops are literally the same, in the same
    /// order, so the tolerance is tight).
    #[test]
    fn incremental_matches_linear_all_methods() {
        let scales = vec![1.0, 0.5];
        let mut rng = Rng::new(41);
        for (method, d) in [
            (Method::Abs, 8),
            (Method::Rope2d, 8),
            (Method::Se2Rep, 9),
            (Method::Se2Fourier, 12),
        ] {
            let n = 6;
            let m = 17;
            let (q, _, _, pq, tq) = rand_data(&mut rng, n, d, 1.5);
            let (_, k, v, pk, tk) = rand_data(&mut rng, m, d, 1.5);
            let p = AttnProblem {
                method,
                d,
                fourier_f: 16,
                scales: &scales,
                q: &q,
                k: &k,
                v: &v,
                pose_q: &pq,
                pose_k: &pk,
                tq: &tq,
                tk: &tk,
            };
            let want = linear::attention(&p).out;

            let mut eng = IncrementalAttention::new(IncrementalConfig {
                method,
                d,
                fourier_f: 16,
                scales: scales.clone(),
                kernel: KernelConfig::default(),
                precision: CachePrecision::F32,
            });
            // append in three uneven chunks, as a rollout would
            for (lo, hi) in [(0usize, 5usize), (5, 6), (6, m)] {
                eng.append(
                    &k[lo * d..hi * d],
                    &v[lo * d..hi * d],
                    &pk[lo..hi],
                    &tk[lo..hi],
                );
            }
            assert_eq!(eng.len(), m);
            let got = eng.attend(&q, &pq, &tq).out;
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!((a - b).abs() < 1e-6, "{method:?} [{i}]: {a} vs {b}");
            }
        }
    }

    /// Sliding-window eviction leaves a cache identical to one built from
    /// the retained suffix only.
    #[test]
    fn eviction_matches_suffix_recompute() {
        let scales = vec![1.0, 0.5];
        let mut rng = Rng::new(42);
        let (d, f, m, evict) = (12usize, 12usize, 20usize, 7usize);
        let (q, k, v, pk, tk) = rand_data(&mut rng, m, d, 1.5);
        let n = 4;
        let pq = &pk[..n];
        let tq = vec![10i32; n];

        let cfg = IncrementalConfig {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales: scales.clone(),
            kernel: KernelConfig::default(),
            precision: CachePrecision::F32,
        };
        let mut eng = IncrementalAttention::new(cfg.clone());
        eng.append(&k, &v, &pk, &tk);
        eng.evict_front(evict);
        assert_eq!(eng.len(), m - evict);

        let mut suffix = IncrementalAttention::new(cfg);
        suffix.append(
            &k[evict * d..],
            &v[evict * d..],
            &pk[evict..],
            &tk[evict..],
        );
        let a = eng.attend(&q[..n * d], pq, &tq).out;
        let b = suffix.attend(&q[..n * d], pq, &tq).out;
        assert_eq!(a, b, "evicted cache must equal suffix-built cache");
        assert_eq!(eng.resident_bytes(), suffix.resident_bytes());
    }

    /// The se2fourier feature-space re-anchor reproduces a fresh
    /// projection at the shifted poses to Fourier-tail accuracy.
    #[test]
    fn se2f_re_anchor_matches_fresh_projection() {
        check("se2f re-anchor == fresh projection", 10, |rng| {
            let (d, f) = (12usize, 24usize);
            let scales = vec![1.0, 0.5];
            let m = 5;
            let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let poses: Vec<Pose> = (0..m).map(|_| rand_pose(rng, 1.2)).collect();
            let t = vec![0i32; m];
            let g = rand_pose(rng, 0.8);

            let cfg = IncrementalConfig {
                method: Method::Se2Fourier,
                d,
                fourier_f: f,
                scales: scales.clone(),
                kernel: KernelConfig::default(),
                precision: CachePrecision::F32,
            };
            let mut eng = IncrementalAttention::new(cfg.clone());
            eng.append(&k, &v, &poses, &t);
            eng.re_anchor(&g).map_err(|e| e.to_string())?;

            let shifted: Vec<Pose> = poses.iter().map(|p| g.compose(p)).collect();
            let mut fresh = IncrementalAttention::new(cfg);
            fresh.append(&k, &v, &shifted, &t);

            all_close_f32(&dump(&eng.kt), &dump(&fresh.kt), 1e-5, "re-anchored k rows")?;
            all_close_f32(&dump(&eng.vt), &dump(&fresh.vt), 1e-5, "re-anchored v rows")
        });
    }

    /// Attention outputs are invariant under re-anchoring cache + queries
    /// by the same global transform (the paper's Eq. 2, streamed).
    #[test]
    fn outputs_invariant_under_re_anchor() {
        check("re-anchor invariance", 8, |rng| {
            let scales = vec![1.0, 0.5];
            for (method, d, f) in [(Method::Se2Rep, 9usize, 8usize), (Method::Se2Fourier, 12, 24)] {
                let (n, m) = (4usize, 12usize);
                let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
                let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
                let pk: Vec<Pose> = (0..m).map(|_| rand_pose(rng, 1.2)).collect();
                let pq: Vec<Pose> = (0..n).map(|_| rand_pose(rng, 1.2)).collect();
                let tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, 3) as i32).collect();
                let tq = vec![5i32; n];
                let g = rand_pose(rng, 0.8);

                let mut eng = IncrementalAttention::new(IncrementalConfig {
                    method,
                    d,
                    fourier_f: f,
                    scales: scales.clone(),
                    kernel: KernelConfig::default(),
                    precision: CachePrecision::F32,
                });
                eng.append(&k, &v, &pk, &tk);
                let before = eng.attend(&q, &pq, &tq).out;
                eng.re_anchor(&g).map_err(|e| e.to_string())?;
                let pq_shifted: Vec<Pose> = pq.iter().map(|p| g.compose(p)).collect();
                let after = eng.attend(&q, &pq_shifted, &tq).out;
                all_close_f32(&before, &after, 1e-5, &format!("{method:?} invariance"))?;
            }
            Ok(())
        });
    }

    /// Two successive re-anchors compose like a single one by the product
    /// transform.
    #[test]
    fn re_anchor_composes() {
        let mut rng = Rng::new(77);
        let (d, f) = (6usize, 24usize);
        let scales = vec![1.0];
        let m = 4;
        let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let v = k.clone();
        let poses: Vec<Pose> = (0..m).map(|_| rand_pose(&mut rng, 1.0)).collect();
        let t = vec![0i32; m];
        let g1 = rand_pose(&mut rng, 0.5);
        let g2 = rand_pose(&mut rng, 0.5);

        let cfg = IncrementalConfig {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales,
            kernel: KernelConfig::default(),
            precision: CachePrecision::F32,
        };
        let mut seq = IncrementalAttention::new(cfg.clone());
        seq.append(&k, &v, &poses, &t);
        seq.re_anchor(&g1).unwrap();
        seq.re_anchor(&g2).unwrap();

        let mut once = IncrementalAttention::new(cfg);
        once.append(&k, &v, &poses, &t);
        once.re_anchor(&g2.compose(&g1)).unwrap();

        for (a, b) in dump(&seq.kt).iter().zip(dump(&once.kt).iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for (pa, pb) in seq.poses.iter().zip(once.poses.iter()) {
            assert!(pa.dist(pb) < 1e-9);
        }
    }

    /// rope2d: translation-only re-anchors are exact; rotations rejected.
    #[test]
    fn rope2d_re_anchor_translation_only() {
        let mut rng = Rng::new(5);
        let (d, m) = (8usize, 6usize);
        let scales = vec![1.0, 0.25];
        let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..m).map(|_| rand_pose(&mut rng, 2.0)).collect();
        let t = vec![0i32; m];
        let cfg = IncrementalConfig {
            method: Method::Rope2d,
            d,
            fourier_f: 4,
            scales: scales.clone(),
            kernel: KernelConfig::default(),
            precision: CachePrecision::F32,
        };
        let mut eng = IncrementalAttention::new(cfg.clone());
        eng.append(&k, &v, &poses, &t);

        let g = Pose::new(0.7, -0.3, 0.0);
        eng.re_anchor(&g).unwrap();
        let shifted: Vec<Pose> = poses.iter().map(|p| g.compose(p)).collect();
        let mut fresh = IncrementalAttention::new(cfg);
        fresh.append(&k, &v, &shifted, &t);
        for (a, b) in dump(&eng.kt).iter().zip(dump(&fresh.kt).iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }

        assert!(eng.re_anchor(&Pose::new(0.0, 0.0, 0.5)).is_err());
    }

    /// A quantized engine fed the same stream tracks the f32 engine
    /// within the storage rounding, halves (–ish) resident bytes, and
    /// evicts/attends through the same paths.
    #[test]
    fn quantized_engine_tracks_f32_and_shrinks_bytes() {
        let scales = vec![1.0, 0.5];
        let mut rng = Rng::new(1717);
        let (d, f, m, n) = (12usize, 16usize, 24usize, 5usize);
        let (q, _, _, pq, tq) = rand_data(&mut rng, n, d, 1.5);
        let (_, k, v, pk, tk) = rand_data(&mut rng, m, d, 1.5);
        let build = |precision: CachePrecision| {
            let mut eng = IncrementalAttention::new(IncrementalConfig {
                method: Method::Se2Fourier,
                d,
                fourier_f: f,
                scales: scales.clone(),
                kernel: KernelConfig::default(),
                precision,
            });
            eng.append(&k, &v, &pk, &tk);
            eng.evict_front(3);
            eng
        };
        let exact = build(CachePrecision::F32);
        let want = exact.attend(&q, &pq, &tq).out;
        for (precision, tol) in [(CachePrecision::F16, 1e-2f32), (CachePrecision::Bf16, 5e-2)] {
            let qeng = build(precision);
            assert_eq!(qeng.precision(), precision);
            assert_eq!(qeng.len(), exact.len());
            let got = qeng.attend(&q, &pq, &tq).out;
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!((a - b).abs() < tol, "{precision:?} [{i}]: {a} vs {b}");
            }
            let ratio = qeng.resident_bytes() as f64 / exact.resident_bytes() as f64;
            assert!(ratio <= 0.60, "{precision:?} byte ratio {ratio}");
        }
    }

    /// Drift bookkeeping: appending far-out tokens raises the radius, a
    /// re-centering re-anchor brings it back down.
    #[test]
    fn drift_radius_tracks_re_anchor() {
        let mut rng = Rng::new(6);
        let d = 6;
        let cfg = IncrementalConfig {
            method: Method::Se2Fourier,
            d,
            fourier_f: 8,
            scales: vec![1.0, 0.5],
            kernel: KernelConfig::default(),
            precision: CachePrecision::F32,
        };
        let mut eng = IncrementalAttention::new(cfg);
        let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        eng.append(&k, &k, &[Pose::new(3.0, 0.0, 0.2)], &[0]);
        assert!((eng.max_scaled_radius() - 3.0).abs() < 1e-9);
        // recenter onto the token
        eng.re_anchor(&Pose::new(-3.0, 0.0, 0.0)).unwrap();
        assert!(eng.max_scaled_radius() < 1e-9);
    }
}
