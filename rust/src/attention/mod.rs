//! CPU attention library: the paper's Algorithm 1 (quadratic memory) and
//! Algorithm 2 (linear memory) for all four relative-attention methods.
//!
//! These native implementations serve three purposes:
//! 1. **Oracle** — the quadratic Algorithm 1 is the exactness reference the
//!    AOT artifacts are integration-tested against.
//! 2. **Baseline** — the benches compare linear vs quadratic wall-clock and
//!    peak memory on identical inputs (paper's headline claim).
//! 3. **Fallback** — the coordinator can score small scenes without PJRT.
//!
//! Data layout: row-major `[N, d]` f32 slices, poses as `&[Pose]`,
//! visibility timesteps as `&[i32]` (see the flash kernel's masking rule).
//!
//! Cached feature rows (the incremental decode engine and the serving
//! tokenization cache) can additionally be stored at a reduced
//! [`crate::config::CachePrecision`] (f16/bf16 with per-row
//! scale/offset, [`quant`]); the blocked kernel dequantizes them on the
//! fly and [`memmodel`] prices both precisions.

pub mod incremental;
pub mod kernel;
pub mod linear;
pub mod memmodel;
pub mod projections;
pub mod quadratic;
pub mod quant;

use crate::config::Method;
use crate::geometry::Pose;

/// Shared description of one attention call.
#[derive(Clone, Debug)]
pub struct AttnProblem<'a> {
    pub method: Method,
    /// Per-head feature width d (multiple of 6 for se2fourier, 4 for
    /// rope2d, 3 for se2rep).
    pub d: usize,
    /// Fourier basis size F (se2fourier only).
    pub fourier_f: usize,
    /// Spatial scale ladder, cycled across blocks.
    pub scales: &'a [f64],
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub pose_q: &'a [Pose],
    pub pose_k: &'a [Pose],
    /// Visibility timesteps; token n sees token m iff tq[n] >= tk[m].
    pub tq: &'a [i32],
    pub tk: &'a [i32],
}

impl<'a> AttnProblem<'a> {
    pub fn n(&self) -> usize {
        self.pose_q.len()
    }

    pub fn m(&self) -> usize {
        self.pose_k.len()
    }

    pub fn validate(&self) {
        let (n, m, d) = (self.n(), self.m(), self.d);
        assert_eq!(self.q.len(), n * d, "q shape");
        assert_eq!(self.k.len(), m * d, "k shape");
        assert_eq!(self.v.len(), m * d, "v shape");
        assert_eq!(self.tq.len(), n, "tq shape");
        assert_eq!(self.tk.len(), m, "tk shape");
        match self.method {
            Method::Se2Fourier => assert_eq!(d % 6, 0, "d % 6 for se2fourier"),
            Method::Rope2d => assert_eq!(d % 4, 0, "d % 4 for rope2d"),
            Method::Se2Rep => assert_eq!(d % 3, 0, "d % 3 for se2rep"),
            Method::Abs => {}
        }
    }

    /// Per-block scale for block index j.
    pub fn scale_for(&self, j: usize) -> f64 {
        self.scales[j % self.scales.len()]
    }
}

/// Result wrapper so benches can also inspect peak temporary bytes.
pub struct AttnOutput {
    pub out: Vec<f32>,
    /// Bytes of the largest transient buffer the algorithm materialized
    /// (the quantity Fig-of-merit for linear vs quadratic memory).
    pub peak_temp_bytes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    pub(crate) fn random_problem_data(
        rng: &mut Rng,
        n: usize,
        m: usize,
        d: usize,
        rmax: f64,
        tmax: i64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<Pose>, Vec<Pose>, Vec<i32>, Vec<i32>) {
        let gen_vec = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        let gen_poses = |rng: &mut Rng, len: usize| -> Vec<Pose> {
            (0..len)
                .map(|_| {
                    Pose::new(
                        rng.range(-rmax, rmax),
                        rng.range(-rmax, rmax),
                        rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                    )
                })
                .collect()
        };
        let q = gen_vec(rng, n * d);
        let k = gen_vec(rng, m * d);
        let v = gen_vec(rng, m * d);
        let pq = gen_poses(rng, n);
        let pk = gen_poses(rng, m);
        let tq: Vec<i32> = (0..n).map(|_| rng.int_range(0, tmax) as i32).collect();
        let tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, tmax) as i32).collect();
        (q, k, v, pq, pk, tq, tk)
    }

    /// Algorithm 2 == Algorithm 1 exactly for the factorizable methods,
    /// to Fourier tolerance for se2fourier — the Rust mirror of the
    /// Python test suite's core check.
    #[test]
    fn linear_matches_quadratic_all_methods() {
        let scales = [1.0, 0.5];
        let mut rng = Rng::new(99);
        for (method, d, tol) in [
            (Method::Abs, 8, 1e-5),
            (Method::Rope2d, 8, 1e-4),
            (Method::Se2Rep, 9, 1e-4),
            (Method::Se2Fourier, 12, 5e-3),
        ] {
            let (q, k, v, pq, pk, tq, tk) =
                random_problem_data(&mut rng, 10, 14, d, 1.5, 3);
            let p = AttnProblem {
                method,
                d,
                fourier_f: 16,
                scales: &scales,
                q: &q,
                k: &k,
                v: &v,
                pose_q: &pq,
                pose_k: &pk,
                tq: &tq,
                tk: &tk,
            };
            let o1 = quadratic::attention(&p);
            let o2 = linear::attention(&p);
            for (i, (a, b)) in o1.out.iter().zip(o2.out.iter()).enumerate() {
                assert!(
                    (a - b).abs() < tol,
                    "{method:?} [{i}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn linear_memory_is_actually_linear() {
        let scales = [1.0];
        let mut rng = Rng::new(100);
        let mut peaks = Vec::new();
        for n in [32usize, 64, 128] {
            let (q, k, v, pq, pk, tq, tk) =
                random_problem_data(&mut rng, n, n, 12, 1.0, 3);
            let p = AttnProblem {
                method: Method::Se2Fourier,
                d: 12,
                fourier_f: 8,
                scales: &scales,
                q: &q,
                k: &k,
                v: &v,
                pose_q: &pq,
                pose_k: &pk,
                tq: &tq,
                tk: &tk,
            };
            peaks.push(linear::attention(&p).peak_temp_bytes as f64 / n as f64);
        }
        // bytes-per-token roughly constant for the linear algorithm
        assert!(peaks[2] < peaks[0] * 1.5, "{peaks:?}");
        // while the quadratic algorithm grows linearly in bytes-per-token:
        // past the crossover (N*8 bytes/token vs 4c*4 bytes/token) the
        // quadratic transient dominates.
        let n = 1024;
        let (q, k, v, pq, pk, tq, tk) =
            random_problem_data(&mut rng, n, n, 12, 1.0, 3);
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d: 12,
            fourier_f: 8,
            scales: &scales,
            q: &q,
            k: &k,
            v: &v,
            pose_q: &pq,
            pose_k: &pk,
            tq: &tq,
            tk: &tk,
        };
        let quad = quadratic::attention(&p).peak_temp_bytes as f64 / n as f64;
        assert!(quad > peaks[2] * 4.0, "quad {quad} vs lin {}", peaks[2]);
    }
}
