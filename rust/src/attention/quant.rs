//! Quantized storage tier for cached feature rows (DESIGN.md §14).
//!
//! Long rollouts make the cached per-token feature rows — the incremental
//! decode engine's projected `phi_k k` / `phi_k v` rows
//! ([`super::incremental::IncrementalAttention`]) and the per-session
//! tokenization cache's agent-step rows
//! ([`crate::coordinator::kvcache::WindowCache`]) — the dominant resident
//! state of a serving shard, so cache **bytes**, not compute, bound how
//! many concurrent sessions a shard holds.  This module halves
//! bytes-per-row by storing rows as 16-bit codes behind a per-row
//! scale/offset (block floating point):
//!
//! ```text
//! x_i  ≈  offset + scale * decode16(code_i),      code_i = encode16((x_i - offset) / scale)
//! ```
//!
//! with `offset` the row midpoint and `scale` the row half-range, so the
//! normalized values fill `[-1, 1]` where both codecs keep their full
//! mantissa.  The absolute error of a stored value is bounded by
//! `scale * eps` with `eps` = [`CachePrecision::unit_rounding`]
//! (2^-11 for f16, 2^-8 for bf16).
//!
//! Three invariants the rest of the system relies on:
//!
//! * **f32 is bit-exact** — [`FeatureRows`] at
//!   [`CachePrecision::F32`] stores raw `f32` and reads it back verbatim,
//!   so every existing exact-equality test keeps holding on the default
//!   path.
//! * **Reads are O(c)** — the flash kernel dequantizes one row at a time
//!   into per-thread scratch ([`KvRowSource::row`]); no full-cache f32
//!   copy is ever materialized, preserving the linear-memory claim.
//! * **Geometry is never quantized** — poses and timestamps stay exact,
//!   so SE(2) re-anchoring remains an exact frame operation; only feature
//!   mantissas round (the GoRela-style invariance argument survives
//!   compression — see `re_anchor` in [`super::incremental`]).

use crate::config::CachePrecision;

// ---------------------------------------------------------------------------
// f32 <-> f16 / bf16 bit codecs (no `half` crate: the container is offline)
// ---------------------------------------------------------------------------

/// Round an `f32` to IEEE binary16 bits (round-to-nearest-even, with
/// overflow to infinity and graceful subnormal/zero handling).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (quantized caches never store these, but the codec is
        // total): preserve the class, force a quiet-NaN payload bit
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent, rebased to f16's bias of 15
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow -> +-inf
    }
    if e16 <= 0 {
        // subnormal or underflow-to-zero: shift the (implicit-1) mantissa
        if e16 < -10 {
            return sign; // +-0
        }
        let m = mant | 0x0080_0000; // restore the implicit leading 1
        let shift = (14 - e16) as u32; // bits dropped from the 24-bit mantissa
        let half = 1u32 << (shift - 1);
        let rounded = (m + half - 1 + ((m >> shift) & 1)) >> shift; // RNE
        return sign | rounded as u16;
    }
    // normal: keep 10 mantissa bits, round-to-nearest-even on the rest
    let half = 0x0000_0fff + ((mant >> 13) & 1);
    let rounded = mant + half;
    if rounded & 0x0080_0000 != 0 {
        // mantissa rollover bumps the exponent
        let e16 = e16 + 1;
        if e16 >= 0x1f {
            return sign | 0x7c00;
        }
        return sign | ((e16 as u16) << 10);
    }
    sign | ((e16 as u16) << 10) | ((rounded >> 13) as u16)
}

/// Decode IEEE binary16 bits to `f32` (exact — every f16 value is
/// representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else if exp == 0 {
        if mant == 0 {
            sign // +-0
        } else {
            // subnormal: value = mant * 2^-24.  With p the top set bit,
            // that is (1 + rest/2^p) * 2^(p-24), i.e. f32 biased
            // exponent p + 103 and mantissa rest << (23 - p).
            let p = 31 - mant.leading_zeros();
            let rest = mant ^ (1 << p);
            sign | ((p + 103) << 23) | (rest << (23 - p))
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an `f32` to bfloat16 bits (truncate the low 16 mantissa bits
/// with round-to-nearest-even).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep NaN a NaN after truncation
        return ((bits >> 16) as u16) | 0x0040;
    }
    let half = 0x0000_7fff + ((bits >> 16) & 1);
    ((bits + half) >> 16) as u16
}

/// Decode bfloat16 bits to `f32` (exact: bf16 is f32's top half).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[inline]
fn encode(codec: CachePrecision, y: f32) -> u16 {
    match codec {
        CachePrecision::F16 => f32_to_f16_bits(y),
        CachePrecision::Bf16 => f32_to_bf16_bits(y),
        CachePrecision::F32 => unreachable!("f32 rows are stored raw"),
    }
}

#[inline]
fn decode(codec: CachePrecision, b: u16) -> f32 {
    match codec {
        CachePrecision::F16 => f16_bits_to_f32(b),
        CachePrecision::Bf16 => bf16_bits_to_f32(b),
        CachePrecision::F32 => unreachable!("f32 rows are stored raw"),
    }
}

// ---------------------------------------------------------------------------
// Quantized row store
// ---------------------------------------------------------------------------

/// Per-row overhead bytes of a quantized row: one `f32` offset + one
/// `f32` scale (the byte-model term shared with
/// [`super::memmodel`]).
pub const QUANT_ROW_OVERHEAD: usize = 8;

/// Midpoint offset + half-range scale of one row (the scale guards
/// all-constant rows, where a zero range would make the normalize 0/0).
fn row_affine(row: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in row {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (0.5 * (lo + hi), (0.5 * (hi - lo)).max(f32::MIN_POSITIVE))
}

/// Fixed-width rows stored as 16-bit codes with per-row scale/offset.
///
/// The value model is `x ≈ offset + scale * decode(code)` with the codes
/// normalized to `[-1, 1]` at encode time; see the module docs for the
/// error bound.  Rows append at the back and drain from the front
/// (sliding-window eviction), mirroring the f32 stores they replace.
#[derive(Clone, Debug)]
pub struct QuantizedRows {
    codec: CachePrecision,
    c: usize,
    data: Vec<u16>,
    offset: Vec<f32>,
    scale: Vec<f32>,
}

impl QuantizedRows {
    /// Empty store of `c`-wide rows.  `codec` must be a quantized
    /// precision ([`CachePrecision::is_quantized`]).
    pub fn new(codec: CachePrecision, c: usize) -> QuantizedRows {
        assert!(codec.is_quantized(), "QuantizedRows requires f16/bf16");
        assert!(c > 0, "row width must be positive");
        QuantizedRows {
            codec,
            c,
            data: Vec::new(),
            offset: Vec::new(),
            scale: Vec::new(),
        }
    }

    pub fn codec(&self) -> CachePrecision {
        self.codec
    }

    /// Row width c.
    pub fn width(&self) -> usize {
        self.c
    }

    /// Number of stored rows.
    pub fn len(&self) -> usize {
        self.offset.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offset.is_empty()
    }

    /// Quantize and append one row (length `c`): midpoint offset,
    /// half-range scale, codes rounded by the codec.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.c, "row width");
        let (offset, scale) = row_affine(row);
        self.offset.push(offset);
        self.scale.push(scale);
        let inv = 1.0 / scale;
        let codec = self.codec;
        self.data
            .extend(row.iter().map(|&x| encode(codec, (x - offset) * inv)));
    }

    /// Re-encode row `j` in place from fresh f32 values, with a freshly
    /// computed scale/offset — the storage half of a quantization-safe
    /// row transform; no second store is ever materialized.
    pub fn requant_row(&mut self, j: usize, row: &[f32]) {
        assert_eq!(row.len(), self.c, "row width");
        let (offset, scale) = row_affine(row);
        self.offset[j] = offset;
        self.scale[j] = scale;
        let inv = 1.0 / scale;
        let codec = self.codec;
        for (dst, &x) in self.data[j * self.c..(j + 1) * self.c]
            .iter_mut()
            .zip(row.iter())
        {
            *dst = encode(codec, (x - offset) * inv);
        }
    }

    /// Dequantize row `j` into `dst` (resized to `c`).
    pub fn dequant_row_into(&self, j: usize, dst: &mut Vec<f32>) {
        dst.resize(self.c, 0.0);
        let (off, sc) = (self.offset[j], self.scale[j]);
        let codes = &self.data[j * self.c..(j + 1) * self.c];
        for (d, &b) in dst.iter_mut().zip(codes) {
            *d = off + sc * decode(self.codec, b);
        }
    }

    /// Drop the `n` oldest rows.
    pub fn drain_front(&mut self, n: usize) {
        let n = n.min(self.len());
        self.data.drain(..n * self.c);
        self.offset.drain(..n);
        self.scale.drain(..n);
    }

    /// True resident bytes: 2-byte codes plus the per-row scale/offset
    /// pair ([`QUANT_ROW_OVERHEAD`]).
    pub fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<u16>() + self.len() * QUANT_ROW_OVERHEAD
    }

    /// Raw storage of row `j` — `(scale, offset, codes)` — for lossless
    /// serialization: re-inserting the same triple through
    /// [`QuantizedRows::push_row_raw`] reproduces the row bit-exactly,
    /// with no decode/re-encode rounding on the migration path.
    pub fn row_raw(&self, j: usize) -> (f32, f32, &[u16]) {
        (
            self.scale[j],
            self.offset[j],
            &self.data[j * self.c..(j + 1) * self.c],
        )
    }

    /// Append one row from its raw serialized parts (the inverse of
    /// [`QuantizedRows::row_raw`]); codes are stored verbatim.
    pub fn push_row_raw(&mut self, scale: f32, offset: f32, codes: &[u16]) {
        assert_eq!(codes.len(), self.c, "row width");
        self.scale.push(scale);
        self.offset.push(offset);
        self.data.extend_from_slice(codes);
    }
}

// ---------------------------------------------------------------------------
// Precision-tagged row storage
// ---------------------------------------------------------------------------

/// Row storage at a [`CachePrecision`]: raw `f32` rows (bit-exact, the
/// seed behavior) or [`QuantizedRows`].  This is the storage tier behind
/// both feature caches; the flash kernel reads it through
/// [`KvRowSource`] so one tiled loop serves both representations.
#[derive(Clone, Debug)]
pub enum FeatureRows {
    /// Raw rows, `data.len() == len * c`.
    F32 { c: usize, data: Vec<f32> },
    Quant(QuantizedRows),
}

impl FeatureRows {
    pub fn new(precision: CachePrecision, c: usize) -> FeatureRows {
        match precision {
            CachePrecision::F32 => FeatureRows::F32 {
                c,
                data: Vec::new(),
            },
            q => FeatureRows::Quant(QuantizedRows::new(q, c)),
        }
    }

    pub fn precision(&self) -> CachePrecision {
        match self {
            FeatureRows::F32 { .. } => CachePrecision::F32,
            FeatureRows::Quant(q) => q.codec(),
        }
    }

    pub fn width(&self) -> usize {
        match self {
            FeatureRows::F32 { c, .. } => *c,
            FeatureRows::Quant(q) => q.width(),
        }
    }

    /// Number of stored rows.
    pub fn len(&self) -> usize {
        match self {
            FeatureRows::F32 { c, data } => data.len() / c,
            FeatureRows::Quant(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one row (length `c`).
    pub fn push_row(&mut self, row: &[f32]) {
        match self {
            FeatureRows::F32 { c, data } => {
                assert_eq!(row.len(), *c, "row width");
                data.extend_from_slice(row);
            }
            FeatureRows::Quant(q) => q.push_row(row),
        }
    }

    /// Append `rows.len() / c` rows at once.
    pub fn push_rows(&mut self, rows: &[f32]) {
        let c = self.width();
        assert_eq!(rows.len() % c, 0, "rows must be a whole number of rows");
        match self {
            FeatureRows::F32 { data, .. } => data.extend_from_slice(rows),
            FeatureRows::Quant(q) => {
                for row in rows.chunks(c) {
                    q.push_row(row);
                }
            }
        }
    }

    /// Drop the `n` oldest rows.
    pub fn drain_front(&mut self, n: usize) {
        match self {
            FeatureRows::F32 { c, data } => {
                data.drain(..n.min(data.len() / *c) * *c);
            }
            FeatureRows::Quant(q) => q.drain_front(n),
        }
    }

    /// Materialize every row into `dst` (length `len * c`): a verbatim
    /// `memcpy` for f32 (bit-exact), a dequantization loop otherwise.
    pub fn read_all_into(&self, dst: &mut [f32]) {
        match self {
            FeatureRows::F32 { data, .. } => dst.copy_from_slice(data),
            FeatureRows::Quant(q) => {
                let c = q.width();
                assert_eq!(dst.len(), q.len() * c, "dst shape");
                let mut row = Vec::with_capacity(c);
                for j in 0..q.len() {
                    q.dequant_row_into(j, &mut row);
                    dst[j * c..(j + 1) * c].copy_from_slice(&row);
                }
            }
        }
    }

    /// Apply an in-place transform to every row.  On quantized storage
    /// each row is dequantized, transformed, and **re-encoded with a
    /// freshly computed scale/offset**, so exactly one storage rounding
    /// is added per call — the transform itself runs at full precision
    /// (this is what keeps repeated SE(2) re-anchors from compounding
    /// quantization error multiplicatively; see DESIGN.md §14).
    pub fn for_each_row_mut(&mut self, mut f: impl FnMut(&mut [f32])) {
        match self {
            FeatureRows::F32 { c, data } => {
                for row in data.chunks_mut(*c) {
                    f(row);
                }
            }
            FeatureRows::Quant(q) => {
                // in place, row by row: the cache never transiently holds
                // a second copy of itself (re-anchors happen exactly when
                // bytes are the binding constraint)
                let mut row = Vec::with_capacity(q.width());
                for j in 0..q.len() {
                    q.dequant_row_into(j, &mut row);
                    f(&mut row);
                    q.requant_row(j, &row);
                }
            }
        }
    }

    /// True resident bytes of the stored rows (codes + per-row
    /// scale/offset for quantized storage, raw f32 otherwise).
    pub fn resident_bytes(&self) -> usize {
        match self {
            FeatureRows::F32 { data, .. } => data.len() * std::mem::size_of::<f32>(),
            FeatureRows::Quant(q) => q.resident_bytes(),
        }
    }

    /// Borrow as a kernel row source.
    pub fn as_kv(&self) -> KvRowSource<'_> {
        match self {
            FeatureRows::F32 { data, .. } => KvRowSource::F32(data),
            FeatureRows::Quant(q) => KvRowSource::Quant(q),
        }
    }

    /// Borrow the raw f32 storage (`None` for quantized rows) — the
    /// bit-exact serialization path for the f32 tier.
    pub fn raw_f32(&self) -> Option<&[f32]> {
        match self {
            FeatureRows::F32 { data, .. } => Some(data),
            FeatureRows::Quant(_) => None,
        }
    }

    /// Borrow the quantized store (`None` for f32 rows); pair with
    /// [`QuantizedRows::row_raw`] for lossless serialization.
    pub fn as_quant(&self) -> Option<&QuantizedRows> {
        match self {
            FeatureRows::F32 { .. } => None,
            FeatureRows::Quant(q) => Some(q),
        }
    }

    /// Mutable quantized store (`None` for f32 rows) — the
    /// deserialization half of the raw-row path.
    pub fn as_quant_mut(&mut self) -> Option<&mut QuantizedRows> {
        match self {
            FeatureRows::F32 { .. } => None,
            FeatureRows::Quant(q) => Some(q),
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel row source
// ---------------------------------------------------------------------------

/// What the blocked flash kernel's key-block loop reads k/v rows from:
/// either a borrowed f32 matrix (zero-copy — the row is returned as a
/// subslice, so the f32 path is bit-identical to the pre-abstraction
/// kernel), a [`QuantizedRows`] store (the row is dequantized into the
/// caller's O(c) scratch on the fly), or a raw k/v tensor plus poses
/// ([`RawPoseKv`]) whose rows are phi_k-projected on the fly — the fused
/// path of DESIGN.md §18, where no m x c projected tensor ever exists.
#[derive(Clone, Copy, Debug)]
pub enum KvRowSource<'a> {
    F32(&'a [f32]),
    Quant(&'a QuantizedRows),
    /// Raw rows + poses, projected per key block by the fused driver.
    /// `value_side` selects which half of the pair this source reads
    /// (k~ carries the (c/d)^(1/4) prefactor, v~ does not).
    RawPose {
        kv: &'a RawPoseKv<'a>,
        value_side: bool,
    },
}

pub use super::projections::RawPoseKv;

impl<'a> KvRowSource<'a> {
    /// Row `j` as f32: borrowed for f32 sources, dequantized into
    /// `scratch` for quantized ones, projected into `scratch` for
    /// raw-pose ones.
    ///
    /// For [`KvRowSource::RawPose`] this is the *cold* path (it builds a
    /// fresh se2fourier quadrature scratch per call); the fused kernel
    /// driver instead projects whole key blocks through
    /// [`RawPoseKv::project_pair_into`] and never lands here.
    #[inline]
    pub fn row<'s>(&'s self, j: usize, c: usize, scratch: &'s mut Vec<f32>) -> &'s [f32] {
        match self {
            KvRowSource::F32(data) => &data[j * c..(j + 1) * c],
            KvRowSource::Quant(q) => {
                q.dequant_row_into(j, scratch);
                scratch
            }
            KvRowSource::RawPose { kv, value_side } => {
                let mut se2f = None;
                kv.project_row_into(j, *value_side, &mut se2f, scratch);
                scratch
            }
        }
    }

    /// Whether reads go through the dequantization scratch (the kernel's
    /// per-thread scratch accounting adds 2 c-wide f32 buffers if so).
    pub fn is_quantized(&self) -> bool {
        matches!(self, KvRowSource::Quant(_))
    }

    /// The raw-pose view behind this source, if it is one (the blocked
    /// kernel dispatches such sources to the fused block driver).
    pub fn raw_pose(&self) -> Option<(&'a RawPoseKv<'a>, bool)> {
        match self {
            KvRowSource::RawPose { kv, value_side } => Some((kv, *value_side)),
            _ => None,
        }
    }

    /// Number of rows, given the row width `c`.
    pub fn len(&self, c: usize) -> usize {
        match self {
            KvRowSource::F32(data) => data.len() / c.max(1),
            KvRowSource::Quant(q) => q.len(),
            KvRowSource::RawPose { kv, .. } => kv.len(),
        }
    }

    /// Assert this source holds exactly `m` rows of width `c` (for f32
    /// slices this also rejects a trailing partial row, keeping the
    /// legacy slice entry point's exact shape contract).
    pub fn assert_shape(&self, c: usize, m: usize, what: &str) {
        match self {
            KvRowSource::F32(data) => assert_eq!(data.len(), m * c, "{what} shape"),
            KvRowSource::Quant(q) => {
                assert_eq!(q.width(), c, "{what} width");
                assert_eq!(q.len(), m, "{what} shape");
            }
            KvRowSource::RawPose { kv, value_side } => {
                assert_eq!(kv.proj_width(), c, "{what} projected width");
                assert_eq!(kv.poses.len(), m, "{what} poses");
                let side = if *value_side { kv.v } else { kv.k };
                assert_eq!(side.len(), m * kv.d, "{what} shape");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn f16_roundtrip_exact_values() {
        // values exactly representable in binary16 must round-trip
        for &x in &[
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            0.5,
            0.25,
            65504.0,
            2.0f32.powi(-14),  // smallest f16 normal
            2.0f32.powi(-24),  // smallest f16 subnormal
            -3.0 * 2.0f32.powi(-24), // mid-range subnormal
            0.0999755859375,   // f16's nearest value to 0.1
        ] {
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {back}");
        }
        // overflow saturates to infinity, sign preserved
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e6)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // tiny values flush toward zero through the subnormal range
        let tiny = f16_bits_to_f32(f32_to_f16_bits(1e-10));
        assert_eq!(tiny, 0.0);
    }

    #[test]
    fn f16_rounding_is_bounded_on_unit_range() {
        let mut rng = Rng::new(7);
        let eps = CachePrecision::F16.unit_rounding() as f32;
        for _ in 0..2000 {
            let x = rng.range(-1.0, 1.0) as f32;
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!((back - x).abs() <= eps, "{x} -> {back}");
        }
    }

    #[test]
    fn bf16_roundtrip_and_bound() {
        for &x in &[0.0f32, 1.0, -2.0, 0.5, 3.0e38, 1.0e-38] {
            let back = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert!(
                ((back - x) / x.abs().max(1.0)).abs() <= 1.0 / 256.0,
                "{x} -> {back}"
            );
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        let mut rng = Rng::new(8);
        let eps = CachePrecision::Bf16.unit_rounding() as f32;
        for _ in 0..2000 {
            let x = rng.range(-1.0, 1.0) as f32;
            let back = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert!((back - x).abs() <= eps, "{x} -> {back}");
        }
    }

    #[test]
    fn quantized_rows_error_is_within_row_scale_bound() {
        let mut rng = Rng::new(41);
        let c = 50;
        for codec in [CachePrecision::F16, CachePrecision::Bf16] {
            let mut q = QuantizedRows::new(codec, c);
            let rows: Vec<Vec<f32>> = (0..20)
                .map(|r| {
                    let amp = 10.0f64.powi(r % 5 - 2); // spread 1e-2 .. 1e2
                    (0..c).map(|_| (rng.normal() * amp) as f32).collect()
                })
                .collect();
            for row in &rows {
                q.push_row(row);
            }
            let mut back = Vec::new();
            for (j, row) in rows.iter().enumerate() {
                q.dequant_row_into(j, &mut back);
                let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                // one f32 mul-add of slack on top of the codec rounding
                let bound = 0.5 * (hi - lo) * (codec.unit_rounding() as f32) * 1.001 + 1e-6;
                for (a, b) in row.iter().zip(back.iter()) {
                    assert!((a - b).abs() <= bound, "{codec:?}: {a} vs {b} (bound {bound})");
                }
            }
        }
    }

    #[test]
    fn quantized_rows_handle_constant_rows_and_eviction() {
        let mut q = QuantizedRows::new(CachePrecision::F16, 4);
        q.push_row(&[3.0, 3.0, 3.0, 3.0]); // zero range: scale guard path
        q.push_row(&[0.0, 1.0, 2.0, 3.0]);
        q.push_row(&[-1.0, 0.0, 0.0, 1.0]);
        let mut row = Vec::new();
        q.dequant_row_into(0, &mut row);
        for &x in &row {
            assert!((x - 3.0).abs() < 1e-6, "{x}");
        }
        let bytes3 = q.resident_bytes();
        assert_eq!(bytes3, 3 * (4 * 2 + QUANT_ROW_OVERHEAD));
        q.drain_front(1);
        assert_eq!(q.len(), 2);
        q.dequant_row_into(0, &mut row);
        assert!((row[3] - 3.0).abs() < 1e-2);
        assert_eq!(q.resident_bytes(), 2 * (4 * 2 + QUANT_ROW_OVERHEAD));
        q.drain_front(10); // over-drain clamps
        assert!(q.is_empty());
    }

    #[test]
    fn feature_rows_f32_path_is_bit_exact() {
        let mut s = FeatureRows::new(CachePrecision::F32, 3);
        let rows = [1.0f32, 2.0, 3.0, -4.0, 5.5, f32::MIN_POSITIVE];
        s.push_rows(&rows);
        assert_eq!(s.len(), 2);
        let mut out = vec![0.0f32; 6];
        s.read_all_into(&mut out);
        assert_eq!(out, rows, "f32 storage must be verbatim");
        s.for_each_row_mut(|r| r.iter_mut().for_each(|x| *x *= 2.0));
        s.read_all_into(&mut out);
        assert_eq!(out[0], 2.0);
        s.drain_front(1);
        assert_eq!(s.len(), 1);
        assert_eq!(s.resident_bytes(), 3 * 4);
    }

    #[test]
    fn feature_rows_quantized_transform_adds_one_rounding() {
        let mut rng = Rng::new(99);
        let c = 32;
        let row: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let mut s = FeatureRows::new(CachePrecision::F16, c);
        s.push_row(&row);
        // identity transform: error stays at a single quantization step
        // of the (stable) row scale — it does not double
        let eps = CachePrecision::F16.unit_rounding() as f32;
        let amax = row.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for _ in 0..8 {
            s.for_each_row_mut(|_| {});
        }
        let mut out = vec![0.0f32; c];
        s.read_all_into(&mut out);
        for (a, b) in row.iter().zip(out.iter()) {
            // generous slack: 8 identity re-encodes may each move by <=
            // one step, but the fixed-point of encode/decode is reached
            // after the first — pin well under the compounding bound
            assert!((a - b).abs() <= 3.0 * amax * eps, "{a} vs {b}");
        }
    }

    #[test]
    fn kv_row_source_reads_match_storage() {
        let mut rng = Rng::new(3);
        let c = 10;
        let rows: Vec<f32> = (0..3 * c).map(|_| rng.normal() as f32).collect();
        let mut f = FeatureRows::new(CachePrecision::F32, c);
        f.push_rows(&rows);
        let mut q = FeatureRows::new(CachePrecision::F16, c);
        q.push_rows(&rows);
        let mut scratch = Vec::new();
        let fs = f.as_kv();
        let qs = q.as_kv();
        assert!(!fs.is_quantized() && qs.is_quantized());
        assert_eq!(fs.len(c), 3);
        assert_eq!(qs.len(c), 3);
        for j in 0..3 {
            let want = &rows[j * c..(j + 1) * c];
            assert_eq!(fs.row(j, c, &mut scratch), want, "f32 zero-copy row");
            let got = qs.row(j, c, &mut scratch).to_vec();
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((a - b).abs() < 5e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn raw_pose_row_source_projects_on_read() {
        use crate::config::Method;
        use crate::geometry::Pose;
        let mut rng = Rng::new(5);
        let (d, m) = (8, 3);
        let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..m)
            .map(|_| Pose::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-3.0, 3.0)))
            .collect();
        let kv = RawPoseKv {
            k: &k,
            v: &v,
            poses: &poses,
            method: Method::Rope2d,
            d,
            fourier_f: 0,
            scales: &[1.0, 0.5],
            pref: 1.0,
        };
        let ks = KvRowSource::RawPose { kv: &kv, value_side: false };
        let vs = KvRowSource::RawPose { kv: &kv, value_side: true };
        assert!(!ks.is_quantized());
        assert_eq!(ks.len(d), m);
        assert!(ks.raw_pose().is_some());
        ks.assert_shape(d, m, "k");
        vs.assert_shape(d, m, "v");
        let mut scratch = Vec::new();
        for j in 0..m {
            let mut want = k[j * d..(j + 1) * d].to_vec();
            crate::attention::projections::rope2d_project(&mut want, &poses[j], &[1.0, 0.5]);
            assert_eq!(ks.row(j, d, &mut scratch), &want[..], "key row {j}");
            let mut want_v = v[j * d..(j + 1) * d].to_vec();
            crate::attention::projections::rope2d_project(&mut want_v, &poses[j], &[1.0, 0.5]);
            assert_eq!(vs.row(j, d, &mut scratch), &want_v[..], "value row {j}");
        }
    }
}
