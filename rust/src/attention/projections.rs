//! Per-token phi_q^T / phi_k projections — the linear-memory halves of
//! Algorithm 2, mirroring `python/compile/kernels/{rope,se2_fourier}.py`.
//!
//! All functions operate in place on one token's per-head feature slice of
//! width d (blocks cycled over the scale ladder).

use crate::config::Method;
use crate::fourier::{coefficients, eval_basis, Axis, QuadratureTable};
use crate::geometry::{rotate_pair, Pose};

/// 2D RoPE (Eq. 7): rotate (x-pair, y-pair) blocks by the token's own
/// *absolute* coordinates.  Identical for queries and keys.
pub fn rope2d_project(x: &mut [f32], pose: &Pose, scales: &[f64]) {
    let nb = x.len() / 4;
    for j in 0..nb {
        let a = scales[j % scales.len()];
        let b = &mut x[4 * j..4 * j + 4];
        let (r0, r1) = rotate_pair(b[0] as f64, b[1] as f64, a * pose.x);
        let (r2, r3) = rotate_pair(b[2] as f64, b[3] as f64, a * pose.y);
        b[0] = r0 as f32;
        b[1] = r1 as f32;
        b[2] = r2 as f32;
        b[3] = r3 as f32;
    }
}

/// SE(2) representation (Eq. 9) — query side: psi(p^{-1})^T applied per
/// 3-wide block (positions scaled).
pub fn se2rep_project_q(x: &mut [f32], pose: &Pose, scales: &[f64]) {
    let nb = x.len() / 3;
    for j in 0..nb {
        let p = pose.scaled(scales[j % scales.len()]);
        let inv = p.inverse();
        let (s, c) = inv.theta.sin_cos();
        let b = &mut x[3 * j..3 * j + 3];
        let (x0, x1, x2) = (b[0] as f64, b[1] as f64, b[2] as f64);
        // psi(inv)^T = [c s 0; -s c 0; ix iy 1] applied to column
        b[0] = (c * x0 + s * x1) as f32;
        b[1] = (-s * x0 + c * x1) as f32;
        b[2] = (inv.x * x0 + inv.y * x1 + x2) as f32;
    }
}

/// SE(2) representation — key/value side: psi(p) per 3-wide block.
pub fn se2rep_project_k(x: &mut [f32], pose: &Pose, scales: &[f64]) {
    let nb = x.len() / 3;
    for j in 0..nb {
        let p = pose.scaled(scales[j % scales.len()]);
        let (s, c) = p.theta.sin_cos();
        let b = &mut x[3 * j..3 * j + 3];
        let (x0, x1, x2) = (b[0] as f64, b[1] as f64, b[2] as f64);
        b[0] = (c * x0 - s * x1 + p.x * x2) as f32;
        b[1] = (s * x0 + c * x1 + p.y * x2) as f32;
        b[2] = x2 as f32;
    }
}

/// SE(2) representation — output side: psi(p^{-1}) per 3-wide block
/// (Alg. 2 line 4).
pub fn se2rep_unproject_o(x: &mut [f32], pose: &Pose, scales: &[f64]) {
    let nb = x.len() / 3;
    for j in 0..nb {
        let p = pose.scaled(scales[j % scales.len()]);
        let inv = p.inverse();
        let (s, c) = inv.theta.sin_cos();
        let b = &mut x[3 * j..3 * j + 3];
        let (x0, x1, x2) = (b[0] as f64, b[1] as f64, b[2] as f64);
        b[0] = (c * x0 - s * x1 + inv.x * x2) as f32;
        b[1] = (s * x0 + c * x1 + inv.y * x2) as f32;
        b[2] = x2 as f32;
    }
}

/// Projected width per 6-wide SE(2) Fourier block.
pub fn se2f_block_width(f: usize) -> usize {
    4 * f + 2
}

/// SE(2) Fourier query projection (Eq. 19): 6-wide block -> (4F+2)-wide.
/// Layout per block: [x-cos F | x-sin F | y-cos F | y-sin F | theta 2].
pub fn se2f_project_q(
    x: &[f32],
    pose: &Pose,
    scales: &[f64],
    f: usize,
    scale_pref: f32,
    out: &mut Vec<f32>,
) {
    let nb = x.len() / 6;
    let w = se2f_block_width(f);
    out.clear();
    out.reserve(nb * w);
    let b = eval_basis(pose.theta, f);
    let (st, ct) = pose.theta.sin_cos();
    for j in 0..nb {
        let a = scales[j % scales.len()];
        let (px, py) = (a * pose.x, a * pose.y);
        let vx = -px * ct - py * st;
        let vy = px * st - py * ct;
        let (sx, cx) = vx.sin_cos();
        let (sy, cy) = vy.sin_cos();
        let blk = &x[6 * j..6 * j + 6];
        let (q0, q1) = (blk[0] as f64, blk[1] as f64);
        let (q2, q3) = (blk[2] as f64, blk[3] as f64);
        let (q4, q5) = (blk[4] as f64, blk[5] as f64);
        let pref = scale_pref as f64;
        for i in 0..f {
            out.push((pref * b[i] * (cx * q0 + sx * q1)) as f32);
        }
        for i in 0..f {
            out.push((pref * b[i] * (-sx * q0 + cx * q1)) as f32);
        }
        for i in 0..f {
            out.push((pref * b[i] * (cy * q2 + sy * q3)) as f32);
        }
        for i in 0..f {
            out.push((pref * b[i] * (-sy * q2 + cy * q3)) as f32);
        }
        // theta pair: rho(-t)^T = rho(t)
        out.push((pref * (ct * q4 - st * q5)) as f32);
        out.push((pref * (st * q4 + ct * q5)) as f32);
    }
}

/// SE(2) Fourier key/value projection (Eq. 19): phi_k(p) x.
///
/// Allocation-free hot path when a [`QuadratureTable`] and scratch buffers
/// are provided via [`Se2fKeyScratch`]; the convenience wrapper below
/// builds them per call for tests/small uses.
pub struct Se2fKeyScratch {
    pub table: QuadratureTable,
    gx: Vec<f64>,
    lx: Vec<f64>,
    gy: Vec<f64>,
    ly: Vec<f64>,
}

impl Se2fKeyScratch {
    pub fn new(f: usize) -> Se2fKeyScratch {
        Se2fKeyScratch {
            table: QuadratureTable::new(f),
            gx: vec![0.0; f],
            lx: vec![0.0; f],
            gy: vec![0.0; f],
            ly: vec![0.0; f],
        }
    }
}

pub fn se2f_project_k_with(
    scratch: &mut Se2fKeyScratch,
    x: &[f32],
    pose: &Pose,
    scales: &[f64],
    scale_pref: f32,
    out: &mut Vec<f32>,
) {
    let f = scratch.table.f;
    let nb = x.len() / 6;
    out.clear();
    out.reserve(nb * se2f_block_width(f));
    let (st, ct) = pose.theta.sin_cos();
    for j in 0..nb {
        let a = scales[j % scales.len()];
        let (px, py) = (a * pose.x, a * pose.y);
        scratch
            .table
            .coefficients_into(px, py, Axis::X, &mut scratch.gx, &mut scratch.lx);
        scratch
            .table
            .coefficients_into(px, py, Axis::Y, &mut scratch.gy, &mut scratch.ly);
        let (gx, lx, gy, ly) = (&scratch.gx, &scratch.lx, &scratch.gy, &scratch.ly);
        let blk = &x[6 * j..6 * j + 6];
        let (k0, k1) = (blk[0] as f64, blk[1] as f64);
        let (k2, k3) = (blk[2] as f64, blk[3] as f64);
        let (k4, k5) = (blk[4] as f64, blk[5] as f64);
        let pref = scale_pref as f64;
        for i in 0..f {
            out.push((pref * (gx[i] * k0 - lx[i] * k1)) as f32);
        }
        for i in 0..f {
            out.push((pref * (lx[i] * k0 + gx[i] * k1)) as f32);
        }
        for i in 0..f {
            out.push((pref * (gy[i] * k2 - ly[i] * k3)) as f32);
        }
        for i in 0..f {
            out.push((pref * (ly[i] * k2 + gy[i] * k3)) as f32);
        }
        out.push((pref * (ct * k4 - st * k5)) as f32);
        out.push((pref * (st * k4 + ct * k5)) as f32);
    }
}

/// Key *and* value projection of one token in a single pass: the
/// Gamma/Lambda coefficients depend only on the pose, so they are computed
/// once and applied to both tensors (Alg. 2 line 2) — ~2x on the key side
/// (EXPERIMENTS.md §Perf L3 iteration 4).
#[allow(clippy::too_many_arguments)]
pub fn se2f_project_kv_with(
    scratch: &mut Se2fKeyScratch,
    k: &[f32],
    v: &[f32],
    pose: &Pose,
    scales: &[f64],
    k_pref: f32,
    k_out: &mut Vec<f32>,
    v_out: &mut Vec<f32>,
) {
    let f = scratch.table.f;
    let nb = k.len() / 6;
    k_out.clear();
    v_out.clear();
    k_out.reserve(nb * se2f_block_width(f));
    v_out.reserve(nb * se2f_block_width(f));
    let (st, ct) = pose.theta.sin_cos();
    for j in 0..nb {
        let a = scales[j % scales.len()];
        let (px, py) = (a * pose.x, a * pose.y);
        scratch
            .table
            .coefficients_into(px, py, Axis::X, &mut scratch.gx, &mut scratch.lx);
        scratch
            .table
            .coefficients_into(px, py, Axis::Y, &mut scratch.gy, &mut scratch.ly);
        let (gx, lx, gy, ly) = (&scratch.gx, &scratch.lx, &scratch.gy, &scratch.ly);
        for (x, out, pref) in [(k, &mut *k_out, k_pref as f64), (v, &mut *v_out, 1.0)] {
            let blk = &x[6 * j..6 * j + 6];
            let (k0, k1) = (blk[0] as f64, blk[1] as f64);
            let (k2, k3) = (blk[2] as f64, blk[3] as f64);
            let (k4, k5) = (blk[4] as f64, blk[5] as f64);
            for i in 0..f {
                out.push((pref * (gx[i] * k0 - lx[i] * k1)) as f32);
            }
            for i in 0..f {
                out.push((pref * (lx[i] * k0 + gx[i] * k1)) as f32);
            }
            for i in 0..f {
                out.push((pref * (gy[i] * k2 - ly[i] * k3)) as f32);
            }
            for i in 0..f {
                out.push((pref * (ly[i] * k2 + gy[i] * k3)) as f32);
            }
            out.push((pref * (ct * k4 - st * k5)) as f32);
            out.push((pref * (st * k4 + ct * k5)) as f32);
        }
    }
}

pub fn se2f_project_k(
    x: &[f32],
    pose: &Pose,
    scales: &[f64],
    f: usize,
    scale_pref: f32,
    out: &mut Vec<f32>,
) {
    let nb = x.len() / 6;
    out.clear();
    out.reserve(nb * se2f_block_width(f));
    let (st, ct) = pose.theta.sin_cos();
    for j in 0..nb {
        let a = scales[j % scales.len()];
        let (px, py) = (a * pose.x, a * pose.y);
        let (gx, lx) = coefficients(px, py, f, Axis::X);
        let (gy, ly) = coefficients(px, py, f, Axis::Y);
        let blk = &x[6 * j..6 * j + 6];
        let (k0, k1) = (blk[0] as f64, blk[1] as f64);
        let (k2, k3) = (blk[2] as f64, blk[3] as f64);
        let (k4, k5) = (blk[4] as f64, blk[5] as f64);
        let pref = scale_pref as f64;
        for i in 0..f {
            out.push((pref * (gx[i] * k0 - lx[i] * k1)) as f32);
        }
        for i in 0..f {
            out.push((pref * (lx[i] * k0 + gx[i] * k1)) as f32);
        }
        for i in 0..f {
            out.push((pref * (gy[i] * k2 - ly[i] * k3)) as f32);
        }
        for i in 0..f {
            out.push((pref * (ly[i] * k2 + gy[i] * k3)) as f32);
        }
        out.push((pref * (ct * k4 - st * k5)) as f32);
        out.push((pref * (st * k4 + ct * k5)) as f32);
    }
}

/// SE(2) Fourier output unprojection (Alg. 2 line 4): (4F+2)-wide block ->
/// 6-wide, o = phi_q(p) o_tilde.
pub fn se2f_unproject_o(
    ot: &[f32],
    pose: &Pose,
    scales: &[f64],
    f: usize,
    out: &mut Vec<f32>,
) {
    let w = se2f_block_width(f);
    let nb = ot.len() / w;
    out.clear();
    out.reserve(nb * 6);
    let b = eval_basis(pose.theta, f);
    let (st, ct) = pose.theta.sin_cos();
    for j in 0..nb {
        let a = scales[j % scales.len()];
        let (px, py) = (a * pose.x, a * pose.y);
        let vx = -px * ct - py * st;
        let vy = px * st - py * ct;
        let (sx, cx) = vx.sin_cos();
        let (sy, cy) = vy.sin_cos();
        let blk = &ot[w * j..w * (j + 1)];
        let dot = |lo: usize| -> f64 {
            (0..f).map(|i| b[i] * blk[lo + i] as f64).sum()
        };
        let (sxa, sxb) = (dot(0), dot(f));
        let (sya, syb) = (dot(2 * f), dot(3 * f));
        let (o4, o5) = (blk[4 * f] as f64, blk[4 * f + 1] as f64);
        out.push((cx * sxa - sx * sxb) as f32);
        out.push((sx * sxa + cx * sxb) as f32);
        out.push((cy * sya - sy * syb) as f32);
        out.push((sy * sya + cy * syb) as f32);
        // theta pair: rho(-t)
        out.push((ct * o4 + st * o5) as f32);
        out.push((-st * o4 + ct * o5) as f32);
    }
}

/// A raw (un-projected) key/value tensor view plus per-token poses: the
/// row source the fused kernel path consumes
/// ([`crate::attention::kernel::flash_sdpa_fused`]).  Instead of
/// materializing the m x c projected k~/v~ tensors of Algorithm 2 line 2,
/// the fused driver projects each key block on the fly into O(block_m * c)
/// per-thread scratch via [`RawPoseKv::project_pair_into`] — the same
/// projection functions `linear::project` runs, in the same order, so the
/// fused output is bit-identical to project-then-attend (DESIGN.md §18).
#[derive(Debug)]
pub struct RawPoseKv<'a> {
    /// Raw key rows, row-major (m x d).
    pub k: &'a [f32],
    /// Raw value rows, row-major (m x d).
    pub v: &'a [f32],
    /// One pose per key/value row.
    pub poses: &'a [Pose],
    pub method: Method,
    /// Raw per-head width.
    pub d: usize,
    /// Fourier order F (se2fourier only; ignored elsewhere).
    pub fourier_f: usize,
    pub scales: &'a [f64],
    /// The (c/d)^(1/4) Alg. 2 prefactor applied to k~ (se2fourier only;
    /// pass 1.0 for the width-preserving methods).
    pub pref: f32,
}

impl<'a> RawPoseKv<'a> {
    /// Number of key/value rows.
    pub fn len(&self) -> usize {
        self.poses.len()
    }

    pub fn is_empty(&self) -> bool {
        self.poses.is_empty()
    }

    /// Projected per-head width c (matches `linear::proj_dim`, computed
    /// inline to keep this module free of a `linear` dependency).
    pub fn proj_width(&self) -> usize {
        match self.method {
            Method::Se2Fourier => se2f_block_width(self.fourier_f) * (self.d / 6),
            _ => self.d,
        }
    }

    /// Project key row `j` *and* value row `j` in one pass (the se2fourier
    /// Gamma/Lambda coefficients depend only on the pose, so the pair costs
    /// barely more than one side).  Element-identical to the rows
    /// `linear::project` would have written at index `j`.
    pub fn project_pair_into(
        &self,
        j: usize,
        se2f: &mut Option<Se2fKeyScratch>,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let d = self.d;
        let kr = &self.k[j * d..(j + 1) * d];
        let vr = &self.v[j * d..(j + 1) * d];
        match self.method {
            Method::Abs => {
                k_out.clear();
                k_out.extend_from_slice(kr);
                v_out.clear();
                v_out.extend_from_slice(vr);
            }
            Method::Rope2d => {
                k_out.clear();
                k_out.extend_from_slice(kr);
                v_out.clear();
                v_out.extend_from_slice(vr);
                rope2d_project(k_out, &self.poses[j], self.scales);
                rope2d_project(v_out, &self.poses[j], self.scales);
            }
            Method::Se2Rep => {
                k_out.clear();
                k_out.extend_from_slice(kr);
                v_out.clear();
                v_out.extend_from_slice(vr);
                se2rep_project_k(k_out, &self.poses[j], self.scales);
                se2rep_project_k(v_out, &self.poses[j], self.scales);
            }
            Method::Se2Fourier => {
                let scratch =
                    se2f.get_or_insert_with(|| Se2fKeyScratch::new(self.fourier_f));
                se2f_project_kv_with(
                    scratch,
                    kr,
                    vr,
                    &self.poses[j],
                    self.scales,
                    self.pref,
                    k_out,
                    v_out,
                );
            }
        }
    }

    /// Project one side of row `j` (cold path for the generic
    /// [`crate::attention::quant::KvRowSource::row`] contract; the fused
    /// driver always uses the pair form above).  Element-identical to the
    /// corresponding half of [`Self::project_pair_into`]:
    /// `se2f_project_k_with` emits the same expressions as the kv pair
    /// loop, and values carry prefactor 1.0.
    pub fn project_row_into(
        &self,
        j: usize,
        value_side: bool,
        se2f: &mut Option<Se2fKeyScratch>,
        out: &mut Vec<f32>,
    ) {
        let d = self.d;
        let side = if value_side { self.v } else { self.k };
        let row = &side[j * d..(j + 1) * d];
        match self.method {
            Method::Abs => {
                out.clear();
                out.extend_from_slice(row);
            }
            Method::Rope2d => {
                out.clear();
                out.extend_from_slice(row);
                rope2d_project(out, &self.poses[j], self.scales);
            }
            Method::Se2Rep => {
                out.clear();
                out.extend_from_slice(row);
                se2rep_project_k(out, &self.poses[j], self.scales);
            }
            Method::Se2Fourier => {
                let scratch =
                    se2f.get_or_insert_with(|| Se2fKeyScratch::new(self.fourier_f));
                let pref = if value_side { 1.0 } else { self.pref };
                se2f_project_k_with(scratch, row, &self.poses[j], self.scales, pref, out);
            }
        }
    }
}

/// Project one raw query row (width d) to q~ (width c), dispatching on
/// `method` exactly as `linear::project` does per row — the fused kernel's
/// query-side half (Alg. 2 line 1).  `pref` is the (c/d)^(1/4) prefactor
/// (se2fourier only; ignored elsewhere).
#[allow(clippy::too_many_arguments)]
pub fn project_q_row_into(
    method: Method,
    row: &[f32],
    pose: &Pose,
    scales: &[f64],
    fourier_f: usize,
    pref: f32,
    out: &mut Vec<f32>,
) {
    match method {
        Method::Abs => {
            out.clear();
            out.extend_from_slice(row);
        }
        Method::Rope2d => {
            out.clear();
            out.extend_from_slice(row);
            rope2d_project(out, pose, scales);
        }
        Method::Se2Rep => {
            out.clear();
            out.extend_from_slice(row);
            se2rep_project_q(out, pose, scales);
        }
        Method::Se2Fourier => {
            se2f_project_q(row, pose, scales, fourier_f, pref, out);
        }
    }
}

/// Map one attended o~ row (width c) back to width d, dispatching on
/// `method` exactly as `linear::unproject` does per row (Alg. 2 line 4).
pub fn unproject_o_row_into(
    method: Method,
    ot_row: &[f32],
    pose: &Pose,
    scales: &[f64],
    fourier_f: usize,
    out: &mut Vec<f32>,
) {
    match method {
        Method::Abs => {
            out.clear();
            out.extend_from_slice(ot_row);
        }
        Method::Rope2d => {
            out.clear();
            out.extend_from_slice(ot_row);
            // phi_q(p_n) = rho(-a x_n) blocks: rotate by the negated own
            // coordinates
            let neg = Pose {
                x: -pose.x,
                y: -pose.y,
                theta: 0.0,
            };
            rope2d_project(out, &neg, scales);
        }
        Method::Se2Rep => {
            out.clear();
            out.extend_from_slice(ot_row);
            se2rep_unproject_o(out, pose, scales);
        }
        Method::Se2Fourier => {
            se2f_unproject_o(ot_row, pose, scales, fourier_f, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::{phi_k_block, phi_q_block};
    use crate::prng::Rng;
    use crate::proplite::{all_close_f32, check};

    fn rand_pose(rng: &mut Rng) -> Pose {
        Pose::new(
            rng.range(-2.0, 2.0),
            rng.range(-2.0, 2.0),
            rng.range(-3.1, 3.1),
        )
    }

    #[test]
    fn se2f_projections_match_explicit_matrices() {
        check("se2f projections == matrices", 40, |rng| {
            let f = 4 + rng.below(12);
            let pose = rand_pose(rng);
            let x: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
            // query: phi_q^T x
            let pq = phi_q_block(&pose, f);
            let expect_q: Vec<f32> = pq
                .transpose()
                .matvec(&x.iter().map(|v| *v as f64).collect::<Vec<_>>())
                .iter()
                .map(|v| *v as f32)
                .collect();
            let mut got = Vec::new();
            se2f_project_q(&x, &pose, &[1.0], f, 1.0, &mut got);
            all_close_f32(&got, &expect_q, 1e-5, "phi_q^T x")?;
            // key: phi_k x
            let pk = phi_k_block(&pose, f);
            let expect_k: Vec<f32> = pk
                .matvec(&x.iter().map(|v| *v as f64).collect::<Vec<_>>())
                .iter()
                .map(|v| *v as f32)
                .collect();
            se2f_project_k(&x, &pose, &[1.0], f, 1.0, &mut got);
            all_close_f32(&got, &expect_k, 1e-5, "phi_k x")?;
            // output: phi_q ot
            let ot: Vec<f32> =
                (0..4 * f + 2).map(|_| rng.normal() as f32).collect();
            let expect_o: Vec<f32> = pq
                .matvec(&ot.iter().map(|v| *v as f64).collect::<Vec<_>>())
                .iter()
                .map(|v| *v as f32)
                .collect();
            se2f_unproject_o(&ot, &pose, &[1.0], f, &mut got);
            all_close_f32(&got, &expect_o, 1e-5, "phi_q ot")
        });
    }

    #[test]
    fn rope2d_inner_product_encodes_relative_position() {
        // <phi(pn) q, phi(pm) k> == <q, rho(dx) rho(dy) ... k>
        check("rope2d relativity", 40, |rng| {
            let (pn, pm) = (rand_pose(rng), rand_pose(rng));
            let q: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let k: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let mut qp = q.clone();
            let mut kp = k.clone();
            rope2d_project(&mut qp, &pn, &[1.0]);
            rope2d_project(&mut kp, &pm, &[1.0]);
            let got: f64 = qp
                .iter()
                .zip(kp.iter())
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            // expected: rotate k by the relative offsets, dot with raw q
            let (dx, dy) = (pm.x - pn.x, pm.y - pn.y);
            let (r0, r1) = rotate_pair(k[0] as f64, k[1] as f64, dx);
            let (r2, r3) = rotate_pair(k[2] as f64, k[3] as f64, dy);
            let expect = q[0] as f64 * r0
                + q[1] as f64 * r1
                + q[2] as f64 * r2
                + q[3] as f64 * r3;
            crate::proplite::close(got, expect, 1e-6, "bilinear form")
        });
    }

    #[test]
    fn raw_pose_kv_pair_is_bit_identical_to_single_side() {
        // the fused hot path projects pairs; the generic row() cold path
        // projects one side — both must emit the exact same bits
        let mut rng = Rng::new(77);
        for (method, d, f) in [
            (Method::Abs, 8, 0),
            (Method::Rope2d, 8, 0),
            (Method::Se2Rep, 9, 0),
            (Method::Se2Fourier, 12, 5),
        ] {
            let m = 5;
            let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let poses: Vec<Pose> = (0..m).map(|_| rand_pose(&mut rng)).collect();
            let kv = RawPoseKv {
                k: &k,
                v: &v,
                poses: &poses,
                method,
                d,
                fourier_f: f,
                scales: &[1.0, 0.5],
                pref: 1.25,
            };
            assert_eq!(kv.len(), m);
            let mut se2f = None;
            let (mut kp, mut vp) = (Vec::new(), Vec::new());
            let mut single = Vec::new();
            for j in 0..m {
                kv.project_pair_into(j, &mut se2f, &mut kp, &mut vp);
                assert_eq!(kp.len(), kv.proj_width(), "{method:?} k width");
                assert_eq!(vp.len(), kv.proj_width(), "{method:?} v width");
                kv.project_row_into(j, false, &mut se2f, &mut single);
                assert_eq!(kp, single, "{method:?} key row {j}");
                kv.project_row_into(j, true, &mut se2f, &mut single);
                assert_eq!(vp, single, "{method:?} value row {j}");
            }
        }
    }

    #[test]
    fn se2rep_q_then_k_composes_to_relative() {
        // q^T [psi(pn^-1)] [psi(pm)] k == q^T psi(pn^-1 pm) k
        check("se2rep composition", 40, |rng| {
            let (pn, pm) = (rand_pose(rng), rand_pose(rng));
            let q: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
            let k: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
            let mut qp = q.clone();
            let mut kp = k.clone();
            se2rep_project_q(&mut qp, &pn, &[1.0]);
            se2rep_project_k(&mut kp, &pm, &[1.0]);
            let got: f64 = qp
                .iter()
                .zip(kp.iter())
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let rel = pn.relative_to(&pm).matrix();
            let kk: Vec<f64> = k.iter().map(|v| *v as f64).collect();
            let relk = rel.matvec(&kk);
            let expect: f64 = q
                .iter()
                .zip(relk.iter())
                .map(|(a, b)| (*a as f64) * b)
                .sum();
            crate::proplite::close(got, expect, 1e-5, "bilinear form")
        });
    }
}
