//! Paper Algorithm 2: relative SDPA with linear memory.
//!
//! Per-token pre-projection (phi_q^T q, phi_k k, phi_k v), then a streaming
//! flash-style SDPA (online softmax, O(c) per row), then per-token
//! post-projection.  No N x M tensor is ever materialized — the
//! `peak_temp_bytes` accounting proves it.
//!
//! The SDPA core is the blocked multithreaded kernel in
//! [`super::kernel`]; [`attention_ref`] runs the same projections over the
//! scalar oracle ([`super::kernel::flash_sdpa_scalar`]) and is what the
//! equivalence tests and the CI perf gate compare against.

use crate::config::Method;
use crate::geometry::Pose;

use super::kernel::{flash_sdpa_blocked, flash_sdpa_fused, flash_sdpa_scalar, KernelConfig};
use super::projections::{self as proj, RawPoseKv};
use super::{AttnOutput, AttnProblem};

/// Query-row threshold below which [`attention_with`] takes the fused
/// path.  Fusion re-projects each key block once per query *chunk*
/// (`kernel::ROWS_PER_TASK` rows), so its projection work scales with
/// `ceil(n / 8) * m` versus project-then-attend's `n + m`: at decode
/// shapes (n ≤ chunk ⇒ exactly one projection pass over the keys) fusion
/// strictly wins by never materializing the O(m·c) k~/v~ tensors, while
/// at prefill shapes the recompute factor makes the materialized path
/// faster.  See DESIGN.md §18.
pub const FUSED_MAX_QUERY_ROWS: usize = 16;

/// The scalar flash-SDPA oracle, re-exported under its historical name so
/// callers of `linear::flash_sdpa` keep compiling (the blocked kernel
/// lives in [`super::kernel::flash_sdpa_blocked`]).
///
/// One query row (`tq = [1]`) attending two key rows (`tk = [0, 0]`) of
/// width `c = 4`; both values rows are constant 2.0, so the softmax mix
/// must return exactly 2.0 in every output slot:
///
/// ```
/// use se2attn::attention::linear::flash_sdpa;
///
/// let q = vec![1.0f32; 4]; // (n=1, c=4)
/// let k = vec![1.0f32; 8]; // (m=2, c=4)
/// let v = vec![2.0f32; 8];
/// let (tq, tk) = (vec![1i32], vec![0i32, 0]);
/// let mut out = vec![0.0f32; 4];
/// flash_sdpa(&q, &k, &v, &tq, &tk, 4, 0.5, &mut out);
/// assert!(out.iter().all(|&o| (o - 2.0).abs() < 1e-6));
/// ```
pub use super::kernel::flash_sdpa_scalar as flash_sdpa;

/// Projected per-head width c for a problem.
pub fn proj_dim(method: Method, d: usize, fourier_f: usize) -> usize {
    match method {
        Method::Se2Fourier => proj::se2f_block_width(fourier_f) * (d / 6),
        _ => d,
    }
}

/// The projected tensors of Algorithm 2 lines 1–2 (q~, k~, v~), plus the
/// SDPA scale they must be attended with.  Public so benches and tests
/// can time / verify the SDPA core on its own, without re-projecting per
/// iteration.
pub struct Projected {
    pub qt: Vec<f32>,
    pub kt: Vec<f32>,
    pub vt: Vec<f32>,
    /// Projected per-head width.
    pub c: usize,
    /// Effective SDPA scale (1/sqrt(d) for the width-preserving methods;
    /// 1/sqrt(c) for se2fourier, whose (c/d)^(1/4) prefactor on q~/k~
    /// makes the composition equal 1/sqrt(d)).
    pub eff_scale: f64,
}

impl Projected {
    /// Bytes held by the materialized q~/k~/v~ tensors.  This is the
    /// projection-intermediate cost of the project-then-attend path only:
    /// the fused path ([`attention_fused_with`]) never builds a
    /// `Projected` and reports **zero** projection-intermediate bytes —
    /// its entire transient footprint is the O(block_m·c) per-thread
    /// kernel scratch measured under the `obs` allocator's
    /// `kernel_scratch` scope.
    pub fn bytes(&self) -> usize {
        (self.qt.len() + self.kt.len() + self.vt.len()) * std::mem::size_of::<f32>()
    }
}

/// Pre-projection (Alg. 2 lines 1–2): linear in N + M.
pub fn project(p: &AttnProblem) -> Projected {
    let (n, m, d, f) = (p.n(), p.m(), p.d, p.fourier_f);
    let c = proj_dim(p.method, d, f);
    // Alg. 2 prefactor (c/d)^(1/4) on q~ and k~ makes the effective scale
    // 1/sqrt(d) after SDPA's 1/sqrt(c).
    let pref = ((c as f64) / (d as f64)).powf(0.25) as f32;

    let mut qt = vec![0.0f32; n * c];
    let mut kt = vec![0.0f32; m * c];
    let mut vt = vec![0.0f32; m * c];
    let mut scratch: Vec<f32> = Vec::with_capacity(c);

    match p.method {
        Method::Abs => {
            qt.copy_from_slice(p.q);
            kt.copy_from_slice(p.k);
            vt.copy_from_slice(p.v);
        }
        Method::Rope2d => {
            qt.copy_from_slice(p.q);
            kt.copy_from_slice(p.k);
            vt.copy_from_slice(p.v);
            for i in 0..n {
                proj::rope2d_project(&mut qt[i * c..(i + 1) * c], &p.pose_q[i], p.scales);
            }
            for j in 0..m {
                proj::rope2d_project(&mut kt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
                // Alg. 2 line 2 transforms values too (v~ = phi_k v); the
                // post-attention phi_q rotation makes the composition equal
                // phi(p_rel) v as in Alg. 1 line 3.
                proj::rope2d_project(&mut vt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
            }
        }
        Method::Se2Rep => {
            qt.copy_from_slice(p.q);
            kt.copy_from_slice(p.k);
            vt.copy_from_slice(p.v);
            for i in 0..n {
                proj::se2rep_project_q(&mut qt[i * c..(i + 1) * c], &p.pose_q[i], p.scales);
            }
            for j in 0..m {
                proj::se2rep_project_k(&mut kt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
                proj::se2rep_project_k(&mut vt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
            }
        }
        Method::Se2Fourier => {
            let mut key_scratch = proj::Se2fKeyScratch::new(f);
            for i in 0..n {
                proj::se2f_project_q(
                    &p.q[i * d..(i + 1) * d],
                    &p.pose_q[i],
                    p.scales,
                    f,
                    pref,
                    &mut scratch,
                );
                qt[i * c..(i + 1) * c].copy_from_slice(&scratch);
            }
            let mut v_scratch: Vec<f32> = Vec::with_capacity(c);
            for j in 0..m {
                proj::se2f_project_kv_with(
                    &mut key_scratch,
                    &p.k[j * d..(j + 1) * d],
                    &p.v[j * d..(j + 1) * d],
                    &p.pose_k[j],
                    p.scales,
                    pref,
                    &mut scratch,
                    &mut v_scratch,
                );
                kt[j * c..(j + 1) * c].copy_from_slice(&scratch);
                vt[j * c..(j + 1) * c].copy_from_slice(&v_scratch);
            }
        }
    }

    let eff_scale = match p.method {
        Method::Se2Fourier => 1.0 / (c as f64).sqrt(),
        // abs/rope2d/se2rep use 1/sqrt(d) directly (c == d)
        _ => 1.0 / (d as f64).sqrt(),
    };
    Projected {
        qt,
        kt,
        vt,
        c,
        eff_scale,
    }
}

/// Post-projection (Alg. 2 line 4): map attended o~ rows back to width d.
fn unproject(p: &AttnProblem, ot: &[f32], c: usize) -> Vec<f32> {
    let (n, d, f) = (p.n(), p.d, p.fourier_f);
    let mut out = vec![0.0f32; n * d];
    match p.method {
        Method::Abs => out.copy_from_slice(ot),
        Method::Rope2d => {
            out.copy_from_slice(ot);
            // phi_q(p_n) = rho(-a x_n) blocks: rotate by the negated own
            // coordinates (Alg. 2 line 4).
            for i in 0..n {
                let neg = Pose {
                    x: -p.pose_q[i].x,
                    y: -p.pose_q[i].y,
                    theta: 0.0,
                };
                proj::rope2d_project(&mut out[i * d..(i + 1) * d], &neg, p.scales);
            }
        }
        Method::Se2Rep => {
            out.copy_from_slice(ot);
            for i in 0..n {
                proj::se2rep_unproject_o(&mut out[i * d..(i + 1) * d], &p.pose_q[i], p.scales);
            }
        }
        Method::Se2Fourier => {
            let mut scratch: Vec<f32> = Vec::with_capacity(d);
            for i in 0..n {
                proj::se2f_unproject_o(
                    &ot[i * c..(i + 1) * c],
                    &p.pose_q[i],
                    p.scales,
                    f,
                    &mut scratch,
                );
                out[i * d..(i + 1) * d].copy_from_slice(&scratch);
            }
        }
    }
    out
}

/// Algorithm 2 with the default kernel configuration (env-overridable —
/// see [`KernelConfig`]).  Transient memory is linear in N + M at worst
/// (project-then-attend) and O(block_m·c) per thread at best (fused
/// decode shapes) — see [`attention_with`] for the routing rule.
pub fn attention(p: &AttnProblem) -> AttnOutput {
    attention_with(p, &KernelConfig::default())
}

/// Algorithm 2 over the blocked multithreaded flash kernel, routing
/// between the fused and project-then-attend executions by query count:
/// `n <= FUSED_MAX_QUERY_ROWS` (decode / short-burst shapes) takes
/// [`attention_fused_with`], everything else takes
/// [`attention_projected_with`].  The two are bit-identical for a given
/// `{block_m, lanes}`, so routing never changes results — only the
/// transient-memory / recompute trade (DESIGN.md §18).
pub fn attention_with(p: &AttnProblem, kcfg: &KernelConfig) -> AttnOutput {
    if p.n() <= FUSED_MAX_QUERY_ROWS {
        attention_fused_with(p, kcfg)
    } else {
        attention_projected_with(p, kcfg)
    }
}

/// Algorithm 2, project-then-attend: materialize q~/k~/v~ once, then run
/// the blocked flash kernel over the projected tensors.  Cheapest in
/// compute (each key row projected exactly once regardless of n) but
/// carries the O((n + 2m)·c) projection intermediates in its peak.
pub fn attention_projected_with(p: &AttnProblem, kcfg: &KernelConfig) -> AttnOutput {
    p.validate();
    let prj = project(p);
    let n = p.n();
    let mut ot = vec![0.0f32; n * prj.c];
    let kernel_scratch = flash_sdpa_blocked(
        &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, prj.c, prj.eff_scale, &mut ot, kcfg,
    );
    let out = unproject(p, &ot, prj.c);
    // projected q~/k~/v~/o~ are the largest transients: 4 * max(n,m) * c
    // f32, plus O(c) flash scratch per participating worker thread — still
    // linear in N + M per worker.
    let peak = prj.bytes() + ot.len() * std::mem::size_of::<f32>() + kernel_scratch;
    AttnOutput {
        out,
        peak_temp_bytes: peak,
    }
}

/// Algorithm 2, fused: phi_q q, phi_k k/v, and the o~ unprojection are all
/// computed inside the kernel's per-chunk loops, so **no** projected
/// tensor is ever allocated — `peak_temp_bytes` is exactly the per-thread
/// kernel scratch (O(block_m·c) per participating worker, constant in n
/// and m).  Bit-identical to [`attention_projected_with`] for the same
/// kernel config.
pub fn attention_fused_with(p: &AttnProblem, kcfg: &KernelConfig) -> AttnOutput {
    p.validate();
    let (n, d, f) = (p.n(), p.d, p.fourier_f);
    let c = proj_dim(p.method, d, f);
    let pref = ((c as f64) / (d as f64)).powf(0.25) as f32;
    let eff_scale = match p.method {
        Method::Se2Fourier => 1.0 / (c as f64).sqrt(),
        _ => 1.0 / (d as f64).sqrt(),
    };
    let kv = RawPoseKv {
        k: p.k,
        v: p.v,
        poses: p.pose_k,
        method: p.method,
        d,
        fourier_f: f,
        scales: p.scales,
        pref,
    };
    let mut out = vec![0.0f32; n * d];
    let kernel_scratch = flash_sdpa_fused(p.q, p.pose_q, &kv, p.tq, p.tk, eff_scale, &mut out, kcfg);
    AttnOutput {
        out,
        // Zero projection intermediates: the output buffer is the result,
        // not a transient, so the fused peak is kernel scratch alone.
        peak_temp_bytes: kernel_scratch,
    }
}

/// Algorithm 2 over the scalar oracle kernel — the reference the blocked
/// path is verified against (`tests/kernel_equivalence.rs`) and the
/// baseline the CI perf-smoke gate must beat.
pub fn attention_ref(p: &AttnProblem) -> AttnOutput {
    p.validate();
    let prj = project(p);
    let n = p.n();
    let mut ot = vec![0.0f32; n * prj.c];
    flash_sdpa_scalar(&prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, prj.c, prj.eff_scale, &mut ot);
    let out = unproject(p, &ot, prj.c);
    let peak = prj.bytes() + ot.len() * std::mem::size_of::<f32>();
    AttnOutput {
        out,
        peak_temp_bytes: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Pose;
    use crate::prng::Rng;
    use crate::proplite::check;

    #[test]
    fn fully_masked_rows_are_zero() {
        let mut rng = Rng::new(1);
        let d = 12;
        let n = 4;
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..n).map(|_| Pose::IDENTITY).collect();
        let tq = vec![-5i32; n];
        let tk = vec![0i32; n];
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d,
            fourier_f: 6,
            scales: &[1.0],
            q: &q,
            k: &q,
            v: &q,
            pose_q: &poses,
            pose_k: &poses,
            tq: &tq,
            tk: &tk,
        };
        let out = attention(&p).out;
        assert!(out.iter().all(|&x| x == 0.0));
        let out_ref = attention_ref(&p).out;
        assert!(out_ref.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn blocked_path_matches_scalar_reference() {
        let scales = [1.0, 0.5];
        let mut rng = Rng::new(4242);
        for (method, d) in [
            (Method::Abs, 8),
            (Method::Rope2d, 8),
            (Method::Se2Rep, 9),
            (Method::Se2Fourier, 12),
        ] {
            let (q, k, v, pq, pk, tq, tk) =
                crate::attention::tests::random_problem_data(&mut rng, 12, 19, d, 1.5, 3);
            let p = AttnProblem {
                method,
                d,
                fourier_f: 16,
                scales: &scales,
                q: &q,
                k: &k,
                v: &v,
                pose_q: &pq,
                pose_k: &pk,
                tq: &tq,
                tk: &tk,
            };
            let want = attention_ref(&p).out;
            let got = attention_with(&p, &KernelConfig::fixed(5, 8, 4)).out;
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!((a - b).abs() < 1e-5, "{method:?} [{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn linear_se2fourier_is_frame_invariant() {
        check("alg2 se2fourier invariance", 15, |rng| {
            let d = 12;
            let n = 6;
            let f = 20;
            let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let poses: Vec<Pose> = (0..n)
                .map(|_| {
                    Pose::new(
                        rng.range(-1.0, 1.0),
                        rng.range(-1.0, 1.0),
                        rng.range(-3.0, 3.0),
                    )
                })
                .collect();
            let t: Vec<i32> = (0..n).map(|_| rng.int_range(0, 2) as i32).collect();
            let z = Pose::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-3.0, 3.0));
            let zi = z.inverse();
            let shifted: Vec<Pose> = poses.iter().map(|p| zi.compose(p)).collect();
            let run = |ps: &[Pose]| {
                attention(&AttnProblem {
                    method: Method::Se2Fourier,
                    d,
                    fourier_f: f,
                    scales: &[1.0, 0.5],
                    q: &q,
                    k: &k,
                    v: &v,
                    pose_q: ps,
                    pose_k: ps,
                    tq: &t,
                    tk: &t,
                })
                .out
            };
            let (o1, o2) = (run(&poses), run(&shifted));
            crate::proplite::all_close_f32(&o1, &o2, 5e-3, "invariance")
        });
    }

    #[test]
    fn fused_path_matches_scalar_reference_ragged() {
        let scales = [1.0, 0.5];
        let mut rng = Rng::new(90210);
        for (method, d) in [
            (Method::Abs, 8),
            (Method::Rope2d, 8),
            (Method::Se2Rep, 9),
            (Method::Se2Fourier, 12),
        ] {
            let (q, k, v, pq, pk, tq, tk) =
                crate::attention::tests::random_problem_data(&mut rng, 9, 31, d, 1.5, 3);
            let p = AttnProblem {
                method,
                d,
                fourier_f: 8,
                scales: &scales,
                q: &q,
                k: &k,
                v: &v,
                pose_q: &pq,
                pose_k: &pk,
                tq: &tq,
                tk: &tk,
            };
            let want = attention_ref(&p).out;
            let got = attention_fused_with(&p, &KernelConfig::fixed(7, 8, 3)).out;
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!((a - b).abs() < 1e-5, "{method:?} [{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn routing_is_bit_identical_to_both_executions() {
        // n <= FUSED_MAX_QUERY_ROWS routes fused; above routes projected.
        // Either way the result must be bitwise what the explicit entry
        // point produces, and the two entry points must agree bitwise.
        let scales = [1.0, 0.5];
        let mut rng = Rng::new(777);
        let d = 12;
        let cfg = KernelConfig::fixed(6, 8, 2);
        for n in [FUSED_MAX_QUERY_ROWS, FUSED_MAX_QUERY_ROWS + 1] {
            let (q, k, v, pq, pk, tq, tk) =
                crate::attention::tests::random_problem_data(&mut rng, n, 25, d, 1.5, 3);
            let p = AttnProblem {
                method: Method::Se2Fourier,
                d,
                fourier_f: 6,
                scales: &scales,
                q: &q,
                k: &k,
                v: &v,
                pose_q: &pq,
                pose_k: &pk,
                tq: &tq,
                tk: &tk,
            };
            let routed = attention_with(&p, &cfg);
            let fused = attention_fused_with(&p, &cfg);
            let projected = attention_projected_with(&p, &cfg);
            assert_eq!(fused.out, projected.out, "n={n}: executions diverge");
            assert_eq!(routed.out, fused.out, "n={n}");
            if n <= FUSED_MAX_QUERY_ROWS {
                assert_eq!(routed.peak_temp_bytes, fused.peak_temp_bytes);
            } else {
                assert_eq!(routed.peak_temp_bytes, projected.peak_temp_bytes);
            }
        }
    }

    #[test]
    fn fused_peak_has_zero_projection_intermediates() {
        let mut rng = Rng::new(31337);
        let d = 12;
        let (n, m) = (8, 512);
        let (q, k, v, pq, pk, tq, tk) =
            crate::attention::tests::random_problem_data(&mut rng, n, m, d, 1.5, 3);
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d,
            fourier_f: 8,
            scales: &[1.0, 0.5],
            q: &q,
            k: &k,
            v: &v,
            pose_q: &pq,
            pose_k: &pk,
            tq: &tq,
            tk: &tk,
        };
        let cfg = KernelConfig::fixed(32, 8, 2);
        let c = proj_dim(p.method, d, p.fourier_f);
        let fused = attention_fused_with(&p, &cfg);
        let projected = attention_projected_with(&p, &cfg);
        // Project-then-attend carries the k~/v~ tensors (>= 2*m*c f32);
        // the fused peak is per-thread scratch only — constant in m.
        assert!(projected.peak_temp_bytes >= 2 * m * c * 4);
        assert!(
            fused.peak_temp_bytes <= 2 * cfg.scratch_bytes_per_thread_fused(c, m),
            "fused peak {} exceeds modeled scratch",
            fused.peak_temp_bytes
        );
        assert!(fused.peak_temp_bytes * 4 < projected.peak_temp_bytes);
    }

    #[test]
    fn proj_dim_table() {
        assert_eq!(proj_dim(Method::Abs, 48, 12), 48);
        assert_eq!(proj_dim(Method::Rope2d, 48, 12), 48);
        assert_eq!(proj_dim(Method::Se2Rep, 48, 12), 48);
        assert_eq!(proj_dim(Method::Se2Fourier, 48, 12), 50 * 8);
    }
}
