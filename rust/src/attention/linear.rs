//! Paper Algorithm 2: relative SDPA with linear memory.
//!
//! Per-token pre-projection (phi_q^T q, phi_k k, phi_k v), then a streaming
//! flash-style SDPA (online softmax, O(c) per row), then per-token
//! post-projection.  No N x M tensor is ever materialized — the
//! `peak_temp_bytes` accounting proves it.

use crate::config::Method;
use crate::geometry::Pose;

use super::projections as proj;
use super::{AttnOutput, AttnProblem};

/// Streaming SDPA over projected tensors: q (n x c), k/v (m x c), online
/// softmax with visibility rule tq >= tk.  O(m*c) reads per row but O(c)
/// transient state — the CPU mirror of the Pallas flash kernel.
///
/// Public so the incremental decode engine
/// ([`super::incremental::IncrementalAttention`]) can answer new-query
/// attention against its cached `phi_k k` / `phi_k v` rows through the
/// exact same online-softmax path.
pub fn flash_sdpa(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
) {
    let n = tq.len();
    let m = tk.len();
    let mut acc = vec![0.0f64; c];
    for i in 0..n {
        let qi = &q[i * c..(i + 1) * c];
        let mut m_i = f64::NEG_INFINITY;
        let mut l_i = 0.0f64;
        acc.iter_mut().for_each(|a| *a = 0.0);
        for j in 0..m {
            if tq[i] < tk[j] {
                continue;
            }
            let kj = &k[j * c..(j + 1) * c];
            let s: f64 = qi
                .iter()
                .zip(kj.iter())
                .map(|(a, b)| *a as f64 * *b as f64)
                .sum::<f64>()
                * scale;
            let m_new = m_i.max(s);
            let alpha = if m_i == f64::NEG_INFINITY {
                0.0
            } else {
                (m_i - m_new).exp()
            };
            let p = (s - m_new).exp();
            l_i = l_i * alpha + p;
            let vj = &v[j * c..(j + 1) * c];
            for (a, &vv) in acc.iter_mut().zip(vj.iter()) {
                *a = *a * alpha + p * vv as f64;
            }
            m_i = m_new;
        }
        let oi = &mut out[i * c..(i + 1) * c];
        if l_i > 0.0 {
            for (o, &a) in oi.iter_mut().zip(acc.iter()) {
                *o = (a / l_i) as f32;
            }
        } else {
            oi.iter_mut().for_each(|o| *o = 0.0);
        }
    }
}

/// Projected per-head width c for a problem.
pub fn proj_dim(method: Method, d: usize, fourier_f: usize) -> usize {
    match method {
        Method::Se2Fourier => proj::se2f_block_width(fourier_f) * (d / 6),
        _ => d,
    }
}

/// Algorithm 2.  Linear transient memory: three projected tensors of width
/// c plus O(c) online-softmax state.
pub fn attention(p: &AttnProblem) -> AttnOutput {
    p.validate();
    let (n, m, d, f) = (p.n(), p.m(), p.d, p.fourier_f);
    let c = proj_dim(p.method, d, f);
    let scale = 1.0 / (c as f64).sqrt();
    // Alg. 2 prefactor (c/d)^(1/4) on q~ and k~ makes the effective scale
    // 1/sqrt(d) after SDPA's 1/sqrt(c).
    let pref = ((c as f64) / (d as f64)).powf(0.25) as f32;

    let mut qt = vec![0.0f32; n * c];
    let mut kt = vec![0.0f32; m * c];
    let mut vt = vec![0.0f32; m * c];
    let mut scratch: Vec<f32> = Vec::with_capacity(c);

    // ---- pre-projection (linear in N+M) --------------------------------
    match p.method {
        Method::Abs => {
            qt.copy_from_slice(p.q);
            kt.copy_from_slice(p.k);
            vt.copy_from_slice(p.v);
        }
        Method::Rope2d => {
            qt.copy_from_slice(p.q);
            kt.copy_from_slice(p.k);
            vt.copy_from_slice(p.v);
            for i in 0..n {
                proj::rope2d_project(&mut qt[i * c..(i + 1) * c], &p.pose_q[i], p.scales);
            }
            for j in 0..m {
                proj::rope2d_project(&mut kt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
                // Alg. 2 line 2 transforms values too (v~ = phi_k v); the
                // post-attention phi_q rotation makes the composition equal
                // phi(p_rel) v as in Alg. 1 line 3.
                proj::rope2d_project(&mut vt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
            }
        }
        Method::Se2Rep => {
            qt.copy_from_slice(p.q);
            kt.copy_from_slice(p.k);
            vt.copy_from_slice(p.v);
            for i in 0..n {
                proj::se2rep_project_q(&mut qt[i * c..(i + 1) * c], &p.pose_q[i], p.scales);
            }
            for j in 0..m {
                proj::se2rep_project_k(&mut kt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
                proj::se2rep_project_k(&mut vt[j * c..(j + 1) * c], &p.pose_k[j], p.scales);
            }
        }
        Method::Se2Fourier => {
            let mut key_scratch = proj::Se2fKeyScratch::new(f);
            for i in 0..n {
                proj::se2f_project_q(
                    &p.q[i * d..(i + 1) * d],
                    &p.pose_q[i],
                    p.scales,
                    f,
                    pref,
                    &mut scratch,
                );
                qt[i * c..(i + 1) * c].copy_from_slice(&scratch);
            }
            let mut v_scratch: Vec<f32> = Vec::with_capacity(c);
            for j in 0..m {
                proj::se2f_project_kv_with(
                    &mut key_scratch,
                    &p.k[j * d..(j + 1) * d],
                    &p.v[j * d..(j + 1) * d],
                    &p.pose_k[j],
                    p.scales,
                    pref,
                    &mut scratch,
                    &mut v_scratch,
                );
                kt[j * c..(j + 1) * c].copy_from_slice(&scratch);
                vt[j * c..(j + 1) * c].copy_from_slice(&v_scratch);
            }
        }
    }

    // ---- standard SDPA (flash-style, linear memory) ---------------------
    let mut ot = vec![0.0f32; n * c];
    let eff_scale = match p.method {
        // abs/rope2d/se2rep use 1/sqrt(d) directly (c == d)
        Method::Se2Fourier => scale,
        _ => 1.0 / (d as f64).sqrt(),
    };
    flash_sdpa(&qt, &kt, &vt, p.tq, p.tk, c, eff_scale, &mut ot);

    // ---- post-projection (Alg. 2 line 4) --------------------------------
    let mut out = vec![0.0f32; n * d];
    match p.method {
        Method::Abs => out.copy_from_slice(&ot),
        Method::Rope2d => {
            out.copy_from_slice(&ot);
            // phi_q(p_n) = rho(-a x_n) blocks: rotate by the negated own
            // coordinates (Alg. 2 line 4).
            for i in 0..n {
                let neg = Pose {
                    x: -p.pose_q[i].x,
                    y: -p.pose_q[i].y,
                    theta: 0.0,
                };
                proj::rope2d_project(&mut out[i * d..(i + 1) * d], &neg, p.scales);
            }
        }
        Method::Se2Rep => {
            out.copy_from_slice(&ot);
            for i in 0..n {
                proj::se2rep_unproject_o(&mut out[i * d..(i + 1) * d], &p.pose_q[i], p.scales);
            }
        }
        Method::Se2Fourier => {
            for i in 0..n {
                proj::se2f_unproject_o(
                    &ot[i * c..(i + 1) * c],
                    &p.pose_q[i],
                    p.scales,
                    f,
                    &mut scratch,
                );
                out[i * d..(i + 1) * d].copy_from_slice(&scratch);
            }
        }
    }

    // projected q~/k~/v~/o~ are the largest transients: 4 * max(n,m) * c f32
    let peak = (qt.len() + kt.len() + vt.len() + ot.len())
        * std::mem::size_of::<f32>();
    AttnOutput {
        out,
        peak_temp_bytes: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Pose;
    use crate::prng::Rng;
    use crate::proplite::check;

    #[test]
    fn fully_masked_rows_are_zero() {
        let mut rng = Rng::new(1);
        let d = 12;
        let n = 4;
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..n).map(|_| Pose::IDENTITY).collect();
        let tq = vec![-5i32; n];
        let tk = vec![0i32; n];
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d,
            fourier_f: 6,
            scales: &[1.0],
            q: &q,
            k: &q,
            v: &q,
            pose_q: &poses,
            pose_k: &poses,
            tq: &tq,
            tk: &tk,
        };
        let out = attention(&p).out;
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn linear_se2fourier_is_frame_invariant() {
        check("alg2 se2fourier invariance", 15, |rng| {
            let d = 12;
            let n = 6;
            let f = 20;
            let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let poses: Vec<Pose> = (0..n)
                .map(|_| {
                    Pose::new(
                        rng.range(-1.0, 1.0),
                        rng.range(-1.0, 1.0),
                        rng.range(-3.0, 3.0),
                    )
                })
                .collect();
            let t: Vec<i32> = (0..n).map(|_| rng.int_range(0, 2) as i32).collect();
            let z = Pose::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-3.0, 3.0));
            let zi = z.inverse();
            let shifted: Vec<Pose> = poses.iter().map(|p| zi.compose(p)).collect();
            let run = |ps: &[Pose]| {
                attention(&AttnProblem {
                    method: Method::Se2Fourier,
                    d,
                    fourier_f: f,
                    scales: &[1.0, 0.5],
                    q: &q,
                    k: &k,
                    v: &v,
                    pose_q: ps,
                    pose_k: ps,
                    tq: &t,
                    tk: &t,
                })
                .out
            };
            let (o1, o2) = (run(&poses), run(&shifted));
            crate::proplite::all_close_f32(&o1, &o2, 5e-3, "invariance")
        });
    }

    #[test]
    fn proj_dim_table() {
        assert_eq!(proj_dim(Method::Abs, 48, 12), 48);
        assert_eq!(proj_dim(Method::Rope2d, 48, 12), 48);
        assert_eq!(proj_dim(Method::Se2Rep, 48, 12), 48);
        assert_eq!(proj_dim(Method::Se2Fourier, 48, 12), 50 * 8);
    }
}
