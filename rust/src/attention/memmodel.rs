//! Byte-accurate HBM model for the paper's headline claim: Algorithm 1
//! needs O(N*M) memory, Algorithm 2 O((N+M)*c).
//!
//! The model counts the tensors a GPU implementation would materialize in
//! HBM (not registers/SRAM): for Alg. 1 the pairwise phi tensor, bias and
//! weight matrices; for Alg. 2 the projected q~/k~/v~/o~ plus flash-SDPA's
//! per-row statistics.  The memory-scaling bench prints both the model and
//! measured peak-allocation numbers.

use crate::config::{CachePrecision, Method};

use super::linear::proj_dim;
use super::quant::QUANT_ROW_OVERHEAD;

/// Bytes per element (f32 on this testbed; the paper runs fp16/bf16 —
/// ratios are unchanged).
pub const BYTES_F32: usize = 4;
pub const BYTES_F16: usize = 2;

/// Bytes of the world-frame pose retained per cached row (3 × f64 —
/// geometry is never quantized; see [`super::quant`]).
pub const POSE_BYTES: usize = 3 * 8;

/// Bytes of one cached feature vector of `width` values at `precision`:
/// the stored codes plus, for quantized rows, the per-row scale/offset
/// pair.  This is THE row formula — [`super::quant::FeatureRows`] and
/// every `resident_bytes()` gauge feeding
/// [`crate::coordinator::telemetry::CacheStats`] agree with it by
/// construction (regression-tested in `tests/quantized_cache.rs`).
pub fn feature_vec_bytes(width: usize, precision: CachePrecision) -> usize {
    width * precision.bytes_per_value()
        + if precision.is_quantized() {
            QUANT_ROW_OVERHEAD
        } else {
            0
        }
}

#[derive(Clone, Copy, Debug)]
pub struct MemoryEstimate {
    /// Inputs resident either way (q, k, v, poses, timesteps).
    pub input_bytes: usize,
    /// Transient working set the algorithm materializes.
    pub transient_bytes: usize,
}

impl MemoryEstimate {
    pub fn total(&self) -> usize {
        self.input_bytes + self.transient_bytes
    }
}

fn input_bytes(n: usize, m: usize, d: usize, elem: usize) -> usize {
    // q + (k + v) + poses (3 floats) + timesteps (i32)
    n * d * elem + 2 * m * d * elem + (n + m) * 3 * elem + (n + m) * 4
}

/// Algorithm 1 (quadratic): the N x M x d x d phi tensor is never stored
/// whole by a sane implementation, but the N x M bias and attention-weight
/// matrices are, plus one d x d phi per active pair during aggregation.
/// GoRela-style implementations additionally materialize the N x M x 3
/// relative-pose tensor; we count bias + weights + relposes.
pub fn quadratic_bytes(n: usize, m: usize, d: usize, elem: usize) -> MemoryEstimate {
    let bias = n * m * elem;
    let weights = n * m * elem;
    let rel_poses = n * m * 3 * elem;
    MemoryEstimate {
        input_bytes: input_bytes(n, m, d, elem),
        transient_bytes: bias + weights + rel_poses,
    }
}

/// Algorithm 2 (linear): projected q~ (N x c), k~/v~ (M x c), o~ (N x c)
/// plus flash statistics (2 floats per row).
pub fn linear_bytes(
    method: Method,
    n: usize,
    m: usize,
    d: usize,
    fourier_f: usize,
    elem: usize,
) -> MemoryEstimate {
    let c = proj_dim(method, d, fourier_f);
    let projected = (n * c + 2 * m * c + n * c) * elem;
    let flash_stats = 2 * n * elem;
    MemoryEstimate {
        input_bytes: input_bytes(n, m, d, elem),
        transient_bytes: projected + flash_stats,
    }
}

/// Algorithm 2 over the fused kernel
/// ([`crate::attention::kernel::flash_sdpa_fused`]): projections are
/// computed inside the key-block loop, so the projected-intermediate term
/// of [`linear_bytes`] vanishes entirely.  The transient working set is
/// the per-thread kernel scratch — one (block_m x c) k~/v~ tile pair plus
/// O(chunk·c) online-softmax state — which is constant in both n and m.
/// `threads` is the number of participating workers (at most
/// `ceil(n / chunk)`), matching the kernel's own
/// `scratch_bytes_per_thread_fused` accounting.
pub fn linear_fused_bytes(
    method: Method,
    n: usize,
    m: usize,
    d: usize,
    fourier_f: usize,
    block_m: usize,
    threads: usize,
) -> MemoryEstimate {
    use crate::attention::kernel::{KernelConfig, ROWS_PER_TASK};
    let c = proj_dim(method, d, fourier_f);
    let cfg = KernelConfig::fixed(block_m, 8, threads.max(1));
    let participating = threads.max(1).min(n.div_ceil(ROWS_PER_TASK).max(1));
    MemoryEstimate {
        input_bytes: input_bytes(n, m, d, BYTES_F32),
        // Zero projected intermediates — scratch only.
        transient_bytes: participating * cfg.scratch_bytes_per_thread_fused(c, m),
    }
}

/// Bytes of one cached incremental-decode row pair at a storage
/// precision: projected `phi_k k` and `phi_k v` (width c each, with
/// per-row scale/offset when quantized) plus the visibility timestep
/// (i32) and the anchor-frame pose (3 f64, never quantized) retained for
/// drift/re-anchor bookkeeping.
pub fn kv_row_bytes(
    method: Method,
    d: usize,
    fourier_f: usize,
    precision: CachePrecision,
) -> usize {
    let c = proj_dim(method, d, fourier_f);
    2 * feature_vec_bytes(c, precision) + 4 + POSE_BYTES
}

/// Resident bytes of an m-token incremental KV cache
/// ([`crate::attention::incremental::IncrementalAttention`]) — linear in
/// the window, the whole point of the paper's construction.  The f16
/// tier roughly halves the dominant `2 c` term (`2 c + 44` bytes/row vs
/// `8 c + 28` at f32), which is what the CI decode-bench gate pins at
/// ≤ 60% of the f32 bytes.
pub fn incremental_cache_bytes(
    method: Method,
    m: usize,
    d: usize,
    fourier_f: usize,
    precision: CachePrecision,
) -> usize {
    m * kv_row_bytes(method, d, fourier_f, precision)
}

/// Per-session resident bytes of a tokenized-window cache entry
/// ([`crate::coordinator::kvcache::WindowCache::resident_bytes`]): h
/// agent-step rows of invariant features (at the session's storage
/// precision) plus exact world poses.  Shared map rows are counted once
/// per *scene* via [`map_tokens_bytes`], not per session.
pub fn window_cache_bytes(
    n_agents: usize,
    history_steps: usize,
    feat_dim: usize,
    precision: CachePrecision,
) -> usize {
    n_agents * history_steps * (feature_vec_bytes(feat_dim, precision) + POSE_BYTES)
}

/// Shared map-row bytes of one scene
/// ([`crate::coordinator::kvcache::MapTokens::resident_bytes`]).  Map
/// rows are always f32: they are shared across sessions of every
/// precision and counted once per scene, so compressing them buys
/// little and would force per-precision registry entries.
pub fn map_tokens_bytes(n_map: usize, feat_dim: usize) -> usize {
    n_map * (feat_dim * BYTES_F32 + POSE_BYTES)
}

/// Projection rows touched by one decode step: the full-recompute path
/// re-projects the whole window plus the queries; the cached path projects
/// only the appended frontier plus the queries.  The ratio is the paper's
/// O(window) -> O(new) serving claim in closed form.
pub fn decode_step_projection_rows(window: usize, n_new: usize, cached: bool) -> usize {
    if cached {
        2 * n_new // append frontier + project queries
    } else {
        window + n_new
    }
}

/// N at which quadratic transient memory overtakes linear (self-attention,
/// n == m) — the crossover the memory-scaling bench sweeps across.
pub fn crossover_n(method: Method, d: usize, fourier_f: usize, elem: usize) -> usize {
    let mut n = 2;
    while n < 1 << 22 {
        let q = quadratic_bytes(n, n, d, elem).transient_bytes;
        let l = linear_bytes(method, n, n, d, fourier_f, elem).transient_bytes;
        if q > l {
            return n;
        }
        n *= 2;
    }
    usize::MAX
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_grows_quadratically() {
        let a = quadratic_bytes(256, 256, 48, BYTES_F32).transient_bytes;
        let b = quadratic_bytes(512, 512, 48, BYTES_F32).transient_bytes;
        assert_eq!(b, 4 * a);
    }

    #[test]
    fn linear_grows_linearly() {
        let a = linear_bytes(Method::Se2Fourier, 256, 256, 48, 12, BYTES_F32)
            .transient_bytes;
        let b = linear_bytes(Method::Se2Fourier, 512, 512, 48, 12, BYTES_F32)
            .transient_bytes;
        assert!(b <= 2 * a + 64);
    }

    #[test]
    fn fourier_pays_constant_factor_c_over_d() {
        // c = (4F+2)/6 * d: the paper's trade — bigger constant, better
        // asymptotics.
        let lin_fourier =
            linear_bytes(Method::Se2Fourier, 128, 128, 48, 12, BYTES_F32);
        let lin_rope =
            linear_bytes(Method::Rope2d, 128, 128, 48, 12, BYTES_F32);
        let ratio = lin_fourier.transient_bytes as f64
            / lin_rope.transient_bytes as f64;
        assert!((ratio - 50.0 / 6.0).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn fused_transients_are_constant_in_window() {
        // The fused path's transient working set must not grow with m
        // beyond the block_m cap — that is the whole point of computing
        // phi_k inside the key loop instead of materializing k~/v~.
        let a = linear_fused_bytes(Method::Se2Fourier, 8, 512, 48, 12, 64, 4).transient_bytes;
        let b = linear_fused_bytes(Method::Se2Fourier, 8, 4096, 48, 12, 64, 4).transient_bytes;
        assert_eq!(a, b, "fused transients grew with m: {a} vs {b}");
        // and sits far below project-then-attend's k~/v~ intermediates
        let projected = linear_bytes(Method::Se2Fourier, 8, 4096, 48, 12, BYTES_F32)
            .transient_bytes;
        assert!(b * 8 < projected, "fused {b} vs projected {projected}");
    }

    #[test]
    fn fused_transients_count_participating_workers_only() {
        // n=8 is a single ROWS_PER_TASK chunk: only one worker ever holds
        // scratch, no matter how many threads the config names.
        let one = linear_fused_bytes(Method::Se2Fourier, 8, 1024, 48, 12, 64, 1).transient_bytes;
        let many = linear_fused_bytes(Method::Se2Fourier, 8, 1024, 48, 12, 64, 16).transient_bytes;
        assert_eq!(one, many);
        // n=64 across 16 threads: 8 chunks -> 8 participants.
        let wide = linear_fused_bytes(Method::Se2Fourier, 64, 1024, 48, 12, 64, 16).transient_bytes;
        assert_eq!(wide, 8 * one);
    }

    #[test]
    fn incremental_cache_is_linear_in_window() {
        for p in CachePrecision::ALL {
            let a = incremental_cache_bytes(Method::Se2Fourier, 64, 48, 12, p);
            let b = incremental_cache_bytes(Method::Se2Fourier, 128, 48, 12, p);
            assert_eq!(b, 2 * a, "{p:?}");
        }
        // and matches the engine's own accounting, per precision
        use crate::attention::incremental::{IncrementalAttention, IncrementalConfig};
        for p in CachePrecision::ALL {
            let mut eng = IncrementalAttention::new(IncrementalConfig {
                method: Method::Se2Fourier,
                d: 12,
                fourier_f: 12,
                scales: vec![1.0],
                kernel: crate::attention::kernel::KernelConfig::default(),
                precision: p,
            });
            let k = vec![0.0f32; 5 * 12];
            let poses = vec![crate::geometry::Pose::IDENTITY; 5];
            eng.append(&k, &k, &poses, &[0, 0, 0, 1, 1]);
            assert_eq!(
                eng.resident_bytes(),
                incremental_cache_bytes(Method::Se2Fourier, 5, 12, 12, p),
                "{p:?}"
            );
        }
    }

    #[test]
    fn quantized_rows_cut_the_dominant_term() {
        // d=48, F=12: c=400 — the paper head.  f16 must land well under
        // the 60% CI gate; the overhead terms must keep it above 40%.
        let f32b = kv_row_bytes(Method::Se2Fourier, 48, 12, CachePrecision::F32);
        let f16b = kv_row_bytes(Method::Se2Fourier, 48, 12, CachePrecision::F16);
        assert_eq!(f32b, 8 * 400 + 28);
        assert_eq!(f16b, 4 * 400 + 2 * QUANT_ROW_OVERHEAD + 28);
        let ratio = f16b as f64 / f32b as f64;
        assert!(ratio <= 0.60, "f16/f32 row ratio {ratio}");
        assert!(ratio >= 0.40, "overhead accounting vanished: {ratio}");
        // bf16 prices identically to f16 (same code width)
        assert_eq!(
            kv_row_bytes(Method::Se2Fourier, 48, 12, CachePrecision::Bf16),
            f16b
        );
    }

    #[test]
    fn cached_decode_touches_o_new_rows() {
        // window 256, 8 new tokens: 264 rows recomputed vs 16 cached.
        assert_eq!(decode_step_projection_rows(256, 8, false), 264);
        assert_eq!(decode_step_projection_rows(256, 8, true), 16);
        let speedup = decode_step_projection_rows(256, 8, false) as f64
            / decode_step_projection_rows(256, 8, true) as f64;
        assert!(speedup > 16.0);
    }

    #[test]
    fn window_cache_bytes_counts_rows() {
        assert_eq!(
            window_cache_bytes(6, 8, 16, CachePrecision::F32),
            48 * (16 * 4 + 24)
        );
        assert_eq!(
            window_cache_bytes(6, 8, 16, CachePrecision::F16),
            48 * (16 * 2 + QUANT_ROW_OVERHEAD + 24)
        );
        assert_eq!(map_tokens_bytes(16, 16), 16 * (16 * 4 + 24));
        assert_eq!(feature_vec_bytes(16, CachePrecision::F32), 64);
        assert_eq!(
            feature_vec_bytes(16, CachePrecision::Bf16),
            32 + QUANT_ROW_OVERHEAD
        );
    }

    #[test]
    fn crossover_is_moderate() {
        // With d=48, F=12 the crossover lands in the hundreds of tokens —
        // real scenes (hundreds to thousands of elements) benefit.
        let n = crossover_n(Method::Se2Fourier, 48, 12, BYTES_F32);
        assert!(n >= 64 && n <= 2048, "crossover {n}");
    }
}
