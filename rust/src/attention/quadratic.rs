//! Paper Algorithm 1: relative SDPA with quadratic memory.
//!
//! Materializes phi(p_{n->m}) for every pair — exactly the cost the paper
//! eliminates.  Kept as the correctness oracle and the memory/throughput
//! baseline for the benches.

use crate::config::Method;
use crate::exec::{run_chunked, SendPtr};
use crate::geometry::{rotate_pair, Pose};

use super::kernel::KernelConfig;
use super::{AttnOutput, AttnProblem};

/// Apply the method's phi(p_rel) to a d-vector (block-stacked).
/// For rope2d `rel` must be the *abelian* difference; for the SE(2) methods
/// the group-relative pose.
fn apply_phi_rel(
    method: Method,
    rel: &Pose,
    scales: &[f64],
    x: &[f32],
    out: &mut [f32],
) {
    match method {
        Method::Abs => out.copy_from_slice(x),
        Method::Rope2d => {
            let nb = x.len() / 4;
            for j in 0..nb {
                let a = scales[j % scales.len()];
                let b = &x[4 * j..4 * j + 4];
                let (r0, r1) = rotate_pair(b[0] as f64, b[1] as f64, a * rel.x);
                let (r2, r3) = rotate_pair(b[2] as f64, b[3] as f64, a * rel.y);
                out[4 * j] = r0 as f32;
                out[4 * j + 1] = r1 as f32;
                out[4 * j + 2] = r2 as f32;
                out[4 * j + 3] = r3 as f32;
            }
        }
        Method::Se2Rep => {
            let nb = x.len() / 3;
            for j in 0..nb {
                let p = rel.scaled(scales[j % scales.len()]);
                let (s, c) = p.theta.sin_cos();
                let b = &x[3 * j..3 * j + 3];
                let (x0, x1, x2) = (b[0] as f64, b[1] as f64, b[2] as f64);
                out[3 * j] = (c * x0 - s * x1 + p.x * x2) as f32;
                out[3 * j + 1] = (s * x0 + c * x1 + p.y * x2) as f32;
                out[3 * j + 2] = x2 as f32;
            }
        }
        Method::Se2Fourier => {
            // the *exact* target diag[rho(x), rho(y), rho(theta)] (Eq. 10)
            let nb = x.len() / 6;
            for j in 0..nb {
                let a = scales[j % scales.len()];
                let b = &x[6 * j..6 * j + 6];
                let (r0, r1) = rotate_pair(b[0] as f64, b[1] as f64, a * rel.x);
                let (r2, r3) = rotate_pair(b[2] as f64, b[3] as f64, a * rel.y);
                let (r4, r5) = rotate_pair(b[4] as f64, b[5] as f64, rel.theta);
                out[6 * j] = r0 as f32;
                out[6 * j + 1] = r1 as f32;
                out[6 * j + 2] = r2 as f32;
                out[6 * j + 3] = r3 as f32;
                out[6 * j + 4] = r4 as f32;
                out[6 * j + 5] = r5 as f32;
            }
        }
    }
}

/// Relative pose convention per method (Sec. II-D vs II-E).
fn relative(method: Method, pn: &Pose, pm: &Pose) -> Pose {
    match method {
        Method::Rope2d => Pose {
            x: pm.x - pn.x,
            y: pm.y - pn.y,
            theta: 0.0,
        },
        _ => pn.relative_to(pm),
    }
}

/// Query rows per pool task — quadratic rows are heavy (m pairwise phi
/// applications each), so small chunks load-balance better.
const ROWS_PER_TASK: usize = 4;

/// Algorithm 1 with the default kernel configuration (the `threads` knob
/// partitions query rows across the same scoped pool as the blocked
/// flash kernel; `block_m`/`lanes` do not apply — every pair materializes
/// its own phi).
pub fn attention(p: &AttnProblem) -> AttnOutput {
    attention_with(p, &KernelConfig::default())
}

/// Algorithm 1.  O(N*M*d) time, O(N*M) transient memory (the bias and
/// weight matrices plus a phi-transformed copy of V per row).  Each query
/// row is computed exactly as the single-threaded original — row
/// partitioning never changes reduction order, so outputs are
/// bit-identical across thread counts.
pub fn attention_with(p: &AttnProblem, kcfg: &KernelConfig) -> AttnOutput {
    p.validate();
    let (n, m, d) = (p.n(), p.m(), p.d);
    let mut out = vec![0.0f32; n * d];
    // The full n x m score matrix IS the quadratic cost being measured.
    let mut scores = vec![0.0f64; n * m];
    let inv_sqrt_d = 1.0 / (d as f64).sqrt();
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let scores_ptr = SendPtr::new(scores.as_mut_ptr());

    // Per-thread phi scratch, reused across chunks: like the fused flash
    // path (DESIGN.md §18), the projected row never hits the allocator on
    // the steady-state path — the thread-local grows once to d and stays.
    thread_local! {
        static PHI_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    }

    let body = |lo: usize, hi: usize| {
        PHI_SCRATCH.with(|cell| {
            let mut phik = cell.borrow_mut();
            if phik.len() < d {
                phik.resize(d, 0.0);
            }
            let phik = &mut phik[..d];
            for i in lo..hi {
                let qi = &p.q[i * d..(i + 1) * d];
                // disjoint per-row slices — the only mutable state
                let row = unsafe { scores_ptr.slice_mut(i * m, m) };
                let oi = unsafe { out_ptr.slice_mut(i * d, d) };
                for j in 0..m {
                    if p.tq[i] < p.tk[j] {
                        row[j] = f64::NEG_INFINITY;
                        continue;
                    }
                    let rel = relative(p.method, &p.pose_q[i], &p.pose_k[j]);
                    apply_phi_rel(p.method, &rel, p.scales, &p.k[j * d..(j + 1) * d], phik);
                    let dot: f64 = qi
                        .iter()
                        .zip(phik.iter())
                        .map(|(a, b)| *a as f64 * *b as f64)
                        .sum();
                    row[j] = dot * inv_sqrt_d;
                }
                crate::linalg::softmax_inplace(row);
                // o_i = sum_j a_ij phi(rel_ij) v_j   (Alg. 1 line 3)
                for j in 0..m {
                    let a = row[j];
                    if a == 0.0 {
                        continue;
                    }
                    let rel = relative(p.method, &p.pose_q[i], &p.pose_k[j]);
                    apply_phi_rel(p.method, &rel, p.scales, &p.v[j * d..(j + 1) * d], phik);
                    for (o, &pv) in oi.iter_mut().zip(phik.iter()) {
                        *o += (a * pv as f64) as f32;
                    }
                }
            }
        })
    };
    let threads = run_chunked(n, ROWS_PER_TASK, kcfg.normalized().threads, &body);

    AttnOutput {
        out,
        peak_temp_bytes: scores.len() * std::mem::size_of::<f64>()
            + threads * d * std::mem::size_of::<f32>(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn problem_data(
        rng: &mut Rng,
        n: usize,
        d: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<Pose>, Vec<i32>) {
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..n)
            .map(|_| {
                Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.0, 3.0))
            })
            .collect();
        let t: Vec<i32> = (0..n).map(|_| rng.int_range(0, 3) as i32).collect();
        (q, k, v, poses, t)
    }

    #[test]
    fn se2_methods_are_frame_invariant() {
        // Algorithm 1 invariance (paper Eq. 2) for the SE(2) methods.
        let mut rng = Rng::new(7);
        let scales = [1.0, 0.5];
        let (q, k, v, poses, t) = problem_data(&mut rng, 8, 12);
        let z = Pose::new(0.8, -0.5, 1.2);
        let zi = z.inverse();
        let shifted: Vec<Pose> = poses.iter().map(|p| zi.compose(p)).collect();
        for method in [Method::Se2Rep, Method::Se2Fourier] {
            let d = if method == Method::Se2Rep { 12 } else { 12 };
            let mk = |ps: &[Pose]| AttnOutput {
                out: attention(&AttnProblem {
                    method,
                    d,
                    fourier_f: 8,
                    scales: &scales,
                    q: &q,
                    k: &k,
                    v: &v,
                    pose_q: ps,
                    pose_k: ps,
                    tq: &t,
                    tk: &t,
                })
                .out,
                peak_temp_bytes: 0,
            };
            let o1 = mk(&poses).out;
            let o2 = mk(&shifted).out;
            for (a, b) in o1.iter().zip(o2.iter()) {
                assert!((a - b).abs() < 1e-4, "{method:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn abs_reduces_to_plain_sdpa() {
        let mut rng = Rng::new(8);
        let (q, k, v, poses, t) = problem_data(&mut rng, 6, 8);
        let p = AttnProblem {
            method: Method::Abs,
            d: 8,
            fourier_f: 4,
            scales: &[1.0],
            q: &q,
            k: &k,
            v: &v,
            pose_q: &poses,
            pose_k: &poses,
            tq: &t,
            tk: &t,
        };
        let got = attention(&p).out;
        // hand-rolled plain SDPA
        let n = 6;
        let d = 8;
        for i in 0..n {
            let mut logits: Vec<f64> = (0..n)
                .map(|j| {
                    if t[i] < t[j] {
                        f64::NEG_INFINITY
                    } else {
                        (0..d)
                            .map(|c| q[i * d + c] as f64 * k[j * d + c] as f64)
                            .sum::<f64>()
                            / (d as f64).sqrt()
                    }
                })
                .collect();
            crate::linalg::softmax_inplace(&mut logits);
            for c in 0..d {
                let expect: f64 = (0..n)
                    .map(|j| logits[j] * v[j * d + c] as f64)
                    .sum();
                assert!((got[i * d + c] as f64 - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn row_partition_is_bit_identical_across_threads() {
        let mut rng = Rng::new(11);
        let (q, k, v, poses, t) = problem_data(&mut rng, 10, 12);
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d: 12,
            fourier_f: 8,
            scales: &[1.0, 0.5],
            q: &q,
            k: &k,
            v: &v,
            pose_q: &poses,
            pose_k: &poses,
            tq: &t,
            tk: &t,
        };
        let one = attention_with(&p, &KernelConfig::fixed(64, 8, 1)).out;
        for threads in [2usize, 4] {
            let par = attention_with(&p, &KernelConfig::fixed(64, 8, threads)).out;
            assert_eq!(one, par, "threads={threads}");
        }
    }

    #[test]
    fn masked_pairs_get_zero_weight() {
        let mut rng = Rng::new(9);
        let (q, k, v, poses, _) = problem_data(&mut rng, 4, 8);
        // token 0 sees only itself; tokens with equal t see each other
        let t = vec![0, 1, 1, 2];
        let p = AttnProblem {
            method: Method::Rope2d,
            d: 8,
            fourier_f: 4,
            scales: &[1.0],
            q: &q,
            k: &k,
            v: &v,
            pose_q: &poses,
            pose_k: &poses,
            tq: &t,
            tk: &t,
        };
        let got = attention(&p).out;
        // row 0 attends only to key 0: output must equal phi(rel_00) v_0,
        // where rel_00 = 0 so phi = I -> v_0 exactly.
        for c in 0..8 {
            assert!((got[c] - v[c]).abs() < 1e-5, "{c}");
        }
    }
}
